# Convenience entry points matching the ROADMAP commands.
.PHONY: tier1 tier1-full coverage bench bench-serving bench-batching \
	bench-paging bench-buckets bench-spec bench-quant bench-check \
	plan-smoke serve-smoke batch-smoke page-smoke spec-smoke \
	convert-smoke obs-smoke docs-check

tier1:
	scripts/tier1.sh

tier1-full:
	scripts/tier1.sh --full

coverage:
	scripts/tier1.sh --coverage

bench:
	PYTHONPATH=src:. python benchmarks/partitioner_bench.py

bench-serving:
	PYTHONPATH=src:. python benchmarks/serving_bench.py

bench-batching:
	PYTHONPATH=src:. python benchmarks/batching_bench.py

bench-paging:
	PYTHONPATH=src:. python benchmarks/batching_bench.py --paging

bench-buckets:
	PYTHONPATH=src:. python benchmarks/batching_bench.py --buckets

bench-spec:
	PYTHONPATH=src:. python benchmarks/spec_bench.py

bench-quant:
	PYTHONPATH=src:. python benchmarks/quant_bench.py

bench-check:
	python scripts/bench_check.py

plan-smoke:
	python scripts/plan_smoke.py

serve-smoke:
	python scripts/serve_smoke.py

batch-smoke:
	python scripts/batch_smoke.py

page-smoke:
	python scripts/page_smoke.py

spec-smoke:
	python scripts/spec_smoke.py

convert-smoke:
	python scripts/convert_smoke.py

obs-smoke:
	python scripts/obs_smoke.py

docs-check:
	python scripts/docs_check.py
