"""Continuous-batching benchmark: goodput + latency, sync vs continuous.

Drives the REAL slot scheduler
(``repro.serving.batcher.ContinuousBatchingSession`` — the same
admission/eviction/accounting code the live engine runs) with an
analytic engine whose op costs come from the serve schedule tables:
one decode round costs ``core/schedule.py::weighted_round_time`` of
the forward-only tables over the rectangular-DP partition, one masked
admission pass costs the prefill round
(``core/schedule.py::serve_ttft`` ramp over the prefill-length
profile) — per-layer seconds from
``core/profiler.py::profile_analytic``, the same machinery
``plan_search`` scores candidates with, so the bench runs in
milliseconds on CPU and tracks exactly what the planner optimizes.

Workload: a Poisson arrival trace (exponential inter-arrivals,
measured in scheduler steps — the granularity at which the server can
react) of requests with geometric-ish output lengths, where at least
half of each admitted batch finishes early.  Each (arch, policy) cell
reports goodput (completed tokens/s of modeled time), p50/p99
per-token latency and mean TTFT; the acceptance row asserts continuous
batching strictly beats synchronized (drain-then-refill) goodput.

Emits the ``BENCH_batching.json`` trajectory artifact and prints
``name,us_per_call,derived`` CSV rows like the other benchmarks.  Run
via ``make bench-batching``:

  PYTHONPATH=src:. python benchmarks/batching_bench.py [--out BENCH_batching.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict

import numpy as np

from repro import configs
from repro.core import profiler as prof
from repro.core.partitioner import partition_rectangular, stage_phase_times
from repro.core.schedule import (fit_serving_microbatches,
                                 make_serving_schedule,
                                 plan_kwargs_for_schedule, serve_ttft,
                                 weighted_round_time)
from repro.serving.batcher import ContinuousBatchingSession, Request

ARCHS = ("qwen3_14b", "olmoe_1b_7b")
HW = prof.TPU_V5E
DATA = 16                       # production mesh: 16 data x 16 model
PREFILL = 512
N_REQUESTS = 64
MEAN_NEW_TOKENS = 48
SEED = 0


@dataclasses.dataclass
class _Spec:
    shape: tuple


class AnalyticEngine:
    """Engine-shaped cost model over the serve schedule tables.

    Implements exactly the surface ContinuousBatchingSession drives
    (start / reset_slots / write_prefill_into_slots / decode) with a
    modeled clock: decode advances by the forward-only round time,
    admission by the prefill round.  Tokens are deterministic
    nonsense — the bench measures scheduling, not logits.
    """

    def __init__(self, sched, *, rows, text_len, decode_s, admit_s):
        self.sched = sched
        R = sched.n_microbatches
        self.token_spec = _Spec((R * rows,))
        self.prefill_specs = {"tokens": _Spec((R, rows, text_len))}
        self.admit_step = object()
        self.state = None
        self.now = 0.0
        self.decode_s, self.admit_s = decode_s, admit_s

    def clock(self):
        return self.now

    def start(self, key=None):
        self.state = object()
        return self

    def reset_slots(self, mask):
        return self                      # elementwise zeroing: free

    def write_prefill_into_slots(self, batch, mask):
        self.now += self.admit_s
        return (batch["tokens"][:, :, -1].reshape(-1) % 251 + 1).astype(
            np.int32)

    def decode(self, tokens):
        self.now += self.decode_s
        return ((np.asarray(tokens) * 31 + 7) % 251 + 1).astype(np.int32)


def poisson_trace(n, slots, rng, text_len):
    """Poisson arrivals; >= half of each slot-cohort finishes early.

    Inter-arrival ~ Exp(rate) in scheduler steps with rate chosen so
    the server stays busy (~2 requests per freed slot); output lengths
    alternate short (finish early) and long, so at least half the
    admitted batch drains while the rest keeps decoding — the regime
    where synchronized batching bubbles hardest.
    """
    gaps = rng.exponential(scale=max(MEAN_NEW_TOKENS / (2 * slots), 1.0),
                           size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    out = []
    for i in range(n):
        short = i % 2 == 0
        n_new = (rng.integers(4, MEAN_NEW_TOKENS // 4) if short
                 else rng.integers(MEAN_NEW_TOKENS, 2 * MEAN_NEW_TOKENS))
        out.append(Request(
            rid=i, prompt=rng.integers(1, 999, text_len).astype(np.int32),
            max_new_tokens=int(n_new), arrival=int(arrivals[i])))
    return out


def _serve_setup(arch: str):
    """(spec, plan, shape, R, rows): the arch's decode-serving shape."""
    cfg = configs.get(arch)
    spec, base = cfg.full_spec(), cfg.PLAN
    shape = configs.SHAPES["decode_32k"]
    R = fit_serving_microbatches(base.decode_microbatches,
                                 shape.global_batch, DATA)
    rows = max(shape.global_batch // DATA // R, 1) * DATA  # global rows/slot
    plan = base.with_(**plan_kwargs_for_schedule(
        ("serve_interleaved" if base.virtual_stages > 1
         and spec.n_layers % (base.pp * base.virtual_stages) == 0
         else "serve_1f"), virtual_stages=base.virtual_stages,
        stash_mode=base.stash_mode))
    if spec.n_layers % (plan.pp * plan.virtual_stages):
        plan = plan.with_(schedule="serve_1f", virtual_stages=1)
    return spec, plan, shape, R, rows


def _round_costs(spec, plan, shape, R, rows):
    """(sched, decode_s, admit_s): modeled per-op costs at R slots."""
    sched = make_serving_schedule(plan, R)
    dec_prof = prof.profile_analytic(
        spec, HW, minibatch_tokens=rows // DATA, kv_len=shape.seq_len)
    part = partition_rectangular(dec_prof, sched.n_chunks, DATA, HW)
    tf, _ = stage_phase_times(dec_prof, part, plan.pp, plan.tp, HW,
                              data_replicas=DATA)
    decode_s, _ = weighted_round_time(sched, tf, 0.0)
    pre_prof = prof.profile_analytic(
        spec, HW, minibatch_tokens=(rows // DATA) * PREFILL)
    ppart = partition_rectangular(pre_prof, sched.n_chunks, DATA, HW)
    ptf, _ = stage_phase_times(pre_prof, ppart, plan.pp, plan.tp, HW,
                               data_replicas=DATA)
    admit_s = serve_ttft(sched, ptf)
    return sched, decode_s, admit_s


def bench_arch(arch: str) -> list:
    spec, plan, shape, R, rows = _serve_setup(arch)
    sched, decode_s, admit_s = _round_costs(spec, plan, shape, R, rows)

    rows_out = []
    for policy in ("synchronized", "continuous"):
        rng = np.random.default_rng(SEED)
        eng = AnalyticEngine(sched, rows=rows, text_len=PREFILL,
                             decode_s=decode_s, admit_s=admit_s)
        server = ContinuousBatchingSession(eng, policy=policy,
                                           clock=eng.clock)
        report = server.run(poisson_trace(N_REQUESTS, R, rng, PREFILL))
        s = report.summary()
        assert s["completed"] == N_REQUESTS, s
        rows_out.append({
            "arch": arch, "schedule": sched.name, "pp": plan.pp,
            "tp": plan.tp, "virtual_stages": sched.virtual_stages,
            "slots": R, "rows_per_slot": rows,
            "decode_round_ms": decode_s * 1e3,
            "admit_round_ms": admit_s * 1e3, **s,
        })
    return rows_out


def bench_paging(arch: str, page_size: int = 64) -> list:
    """Slots-per-HBM-byte: pages-in-use vs dense capacity slabs.

    Fixes the KV HBM budget at what the PAGED engine spends serving its
    nominal R slots when each slot holds pages for the expected request
    length (PREFILL + mean new tokens, page-quantized) instead of a
    full ``cache_len`` slab — then squeezes the dense engine into that
    same budget (``floor(budget / dense-per-slot-bytes)`` slots, at
    least 1).  The per-slot byte ratio and the executed R ratio are the
    headline; one saturating Poisson trace (load beyond the squeezed
    engine's concurrency) through BOTH configurations then shows what
    the recovered slots buy: the squeezed engine queues — p99
    per-token latency and mean TTFT blow up — while the paged engine
    absorbs the same offered load.  Goodput is reported but not
    asserted: the analytic per-tick cost model is linear in tokens, so
    steady-state throughput is nearly flat in R — queueing delay is
    where slot starvation actually bites.
    """
    import math

    from repro.core.schedule import serving_cache_bytes

    spec, plan, shape, R, rows = _serve_setup(arch)
    sched = make_serving_schedule(plan, R)
    cache_len = shape.seq_len
    kw = dict(cache_len=cache_len, global_batch=shape.global_batch,
              data_replicas=DATA)
    dense_bytes = serving_cache_bytes(spec, plan, sched, **kw)
    exp_tokens = PREFILL + MEAN_NEW_TOKENS
    # page-granular per-request occupancy (no slot rounding: each slot
    # holds its own partial page run)
    occ = math.ceil(exp_tokens / page_size) * page_size / cache_len
    paged_bytes = serving_cache_bytes(spec, plan, sched,
                                      page_size=page_size,
                                      kv_occupancy=occ, **kw)
    bytes_mult = dense_bytes / paged_bytes       # per-slot HBM ratio
    R_dense = max(1, int(R * paged_bytes // dense_bytes))
    slot_mult = R / R_dense
    assert slot_mult >= 2.0, (
        f"{arch}: paging must at least double the slots that fit the "
        f"{paged_bytes / 1e9:.2f} GB budget at expected length "
        f"{exp_tokens}/{cache_len} (dense fits {R_dense} of {R})")

    n_req, rate_slots = 4 * N_REQUESTS, R * rows
    rows_out = []
    for mode, r_run in (("dense_squeezed", R_dense), ("paged", R)):
        rng = np.random.default_rng(SEED)
        sched_r, decode_s, admit_s = _round_costs(spec, plan, shape,
                                                  r_run, rows)
        eng = AnalyticEngine(sched_r, rows=rows, text_len=PREFILL,
                             decode_s=decode_s, admit_s=admit_s)
        server = ContinuousBatchingSession(eng, policy="continuous",
                                           clock=eng.clock)
        report = server.run(poisson_trace(n_req, rate_slots, rng, PREFILL))
        s = report.summary()
        assert s["completed"] == n_req, s
        rows_out.append({
            "arch": arch, "mode": mode, "schedule": sched_r.name,
            "pp": plan.pp, "tp": plan.tp, "page_size": page_size,
            "slots": r_run, "rows_per_slot": rows,
            "slot_multiplier": slot_mult,
            "per_slot_bytes_multiplier": bytes_mult,
            "kv_budget_gb": paged_bytes / 1e9,
            "expected_tokens": exp_tokens, "cache_len": cache_len,
            "decode_round_ms": decode_s * 1e3,
            "admit_round_ms": admit_s * 1e3, **s,
        })
    return rows_out


def main_paging(out: str):
    rows = []
    for arch in ARCHS:
        rows.extend(bench_paging(arch))
    print("name,us_per_call,derived")
    by: Dict[str, Dict[str, dict]] = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["mode"]] = r
        print(f"{r['arch']}.paging.{r['mode']},"
              f"{r['decode_round_ms'] * 1e3:.1f},"
              f"slots={r['slots']} "
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s "
              f"p99={r['p99_per_token_latency_s'] * 1e3:.1f}ms "
              f"ttft={r['mean_ttft_s'] * 1e3:.1f}ms")
    # acceptance: >= 2x slots at the fixed paged budget, and the
    # recovered slots must show up as lower queueing latency under the
    # same saturating offered load
    for arch, m in by.items():
        d, p = m["dense_squeezed"], m["paged"]
        assert p["slot_multiplier"] >= 2.0, (arch, p["slot_multiplier"])
        assert p["p99_per_token_latency_s"] < d["p99_per_token_latency_s"], (
            arch, p["p99_per_token_latency_s"],
            d["p99_per_token_latency_s"])
        assert p["mean_ttft_s"] < d["mean_ttft_s"], (
            arch, p["mean_ttft_s"], d["mean_ttft_s"])
        print(f"# {arch}: {p['per_slot_bytes_multiplier']:.1f}x "
              f"slots-per-HBM-byte at {p['expected_tokens']}-token "
              f"requests in a {p['cache_len']}-token cache; at the fixed "
              f"{p['kv_budget_gb']:.2f} GB budget dense fits "
              f"{d['slots']}/{p['slots']} slots "
              f"({p['slot_multiplier']:.1f}x), p99 "
              f"{d['p99_per_token_latency_s'] / p['p99_per_token_latency_s']:.1f}x "
              f"better paged, ttft "
              f"{d['mean_ttft_s'] / p['mean_ttft_s']:.1f}x better")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--paging", action="store_true",
                    help="paged-KV slots-per-HBM-byte bench "
                         "(-> BENCH_paging.json)")
    args = ap.parse_args(argv)
    if args.paging:
        return main_paging(args.out or "BENCH_paging.json")
    args.out = args.out or "BENCH_batching.json"
    rows = []
    for arch in ARCHS:
        rows.extend(bench_arch(arch))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['arch']}.{r['schedule']}.{r['policy']},"
              f"{r['decode_round_ms'] * 1e3:.1f},"
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s "
              f"p50={r['p50_per_token_latency_s'] * 1e3:.1f}ms "
              f"p99={r['p99_per_token_latency_s'] * 1e3:.1f}ms "
              f"ttft={r['mean_ttft_s'] * 1e3:.1f}ms")
    # acceptance: continuous strictly beats synchronized goodput on the
    # staggered trace (half of each admitted batch finishes early)
    by: Dict[str, Dict[str, dict]] = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["policy"]] = r
    for arch, pol in by.items():
        c, s = pol["continuous"], pol["synchronized"]
        assert c["goodput_tokens_per_s"] > s["goodput_tokens_per_s"], (
            arch, c["goodput_tokens_per_s"], s["goodput_tokens_per_s"])
        print(f"# {arch}: continuous/synchronized goodput = "
              f"{c['goodput_tokens_per_s'] / s['goodput_tokens_per_s']:.2f}x")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
