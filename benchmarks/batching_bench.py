"""Continuous-batching benchmark: goodput + latency, sync vs continuous.

Drives the REAL slot scheduler
(``repro.serving.batcher.ContinuousBatchingSession`` — the same
admission/eviction/accounting code the live engine runs) with an
analytic engine whose op costs come from the serve schedule tables:
one decode round costs ``core/schedule.py::weighted_round_time`` of
the forward-only tables over the rectangular-DP partition, one masked
admission pass costs the prefill round
(``core/schedule.py::serve_ttft`` ramp over the prefill-length
profile) — per-layer seconds from
``core/profiler.py::profile_analytic``, the same machinery
``plan_search`` scores candidates with, so the bench runs in
milliseconds on CPU and tracks exactly what the planner optimizes.

Workload: a Poisson arrival trace (exponential inter-arrivals,
measured in scheduler steps — the granularity at which the server can
react) of requests with geometric-ish output lengths, where at least
half of each admitted batch finishes early.  Each (arch, policy) cell
reports goodput (completed tokens/s of modeled time), p50/p99
per-token latency and mean TTFT; the acceptance row asserts continuous
batching strictly beats synchronized (drain-then-refill) goodput.

Emits the ``BENCH_batching.json`` trajectory artifact and prints
``name,us_per_call,derived`` CSV rows like the other benchmarks.  Run
via ``make bench-batching``:

  PYTHONPATH=src:. python benchmarks/batching_bench.py [--out BENCH_batching.json]

``--buckets`` (``make bench-buckets``) runs the liveness-aware
bucketed-executor comparison instead: full-R lockstep vs bucketed
execution over a ~25%-occupancy Poisson trace, reporting executed
slot-ticks per completed token (see :func:`bench_buckets`); its rows
merge into the same BENCH_batching.json.  ``--paging`` runs the
paged-KV capacity study (-> BENCH_paging.json).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict

import numpy as np

from repro import configs
from repro.core import profiler as prof
from repro.core.partitioner import partition_rectangular, stage_phase_times
from repro.core.schedule import (F_MB, bucket_lattice,
                                 fit_serving_microbatches,
                                 make_serving_schedule, pick_bucket,
                                 plan_kwargs_for_schedule, serve_ttft,
                                 weighted_round_time)
from repro.serving.batcher import ContinuousBatchingSession, Request

ARCHS = ("qwen3_14b", "olmoe_1b_7b")
HW = prof.TPU_V5E
DATA = 16                       # production mesh: 16 data x 16 model
PREFILL = 512
N_REQUESTS = 64
MEAN_NEW_TOKENS = 48
SEED = 0


@dataclasses.dataclass
class _Spec:
    shape: tuple


def _slot_ticks(sched) -> int:
    """(tick, stage) cells of the table that name a microbatch slot.

    The table executor runs the stage compute for every named cell —
    a lockstep full-R table names every slot whether live or dead, so
    dead slots burn real stage executions; ramp bubbles (``F_MB < 0``)
    execute nothing and do not count.
    """
    return int((np.asarray(sched.tables().fwd)[:, :, F_MB] >= 0).sum())


class AnalyticEngine:
    """Engine-shaped cost model over the serve schedule tables.

    Implements exactly the surface ContinuousBatchingSession drives
    (start / reset_slots / write_prefill_into_slots / decode) with a
    modeled clock: decode advances by the forward-only round time,
    admission by the prefill round.  Tokens are deterministic
    nonsense — the bench measures scheduling, not logits.

    ``bucket_costs`` turns on the liveness-aware bucketed cost model:
    a ``{R_b: (decode_s, admit_s, slot_ticks)}`` table over the bucket
    lattice.  The engine then mirrors slot liveness through
    reset/admit/compact (the batcher compacts live slots into a prefix,
    exactly as the real bucketed EngineSession requires) and charges
    each round at the smallest bucket covering the live count.  Every
    round — bucketed or not — accrues ``executed_slot_ticks``: the
    (tick, stage) cells of the round's table that *name* a slot
    (``F_MB >= 0`` — a full-R table names every slot, dead or live, and
    a dead slot's stage compute still executes; ramp bubbles execute
    nothing).  That count is the honest unit of the bucketing win.
    """

    def __init__(self, sched, *, rows, text_len, decode_s, admit_s,
                 bucket_costs=None):
        self.sched = sched
        R = self.R = sched.n_microbatches
        self.token_spec = _Spec((R * rows,))
        self.prefill_specs = {"tokens": _Spec((R, rows, text_len))}
        self.admit_step = object()
        self.state = None
        self.now = 0.0
        self.decode_s, self.admit_s = decode_s, admit_s
        full_ticks = _slot_ticks(sched)
        self.buckets = tuple(sorted(bucket_costs)) if bucket_costs else None
        self._costs = dict(bucket_costs) if bucket_costs else {
            R: (decode_s, admit_s, full_ticks)}
        self._live = np.zeros(R, bool)
        self.executed_slot_ticks = 0
        self.bucket_log: list = []
        self._occ_sum = 0            # live slots summed over decode rounds
        self._occ_rounds = 0

    def clock(self):
        return self.now

    def start(self, key=None):
        self.state = object()
        return self

    def _bucket(self) -> int:
        n = max(1, int(self._live.sum()))
        if self.buckets is None:
            return self.R
        return pick_bucket(n, self.buckets)

    @property
    def mean_occupancy(self) -> float:
        """Mean live-slot fraction over the decode rounds run so far."""
        return self._occ_sum / max(self._occ_rounds * self.R, 1)

    def reset_slots(self, mask):
        self._live[np.asarray(mask).reshape(-1) > 0] = False
        return self                      # elementwise zeroing: free

    def compact_slots(self, perm):
        self._live = self._live[np.asarray(perm, np.int64)]
        return self.state                # pure permutation: free

    def write_prefill_into_slots(self, batch, mask):
        self._live |= np.asarray(mask).reshape(-1) > 0
        _, admit_s, ticks = self._costs[self._bucket()]
        self.now += admit_s
        self.executed_slot_ticks += ticks
        return (batch["tokens"][:, :, -1].reshape(-1) % 251 + 1).astype(
            np.int32)

    def decode(self, tokens):
        b = self._bucket()
        decode_s, _, ticks = self._costs[b]
        self.now += decode_s
        self.executed_slot_ticks += ticks
        self.bucket_log.append(b)
        self._occ_sum += int(self._live.sum())
        self._occ_rounds += 1
        return ((np.asarray(tokens) * 31 + 7) % 251 + 1).astype(np.int32)


def poisson_trace(n, slots, rng, text_len):
    """Poisson arrivals; >= half of each slot-cohort finishes early.

    Inter-arrival ~ Exp(rate) in scheduler steps with rate chosen so
    the server stays busy (~2 requests per freed slot); output lengths
    alternate short (finish early) and long, so at least half the
    admitted batch drains while the rest keeps decoding — the regime
    where synchronized batching bubbles hardest.
    """
    gaps = rng.exponential(scale=max(MEAN_NEW_TOKENS / (2 * slots), 1.0),
                           size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    out = []
    for i in range(n):
        short = i % 2 == 0
        n_new = (rng.integers(4, MEAN_NEW_TOKENS // 4) if short
                 else rng.integers(MEAN_NEW_TOKENS, 2 * MEAN_NEW_TOKENS))
        out.append(Request(
            rid=i, prompt=rng.integers(1, 999, text_len).astype(np.int32),
            max_new_tokens=int(n_new), arrival=int(arrivals[i])))
    return out


def _serve_setup(arch: str):
    """(spec, plan, shape, R, rows): the arch's decode-serving shape."""
    cfg = configs.get(arch)
    spec, base = cfg.full_spec(), cfg.PLAN
    shape = configs.SHAPES["decode_32k"]
    R = fit_serving_microbatches(base.decode_microbatches,
                                 shape.global_batch, DATA)
    rows = max(shape.global_batch // DATA // R, 1) * DATA  # global rows/slot
    plan = base.with_(**plan_kwargs_for_schedule(
        ("serve_interleaved" if base.virtual_stages > 1
         and spec.n_layers % (base.pp * base.virtual_stages) == 0
         else "serve_1f"), virtual_stages=base.virtual_stages,
        stash_mode=base.stash_mode))
    if spec.n_layers % (plan.pp * plan.virtual_stages):
        plan = plan.with_(schedule="serve_1f", virtual_stages=1)
    return spec, plan, shape, R, rows


def _phase_times(spec, plan, shape, R, rows):
    """(sched, tf, ptf): per-stage decode/prefill phase seconds at R.

    The phase times depend on the partition and per-row token counts,
    never on which slots are live — so one (tf, ptf) pair prices every
    bucket of the same schedule (shorter tables, same stage work)."""
    sched = make_serving_schedule(plan, R)
    dec_prof = prof.profile_analytic(
        spec, HW, minibatch_tokens=rows // DATA, kv_len=shape.seq_len)
    part = partition_rectangular(dec_prof, sched.n_chunks, DATA, HW)
    tf, _ = stage_phase_times(dec_prof, part, plan.pp, plan.tp, HW,
                              data_replicas=DATA)
    pre_prof = prof.profile_analytic(
        spec, HW, minibatch_tokens=(rows // DATA) * PREFILL)
    ppart = partition_rectangular(pre_prof, sched.n_chunks, DATA, HW)
    ptf, _ = stage_phase_times(pre_prof, ppart, plan.pp, plan.tp, HW,
                               data_replicas=DATA)
    return sched, tf, ptf


def _round_costs(spec, plan, shape, R, rows):
    """(sched, decode_s, admit_s): modeled per-op costs at R slots."""
    sched, tf, ptf = _phase_times(spec, plan, shape, R, rows)
    decode_s, _ = weighted_round_time(sched, tf, 0.0)
    admit_s = serve_ttft(sched, ptf)
    return sched, decode_s, admit_s


def bench_arch(arch: str) -> list:
    spec, plan, shape, R, rows = _serve_setup(arch)
    sched, decode_s, admit_s = _round_costs(spec, plan, shape, R, rows)

    rows_out = []
    for policy in ("synchronized", "continuous"):
        rng = np.random.default_rng(SEED)
        eng = AnalyticEngine(sched, rows=rows, text_len=PREFILL,
                             decode_s=decode_s, admit_s=admit_s)
        server = ContinuousBatchingSession(eng, policy=policy,
                                           clock=eng.clock)
        report = server.run(poisson_trace(N_REQUESTS, R, rng, PREFILL))
        s = report.summary()
        assert s["completed"] == N_REQUESTS, s
        rows_out.append({
            "arch": arch, "schedule": sched.name, "pp": plan.pp,
            "tp": plan.tp, "virtual_stages": sched.virtual_stages,
            "slots": R, "rows_per_slot": rows,
            "decode_round_ms": decode_s * 1e3,
            "admit_round_ms": admit_s * 1e3, **s,
        })
    return rows_out


def bench_paging(arch: str, page_size: int = 64) -> list:
    """Slots-per-HBM-byte: pages-in-use vs dense capacity slabs.

    Fixes the KV HBM budget at what the PAGED engine spends serving its
    nominal R slots when each slot holds pages for the expected request
    length (PREFILL + mean new tokens, page-quantized) instead of a
    full ``cache_len`` slab — then squeezes the dense engine into that
    same budget (``floor(budget / dense-per-slot-bytes)`` slots, at
    least 1).  The per-slot byte ratio and the executed R ratio are the
    headline; one saturating Poisson trace (load beyond the squeezed
    engine's concurrency) through BOTH configurations then shows what
    the recovered slots buy: the squeezed engine queues — p99
    per-token latency and mean TTFT blow up — while the paged engine
    absorbs the same offered load.  Goodput is reported but not
    asserted: the analytic per-tick cost model is linear in tokens, so
    steady-state throughput is nearly flat in R — queueing delay is
    where slot starvation actually bites.
    """
    import math

    from repro.core.schedule import serving_cache_bytes

    spec, plan, shape, R, rows = _serve_setup(arch)
    sched = make_serving_schedule(plan, R)
    cache_len = shape.seq_len
    kw = dict(cache_len=cache_len, global_batch=shape.global_batch,
              data_replicas=DATA)
    dense_bytes = serving_cache_bytes(spec, plan, sched, **kw)
    exp_tokens = PREFILL + MEAN_NEW_TOKENS
    # page-granular per-request occupancy (no slot rounding: each slot
    # holds its own partial page run)
    occ = math.ceil(exp_tokens / page_size) * page_size / cache_len
    paged_bytes = serving_cache_bytes(spec, plan, sched,
                                      page_size=page_size,
                                      kv_occupancy=occ, **kw)
    bytes_mult = dense_bytes / paged_bytes       # per-slot HBM ratio
    R_dense = max(1, int(R * paged_bytes // dense_bytes))
    slot_mult = R / R_dense
    assert slot_mult >= 2.0, (
        f"{arch}: paging must at least double the slots that fit the "
        f"{paged_bytes / 1e9:.2f} GB budget at expected length "
        f"{exp_tokens}/{cache_len} (dense fits {R_dense} of {R})")

    n_req, rate_slots = 4 * N_REQUESTS, R * rows
    rows_out = []
    for mode, r_run in (("dense_squeezed", R_dense), ("paged", R)):
        rng = np.random.default_rng(SEED)
        sched_r, decode_s, admit_s = _round_costs(spec, plan, shape,
                                                  r_run, rows)
        eng = AnalyticEngine(sched_r, rows=rows, text_len=PREFILL,
                             decode_s=decode_s, admit_s=admit_s)
        server = ContinuousBatchingSession(eng, policy="continuous",
                                           clock=eng.clock)
        report = server.run(poisson_trace(n_req, rate_slots, rng, PREFILL))
        s = report.summary()
        assert s["completed"] == n_req, s
        rows_out.append({
            "arch": arch, "mode": mode, "schedule": sched_r.name,
            "pp": plan.pp, "tp": plan.tp, "page_size": page_size,
            "slots": r_run, "rows_per_slot": rows,
            "slot_multiplier": slot_mult,
            "per_slot_bytes_multiplier": bytes_mult,
            "kv_budget_gb": paged_bytes / 1e9,
            "expected_tokens": exp_tokens, "cache_len": cache_len,
            "decode_round_ms": decode_s * 1e3,
            "admit_round_ms": admit_s * 1e3, **s,
        })
    return rows_out


def low_occupancy_trace(n, slots, rng, text_len, occupancy=0.25):
    """Poisson arrivals tuned to hold ~``occupancy``·R slots live.

    Little's law: live slots = arrival rate x mean residence, so the
    exponential inter-arrival scale is MEAN_NEW_TOKENS steps of
    residence over the ``occupancy * slots`` concurrency target.  This
    is the regime the bucketed executor exists for: the full-R lockstep
    engine burns the whole table every round while only a quarter of
    the slots produce tokens.
    """
    target_live = max(occupancy * slots, 0.5)
    gaps = rng.exponential(scale=MEAN_NEW_TOKENS / target_live, size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    return [Request(
        rid=i, prompt=rng.integers(1, 999, text_len).astype(np.int32),
        max_new_tokens=int(rng.integers(MEAN_NEW_TOKENS // 2,
                                        (3 * MEAN_NEW_TOKENS) // 2)),
        arrival=int(arrivals[i])) for i in range(n)]


def bench_buckets(arch: str, occupancy: float = 0.25) -> list:
    """Executed slot-ticks per token: full-R lockstep vs bucketed.

    Both engines serve the SAME ~25%-occupancy Poisson trace through
    the real slot scheduler; the only difference is the cost/tick
    model: lockstep charges every round the full-R table (R·S·v named
    cells — dead slots' stage compute still executes), bucketed charges
    the smallest lattice bucket covering the live prefix — exactly the
    table the liveness-aware EngineSession scans.  Token streams are
    identical by construction (the real engine's buckets are bit-exact,
    proven by scripts/batch_smoke.py), so executed slot-ticks per
    completed token is the apples-to-apples waste metric.

    Wall-clock rounds (and therefore the goodput column) win less than
    the slot-tick ratio: the S-1 pipeline ramp is paid per round no
    matter how few slots the table names.  The per-round slot-tick
    ceiling is R/1 (all lattice tables share the same S·v per slot);
    each row records its measured ratio next to that ceiling.
    """
    spec, plan, shape, R, rows = _serve_setup(arch)
    sched, tf, ptf = _phase_times(spec, plan, shape, R, rows)
    costs = {}
    for b in bucket_lattice(R):
        sb = sched.bucketed(b)
        costs[b] = (weighted_round_time(sb, tf, 0.0)[0],
                    serve_ttft(sb, ptf), _slot_ticks(sb))
    ceiling = costs[R][2] / costs[1][2]

    rows_out = []
    for mode in ("lockstep_full_R", "bucketed"):
        rng = np.random.default_rng(SEED)
        eng = AnalyticEngine(
            sched, rows=rows, text_len=PREFILL,
            decode_s=costs[R][0], admit_s=costs[R][1],
            bucket_costs=costs if mode == "bucketed" else None)
        server = ContinuousBatchingSession(eng, policy="continuous",
                                           clock=eng.clock)
        report = server.run(low_occupancy_trace(N_REQUESTS, R, rng,
                                                PREFILL, occupancy))
        s = report.summary()
        assert s["completed"] == N_REQUESTS, s
        occ = eng.mean_occupancy
        assert abs(occ - occupancy) < 0.15, (
            f"{arch}/{mode}: trace drifted to {occ:.2f} mean occupancy, "
            f"target {occupancy}")
        hist = {int(b): eng.bucket_log.count(b)
                for b in sorted(set(eng.bucket_log))}
        rows_out.append({
            "arch": arch, "mode": mode, "schedule": sched.name,
            "pp": plan.pp, "tp": plan.tp, "slots": R,
            "rows_per_slot": rows, "target_occupancy": occupancy,
            "mean_occupancy": occ,
            "buckets": list(eng.buckets) if eng.buckets else [R],
            "bucket_rounds": hist,
            "executed_slot_ticks": int(eng.executed_slot_ticks),
            "slot_ticks_per_token": (eng.executed_slot_ticks
                                     / max(report.completed_tokens, 1)),
            "tick_ratio_ceiling": ceiling, **s,
        })
    full, bkt = rows_out
    assert full["completed_tokens"] == bkt["completed_tokens"], rows_out
    ratio = (full["slot_ticks_per_token"] / bkt["slot_ticks_per_token"])
    for r in rows_out:
        r["slot_ticks_ratio"] = ratio
    return rows_out


def main_buckets(out: str, occupancy: float = 0.25):
    rows = []
    for arch in ARCHS:
        rows.extend(bench_buckets(arch, occupancy))
    print("name,us_per_call,derived")
    by: Dict[str, Dict[str, dict]] = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["mode"]] = r
        print(f"{r['arch']}.buckets.{r['mode']},"
              f"{r['decode_rounds']},"
              f"slot_ticks/token={r['slot_ticks_per_token']:.1f} "
              f"occ={r['mean_occupancy']:.2f} "
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s")
    # acceptance: at ~25% occupancy the bucketed executor must cut
    # executed slot-ticks per token >= 3x on the shallow-pipe serving
    # config (the ratio a deep pipe can reach is capped by its S-1 ramp
    # — asserted against each table's own analytic ceiling instead)
    best = 0.0
    for arch, m in by.items():
        b = m["bucketed"]
        ratio, ceil_ = b["slot_ticks_ratio"], b["tick_ratio_ceiling"]
        best = max(best, ratio)
        assert ratio >= min(3.0, 0.8 * ceil_), (arch, ratio, ceil_)
        print(f"# {arch}: {ratio:.2f}x fewer executed slot-ticks per "
              f"token at {b['mean_occupancy']:.0%} occupancy "
              f"(lattice {b['buckets']}, per-round ceiling {ceil_:.2f}x)")
    assert best >= 3.0, f"no arch reached the 3x acceptance bar: {best:.2f}x"
    # merge into the batching artifact: bucket rows live alongside the
    # policy-comparison rows, replacing any stale bucket rows
    try:
        with open(out) as f:
            prev = [r for r in json.load(f)
                    if r.get("mode") not in ("lockstep_full_R", "bucketed")]
    except (FileNotFoundError, json.JSONDecodeError):
        prev = []
    rows = prev + rows
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {out}")


def main_paging(out: str):
    rows = []
    for arch in ARCHS:
        rows.extend(bench_paging(arch))
    print("name,us_per_call,derived")
    by: Dict[str, Dict[str, dict]] = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["mode"]] = r
        print(f"{r['arch']}.paging.{r['mode']},"
              f"{r['decode_round_ms'] * 1e3:.1f},"
              f"slots={r['slots']} "
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s "
              f"p99={r['p99_per_token_latency_s'] * 1e3:.1f}ms "
              f"ttft={r['mean_ttft_s'] * 1e3:.1f}ms")
    # acceptance: >= 2x slots at the fixed paged budget, and the
    # recovered slots must show up as lower queueing latency under the
    # same saturating offered load
    for arch, m in by.items():
        d, p = m["dense_squeezed"], m["paged"]
        assert p["slot_multiplier"] >= 2.0, (arch, p["slot_multiplier"])
        assert p["p99_per_token_latency_s"] < d["p99_per_token_latency_s"], (
            arch, p["p99_per_token_latency_s"],
            d["p99_per_token_latency_s"])
        assert p["mean_ttft_s"] < d["mean_ttft_s"], (
            arch, p["mean_ttft_s"], d["mean_ttft_s"])
        print(f"# {arch}: {p['per_slot_bytes_multiplier']:.1f}x "
              f"slots-per-HBM-byte at {p['expected_tokens']}-token "
              f"requests in a {p['cache_len']}-token cache; at the fixed "
              f"{p['kv_budget_gb']:.2f} GB budget dense fits "
              f"{d['slots']}/{p['slots']} slots "
              f"({p['slot_multiplier']:.1f}x), p99 "
              f"{d['p99_per_token_latency_s'] / p['p99_per_token_latency_s']:.1f}x "
              f"better paged, ttft "
              f"{d['mean_ttft_s'] / p['mean_ttft_s']:.1f}x better")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--paging", action="store_true",
                    help="paged-KV slots-per-HBM-byte bench "
                         "(-> BENCH_paging.json)")
    ap.add_argument("--buckets", action="store_true",
                    help="liveness-aware bucketed executor bench: "
                         "executed slot-ticks per token, lockstep vs "
                         "bucketed, on a ~25%%-occupancy trace "
                         "(rows merged into BENCH_batching.json)")
    ap.add_argument("--occupancy", type=float, default=0.25,
                    help="target live-slot fraction for --buckets")
    args = ap.parse_args(argv)
    if args.paging:
        return main_paging(args.out or "BENCH_paging.json")
    if args.buckets:
        return main_buckets(args.out or "BENCH_batching.json",
                            args.occupancy)
    args.out = args.out or "BENCH_batching.json"
    rows = []
    for arch in ARCHS:
        rows.extend(bench_arch(arch))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['arch']}.{r['schedule']}.{r['policy']},"
              f"{r['decode_round_ms'] * 1e3:.1f},"
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s "
              f"p50={r['p50_per_token_latency_s'] * 1e3:.1f}ms "
              f"p99={r['p99_per_token_latency_s'] * 1e3:.1f}ms "
              f"ttft={r['mean_ttft_s'] * 1e3:.1f}ms")
    # acceptance: continuous strictly beats synchronized goodput on the
    # staggered trace (half of each admitted batch finishes early)
    by: Dict[str, Dict[str, dict]] = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["policy"]] = r
    for arch, pol in by.items():
        c, s = pol["continuous"], pol["synchronized"]
        assert c["goodput_tokens_per_s"] > s["goodput_tokens_per_s"], (
            arch, c["goodput_tokens_per_s"], s["goodput_tokens_per_s"])
        print(f"# {arch}: continuous/synchronized goodput = "
              f"{c['goodput_tokens_per_s'] / s['goodput_tokens_per_s']:.2f}x")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
