"""Paper §5.2 / Figure 5: communication reduction of pipeline parallelism
vs BSP data parallelism.

Two sources:
  1. The 2018 model zoo through the partitioner (per-worker wire bytes:
     boundary activations+gradients vs full parameter sync) — the
     paper's ≥90% claims for VGG16/AlexNet/S2VT.
  2. The assigned LM architectures analytically: PipeDream stage-boundary
     bytes per microbatch vs replicated-parameter all-reduce bytes — the
     same trend at transformer scale (plus the HLO-measured collective
     bytes from the dry-run artifacts, when present).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import models_2018 as zoo
from benchmarks.table1 import comm_bytes_bsp, comm_bytes_pp
from repro import configs
from repro.core import profiler as prof
from repro.core.partitioner import partition


def zoo_rows(machines: int = 8):
    out = []
    for name, (fn, mb) in zoo.MODELS.items():
        for hw in (prof.CLUSTER_A, prof.CLUSTER_B):
            profiles = fn(hw, mb)
            part = partition(profiles, machines, hw)
            bsp = comm_bytes_bsp(profiles, machines, hw)
            pp = comm_bytes_pp(profiles, part, hw)
            out.append({"model": name, "cluster": hw.name,
                        "config": part.config_string,
                        "bsp_bytes": bsp, "pp_bytes": pp,
                        "reduction_pct": 100 * (1 - pp / bsp)})
    return out


def lm_rows():
    """Assigned archs, train_4k: per-device per-microbatch bytes.

    BSP: ring all-reduce of all grads = 2(d−1)/d · P · 2B per microbatch
    (d = 256 data replicas).  PipeDream: one boundary activation + one
    gradient = 2 · mb·seq·d_model · 2B, plus the stage-replica sync of
    1/pp of the params over 16 replicas.
    """
    shape = configs.SHAPES["train_4k"]
    out = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        spec, plan = cfg.full_spec(), cfg.PLAN
        chips = 256
        dp = chips // (plan.pp * plan.tp)
        mb_tokens = shape.seq_len * shape.global_batch // (dp * 8)
        p_bytes = spec.param_count() * 2
        bsp = 2 * (chips - 1) / chips * p_bytes
        act = 2 * mb_tokens * spec.d_model * 2
        stage_sync = (2 * (dp - 1) / dp * p_bytes / plan.pp
                      / max(plan.tp, 1))
        pp = act + stage_sync
        out.append({"model": arch, "cluster": "tpu-v5e-256",
                    "config": f"pp{plan.pp}xtp{plan.tp}",
                    "bsp_bytes": bsp, "pp_bytes": pp,
                    "reduction_pct": 100 * (1 - pp / bsp)})
    return out


def hlo_rows(dryrun_dir: str = "experiments/dryrun"):
    """Measured per-device collective bytes from dry-run artifacts."""
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              "*train_4k__16x16*.json"))):
        with open(path) as f:
            r = json.load(f)
        out.append({"model": r["arch"], "shape": r["shape"],
                    "coll_bytes": r["coll_operand_bytes"],
                    "per_kind": r["per_collective"]})
    return out


def main():
    print("== 2018 zoo (partitioner-chosen configs, 8 machines) ==")
    for r in zoo_rows():
        print(f"{r['model']:14s} {r['cluster']:16s} {r['config']:>10s} "
              f"bsp={r['bsp_bytes'] / 1e6:9.1f}MB "
              f"pp={r['pp_bytes'] / 1e6:9.1f}MB "
              f"reduction={r['reduction_pct']:5.1f}%")
    print("\n== assigned archs (train_4k, 256 chips, analytic) ==")
    rows = lm_rows()
    for r in rows:
        print(f"{r['model']:18s} {r['config']:>10s} "
              f"bsp={r['bsp_bytes'] / 1e9:7.2f}GB "
              f"pp={r['pp_bytes'] / 1e9:7.2f}GB "
              f"reduction={r['reduction_pct']:5.1f}%")
    hlo = hlo_rows()
    if hlo:
        print("\n== HLO-measured per-device collective bytes "
              "(dry-run, train_4k) ==")
        for r in hlo:
            print(f"{r['model']:18s} {r['coll_bytes']:.3e} B/device/step")
    for path in sorted(glob.glob("experiments/dryrun/bsp_compare__*.json")):
        with open(path) as f:
            r = json.load(f)
        print(f"\n== compiled BSP vs PipeDream ({r['arch']}, 256 chips) ==")
        print(f"BSP {r['bsp_coll_bytes_per_device']:.3e} B/dev/step  "
              f"PP {r['pp_coll_bytes_per_device']:.3e} B/dev/step  "
              f"reduction {r['reduction_pct']:.1f}%")
    print("\nname,us_per_call,derived")
    for r in zoo_rows() + rows:
        print(f"comm_reduction.{r['model']}.{r['cluster']},0.0,"
              f"reduction={r['reduction_pct']:.1f}%")
    return rows


if __name__ == "__main__":
    main()
