"""Layer profiles for the paper's five evaluation models (§5.1).

Parameter counts are the published per-layer tables (VGG16 exact;
AlexNet exact; others grouped into modules).  Compute times come from
published per-image FLOPs divided through hw.flops_peak × hw.mfu — the
same analytic mode the TPU partitioner uses (profiler.py), so the Table-1
reproduction exercises the production code path end-to-end.

Activation sizes a_l are per-minibatch output bytes (fp32), the paper's
Figure-5 quantities.
"""
from __future__ import annotations

from typing import List

from repro.core.profiler import Hardware, LayerProfile

BWD_FACTOR = 2.0  # paper §3.3: backward ≈ 2× forward


def _mk(name, gflops_fwd, act_bytes, params, hw, mb):
    t_f = gflops_fwd * 1e9 * mb / (hw.flops_peak * hw.mfu)
    return LayerProfile(name, t_f, BWD_FACTOR * t_f, act_bytes * mb, params)


# --------------------------------------------------------------------------
# VGG16 — 138.3 M params (553 MB fp32), 15.5 GFLOPs/image fwd
# --------------------------------------------------------------------------

_VGG16 = [
    # name, GFLOPs fwd/img, out C×H×W, params
    ("conv1_1", 0.087, 64 * 224 * 224, 1_792),
    ("conv1_2", 1.850, 64 * 224 * 224, 36_928),
    ("conv2_1", 0.924, 128 * 112 * 112, 73_856),
    ("conv2_2", 1.850, 128 * 112 * 112, 147_584),
    ("conv3_1", 0.925, 256 * 56 * 56, 295_168),
    ("conv3_2", 1.850, 256 * 56 * 56, 590_080),
    ("conv3_3", 1.850, 256 * 56 * 56, 590_080),
    ("conv4_1", 0.924, 512 * 28 * 28, 1_180_160),
    ("conv4_2", 1.850, 512 * 28 * 28, 2_359_808),
    ("conv4_3", 1.850, 512 * 28 * 28, 2_359_808),
    ("conv5_1", 0.462, 512 * 14 * 14, 2_359_808),
    ("conv5_2", 0.462, 512 * 14 * 14, 2_359_808),
    ("conv5_3", 0.462, 512 * 14 * 14, 2_359_808),
    ("fc6", 0.206, 4096, 102_764_544),
    ("fc7", 0.034, 4096, 16_781_312),
    ("fc8", 0.008, 1000, 4_097_000),
]


def vgg16(hw: Hardware, mb: int = 32) -> List[LayerProfile]:
    return [_mk(n, f, c * 4, p, hw, mb) for n, f, c, p in _VGG16]


# --------------------------------------------------------------------------
# AlexNet — 61 M params (244 MB), 0.72 GFLOPs/image
# --------------------------------------------------------------------------

_ALEXNET = [
    ("conv1", 0.105, 96 * 55 * 55, 34_944),
    ("conv2", 0.224, 256 * 27 * 27, 614_656),
    ("conv3", 0.150, 384 * 13 * 13, 885_120),
    ("conv4", 0.112, 384 * 13 * 13, 1_327_488),
    ("conv5", 0.075, 256 * 13 * 13, 884_992),
    ("fc6", 0.075, 4096, 37_752_832),
    ("fc7", 0.034, 4096, 16_781_312),
    ("fc8", 0.008, 1000, 4_097_000),
]


def alexnet(hw: Hardware, mb: int = 32) -> List[LayerProfile]:
    return [_mk(n, f, c * 4, p, hw, mb) for n, f, c, p in _ALEXNET]


# --------------------------------------------------------------------------
# Inception-v3 — 23.8 M params (95 MB; paper quotes 157 MB with optimizer
# state), 5.7 GFLOPs/image, small activations after the stem
# --------------------------------------------------------------------------

def inception_v3(hw: Hardware, mb: int = 32) -> List[LayerProfile]:
    out = [_mk("stem", 1.2, 192 * 35 * 35, 1_000_000, hw, mb)]
    # 11 inception modules, compute-heavy, modest params/activations
    for i, (g, c, p) in enumerate(
            [(0.30, 288 * 35 * 35, 400_000)] * 3
            + [(0.45, 768 * 17 * 17, 1_300_000)] * 5
            + [(0.50, 1280 * 8 * 8, 3_500_000)] * 3):
        out.append(_mk(f"mixed{i}", g, c, p, hw, mb))
    out.append(_mk("logits", 0.05, 1000, 2_049_000, hw, mb))
    return out


# --------------------------------------------------------------------------
# ResNet-50 — 25.6 M params (102 MB), 4.1 GFLOPs/image
# --------------------------------------------------------------------------

def resnet50(hw: Hardware, mb: int = 32) -> List[LayerProfile]:
    out = [_mk("stem", 0.24, 64 * 112 * 112, 9_472, hw, mb)]
    blocks = ([(0.24, 256 * 56 * 56, 75_008)] * 3
              + [(0.24, 512 * 28 * 28, 280_064)] * 4
              + [(0.24, 1024 * 14 * 14, 1_117_184)] * 6
              + [(0.24, 2048 * 7 * 7, 4_462_592)] * 3)
    for i, (g, c, p) in enumerate(blocks):
        out.append(_mk(f"block{i}", g, c, p, hw, mb))
    out.append(_mk("fc", 0.004, 1000, 2_049_000, hw, mb))
    return out


# --------------------------------------------------------------------------
# S2VT — seq-to-seq video captioning (paper: 349 MB ⇒ ~87 M params),
# 2-layer LSTM over 80-frame clips, minibatch 80.  LSTM compute per
# step: 2 × 4 × d_in × d_hid MACs; params dominate compute ⇒ the
# comm-bound regime the paper reports (70% overhead on 4×Cluster-A).
# --------------------------------------------------------------------------

def s2vt(hw: Hardware, mb: int = 80, steps: int = 80) -> List[LayerProfile]:
    d_feat, d_hid, vocab = 4096, 1000, 12_594
    out = [_mk("embed", 0.001, d_feat, 500 * d_hid, hw, mb)]
    # LSTM1: input 4096 -> 1000; LSTM2: (1000+500) -> 1000
    for name, d_in in (("lstm1", d_feat + d_hid), ("lstm2", 1500 + d_hid)):
        g = 2 * 4 * d_in * d_hid * steps / 1e9
        p = 4 * (d_in * d_hid + d_hid)
        out.append(_mk(name, g, steps * d_hid, p, hw, mb))
    # the 349 MB model size is dominated by the embedding/projection
    out.append(_mk("proj", 2 * d_hid * vocab * steps / 1e9,
                   steps * vocab, 62_000_000, hw, mb))
    return out


MODELS = {
    "vgg16": (vgg16, 32),
    "alexnet": (alexnet, 32),
    "inception_v3": (inception_v3, 32),
    "resnet50": (resnet50, 32),
    "s2vt": (s2vt, 80),
}
