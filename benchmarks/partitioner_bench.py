"""Partitioner scaling benchmark (paper: O(N²M²)) + DP-vs-simulator
cross-check (the DP's predicted bottleneck must match the event-driven
steady state).

Also records the numpy-vectorized DP's speedup over the original
pure-Python recurrence (``partition_scalar``, kept as the oracle): the
two produce bit-identical partitions, the vectorized one ~10× faster at
N=64, M=16 on one core.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import models_2018 as zoo
from benchmarks.simulator import simulate_pipeline
from repro.core import profiler as prof
from repro.core.partitioner import partition, partition_scalar


def timing_rows():
    hw = prof.CLUSTER_A
    rng = np.random.default_rng(0)
    rows = []
    for n_layers in (16, 32, 64):
        for machines in (4, 8, 16):
            profiles = [prof.LayerProfile(
                f"l{i}", rng.uniform(0.001, 0.01), rng.uniform(0.002, 0.02),
                rng.uniform(1e5, 1e7), rng.uniform(1e4, 1e7))
                for i in range(n_layers)]
            t0 = time.perf_counter()
            part = partition(profiles, machines, hw)
            dt = time.perf_counter() - t0
            slow = partition_scalar(profiles, machines, hw)
            dt_scalar = time.perf_counter() - t0 - dt
            assert slow.stages == part.stages, (part, slow)
            rows.append({"n": n_layers, "m": machines, "seconds": dt,
                         "seconds_scalar": dt_scalar,
                         "speedup": dt_scalar / max(dt, 1e-12),
                         "config": part.config_string})
    return rows


def crosscheck_rows():
    rows = []
    for name, (fn, mb) in zoo.MODELS.items():
        hw = prof.CLUSTER_A
        profiles = fn(hw, mb)
        part = partition(profiles, 8, hw)
        sim = simulate_pipeline(profiles, part, hw)
        # the simulated steady state may add boundary-link time the DP
        # bounds by 2·C_i; both must agree within the link service
        rel = abs(sim.per_minibatch - part.bottleneck_time) \
            / part.bottleneck_time
        rows.append({"model": name, "dp": part.bottleneck_time,
                     "sim": sim.per_minibatch, "rel_err": rel})
    return rows


def main():
    print("== partitioner runtime (O(N^2 M^2), numpy-vectorized) ==")
    t_rows = timing_rows()
    for r in t_rows:
        print(f"N={r['n']:3d} M={r['m']:3d}  {r['seconds'] * 1e3:8.1f} ms"
              f"  (scalar {r['seconds_scalar'] * 1e3:8.1f} ms, "
              f"{r['speedup']:4.1f}x)  -> {r['config']}")
    print("\n== DP bottleneck vs event-driven steady state ==")
    c_rows = crosscheck_rows()
    for r in c_rows:
        print(f"{r['model']:14s} dp={r['dp'] * 1e3:8.2f}ms "
              f"sim={r['sim'] * 1e3:8.2f}ms rel={r['rel_err']:.3f}")
    print("\nname,us_per_call,derived")
    for r in t_rows:
        print(f"partitioner.N{r['n']}.M{r['m']},{r['seconds'] * 1e6:.0f},"
              f"config={r['config']}")
    for r in c_rows:
        print(f"dp_vs_sim.{r['model']},0.0,rel_err={r['rel_err']:.4f}")
    return t_rows, c_rows


if __name__ == "__main__":
    main()
