"""Quantized-storage benchmark: what int8/fp8 weights and int8 KV buy.

Entirely analytic (like serving_bench): walks the serving memory model
(``core/schedule.py::ServingSchedule.memory_model``) for the reference
configs at the production decode shape across a grid of storage dtypes —
weights in {fp32, bf16, int8, fp8} × KV cache in {fp32 dense, bf16
dense, int8 paged} — and reports, per cell:

  * ``weight_bytes`` / ``cache_bytes`` / ``total_bytes`` — the worst
    device's footprint terms;
  * ``weight_reduction_vs_fp32`` — the headline compression ratio (the
    gate: int8 rows must clear 1.9x, they analytically sit at ~3.76x =
    4 / (1 + 4/d_model));
  * ``slots_per_hbm`` — decode slots (concurrent sequences) that fit
    one device's HBM after the non-cache terms are paid, the planner's
    currency for "how much batch does quantization unlock";
  * ``feasible_plans`` — how many (pp, schedule, v) candidates
    ``plan_search`` finds feasible under the stock HBM budget with
    these storage dtypes.

Emits ``BENCH_quant.json`` and prints CSV rows.  Exits non-zero if any
int8 weight row fails the >= 1.9x weight-bytes reduction gate.  Run via
``make bench-quant``:

  PYTHONPATH=src:. python benchmarks/quant_bench.py [--out BENCH_quant.json]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro import configs
from repro.core import profiler as prof
from repro.core.partitioner import plan_search
from repro.core.schedule import fit_serving_microbatches

ARCHS = ("qwen3_14b", "olmoe_1b_7b")
HW = prof.TPU_V5E
DATA = 16                       # production mesh: 16 data × 16 model
SHAPE = "decode_32k"
GATE = 1.9                      # weight-bytes reduction floor for int8

# (weight_dtype, kv_dtype, page_size) storage grid; page_size=0 = dense
GRID = [
    ("fp32", "fp32", 0),
    ("fp32", "int8", 64),
    ("bf16", "bf16", 0),
    ("bf16", "int8", 64),
    ("int8", "bf16", 0),
    ("int8", "int8", 64),
    ("fp8", "int8", 64),
]


def bench_arch(arch: str):
    cfg = configs.get(arch)
    spec, base = cfg.full_spec(), cfg.PLAN
    shape = configs.SHAPES[SHAPE]
    R = fit_serving_microbatches(base.decode_microbatches,
                                 shape.global_batch, DATA)
    rows_dev = max(shape.global_batch // DATA // R, 1)
    plan = base.with_(schedule="serve_1f")
    sched = plan.make_schedule()
    rows, base_weight = [], None
    for weight_dtype, kv_dtype, page_size in GRID:
        mm = sched.memory_model(
            spec, plan, HW, microbatch_tokens=rows_dev,
            data_replicas=DATA, cache_len=shape.seq_len,
            global_batch=shape.global_batch, page_size=page_size,
            weight_dtype=weight_dtype, kv_dtype=kv_dtype)
        if base_weight is None:
            base_weight = mm.weight_bytes       # fp32 row comes first
        per_slot = mm.cache_bytes / shape.global_batch
        slots = max((HW.hbm_bytes - (mm.total_bytes - mm.cache_bytes))
                    / per_slot, 0.0)
        cands = plan_search(
            spec, base, base.pp * base.tp, HW, minibatch_tokens=rows_dev,
            data_replicas=DATA, workload="decode",
            cache_len=shape.seq_len, global_batch=shape.global_batch,
            page_size=page_size, weight_dtype=weight_dtype,
            kv_dtype=kv_dtype, return_all=True)
        rows.append({
            "arch": arch, "shape": SHAPE,
            "weight_dtype": weight_dtype, "kv_dtype": kv_dtype,
            "page_size": page_size,
            "weight_bytes": mm.weight_bytes,
            "cache_bytes": mm.cache_bytes,
            "total_bytes": mm.total_bytes,
            "weight_reduction_vs_fp32": base_weight / mm.weight_bytes,
            "slots_per_hbm": slots,
            "feasible_plans": sum(c.feasible for c in cands),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args(argv)

    rows = []
    for arch in ARCHS:
        rows += bench_arch(arch)

    print("arch,weight_dtype,kv_dtype,page_size,weight_gb,cache_gb,"
          "w_reduction,slots_per_hbm,feasible_plans")
    for r in rows:
        print(f"{r['arch']},{r['weight_dtype']},{r['kv_dtype']},"
              f"{r['page_size']},{r['weight_bytes'] / 1e9:.2f},"
              f"{r['cache_bytes'] / 1e9:.2f},"
              f"{r['weight_reduction_vs_fp32']:.2f},"
              f"{r['slots_per_hbm']:.0f},{r['feasible_plans']}")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")

    bad = [r for r in rows if r["weight_dtype"] == "int8"
           and r["weight_reduction_vs_fp32"] < GATE]
    if bad:
        for r in bad:
            print(f"GATE FAIL: {r['arch']} int8 weight reduction "
                  f"{r['weight_reduction_vs_fp32']:.2f}x < {GATE}x",
                  file=sys.stderr)
        return 1
    print(f"gate OK: every int8 row >= {GATE}x weight-bytes reduction")
    return 0


if __name__ == "__main__":
    sys.exit(main())
