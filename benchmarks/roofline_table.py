"""Aggregate the dry-run roofline artifacts into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
prints per (arch × shape × mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness, and roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict


def load(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "mesh" in r:                # skip non-roofline artifacts
            rows.append(r)
    return rows


def fmt(rows, mesh: str = "16x16", note: str = ""):
    out = []
    for r in rows:
        if r["mesh"] != mesh or r.get("note", "") != note:
            continue
        out.append(r)
    out.sort(key=lambda r: (r["arch"], r["shape"]))
    print(f"\n== roofline terms, mesh={mesh}"
          + (f", note={note}" if note else "") + " ==")
    print(f"{'arch':18s} {'shape':12s} {'plan':22s} "
          f"{'compute':>9s} {'memory':>9s} {'collective':>10s} "
          f"{'dominant':>10s} {'useful':>6s} {'frac':>6s}")
    for r in out:
        print(f"{r['arch']:18s} {r['shape']:12s} {r['plan']:22s} "
              f"{r['compute_s'] * 1e3:8.1f}ms {r['memory_s'] * 1e3:8.1f}ms "
              f"{r['collective_s'] * 1e3:9.1f}ms {r['dominant']:>10s} "
              f"{r['useful_ratio']:6.2f} {r['roofline_fraction']:6.3f}")
    return out


def main():
    rows = load()
    if not rows:
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    by = defaultdict(int)
    for r in rows:
        by[(r["mesh"], r.get("note", ""))] += 1
    for (mesh, note), n in sorted(by.items()):
        fmt(rows, mesh, note)
    print("\nname,us_per_call,derived")
    for r in rows:
        tag = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        if r.get("note"):
            tag += f".{r['note']}"
        print(f"{tag},{r['step_seconds'] * 1e6:.1f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']:.4f};"
              f"useful={r['useful_ratio']:.3f}")
    return rows


if __name__ == "__main__":
    main()
