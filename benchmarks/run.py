"""Benchmark aggregator: one section per paper table/figure.

  table1            paper Table 1 (PipeDream vs BSP speedups, configs)
  comm_reduction    paper Figure 5 / §5.2 (comm bytes PP vs BSP)
  partitioner       §3.2 DP runtime + DP-vs-simulator cross-check
  roofline          §Roofline terms from the dry-run artifacts

Each prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    failures = []
    for name in ("table1", "comm_reduction", "partitioner_bench",
                 "roofline_table"):
        print(f"\n{'=' * 72}\n== benchmarks.{name}\n{'=' * 72}")
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks OK")


if __name__ == "__main__":
    main()
