"""Serving-schedule benchmark: prefill TTFT and decode tokens/sec.

Walks the forward-only serving tables (``serve_1f`` vs
``serve_interleaved``) for the reference configs at the production
serving shapes, entirely analytically — per-layer seconds from
``core/profiler.py::profile_analytic`` over the rectangular-DP
partition, the same machinery ``plan_search`` scores candidates with —
so the bench runs in milliseconds on CPU and tracks exactly what the
planner optimizes:

  * prefill TTFT  — ``core/schedule.py::serve_ttft`` (weighted ramp
    ticks: the worst request's time-to-first-token);
  * decode rate   — global tokens per second of the steady decode loop
    (one forward-only round = one token per sequence).

Emits the ``BENCH_serving.json`` trajectory artifact (flat list of row
dicts) and prints ``name,us_per_call,derived`` CSV rows like the other
benchmarks.  Run via ``make bench-serving``:

  PYTHONPATH=src:. python benchmarks/serving_bench.py [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json

from repro import configs
from repro.core import profiler as prof
from repro.core.partitioner import partition_rectangular, stage_phase_times
from repro.core.schedule import (fit_serving_microbatches,
                                 make_serving_schedule,
                                 plan_kwargs_for_schedule, serve_ttft,
                                 serving_cache_bytes, weighted_round_time)

ARCHS = ("qwen3_14b", "olmoe_1b_7b", "rwkv6_1b6")
HW = prof.TPU_V5E
DATA = 16                       # production mesh: 16 data × 16 model


def bench_arch(arch: str, schedules=("serve_1f", "serve_interleaved")):
    cfg = configs.get(arch)
    spec, base = cfg.full_spec(), cfg.PLAN
    rows = []
    for shape_name, workload in (("prefill_32k", "prefill"),
                                 ("decode_32k", "decode")):
        shape = configs.SHAPES[shape_name]
        R = fit_serving_microbatches(base.decode_microbatches,
                                     shape.global_batch, DATA)
        rows_dev = max(shape.global_batch // DATA // R, 1)
        qlen = shape.seq_len if workload == "prefill" else 1
        mb_tokens = rows_dev * qlen
        profiles = prof.profile_analytic(
            spec, HW, minibatch_tokens=mb_tokens,
            kv_len=shape.seq_len if workload == "decode" else None)
        for name in schedules:
            plan = base.with_(**plan_kwargs_for_schedule(
                name, stash_mode=base.stash_mode))
            if spec.n_layers % (plan.pp * plan.virtual_stages):
                continue        # chunk count must divide the stack
            sched = make_serving_schedule(plan, R)
            part = partition_rectangular(profiles, sched.n_chunks, DATA, HW)
            tf, _ = stage_phase_times(profiles, part, plan.pp, plan.tp, HW,
                                      data_replicas=DATA)
            round_s, bubble = weighted_round_time(sched, tf, 0.0)
            ttft_s = serve_ttft(sched, tf)
            cache = serving_cache_bytes(
                spec, plan, sched, cache_len=shape.seq_len,
                global_batch=shape.global_batch,
                sp=shape.kind == "long_decode", data_replicas=DATA,
                prefill=workload == "prefill")
            row = {
                "arch": arch, "shape": shape_name, "workload": workload,
                "schedule": sched.name, "pp": plan.pp, "tp": plan.tp,
                "virtual_stages": sched.virtual_stages,
                "microbatches": R,
                "ttft_ms": ttft_s * 1e3,
                "round_ms": round_s * 1e3,
                "tokens_per_sec": (shape.global_batch / round_s
                                   if workload == "decode" else
                                   shape.global_batch / max(ttft_s, 1e-12)),
                "bubble": bubble,
                "kv_cache_gb": cache / 1e9,
            }
            rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_serving.json")
    args = ap.parse_args(argv)
    rows = []
    for arch in ARCHS:
        rows.extend(bench_arch(arch))
    print("name,us_per_call,derived")
    for r in rows:
        metric = (r["ttft_ms"] if r["workload"] == "prefill"
                  else r["round_ms"])
        print(f"{r['arch']}.{r['shape']}.{r['schedule']},"
              f"{metric * 1e3:.1f},"
              f"tok/s={r['tokens_per_sec']:.1f} bubble={r['bubble']:.3f} "
              f"kv={r['kv_cache_gb']:.2f}GB")
    # sanity: interleaving must not lose TTFT where both schedules ran
    for arch in ARCHS:
        pre = {r["schedule"]: r for r in rows
               if r["arch"] == arch and r["workload"] == "prefill"}
        if {"serve_1f", "serve_interleaved"} <= set(pre):
            assert (pre["serve_interleaved"]["ttft_ms"]
                    <= pre["serve_1f"]["ttft_ms"] + 1e-9), arch
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
