"""Event-driven cluster simulator for PipeDream configurations.

Reproduces the paper's Table 1 / Figure 13 *throughput* comparisons
without GPUs: given per-layer profiles (T_l, a_l, w_l) and a cluster
(compute speed, network bandwidth), it simulates

  * BSP data parallelism: per-minibatch compute + parameter-server sync
    with wait-free backprop overlap,
  * ASP: compute only (no sync stall, statistical efficiency ignored),
  * model parallelism (no pipelining): one minibatch at a time crossing
    all stages,
  * pipeline parallelism (straight or replicated stages): 1F1B steady
    state — throughput governed by the slowest stage
    max(compute, sync, boundary-activation transfer), startup ignored
    (steady-state epochs).

Steady-state epoch time = minibatches_per_epoch × bottleneck_time —
the same objective PipeDream's partitioner optimizes (§3.2), evaluated
by a discrete-event engine rather than the DP formula so the two
implementations cross-check each other (tests + benchmarks assert the
DP's predicted bottleneck matches the simulated one).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitioner import Partition, Stage
from repro.core.profiler import (Hardware, LayerProfile,
                                 comm_time_activations,
                                 comm_time_weight_sync)
from repro.core.schedule import PipelineSchedule, weighted_round_time


# --------------------------------------------------------------------------
# Schedule-table simulation: per-schedule bubble / steady state
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ScheduleSimResult:
    """Slot-level walk of one schedule round.

    Times are in units of one full-stage (F+B) microbatch pass; a
    virtual-stage chunk slot costs 1/v of that.
    """

    n_ticks: int
    n_microbatches: int
    round_time: float             # time-weighted wall-clock of one round
    ideal_time: float             # R × per-stage work (zero-bubble bound)
    bubble_fraction: float        # idle-slot fraction (count-weighted)
    weighted_bubble_fraction: float  # idle *time* fraction over the round
    per_stage_busy: List[int]     # busy (F+B) slots per physical stage
    steady_ticks: int             # ticks with every stage fully busy

    @property
    def per_microbatch(self) -> float:
        """Amortized time per microbatch including bubble cost."""
        return self.round_time / self.n_microbatches


def simulate_schedule(sched: PipelineSchedule, *, t_fwd=1.0,
                      t_bwd=2.0) -> ScheduleSimResult:
    """Walk a schedule's tables tick by tick and measure its bubble.

    ``t_fwd``/``t_bwd`` are full-stage seconds per direction — scalars,
    or per-physical-stage arrays for heterogeneous partitions (the
    planner's case).  ``round_time`` is time-weighted: a ramp-up/drain
    tick in which only one direction runs is charged only for that
    direction, and each synchronized phase costs its slowest active
    stage (core.schedule.weighted_round_time).  ``bubble_fraction``
    stays the slot-count measure and must equal
    ``sched.bubble_fraction`` exactly (table-invariant tests);
    ``weighted_bubble_fraction`` is the idle-time analogue.  The
    planner ranks schedules by ``round_time``: for v >= 2 (S >= 2) the
    interleaved round is strictly shorter than plain 1F1B's for the
    same (S, R).  ``interleaved`` and ``interleaved_async`` share
    timing tables, so they tie here exactly — the planner separates
    them on the memory model (per-chunk version rings vs the round-long
    grad accumulator), not on time.
    """
    tabs = sched.tables()
    S, R, v = sched.n_stages, sched.n_microbatches, sched.virtual_stages
    fwd_busy = (tabs.fwd[:, :, 0] >= 0)
    bwd_busy = (tabs.bwd[:, :, 0] >= 0)
    per_stage = [int(fwd_busy[:, s].sum() + bwd_busy[:, s].sum())
                 for s in range(S)]
    busy = sum(per_stage)
    total = 2 * sched.n_ticks * S
    steady = int((fwd_busy.all(axis=1) & bwd_busy.all(axis=1)).sum())
    round_time, weighted_bubble = weighted_round_time(sched, t_fwd, t_bwd)
    stage_pass = (np.broadcast_to(np.asarray(t_fwd, float), (S,))
                  + np.broadcast_to(np.asarray(t_bwd, float), (S,)))
    return ScheduleSimResult(
        n_ticks=sched.n_ticks,
        n_microbatches=R,
        round_time=round_time,
        ideal_time=R * float(stage_pass.max()),
        bubble_fraction=1.0 - busy / total,
        weighted_bubble_fraction=weighted_bubble,
        per_stage_busy=per_stage,
        steady_ticks=steady,
    )


@dataclasses.dataclass
class SimResult:
    per_minibatch: float          # steady-state seconds per minibatch
    bottleneck_stage: int
    stage_times: List[float]

    def epoch_seconds(self, minibatches: int) -> float:
        return self.per_minibatch * minibatches


def _stage_compute(profiles, st: Stage) -> float:
    return sum(p.t_total for p in profiles[st.start:st.end + 1])


def _stage_sync(profiles, st: Stage, hw: Hardware) -> float:
    w = sum(p.w_params for p in profiles[st.start:st.end + 1])
    return comm_time_weight_sync(w, st.replicas, hw)


def simulate_pipeline(profiles: Sequence[LayerProfile], part: Partition,
                      hw: Hardware, *, n_minibatches: int = 64) -> SimResult:
    """Discrete-event simulation of the 1F1B pipeline in steady state.

    Each stage is a server processing one minibatch-slot (F+B merged —
    double-tick granularity) at its per-minibatch service time
    T_stage = max(compute, weight-sync)/replicas; boundary links are
    servers with service 2·C_i.  Throughput = 1/busiest-server-rate
    (Jackson-network bottleneck); the event engine verifies it.
    """
    stages = part.stages
    svc: List[float] = []
    for st in stages:
        # steady-state service: wait-free backprop overlaps the sync of
        # one minibatch with the next minibatch's compute, so the stage
        # runs at max(compute, sync) — exactly the paper's T(i→j,m).
        svc.append(max(_stage_compute(profiles, st),
                       _stage_sync(profiles, st, hw)) / st.replicas)
    links = [2.0 * comm_time_activations(profiles[st.end].a_bytes, hw)
             for st in stages[:-1]]

    # event-driven: tokens flow input->output; each server FIFO
    servers = []
    for i, s in enumerate(svc):
        servers.append(("stage", i, s))
        if i < len(links):
            servers.append(("link", i, links[i]))
    free_at = [0.0] * len(servers)
    done_last: List[float] = []
    for m in range(n_minibatches):
        t = 0.0
        for j, (_, _, service) in enumerate(servers):
            start = max(t, free_at[j])
            free_at[j] = start + service
            t = start + service
        done_last.append(t)
    # steady-state rate from the tail spacing
    tail = done_last[n_minibatches // 2:]
    per_mb = (tail[-1] - tail[0]) / max(len(tail) - 1, 1)
    stage_times = svc
    bottleneck = max(range(len(svc)), key=lambda i: svc[i])
    return SimResult(per_mb, bottleneck, stage_times)


def simulate_bsp(profiles: Sequence[LayerProfile], machines: int,
                 hw: Hardware) -> SimResult:
    """BSP data parallelism with wait-free backprop: the backward pass
    overlaps gradient pushes; per-minibatch time = max(compute,
    total-sync) (perfect overlap bound, same model as §3.2's T(i→j,m))."""
    part = Partition((Stage(0, len(profiles) - 1, machines),), 0.0, 1)
    return simulate_pipeline(profiles, part, hw)


def simulate_asp(profiles: Sequence[LayerProfile], machines: int,
                 hw: Hardware) -> SimResult:
    """ASP: no sync stall at all (paper: poor statistical efficiency —
    hardware throughput only)."""
    t = sum(p.t_total for p in profiles)
    return SimResult(t / machines, 0, [t / machines])


def simulate_model_parallel(profiles: Sequence[LayerProfile],
                            n_stages: int, hw: Hardware) -> SimResult:
    """No pipelining: one minibatch occupies the machines sequentially
    (paper Figure 3) — per-minibatch = sum of stage+link times."""
    n = len(profiles)
    per = n // n_stages
    bounds = [(i * per, (i + 1) * per - 1 if i < n_stages - 1 else n - 1)
              for i in range(n_stages)]
    t = 0.0
    for i, (a, b) in enumerate(bounds):
        t += sum(p.t_total for p in profiles[a:b + 1])
        if i + 1 < n_stages:
            t += 2.0 * comm_time_activations(profiles[b].a_bytes, hw)
    return SimResult(t, 0, [t])


def simulate_single_machine(profiles: Sequence[LayerProfile]) -> SimResult:
    t = sum(p.t_total for p in profiles)
    return SimResult(t, 0, [t])
