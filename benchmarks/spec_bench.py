"""Speculative decode benchmark: accepted-token goodput vs plain decode.

Drives the REAL slot scheduler
(``repro.serving.batcher.ContinuousBatchingSession`` — including its
draft–verify rounds and accepted-token accounting) with an analytic
engine whose op costs come from the serve schedule tables, exactly like
benchmarks/batching_bench.py, plus the one physical fact that makes
speculation pay: **decode is bandwidth-bound**.  Per-stage phase times
are priced on a roofline

    t_stage = max(flops_time(q_len tokens), stage_weight_bytes / hbm_bw)

so a verify pass scoring ``spec_k + 1`` positions re-reads the same
stage weights as a 1-token decode round and costs nearly the same wall
clock (its FLOPs sit far below the weight-read floor at serving batch
sizes), while committing up to ``spec_k + 1`` tokens per slot.  The
head-only draft steps are priced the same way (head weight read /
``tp``, it is tensor-sharded like every other matmul).

The engine's "model" is the same deterministic token hash the batching
bench uses (``next = (t*31 + 7) % 251 + 1``); the injected draft
function emits the true continuation with per-token probability
``alpha`` (drawn per *slot* — lanes of a slot share one cache position,
so slot-granular speculation needs slot-shared accept draws) and a
guaranteed-wrong token otherwise, so the measured acceptance emerges
from the verifier's own longest-prefix comparison, not from a dial.
Both runs serve the SAME Poisson trace and must produce bit-identical
token streams — speculation changes only how many rounds that takes.

Acceptance bar (schema-gated into BENCH_spec.json, checked by
scripts/bench_check.py): at draft quality alpha = 0.7, k = 4, accepted-
token goodput must exceed 2x the plain-decode goodput on every arch.

Run via ``make bench-spec``:

  PYTHONPATH=src:. python benchmarks/spec_bench.py [--out BENCH_spec.json]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import numpy as np

from repro.core import profiler as prof
from repro.core.partitioner import partition_rectangular, stage_phase_times
from repro.core.schedule import (make_serving_schedule, serve_ttft,
                                 weighted_round_time)
from repro.serving.batcher import ContinuousBatchingSession

from benchmarks.batching_bench import (ARCHS, DATA, HW, N_REQUESTS, PREFILL,
                                       SEED, AnalyticEngine, _serve_setup)
from repro.serving.batcher import Request

SPEC_K = 4
ALPHAS = (0.5, 0.7, 0.9)
SPEC_NEW_TOKENS = 256   # the long-generation regime speculation targets


def spec_trace(n, lanes, rng, text_len):
    """Saturating Poisson arrivals of long-generation requests.

    Speculation's regime: outputs of ~``SPEC_NEW_TOKENS`` tokens, so a
    lane's residence is decode-round-dominated (the per-admission
    prefill round amortizes away) and arrivals press on the full
    R x rows lane capacity — the server never idles waiting for work,
    which is the only configuration where a goodput ratio measures the
    decode loop rather than the arrival process.
    """
    gaps = rng.exponential(scale=max(SPEC_NEW_TOKENS / (2 * lanes), 1.0),
                           size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    return [Request(
        rid=i, prompt=rng.integers(1, 999, text_len).astype(np.int32),
        max_new_tokens=int(rng.integers(SPEC_NEW_TOKENS // 2,
                                        (3 * SPEC_NEW_TOKENS) // 2)),
        arrival=int(arrivals[i])) for i in range(n)]


def _hash_next(t):
    """The analytic engines' one-step 'model' (batching_bench.decode)."""
    return (np.asarray(t, np.int64) * 31 + 7) % 251 + 1


def _stage_weight_bytes(profiles, part, pp: int, tp: int) -> np.ndarray:
    """Per-physical-stage resident weight bytes (bf16), chunk-placed
    like stage_phase_times: chunk c lives on stage c % pp, / tp."""
    w = np.zeros(pp)
    for c, st in enumerate(part.stages):
        w[c % pp] += sum(p.w_params
                         for p in profiles[st.start:st.end + 1]) / tp
    return w * 2.0


class AnalyticSpecEngine(AnalyticEngine):
    """AnalyticEngine + the draft–verify surface the spec batcher drives.

    ``verify`` scores all ``spec_k + 1`` positions of every slot against
    the hash-chain model, returns (scores, per-slot accepted counts =
    min over the slot's lanes of the longest correct draft prefix), and
    advances the modeled clock by one bandwidth-floored verify round
    plus the k head-only draft steps.
    """

    def __init__(self, sched, *, rows, text_len, decode_s, admit_s,
                 verify_s, draft_s):
        super().__init__(sched, rows=rows, text_len=text_len,
                         decode_s=decode_s, admit_s=admit_s)
        self.verify_s = verify_s
        self.draft_s = draft_s
        self.rows_per_slot = rows

    def verify(self, tokens):
        toks = np.asarray(tokens)                      # (B, K+1)
        b, q = toks.shape
        scores = _hash_next(toks).astype(np.int32)     # y_j = next(t_j)
        match = (toks[:, 1:] == scores[:, :-1])
        acc_rows = np.cumprod(match, axis=1).sum(axis=1)
        acc = acc_rows.reshape(self.R, self.rows_per_slot).min(axis=1)
        self.now += self.verify_s + self.draft_s
        self.executed_slot_ticks += self._costs[self._bucket()][2]
        self.bucket_log.append(self.R)
        self._occ_sum += int(self._live.sum())
        self._occ_rounds += 1
        return scores, acc.astype(np.int32)


def make_draft_fn(spec_k: int, rows_per_slot: int, alpha: float,
                  seed: int):
    """Drafts = true hash-chain continuation w.p. ``alpha`` per token.

    The correctness draw is per (slot, position) — broadcast over the
    slot's lanes — and the wrong branch emits ``(true % 251) + 1``,
    which is never the true token, so realized acceptance is exactly
    the longest-alpha-prefix distribution the verifier measures.
    """
    rng = np.random.default_rng(seed)

    def draft(last):
        cur = np.asarray(last, np.int64).reshape(-1)
        n_slots = cur.size // rows_per_slot
        out = np.empty((cur.size, spec_k), np.int32)
        for i in range(spec_k):
            true = _hash_next(cur)
            ok = np.repeat(rng.random(n_slots) < alpha, rows_per_slot)
            d = np.where(ok, true, (true % 251) + 1)
            out[:, i] = d
            cur = d
        return out

    return draft


def _roofline_costs(arch: str, spec_k: int):
    """(plain sched, spec sched, decode_s, verify_s, draft_s, admit_s,
    shape geometry) — bandwidth-floored round costs at the arch's
    decode-serving shape."""
    spec, plan, shape, R, rows = _serve_setup(arch)
    spec_plan = plan.with_(schedule=(
        "serve_spec_interleaved" if plan.schedule == "serve_interleaved"
        else "serve_spec_1f"))
    sched = make_serving_schedule(plan, R)
    ssched = make_serving_schedule(spec_plan, R, spec_k=spec_k)
    per_row = max(rows // DATA, 1)
    cache = shape.seq_len

    dec_prof = prof.profile_analytic(spec, HW, minibatch_tokens=per_row,
                                     kv_len=cache)
    ver_prof = prof.profile_analytic(
        spec, HW, minibatch_tokens=per_row * (spec_k + 1), kv_len=cache)
    part = partition_rectangular(dec_prof, sched.n_chunks, DATA, HW)
    tf_d, _ = stage_phase_times(dec_prof, part, plan.pp, plan.tp, HW,
                                data_replicas=DATA)
    tf_v, _ = stage_phase_times(ver_prof, part, plan.pp, plan.tp, HW,
                                data_replicas=DATA)
    floor = _stage_weight_bytes(dec_prof, part, plan.pp, plan.tp) / HW.hbm_bw
    decode_s, _ = weighted_round_time(sched, np.maximum(tf_d, floor), 0.0)
    verify_s, _ = weighted_round_time(ssched, np.maximum(tf_v, floor), 0.0)

    pre_prof = prof.profile_analytic(spec, HW,
                                     minibatch_tokens=per_row * PREFILL)
    ppart = partition_rectangular(pre_prof, sched.n_chunks, DATA, HW)
    ptf, _ = stage_phase_times(pre_prof, ppart, plan.pp, plan.tp, HW,
                               data_replicas=DATA)
    admit_s = serve_ttft(sched, ptf)

    # head-only draft: one (tokens, d) x (d, vocab) matmul per step,
    # tensor-sharded over tp — flops or the sharded weight read, per step
    tokens = R * per_row
    head_t = prof.head_flops(spec, tokens) / (HW.flops_peak * HW.mfu)
    head_floor = 2.0 * spec.d_model * spec.vocab / (plan.tp * HW.hbm_bw)
    draft_s = spec_k * max(head_t / plan.tp, head_floor)
    return (spec, plan, shape, R, rows, sched, ssched,
            decode_s, verify_s, draft_s, admit_s)


def bench_arch(arch: str, spec_k: int = SPEC_K) -> List[dict]:
    (mspec, plan, shape, R, rows, sched, ssched,
     decode_s, verify_s, draft_s, admit_s) = _roofline_costs(arch, spec_k)
    # saturating long-generation load: Poisson rate against the full
    # R x rows lane capacity — a goodput comparison is meaningless on an
    # arrival-bound server that idles whichever decode it runs, or on
    # short outputs whose lane residence is one prefill round deep
    n_req, rate_slots = 2 * N_REQUESTS, R * rows

    def run_plain():
        rng = np.random.default_rng(SEED)
        eng = AnalyticEngine(sched, rows=rows, text_len=PREFILL,
                             decode_s=decode_s, admit_s=admit_s)
        server = ContinuousBatchingSession(eng, policy="continuous",
                                           clock=eng.clock)
        trace = spec_trace(n_req, rate_slots, rng, PREFILL)
        return trace, server.run(trace)

    base_trace, base_report = run_plain()
    base_goodput = base_report.summary()["goodput_tokens_per_s"]

    rows_out = []
    for alpha in ALPHAS:
        rng = np.random.default_rng(SEED)
        eng = AnalyticSpecEngine(ssched, rows=rows, text_len=PREFILL,
                                 decode_s=decode_s, admit_s=admit_s,
                                 verify_s=verify_s, draft_s=draft_s)
        server = ContinuousBatchingSession(
            eng, policy="continuous", clock=eng.clock,
            draft_fn=make_draft_fn(spec_k, rows, alpha, SEED + 1))
        trace = spec_trace(n_req, rate_slots, rng, PREFILL)
        report = server.run(trace)
        s = report.summary()
        assert s["completed"] == n_req, s
        # speculation must not change a single emitted token
        for b, sp_ in zip(base_trace, trace):
            assert b.tokens == sp_.tokens, (
                f"{arch} alpha={alpha}: request {b.rid} diverged")
        rows_out.append({
            "arch": arch, "schedule": ssched.name, "pp": plan.pp,
            "tp": plan.tp, "slots": R, "rows_per_slot": rows,
            "spec_k": spec_k, "alpha": alpha,
            "decode_round_ms": decode_s * 1e3,
            "verify_round_ms": verify_s * 1e3,
            "draft_ms": draft_s * 1e3,
            "baseline_goodput_tokens_per_s": base_goodput,
            "speedup": s["goodput_tokens_per_s"] / base_goodput, **s,
        })
    return rows_out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_spec.json")
    args = ap.parse_args(argv)
    rows: List[dict] = []
    for arch in ARCHS:
        rows.extend(bench_arch(arch))
    print("name,us_per_call,derived")
    by: Dict[str, dict] = {}
    for r in rows:
        if r["alpha"] == 0.7:
            by[r["arch"]] = r
        print(f"{r['arch']}.spec.a{r['alpha']},"
              f"{r['verify_round_ms'] * 1e3:.1f},"
              f"k={r['spec_k']} speedup={r['speedup']:.2f}x "
              f"acc_rate={r['acceptance_rate']:.2f} "
              f"tok/round={r['accepted_per_round']:.2f} "
              f"goodput={r['goodput_tokens_per_s']:.1f}tok/s")
    # acceptance: alpha = 0.7 drafts must better than double accepted-
    # token goodput on every arch (the ISSUE 8 bar), token streams
    # bit-identical to the plain run (asserted per trace above)
    for arch, r in by.items():
        assert r["speedup"] > 2.0, (
            f"{arch}: {r['speedup']:.2f}x at alpha=0.7 — speculative "
            "decode must exceed 2x plain-decode goodput")
        print(f"# {arch}: {r['speedup']:.2f}x accepted-token goodput at "
              f"alpha=0.7, k={r['spec_k']} "
              f"({r['accepted_per_round']:.2f} tok/lane-round, verify "
              f"{r['verify_round_ms']:.2f} ms vs decode "
              f"{r['decode_round_ms']:.2f} ms + draft "
              f"{r['draft_ms']:.2f} ms)")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
