"""Reproduce paper Table 1: PipeDream vs BSP data parallelism.

For each (model, machines, cluster) row: run PipeDream's partitioner on
the analytic profiles (benchmarks/models_2018.py), simulate steady-state
throughput for single-machine / BSP / PipeDream (benchmarks/simulator.py),
and compare speedups to the published numbers.

Hardware efficiency only — the paper's time-to-accuracy additionally
folds in statistical efficiency, identical between BSP and PipeDream
with weight stashing (§3.4), so throughput ratios are the comparable
quantity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from benchmarks import models_2018 as zoo
from benchmarks.simulator import (simulate_bsp, simulate_model_parallel,
                                  simulate_pipeline,
                                  simulate_single_machine)
from repro.core import profiler as prof
from repro.core.partitioner import partition


@dataclasses.dataclass
class Row:
    model: str
    machines: int
    cluster: str
    paper_config: str
    paper_bsp_speedup: Optional[float]
    paper_pd_speedup: Optional[float]     # over 1 machine
    paper_comm_reduction: Optional[float]  # %


TABLE1 = [
    Row("vgg16", 4, "A", "2-1-1", 1.47, 3.14, 90.0),
    Row("vgg16", 8, "A", "7-1", 2.35, 7.04, 95.0),
    Row("vgg16", 16, "A", "9-5-1-1", 3.28, 9.86, 91.0),
    Row("vgg16", 8, "B", "7-1", 1.36, 6.98, 95.0),
    Row("inception_v3", 8, "A", "8", 7.66, 7.66, 0.0),
    Row("inception_v3", 8, "B", "7-1", 4.74, 6.88, 47.0),
    Row("s2vt", 4, "A", "2-1-1", 1.10, 3.34, 95.0),
    # §5.2 text: AlexNet / ResNet-50 throughput vs 8-machine BSP (B)
    Row("alexnet", 8, "B", None, None, None, None),
    Row("resnet50", 8, "B", None, None, None, None),
]


def comm_bytes_bsp(profiles, m, hw):
    w = sum(p.w_params for p in profiles)
    return hw.ps_factor * (m - 1) * w * hw.param_bytes / m


def comm_bytes_pp(profiles, part, hw):
    """Per-minibatch worst-stage wire bytes: boundary activations +
    gradient (×2) + intra-stage replica sync."""
    worst = 0.0
    for i, st in enumerate(part.stages):
        b = 0.0
        if i + 1 < len(part.stages):
            b += 2.0 * profiles[st.end].a_bytes
        if i > 0:
            b += 2.0 * profiles[part.stages[i - 1].end].a_bytes
        w = sum(p.w_params for p in profiles[st.start:st.end + 1])
        b += (hw.ps_factor * (st.replicas - 1) * w * hw.param_bytes
              / max(st.replicas, 1))
        worst = max(worst, b)
    return worst


def run_row(row: Row):
    hw = prof.CLUSTER_A if row.cluster == "A" else prof.CLUSTER_B
    fn, mb = zoo.MODELS[row.model]
    profiles = fn(hw, mb)
    part = partition(profiles, row.machines, hw)
    single = simulate_single_machine(profiles).per_minibatch
    bsp = simulate_bsp(profiles, row.machines, hw).per_minibatch
    pd = simulate_pipeline(profiles, part, hw).per_minibatch
    mp = simulate_model_parallel(profiles, min(row.machines, 4),
                                 hw).per_minibatch
    comm_red = 100.0 * (1.0 - comm_bytes_pp(profiles, part, hw)
                        / comm_bytes_bsp(profiles, row.machines, hw))
    return {
        "model": row.model, "machines": row.machines,
        "cluster": row.cluster,
        "config": part.config_string, "noam": part.noam,
        "bsp_speedup": single / bsp,
        "pd_speedup": single / pd,
        "pd_over_bsp": bsp / pd,
        "mp_slowdown": single / mp,
        "comm_reduction_pct": comm_red,
        "paper": row,
    }


def main(csv: bool = True):
    rows = []
    print(f"{'model':14s} {'m':>3s} cl {'config':>10s} "
          f"{'BSP×':>6s}({'paper':>5s}) {'PD×':>6s}({'paper':>5s}) "
          f"{'PD/BSP':>6s} {'comm−%':>6s}({'paper':>5s})")
    for row in TABLE1:
        r = run_row(row)
        p = r["paper"]
        print(f"{r['model']:14s} {r['machines']:3d}  {r['cluster']} "
              f"{r['config']:>10s} "
              f"{r['bsp_speedup']:6.2f}({p.paper_bsp_speedup or 0:5.2f}) "
              f"{r['pd_speedup']:6.2f}({p.paper_pd_speedup or 0:5.2f}) "
              f"{r['pd_over_bsp']:6.2f} "
              f"{r['comm_reduction_pct']:6.1f}({p.paper_comm_reduction or 0:5.1f})")
        rows.append(r)
    if csv:
        print("\nname,us_per_call,derived")
        for r in rows:
            tag = f"table1.{r['model']}.{r['machines']}{r['cluster']}"
            print(f"{tag},{0.0},pd_over_bsp={r['pd_over_bsp']:.3f};"
                  f"config={r['config']};"
                  f"comm_red={r['comm_reduction_pct']:.1f}%")
    return rows


if __name__ == "__main__":
    main()
