"""Fault tolerance demo (paper §4): kill training mid-run, restart from
the last per-stage checkpoint, and show the replayed rounds produce the
identical loss trajectory; then elastically re-plan from pp=2 to pp=4.

    python examples/fault_tolerance.py
"""
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402

from repro.core.pipeline import build_pipeline    # noqa: E402
from repro.data.pipeline import ShardedLoader, SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh      # noqa: E402
from repro.models import spec as S                # noqa: E402
from repro.optim import SGDM                      # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.runtime.driver import (DriverConfig, TrainDriver,  # noqa: E402
                                  elastic_replan, reshard_state_for_plan)


def tiny_spec():
    return S.ModelSpec(name="ft-lm", d_model=64, n_layers=8, n_heads=4,
                       n_kv=2, d_head=16, d_ff=256, vocab=256,
                       blocks=tuple(S.BlockSpec() for _ in range(8)))


def build(plan, mesh):
    spec = tiny_spec()
    bundle = build_pipeline(spec, plan, mesh, seq_len=32, global_batch=4,
                            optimizer=SGDM(lr=0.02),
                            compute_dtype=jnp.float32)
    loader = ShardedLoader(SyntheticLM(spec.vocab, 32),
                           bundle.batch_specs())
    return spec, bundle, loader


def main():
    tmp = tempfile.mkdtemp(prefix="pipedream_ckpt_")
    plan = ParallelismPlan(pp=2, tp=1, microbatches=2, zero1=False)
    mesh = split_model_axis(make_host_mesh(data=1, model=2), 2, 1)
    spec, bundle, loader = build(plan, mesh)

    crash = {"armed": True}

    def failure(step):
        if step == 7 and crash["armed"]:
            crash["armed"] = False
            print(">>> simulated stage failure at round 7 <<<")
            raise RuntimeError("node down")

    driver = TrainDriver(bundle, loader, tmp,
                         DriverConfig(checkpoint_every=3),
                         failure_hook=failure)
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(0))
    state, step = driver.run(state, 10)
    print(f"survived to round {step}; losses:")
    for i, m in enumerate(driver.metrics_log):
        print(f"  round {i:2d}  loss {m['loss']:.4f}")

    # ---- elastic re-plan: the model axis doubles (2 -> 4 devices) ------
    new_plan = elastic_replan(spec, plan, new_model_axis=4,
                              minibatch_tokens=64, data_replicas=1)
    print(f"\nelastic re-plan: pp{plan.pp}xtp{plan.tp} -> "
          f"pp{new_plan.pp}xtp{new_plan.tp}")
    host_state = jax.device_get(state)
    host_state = reshard_state_for_plan(host_state, spec, plan, new_plan)
    mesh4 = split_model_axis(make_host_mesh(data=1, model=4),
                             new_plan.pp, new_plan.tp)
    _, bundle4, loader4 = build(new_plan, mesh4)
    sh = bundle4.state_shardings()
    state4 = jax.tree.map(jax.device_put, host_state, sh)
    step_fn = jax.jit(bundle4.train_step,
                      in_shardings=(sh, bundle4.batch_shardings()),
                      out_shardings=(sh, None))
    for i in range(step, step + 3):
        state4, metrics = step_fn(state4, loader4.get(i))
        print(f"  round {i:2d}  loss {float(metrics['loss']):.4f}  "
              f"(pp={new_plan.pp})")
    print("elastic continuation OK")


if __name__ == "__main__":
    main()
