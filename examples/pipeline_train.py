"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred rounds with PipeDream (stash) and compare the loss curve against
BSP data parallelism on the same data — the paper's §5.2 claim that
weight stashing preserves convergence while pipelining.

    python examples/pipeline_train.py [--steps 200] [--quick]
"""
import argparse
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402
import numpy as np                                # noqa: E402

from repro.core.baselines import build_bsp        # noqa: E402
from repro.core.pipeline import build_pipeline    # noqa: E402
from repro.data.pipeline import ShardedLoader, SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh      # noqa: E402
from repro.models import spec as S                # noqa: E402
from repro.optim import Adam                      # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402


def model_100m(quick=False):
    if quick:
        return S.ModelSpec(name="lm-2m", d_model=128, n_layers=4,
                           n_heads=4, n_kv=2, d_head=32, d_ff=512,
                           vocab=2048,
                           blocks=tuple(S.BlockSpec() for _ in range(4)),
                           qk_norm=True)
    # ~102 M params: 12L, d=768, ffn 3072, 32k vocab
    return S.ModelSpec(name="lm-100m", d_model=768, n_layers=12,
                       n_heads=12, n_kv=4, d_head=64, d_ff=3072,
                       vocab=32768,
                       blocks=tuple(S.BlockSpec() for _ in range(12)),
                       qk_norm=True)


def run_pipedream(spec, steps, seq, gbatch, seed=0):
    plan = ParallelismPlan(pp=4, tp=1, microbatches=4, stash_mode="stash",
                           zero1=False)
    mesh = split_model_axis(make_host_mesh(data=1, model=4), 4, 1)
    bundle = build_pipeline(spec, plan, mesh, seq_len=seq,
                            global_batch=gbatch,
                            optimizer=Adam(lr=1e-3),
                            compute_dtype=jnp.float32)
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(seed))
    loader = ShardedLoader(SyntheticLM(spec.vocab, seq, seed=1),
                           bundle.batch_specs())
    step = jax.jit(bundle.train_step,
                   in_shardings=(bundle.state_shardings(),
                                 bundle.batch_shardings()),
                   out_shardings=(bundle.state_shardings(), None),
                   donate_argnums=0)
    losses = []
    for i in range(steps):
        state, metrics = step(state, loader.get(i))
        losses.append(float(metrics["loss"]))
    return losses


def run_bsp(spec, steps, seq, gbatch, seed=0):
    mesh = make_host_mesh(data=4, model=1)
    train_step, init_state, state_sh, batch_specs = build_bsp(
        spec, mesh, seq_len=seq, global_batch=gbatch,
        optimizer=Adam(lr=1e-3), compute_dtype=jnp.float32)
    state = jax.jit(init_state, out_shardings=state_sh)(
        jax.random.key(seed))
    src = SyntheticLM(spec.vocab, seq, seed=1)
    step = jax.jit(train_step, in_shardings=(state_sh, None),
                   out_shardings=(state_sh, None), donate_argnums=0)
    losses = []
    for i in range(steps):
        # identical token stream, flattened to (B, S)
        host = src.round_batch(i, 4, gbatch // 4)
        batch = {k: jnp.asarray(v.reshape(gbatch, seq))
                 for k, v in host.items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="2M-param model, fewer steps (CI-sized)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    if args.quick:
        args.steps = min(args.steps, 30)

    spec = model_100m(args.quick)
    print(f"model: {spec.name} ({spec.param_count() / 1e6:.1f} M params), "
          f"{args.steps} rounds")
    pd = run_pipedream(spec, args.steps, args.seq, args.batch)
    print(f"pipedream  loss {pd[0]:.4f} -> {pd[-1]:.4f}")
    bsp = run_bsp(spec, args.steps, args.seq, args.batch)
    print(f"bsp        loss {bsp[0]:.4f} -> {bsp[-1]:.4f}")

    # both must converge to the same neighbourhood (§3.4: stashing keeps
    # a valid, mildly delayed gradient)
    tail_pd = np.mean(pd[-5:])
    tail_bsp = np.mean(bsp[-5:])
    print(f"tail means: pipedream {tail_pd:.4f}  bsp {tail_bsp:.4f}  "
          f"gap {abs(tail_pd - tail_bsp):.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"pipedream": pd, "bsp": bsp}, f)


if __name__ == "__main__":
    main()
