"""Quickstart: build a 4-stage PipeDream pipeline on 4 host devices and
train a tiny LM for a few rounds.

    python examples/quickstart.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                        # noqa: E402
import jax.numpy as jnp                           # noqa: E402

from repro.core.pipeline import build_pipeline    # noqa: E402
from repro.data.pipeline import ShardedLoader, SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh      # noqa: E402
from repro.models import spec as S                # noqa: E402
from repro.optim import SGDM                      # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402


def main():
    # 1. a small 8-layer dense LM
    spec = S.ModelSpec(
        name="quickstart-lm", d_model=128, n_layers=8, n_heads=8, n_kv=4,
        d_head=16, d_ff=512, vocab=512,
        blocks=tuple(S.BlockSpec() for _ in range(8)))

    # 2. PipeDream plan: 4 pipeline stages, 4 microbatches in flight,
    #    weight stashing (the paper's default semantics)
    plan = ParallelismPlan(pp=4, tp=1, microbatches=4, stash_mode="stash",
                           zero1=False)
    mesh = split_model_axis(make_host_mesh(data=1, model=4), pp=4, tp=1)

    # 3. build the pipelined train step (1F1B, per-microbatch updates)
    bundle = build_pipeline(spec, plan, mesh, seq_len=64, global_batch=8,
                            optimizer=SGDM(lr=0.05, momentum=0.9),
                            compute_dtype=jnp.float32)
    print(f"stages={plan.pp}  stash ring={plan.stash_slots} versions  "
          f"ticks/round={bundle.sched.n_ticks}  "
          f"bubble={bundle.sched.bubble_fraction:.1%}")

    # 4. train
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(0))
    loader = ShardedLoader(SyntheticLM(spec.vocab, 64),
                           bundle.batch_specs())
    step = jax.jit(bundle.train_step,
                   in_shardings=(bundle.state_shardings(),
                                 bundle.batch_shardings()),
                   out_shardings=(bundle.state_shardings(), None),
                   donate_argnums=0)
    for i in range(10):
        state, metrics = step(state, loader.get(i))
        print(f"round {i:2d}  loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
