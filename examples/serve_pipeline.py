"""Pipelined serving example: batched prefill + streaming decode of an
RWKV6-family model (O(1) recurrent state) across 2 pipeline stages.

    python examples/serve_pipeline.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve  # noqa: E402


def main():
    serve.main(["--arch", "rwkv6-1.6b", "--smoke", "--batch", "4",
                "--prefill", "32", "--tokens", "24", "--data", "1"])


if __name__ == "__main__":
    main()
