#!/usr/bin/env python
"""batch-smoke: continuous batching end-to-end on CPU (CI gate).

A 2-stage pipe with R = 2 microbatch slots serves a staggered
3-request trace through :class:`repro.serving.batcher.
ContinuousBatchingSession`: requests 0 and 1 are admitted at step 0,
request 0 finishes early (3 tokens), and request 2 — which arrived at
step 1 — is admitted into the freed slot mid-stream while request 1 is
still decoding.  Every request's token sequence must be bit-identical
(fp32) to the same request run SOLO through a fresh one-shot
``serve_1f`` session.  This is the cheapest end-to-end proof that
per-slot admission/eviction (masked prefill, per-slot cache positions,
slot resets) never perturbs a live request; the full matrix
(S = 4, interleaved v = 2) lives in tests/test_batcher.py.

Run via ``make batch-smoke`` (wired into scripts/tier1.sh).
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.models import spec as spec_lib                     # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.batcher import ContinuousBatchingSession, Request  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

PP, R, PREFILL, CACHE = 2, 2, 8, 64


def make_session(schedule="auto", virtual_stages=1, page_size=0,
                 n_slots=R, buckets=False):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(PP * max(virtual_stages, 1) * 2))
    spec = spec_lib.ModelSpec(
        name="batch-smoke", d_model=64, n_layers=len(blocks), n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu")
    mesh = make_host_mesh(data=1, model=PP)
    dmesh = split_model_axis(mesh, PP, 1)
    plan = ParallelismPlan(pp=PP, tp=1, microbatches=n_slots,
                           decode_microbatches=n_slots, schedule=schedule,
                           virtual_stages=virtual_stages)
    return spec, build_serving(spec, plan, dmesh, cache_len=CACHE,
                               global_batch=n_slots, prefill_len=PREFILL,
                               compute_dtype=jnp.float32,
                               page_size=page_size, buckets=buckets)


def solo_tokens(spec, prompt, n_tokens, n_slots=R):
    """The request alone through a fresh one-shot serve_1f session."""
    _, sess = make_session(n_slots=n_slots)
    sess.start(jax.random.key(0))
    tokens = jnp.asarray(np.broadcast_to(prompt, (n_slots, 1, PREFILL)))
    toks = [np.asarray(sess.prefill({"tokens": tokens}))[0]]
    for _ in range(n_tokens - 1):
        last = jnp.asarray(np.full((n_slots,), toks[-1], np.int32))
        toks.append(np.asarray(sess.decode(last))[0])
    return [int(t) for t in toks]


def main() -> int:
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 256, PREFILL).astype(np.int32)
               for _ in range(3)]
    trace = [
        Request(rid=0, prompt=prompts[0], max_new_tokens=3, arrival=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=10, arrival=0),
        Request(rid=2, prompt=prompts[2], max_new_tokens=6, arrival=1),
    ]
    spec, sess = make_session()
    sess.start(jax.random.key(0))
    server = ContinuousBatchingSession(sess)
    report = server.run(trace)
    print(f"steps={report.steps} decode_rounds={report.decode_rounds} "
          f"admit_rounds={report.admit_rounds} "
          f"completed={len(report.completed)}")
    assert len(report.completed) == 3, report.summary()
    # request 2 must have been admitted mid-stream, after an eviction
    assert trace[2].step_admitted > trace[0].step_done, (
        trace[2].step_admitted, trace[0].step_done)
    assert trace[1].step_done > trace[2].step_admitted, (
        "request 1 should still be decoding when request 2 is admitted")

    ok = True
    for r in trace:
        want = solo_tokens(spec, r.prompt, r.max_new_tokens)
        mark = "==" if r.tokens == want else "!="
        print(f"  request {r.rid}: continuous {r.tokens} {mark} solo {want}")
        ok &= r.tokens == want
    if not ok:
        print("BATCH SMOKE FAILED: mid-stream admission is not bit-exact")
        return 1
    print("batch smoke OK (3 staggered requests bit-exact vs solo runs)\n")
    return ragged_main()


def ragged_run(page_size):
    """The ragged trace (3 prompt lengths, mid-stream admission)."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 256, n).astype(np.int32) for n in (5, 8, 3)]
    trace = [
        Request(rid=0, prompt=prompts[0], max_new_tokens=3, arrival=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=10, arrival=0),
        Request(rid=2, prompt=prompts[2], max_new_tokens=6, arrival=1),
    ]
    _, sess = make_session(page_size=page_size)
    sess.start(jax.random.key(0))
    report = ContinuousBatchingSession(sess).run(trace)
    assert len(report.completed) == 3, report.summary()
    assert trace[2].step_admitted > trace[0].step_done, (
        "request 2 must admit mid-stream into request 0's freed slot")
    if page_size:
        # eviction must have returned every page to the pool
        sess._alloc.check()
        assert sess._alloc.live_pages == 0, sess._alloc.tables
    return trace


def ragged_main() -> int:
    """Ragged prompts, dense vs paged: every request bit-exact (fp32)."""
    dense = ragged_run(page_size=0)
    paged = ragged_run(page_size=16)
    ok = True
    for d, p in zip(dense, paged):
        mark = "==" if d.tokens == p.tokens else "!="
        print(f"  ragged request {d.rid} (prompt {len(d.prompt)} tok): "
              f"dense {d.tokens} {mark} paged {p.tokens}")
        ok &= d.tokens == p.tokens
    for d in dense:
        solo = [Request(rid=d.rid, prompt=d.prompt,
                        max_new_tokens=d.max_new_tokens, arrival=0)]
        _, sess = make_session()
        sess.start(jax.random.key(0))
        ContinuousBatchingSession(sess).run(solo)
        mark = "==" if d.tokens == solo[0].tokens else "!="
        print(f"  ragged request {d.rid}: batched {d.tokens} {mark} "
              f"solo {solo[0].tokens}")
        ok &= d.tokens == solo[0].tokens
    if not ok:
        print("BATCH SMOKE FAILED: ragged paged/dense traces diverge")
        return 1
    print("\nbatch smoke OK (3 staggered requests bit-exact vs solo runs; "
          "ragged trace bit-exact dense vs paged vs solo)\n")
    return bucket_main()


def _bucket_trace(prompts):
    """Down-then-up bucket pressure over R = 4 slots: four requests fill
    the batch (bucket 4), the two short ones finish and their eviction
    compacts the survivors into a 2-slot prefix (bucket 2), then a late
    arrival admits mid-stream and grows the bucket back (3 live -> 4)."""
    return [
        Request(rid=0, prompt=prompts[0], max_new_tokens=3, arrival=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=3, arrival=0),
        Request(rid=2, prompt=prompts[2], max_new_tokens=12, arrival=0),
        Request(rid=3, prompt=prompts[3], max_new_tokens=12, arrival=0),
        Request(rid=4, prompt=prompts[4], max_new_tokens=4, arrival=5),
    ]


def bucket_main() -> int:
    """Mid-stream bucket switches, bit-exact vs the full-R path.

    The same 5-request trace runs through a plain full-R session and a
    bucketed one (dense and paged): the bucketed server must shrink its
    bucket when evictions compact the batch, grow it back on the late
    admission, and still stream every request bit-identically (fp32) to
    the full-R run and to a solo one-shot session.
    """
    R4 = 4
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, 256, PREFILL).astype(np.int32)
               for _ in range(5)]
    spec = None
    runs = {}
    for name, kw in (("full_R", {}),
                     ("bucketed", {"buckets": True}),
                     ("bucketed_paged", {"buckets": True, "page_size": 16})):
        t = _bucket_trace(prompts)
        spec, sess = make_session(n_slots=R4, **kw)
        sess.start(jax.random.key(0))
        report = ContinuousBatchingSession(sess).run(t)
        assert len(report.completed) == 5, (name, report.summary())
        runs[name] = t
        if kw.get("buckets"):
            log = sess._bucket_log
            shrank = any(b2 < b1 for b1, b2 in zip(log, log[1:]))
            grew = any(b2 > b1 for b1, b2 in zip(log, log[1:]))
            assert len(set(log)) >= 2 and shrank and grew, (
                f"{name}: trace must switch buckets both ways, log={log}")
            print(f"  {name} bucket log: {log}")
        if kw.get("page_size"):
            sess._alloc.check()
            assert sess._alloc.live_pages == 0, sess._alloc.tables
    ok = True
    for r_full, r_bkt, r_pg in zip(runs["full_R"], runs["bucketed"],
                                   runs["bucketed_paged"]):
        solo = solo_tokens(spec, r_full.prompt, r_full.max_new_tokens,
                           n_slots=R4)
        same = (r_full.tokens == r_bkt.tokens == r_pg.tokens == solo)
        mark = "==" if same else "!="
        print(f"  request {r_full.rid}: full-R {r_full.tokens} {mark} "
              f"bucketed {r_bkt.tokens} (paged {r_pg.tokens}, "
              f"solo {solo})")
        ok &= same
    if not ok:
        print("BATCH SMOKE FAILED: bucket switches are not bit-exact")
        return 1
    print("\nbatch smoke OK (bucket shrink/grow mid-stream, bit-exact vs "
          "full-R and solo, dense + paged)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
