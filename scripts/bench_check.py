#!/usr/bin/env python
"""bench-check: every BENCH_*.json artifact must be well-formed.

CI gate (scripts/tier1.sh / `make bench-check`) against benchmark-
artifact rot: the BENCH_*.json trajectory files are committed outputs
of the benchmarks (serving_bench, batching_bench, batching_bench
--paging / --buckets, spec_bench), and downstream plots and the ROADMAP
tables read
them by key.  A half-written file, a renamed column, or a NaN that
snuck through a cost model should fail fast here, not at plot time.

Checks, per file:

  * parses as JSON and is a non-empty list of row dicts;
  * every row of a known artifact carries that artifact's required
    keys (rows are matched to a row-kind by its discriminator column —
    ``policy`` / ``mode`` — so one file may mix row kinds, as
    BENCH_batching.json does with policy rows + bucket rows);
  * every numeric value is finite — ``NaN``/``Infinity`` survive
    ``json.dump`` and silently poison comparisons downstream.

Unknown BENCH_*.json files (a new benchmark's artifact) get the
structural + finiteness checks only, so adding a benchmark does not
require touching this gate.

Exit status: 0 clean, 1 with a listing of every malformed artifact.
"""
import glob
import json
import math
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# required keys per (file, row-kind); the row-kind is picked by the
# discriminator column so mixed-kind files check each row correctly
_COMMON_RUN = ("requests", "completed", "completed_tokens", "steps",
               "goodput_tokens_per_s", "p50_per_token_latency_s",
               "p99_per_token_latency_s", "mean_ttft_s")
SCHEMAS = {
    "BENCH_serving.json": {
        None: ("arch", "shape", "workload", "schedule", "pp", "tp",
               "virtual_stages", "microbatches", "ttft_ms", "round_ms",
               "tokens_per_sec", "bubble"),
    },
    "BENCH_batching.json": {
        ("policy", None): ("arch", "schedule", "slots", "rows_per_slot",
                           "decode_round_ms", "admit_round_ms")
        + _COMMON_RUN,
        ("mode", "lockstep_full_R"): (),   # same as bucketed, below
        ("mode", "bucketed"): (),
    },
    "BENCH_paging.json": {
        ("mode", None): ("arch", "mode", "page_size", "slots",
                         "slot_multiplier", "per_slot_bytes_multiplier",
                         "kv_budget_gb") + _COMMON_RUN,
    },
    "BENCH_quant.json": {
        None: ("arch", "shape", "weight_dtype", "kv_dtype", "page_size",
               "weight_bytes", "cache_bytes", "total_bytes",
               "weight_reduction_vs_fp32", "slots_per_hbm",
               "feasible_plans"),
    },
    "BENCH_spec.json": {
        None: ("arch", "schedule", "slots", "rows_per_slot", "spec_k",
               "alpha", "decode_round_ms", "verify_round_ms", "draft_ms",
               "baseline_goodput_tokens_per_s", "speedup", "spec_rounds",
               "drafted_tokens", "accepted_drafts", "accepted_tokens",
               "acceptance_rate", "accepted_per_round") + _COMMON_RUN,
    },
}
_BUCKET_ROW = ("arch", "mode", "slots", "buckets", "bucket_rounds",
               "mean_occupancy", "executed_slot_ticks",
               "slot_ticks_per_token", "slot_ticks_ratio") + _COMMON_RUN
SCHEMAS["BENCH_batching.json"][("mode", "lockstep_full_R")] = _BUCKET_ROW
SCHEMAS["BENCH_batching.json"][("mode", "bucketed")] = _BUCKET_ROW


def _required_keys(fname: str, row: dict):
    """Required keys for this row, or None when the file is unknown."""
    schema = SCHEMAS.get(fname)
    if schema is None:
        return None
    if None in schema:
        return schema[None]
    for (col, val), keys in schema.items():
        if val is not None and row.get(col) == val:
            return keys
    for (col, val), keys in schema.items():
        if val is None and col in row:
            return keys
    return ()        # no kind matched: reported by the caller


def _bad_numbers(row: dict, prefix=""):
    """Dotted paths of every non-finite numeric value in the row."""
    bad = []
    for k, v in row.items():
        if isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            if not math.isfinite(v):
                bad.append(f"{prefix}{k}={v}")
        elif isinstance(v, dict):
            bad.extend(_bad_numbers(v, f"{prefix}{k}."))
        elif isinstance(v, list):
            bad.extend(f"{prefix}{k}[{i}]={x}" for i, x in enumerate(v)
                       if isinstance(x, (int, float))
                       and not isinstance(x, bool)
                       and not math.isfinite(x))
    return bad


# metrics-registry snapshots (repro.obs.metrics.Registry.snapshot /
# launch --metrics-out / METRICS_*.json): one object, not a row list
_METRIC_ROW = ("name", "labels", "value")
_HIST_ROW = ("name", "labels", "count", "sum", "mean", "min", "max",
             "p50", "p99")


def check_metrics_snapshot(snap, fname: str = "metrics"):
    """Validate one Registry.snapshot() object; returns failure strings.

    Shape: ``{"kind": "metrics", "counters": [...], "gauges": [...],
    "histograms": [...]}``; counter/gauge rows carry
    ``(name, labels, value)``, histogram rows the summary stats.
    ``None`` stats (empty series) are legal; NaN/Infinity are not —
    same finiteness rule as the benchmark artifacts.
    """
    failures = []
    if not isinstance(snap, dict) or snap.get("kind") != "metrics":
        return [f"{fname}: expected a kind='metrics' object, got "
                f"{type(snap).__name__}"]
    for section, required in (("counters", _METRIC_ROW),
                              ("gauges", _METRIC_ROW),
                              ("histograms", _HIST_ROW)):
        rows = snap.get(section)
        if not isinstance(rows, list):
            failures.append(f"{fname}: missing list section {section!r}")
            continue
        for i, row in enumerate(rows):
            where = f"{fname}.{section}[{i}]"
            if not isinstance(row, dict):
                failures.append(f"{where}: row is "
                                f"{type(row).__name__}, not an object")
                continue
            missing = [k for k in required if k not in row]
            if missing:
                failures.append(f"{where}: missing keys {missing}")
            if not isinstance(row.get("labels", {}), dict):
                failures.append(f"{where}: labels must be an object")
            failures.extend(f"{where}: non-finite value {b}"
                            for b in _bad_numbers(row))
    return failures


def check_metrics_file(path: str):
    fname = os.path.basename(path)
    try:
        with open(path) as f:
            snap = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{fname}: does not parse: {e}"]
    return check_metrics_snapshot(snap, fname)


def check_artifact(path: str):
    fname = os.path.basename(path)
    failures = []
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{fname}: does not parse: {e}"]
    if not isinstance(rows, list) or not rows:
        return [f"{fname}: expected a non-empty list of rows, "
                f"got {type(rows).__name__}"]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            failures.append(f"{fname}[{i}]: row is "
                            f"{type(row).__name__}, not an object")
            continue
        required = _required_keys(fname, row)
        if required == ():
            failures.append(
                f"{fname}[{i}]: row matches no known kind for this "
                f"artifact (discriminators: "
                f"policy={row.get('policy')!r} mode={row.get('mode')!r})")
        elif required:
            missing = [k for k in required if k not in row]
            if missing:
                failures.append(
                    f"{fname}[{i}]: missing keys {missing}")
        failures.extend(f"{fname}[{i}]: non-finite value {b}"
                        for b in _bad_numbers(row))
    return failures


def main() -> int:
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    assert paths, "bench-check found no BENCH_*.json artifacts"
    metric_paths = sorted(glob.glob(os.path.join(ROOT, "METRICS_*.json")))
    failures = []
    n_rows = 0
    for p in paths:
        failures.extend(check_artifact(p))
        try:
            with open(p) as f:
                n_rows += len(json.load(f))
        except Exception:
            pass
    for p in metric_paths:
        failures.extend(check_metrics_file(p))
    if failures:
        print("BENCH CHECK FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"bench check OK ({len(paths)} artifacts, {n_rows} rows"
          + (f"; {len(metric_paths)} metrics snapshots"
             if metric_paths else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
