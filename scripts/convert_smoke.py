#!/usr/bin/env python
"""convert-smoke: HF fixture -> chunk files -> engine -> greedy decode.

Tier-1 gate for the checkpoint-ingest path (scripts/tier1.sh /
`make convert-smoke`): writes a synthetic qwen3-family safetensors
fixture, converts it to storage-chunk files at (pp=2, v=2) — the
interleaved layout, so the storage-order contract is exercised — loads
it into the serving engine via ``EngineSession.load_params``, and
asserts the greedy continuation is bit-identical to the direct
in-memory load (``hf_to_params``).  A second engine built with
``weight_dtype="int8"`` + paged ``kv_dtype="int8"`` loads the SAME
checkpoint and must track the fp32 continuation (match-rate gate) —
the quantized serving path stays wired end to end.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.checkpoint import convert as cv                    # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models import spec as spec_lib                     # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

PP, V, STEPS = 2, 2, 4
BATCH, PREFILL, CACHE = 4, 8, 64

blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
               for _ in range(PP * V))
spec = spec_lib.ModelSpec(
    name="convert-smoke", d_model=64, n_layers=PP * V, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
    norm="rmsnorm", act="silu", qk_norm=True)

tmp = tempfile.mkdtemp(prefix="convert_smoke_")
fixture = os.path.join(tmp, "model.safetensors")
tensors = cv.make_synthetic_checkpoint(fixture, spec, seed=13)
ck = os.path.join(tmp, "ck")
manifest = cv.convert(fixture, ck, spec, pp=PP, virtual_stages=V)
assert manifest["storage_order"] == cv.storage_order(PP, V)

params_conv, _ = cv.load_converted(ck, spec)
params_direct = cv.hf_to_params(tensors, spec, pp=PP, virtual_stages=V)
jax.tree.map(np.testing.assert_array_equal, params_conv, params_direct)

mesh = make_host_mesh(data=1, model=PP)
dmesh = split_model_axis(mesh, PP, 1)
plan = ParallelismPlan(pp=PP, tp=1, microbatches=4, decode_microbatches=4,
                       schedule="serve_interleaved", virtual_stages=V)
start_tokens = np.asarray(jax.random.randint(
    jax.random.key(1), (BATCH, PREFILL), 1, spec.vocab, jnp.int32))


def run(sess, params):
    sess.start(jax.random.key(0))
    sess.load_params(params)
    tk = jnp.asarray(start_tokens.reshape(
        sess.prefill_specs["tokens"].shape))
    toks = [np.asarray(sess.prefill({"tokens": tk}))]
    for _ in range(STEPS):
        toks.append(np.asarray(sess.decode(jnp.asarray(toks[-1]))))
    return np.stack(toks)

sess = build_serving(spec, plan, dmesh, cache_len=CACHE,
                     global_batch=BATCH, prefill_len=PREFILL,
                     compute_dtype=jnp.float32)
got_conv = run(sess, params_conv)
got_direct = run(sess, params_direct)
np.testing.assert_array_equal(got_conv, got_direct)
print(f"convert-smoke: converted == direct over {STEPS + 1} greedy "
      f"tokens x {BATCH} rows (pp={PP}, v={V})")

sess_q = build_serving(spec, plan, dmesh, cache_len=CACHE,
                       global_batch=BATCH, prefill_len=PREFILL,
                       compute_dtype=jnp.float32, page_size=16,
                       weight_dtype="int8", kv_dtype="int8")
got_q = run(sess_q, params_conv)
match = float(np.mean(got_q == got_conv))
assert match >= 0.7, f"int8 greedy match rate {match} < 0.7"
print(f"convert-smoke: int8 weights + int8 paged KV match rate "
      f"{match:.3f} (>= 0.7) OK")
