#!/usr/bin/env python
"""docs-check: every code reference in docs/*.md must resolve.

CI gate (scripts/tier1.sh / `make docs-check`) against documentation
rot: scans `docs/*.md` and `README.md` for

  * symbol references — ``path/to/file.py::Symbol`` (optionally
    ``::Class.method``): the file must exist and the symbol must be
    defined in it (``def``/``class``, a module-level assignment, a
    dataclass field, or a quoted registry key);
  * bare path references — `` `path/to/file.py` `` (also .sh/.md/.ini):
    the file must exist.

Paths resolve relative to the repo root, with `src/repro/` tried as a
fallback prefix so docs can say ``core/schedule.py`` the way the code
comments do.  Renamed or deleted symbols fail fast, pointing at the doc
line that went stale.

Exit status: 0 clean, 1 with a listing of every unresolved reference.
"""
import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SYM_RE = re.compile(r"([A-Za-z0-9_./-]+\.(?:py|sh))::([A-Za-z0-9_.]+)")
PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|sh|md|ini))`")


def resolve(path: str):
    """Repo-relative path, trying the src/repro/ prefix as a fallback."""
    for cand in (path, os.path.join("src", "repro", path)):
        full = os.path.join(ROOT, cand)
        if os.path.isfile(full):
            return full
    return None


def _names(nodes):
    """Def/class names and assignment targets of one statement list."""
    out = set()
    for node in nodes:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                out.update(e.id for e in elts if isinstance(e, ast.Name))
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def _module_scopes(source: str):
    """(module names, {class: (members, bases)}, dict-literal keys)."""
    tree = ast.parse(source)
    classes = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            bases = {b.id for b in node.bases if isinstance(b, ast.Name)}
            classes[node.name] = (_names(node.body), bases)
    dict_keys = {k.value for node in ast.walk(tree)
                 if isinstance(node, ast.Dict) for k in node.keys
                 if isinstance(k, ast.Constant) and isinstance(k.value, str)}
    return _names(tree.body), classes, dict_keys


def _class_member(classes, cls: str, member: str) -> bool:
    """Member defined on the class or (module-locally) inherited."""
    seen = set()
    stack = [cls]
    while stack:
        c = stack.pop()
        if c in seen or c not in classes:
            continue
        seen.add(c)
        members, bases = classes[c]
        if member in members:
            return True
        stack.extend(bases)
    return False


def symbol_defined(source: str, symbol: str) -> bool:
    """True when the reference resolves to a real definition.

    Scoping comes from the AST, so function-local variables never
    satisfy a reference.  ``Class.member`` requires the member to be
    defined on that class (or a base class in the same module) — a
    method renamed on the class fails even if the name survives
    elsewhere in the file.  Bare symbols accept a module-level
    def/class/assignment, a member of any class, or a dict-literal key
    (registry names like ``SCHEDULES["interleaved_async"]``) — NOT an
    arbitrary quoted string, so a renamed key is not shielded by stale
    mentions in error messages.
    """
    top, classes, dict_keys = _module_scopes(source)
    parts = symbol.split(".")
    if len(parts) == 2:
        return _class_member(classes, parts[0], parts[1])
    return (symbol in top or symbol in dict_keys
            or any(symbol in members for members, _ in classes.values()))


def check_file(md_path: str):
    failures = []
    rel = os.path.relpath(md_path, ROOT)
    with open(md_path) as f:
        lines = f.read().splitlines()
    for ln, line in enumerate(lines, 1):
        seen_spans = []
        for m in SYM_RE.finditer(line):
            seen_spans.append(m.span(1))
            path, sym = m.group(1), m.group(2)
            full = resolve(path)
            if full is None:
                failures.append(f"{rel}:{ln}: no such file: {path}")
                continue
            if full.endswith(".py"):
                with open(full) as src:
                    if not symbol_defined(src.read(), sym):
                        failures.append(
                            f"{rel}:{ln}: {path} has no symbol {sym!r}")
        for m in PATH_RE.finditer(line):
            if any(a <= m.start(1) < b for a, b in seen_spans):
                continue        # already checked as a ::symbol ref
            if resolve(m.group(1)) is None:
                failures.append(
                    f"{rel}:{ln}: no such file: {m.group(1)}")
    return failures


def main() -> int:
    docs_dir = os.path.join(ROOT, "docs")
    targets = [os.path.join(ROOT, "README.md")]
    if os.path.isdir(docs_dir):
        targets += sorted(os.path.join(docs_dir, f)
                          for f in os.listdir(docs_dir)
                          if f.endswith(".md"))
    targets = [t for t in targets if os.path.isfile(t)]
    assert targets, "docs-check found nothing to check"
    failures = []
    n_refs = 0
    for t in targets:
        with open(t) as f:
            text = f.read()
        n_refs += len(SYM_RE.findall(text)) + len(PATH_RE.findall(text))
        failures.extend(check_file(t))
    if failures:
        print("DOCS CHECK FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"docs check OK ({len(targets)} files, {n_refs} references)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
