#!/usr/bin/env python
"""obs-smoke: the observability subsystem's tier-1 gate (numpy-only).

Drives ``repro.obs`` end to end on the analytic clock — no jax, no
devices, sub-second — and asserts the invariants the subsystem is built
on:

  * the emitted Chrome trace-event JSON is schema-valid (only M/X
    events, per-stage tid tracks named by metadata, spans carrying
    (kind, round, tick, stage, phase[, bucket, microbatch, chunk])
    args, ts monotone per track, ticks monotone per round);
  * per-stage non-bubble span counts equal the schedule table's
    non-bubble cells, full-R and for every bucketed variant;
  * measured-vs-predicted reconciliation has its fixed point: rounds
    timed on a modeled clock that charges exactly
    ``weighted_round_time`` seconds reconcile at round ratio 1.0, and
    the span-measured bubble fraction equals the table's weighted
    bubble prediction;
  * bucketed rounds tag their spans with the ``pick_bucket`` choice and
    count into ``bucket_rounds_total`` consistently with the trace;
  * the registry snapshot passes
    scripts/bench_check.py::check_metrics_snapshot and survives a JSON
    round-trip (no NaN leaks).

Wired into scripts/tier1.sh and ``make obs-smoke``.
"""
import json
import os
import sys
import tempfile

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)                        # scripts.bench_check
sys.path.insert(0, os.path.join(ROOT, "src"))   # repro.*

from repro.core.schedule import (F_MB, SCHEDULES, bucket_lattice,  # noqa: E402
                                 pick_bucket, weighted_round_time)
from repro.obs import Observability, reconcile  # noqa: E402
from scripts.bench_check import check_metrics_snapshot  # noqa: E402

S, R = 2, 4
TF = np.array([1.0e-3, 2.0e-3])    # per-stage forward seconds (stage 1
#                                    deliberately 2x: non-trivial bubble)


class ModeledClock:
    """Advancing analytic clock: the engine 'runs' by adding seconds."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def nonbubble_cells(sched):
    """Per-stage non-bubble forward cells of one table walk."""
    return (np.asarray(sched.tables().fwd)[:, :, F_MB] >= 0).sum(axis=0)


def run_rounds(obs, clock, sched, n_rounds, *, bucket=None):
    """Model ``n_rounds`` decode rounds, each costing exactly the
    weighted_round_time prediction on the modeled clock."""
    cost, _ = weighted_round_time(sched, TF, 0.0)
    for _ in range(n_rounds):
        t0 = clock()
        clock.advance(cost)
        obs.on_round("decode", sched, t0, clock(), bucket=bucket,
                     t_fwd=TF, t_bwd=0.0)


def check_trace_schema(trace):
    """Structural validity of the Chrome trace-event output."""
    doc = trace.to_json()
    doc = json.loads(json.dumps(doc))           # must survive round-trip
    events = doc["traceEvents"]
    assert events, "trace has no events"
    named = set()
    last_ts = {}
    last_tick = {}
    for e in events:
        assert e["ph"] in ("M", "X"), e
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                named.add(e["tid"])
            continue
        tid, args = e["tid"], e["args"]
        assert tid in named, f"span on unnamed track {tid}"
        assert e["ts"] >= 0 and e["dur"] >= 0, e
        assert args["stage"] == tid, (
            f"span for stage {args['stage']} landed on track {tid}")
        assert args["phase"] in ("F", "B", "bubble"), e
        if args["phase"] != "bubble":
            assert args["microbatch"] >= 0 and args["chunk"] >= 0, e
        # ts monotone per track; ticks monotone within a round per track
        assert e["ts"] >= last_ts.get(tid, 0.0) - 1e-9, (
            f"track {tid} time went backwards at {e}")
        last_ts[tid] = e["ts"]
        key = (tid, args["kind"], args["round"])
        assert args["tick"] >= last_tick.get(key, 0), (
            f"ticks not monotone within round on track {tid}: {e}")
        last_tick[key] = args["tick"]


def main():
    # ---- full-R rounds: span counts + exact reconciliation fixed point
    sched = SCHEDULES["serve_1f"](S, R)
    sched.validate()
    clock = ModeledClock()
    obs = Observability(trace=True, clock=clock)
    n_rounds = 5
    run_rounds(obs, clock, sched, n_rounds)
    check_trace_schema(obs.trace)

    counts = obs.trace.span_counts("decode")
    want = nonbubble_cells(sched) * n_rounds
    assert [counts.get(s, 0) for s in range(S)] == want.tolist(), (
        f"per-stage span counts {counts} != table non-bubble cells "
        f"{want.tolist()}")

    rep = reconcile(sched, trace=obs.trace, registry=obs.registry,
                    kind="decode", t_fwd=TF)
    assert rep.rounds == n_rounds, rep
    assert abs(rep.round_ratio - 1.0) < 1e-9, (
        f"analytic round ratio should be exactly 1.0, got "
        f"{rep.round_ratio}")
    assert abs(rep.measured_bubble - rep.predicted_bubble) < 1e-9, (
        f"span-measured bubble {rep.measured_bubble} != weighted "
        f"prediction {rep.predicted_bubble}")
    assert rep.predicted_bubble > 0, "smoke config should have a bubble"
    print(f"obs-smoke: full-R {rep}")

    # ---- bucketed rounds: pick_bucket tags agree between trace,
    #      bucket log, and the registry's bucket_rounds_total series
    lattice = bucket_lattice(R)
    liveness = [4, 3, 2, 1, 2, 4]
    picked = [pick_bucket(n, lattice) for n in liveness]
    base = len(obs.trace.rounds)
    for n_live, b in zip(liveness, picked):
        sb = sched.bucketed(b)
        run_rounds(obs, clock, sb, 1, bucket=b)
        rec = obs.trace.rounds[-1]
        assert rec.bucket == b and rec.n_spans == nonbubble_cells(sb).sum()
    check_trace_schema(obs.trace)
    traced = [r.bucket for r in obs.trace.rounds[base:]]
    assert traced == picked, (traced, picked)
    ctr = obs.registry.counter("bucket_rounds_total")
    for b in set(picked):
        assert ctr.value(kind="decode", bucket=b) == picked.count(b), (
            b, ctr.value(kind="decode", bucket=b))
    # bucket= span tags match the picked bucket per round
    by_round = {}
    for e in obs.trace.to_json()["traceEvents"]:
        if e["ph"] == "X" and "bucket" in e["args"]:
            by_round.setdefault(e["args"]["round"], set()).add(
                e["args"]["bucket"])
        assert "bucket" not in e.get("args", {}) or e["ph"] == "X"
    assert all(len(v) == 1 for v in by_round.values())
    assert [next(iter(by_round[base + i])) for i in
            range(len(picked))] == picked
    print(f"obs-smoke: bucketed rounds {picked} traced + counted OK")

    # ---- artifacts: trace file + metrics snapshot schema
    with tempfile.TemporaryDirectory() as tmp:
        tr, mt = (os.path.join(tmp, "trace.json"),
                  os.path.join(tmp, "metrics.json"))
        obs.save(trace_out=tr, metrics_out=mt)
        with open(tr) as f:
            assert json.load(f)["traceEvents"]
        with open(mt) as f:
            snap = json.load(f)
        failures = check_metrics_snapshot(snap, "metrics.json")
        assert not failures, failures
    n_hist = len(snap["histograms"])
    print(f"obs-smoke OK: {len(obs.trace.rounds)} rounds, "
          f"{len(obs.trace.events)} trace events, "
          f"{len(snap['counters'])} counter / {n_hist} histogram series")
    return 0


if __name__ == "__main__":
    sys.exit(main())
