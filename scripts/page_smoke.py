#!/usr/bin/env python
"""page-smoke: paged KV allocator + undersized-pool serving (CI gate).

Two fast proofs for the paged KV cache (ISSUE 6):

  * a randomized allocator fuzz: a few hundred alloc/extend/release
    ops against :class:`repro.serving.batcher.PageAllocator` with a
    shadow model, running ``check()`` (partition + no-double-booking +
    counts == ceil(tokens/page)) after every op — freed pages are
    reused, failed allocations leak nothing;
  * an end-to-end run with a pool sized BELOW dense-capacity parity
    (``pool_pages=1`` for 2 slots): the continuous batcher must queue
    the second request until the first drains and releases its page —
    pool exhaustion is backpressure, never a crash — and the squeezed
    run's tokens must still be bit-identical (fp32) to the same trace
    through a dense session.

Run via ``make page-smoke`` (wired into scripts/tier1.sh).
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np        # noqa: E402

from repro.serving.batcher import PageAllocator  # noqa: E402

PAGE = 16


def fuzz_allocator(steps=400, seed=0) -> None:
    rng = np.random.default_rng(seed)
    a = PageAllocator(pool_pages=9, n_slots=4, max_pages=4,
                      page_size=PAGE)
    tokens = {}                                  # shadow: slot -> tokens
    for _ in range(steps):
        s = int(rng.integers(0, 4))
        op = rng.choice(["alloc", "extend", "release"])
        try:
            if op == "alloc":
                n = int(rng.integers(1, 4 * PAGE + 16))   # may exceed cap
                try:
                    a.alloc_slot(s, n)
                    tokens[s] = n
                except RuntimeError:             # pool dry: slot released
                    tokens.pop(s, None)
                    raise
            elif op == "extend" and s in tokens:
                n = min(tokens[s] + int(rng.integers(1, PAGE + 1)),
                        4 * PAGE)
                a.extend_slot(s, n)              # dry: slot keeps old pages
                tokens[s] = n
            elif op == "release":
                a.release_slot(s)
                tokens.pop(s, None)
        except (ValueError, RuntimeError):
            pass                   # over capacity / pool dry: both loud,
            #                        neither may corrupt the free list
        a.check()
        want = sum(-(-n // PAGE) for n in tokens.values())
        assert a.live_pages == want, (a.live_pages, want, tokens)
    for s in list(tokens):
        a.release_slot(s)
    a.check()
    assert a.live_pages == 0 and a.free_pages == 9
    print(f"page smoke: allocator fuzz OK ({steps} ops, invariants held)")


def squeezed_pool() -> int:
    import jax
    import jax.numpy as jnp
    from repro.models import spec as spec_lib
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.mesh import ParallelismPlan, split_model_axis
    from repro.serving.batcher import ContinuousBatchingSession, Request
    from repro.serving.engine import build_serving

    PP, R, PREFILL, CACHE = 2, 2, 8, 32

    def session(page_size=0, pool_pages=None):
        blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                       for _ in range(PP * 2))
        spec = spec_lib.ModelSpec(
            name="page-smoke", d_model=64, n_layers=len(blocks), n_heads=4,
            n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
            norm="rmsnorm", act="silu")
        mesh = make_host_mesh(data=1, model=PP)
        dmesh = split_model_axis(mesh, PP, 1)
        plan = ParallelismPlan(pp=PP, tp=1, microbatches=R,
                               decode_microbatches=R, schedule="auto")
        sess = build_serving(spec, plan, dmesh, cache_len=CACHE,
                             global_batch=R, prefill_len=PREFILL,
                             compute_dtype=jnp.float32,
                             page_size=page_size, pool_pages=pool_pages)
        sess.start(jax.random.key(0))
        return sess

    rng = np.random.default_rng(3)
    # 8-token prompts + up to 6 new tokens stay inside one 16-token page
    def trace():
        return [Request(rid=i,
                        prompt=rng.integers(1, 256, PREFILL)
                                  .astype(np.int32),
                        max_new_tokens=n, arrival=0)
                for i, n in enumerate((4, 6))]
    rng = np.random.default_rng(3)
    squeezed = trace()
    sess = session(page_size=PAGE, pool_pages=1)   # 1 page for 2 slots
    report = ContinuousBatchingSession(sess).run(squeezed)
    assert len(report.completed) == 2, report.summary()
    # the pool admits one request at a time: request 1 must wait for
    # request 0 to drain and release its page
    assert squeezed[1].step_admitted > squeezed[0].step_done, (
        squeezed[1].step_admitted, squeezed[0].step_done)
    sess._alloc.check()
    assert sess._alloc.live_pages == 0

    rng = np.random.default_rng(3)
    dense = trace()
    ContinuousBatchingSession(session()).run(dense)
    for d, s in zip(dense, squeezed):
        assert d.tokens == s.tokens, (
            f"request {d.rid}: dense {d.tokens} != squeezed {s.tokens}")
    print("page smoke: 1-page pool queued request 1 behind request 0 "
          "(exhaustion = backpressure), tokens bit-exact vs dense")
    return 0


def main() -> int:
    fuzz_allocator()
    rc = squeezed_pool()
    if rc == 0:
        print("page smoke OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())
