#!/usr/bin/env python
"""Fast plan_search smoke: qwen3 + olmoe under the real v5e HBM budget.

CI gate (scripts/tier1.sh / `make plan-smoke`): runs the schedule-aware
planner on the two reference configs at the production shape and FAILS if

  * the chosen plan's MemoryModel exceeds the hardware HBM budget (a
    planner that picks a plan that cannot fit is broken), or
  * the hand-written config plan itself no longer fits its budget (a
    config regression), or
  * the planner stops preferring interleaved where the simulator says
    the round is shorter (S >= 3, v >= 2 on an otherwise-equal split).

Pure analytic path — no jax, finishes in well under a second.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs                                  # noqa: E402
from repro.core import profiler as prof                    # noqa: E402
from repro.core.partitioner import plan_search             # noqa: E402

ARCHS = ("qwen3_14b", "olmoe_1b_7b")
SHAPE = configs.SHAPES["train_4k"]
HW = prof.TPU_V5E
DATA = 16                       # production mesh: 16 data × 16 model


def main() -> int:
    failures = []
    for arch in ARCHS:
        cfg = configs.get(arch)
        spec, plan = cfg.full_spec(), cfg.PLAN
        mb_tokens = SHAPE.seq_len * max(
            SHAPE.global_batch // DATA // plan.microbatches, 1)
        cands = plan_search(spec, plan, plan.pp * plan.tp, HW,
                            minibatch_tokens=mb_tokens, data_replicas=DATA,
                            return_all=True)
        best = next((c for c in cands if c.feasible), None)
        print(f"== {arch} (budget {HW.hbm_bytes / 1e9:.0f} GB, "
              f"{len(cands)} candidates)")
        for c in cands[:4]:
            print(f"   {c.describe()}")
        if best is None:
            failures.append(f"{arch}: no candidate fits the HBM budget")
            continue
        if not best.memory.fits(HW.hbm_bytes):
            failures.append(f"{arch}: chosen plan over budget: "
                            f"{best.describe()}")
        print(f"   chosen: {best.describe()}")
        # the config's own hand-written plan must also fit
        mm = plan.make_schedule().memory_model(
            spec, plan, HW, microbatch_tokens=mb_tokens, data_replicas=DATA)
        if not mm.fits(HW.hbm_bytes):
            failures.append(f"{arch}: config PLAN over budget: {mm}")
        # schedule-aware objective sanity: at S >= 3 the best interleaved
        # candidate beats the best plain 1f1b one when both exist
        deep_i = [c for c in cands
                  if c.plan.schedule == "interleaved" and c.plan.pp >= 3]
        deep_p = [c for c in cands
                  if c.plan.schedule == "1f1b"
                  and any(c.plan.pp == i.plan.pp for i in deep_i)]
        if deep_i and deep_p and (min(c.round_time for c in deep_i)
                                  >= min(c.round_time for c in deep_p)):
            failures.append(f"{arch}: interleaved no longer beats 1f1b at "
                            f"S >= 3")
    if failures:
        print("\nPLAN SMOKE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nplan smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
