#!/usr/bin/env python
"""serve-smoke: both serving schedules end-to-end on CPU (CI gate).

Drives a tiny dense LM through the full EngineSession surface on 2
host devices — ``serve_1f`` and ``serve_interleaved`` (v = 2) each run
``session.prefill`` plus 4 ``session.decode`` steps — and fails unless
the two schedules' greedy continuations are bit-identical (fp32) and
well-formed.  This is the cheapest end-to-end proof that the serving
engine, the serve schedule tables, and the chunk-major state layout
agree; the full matrix (S = 4, TP, sequence-parallel decode) lives in
tests/test_serving_interleaved.py.

Run via ``make serve-smoke`` (wired into scripts/tier1.sh).
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.models import spec as spec_lib                     # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

PP, V, PREFILL, STEPS, CACHE, BATCH = 2, 2, 8, 4, 32, 4


def main() -> int:
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(PP * V * 2))
    spec = spec_lib.ModelSpec(
        name="serve-smoke", d_model=64, n_layers=PP * V * 2, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu")
    mesh = make_host_mesh(data=1, model=PP)
    dmesh = split_model_axis(mesh, PP, 1)
    start = np.asarray(jax.random.randint(
        jax.random.key(1), (BATCH, PREFILL), 1, spec.vocab, jnp.int32))

    outs = {}
    for name, v in (("serve_1f", 1), ("serve_interleaved", V)):
        plan = ParallelismPlan(pp=PP, tp=1, microbatches=2,
                               decode_microbatches=2,
                               schedule=name if v > 1 else "auto",
                               virtual_stages=v)
        session = build_serving(spec, plan, dmesh, cache_len=CACHE,
                                global_batch=BATCH, prefill_len=PREFILL,
                                compute_dtype=jnp.float32)
        sched = session.sched
        assert sched.name == name, (sched.name, name)
        print(f"== {name}: S={sched.n_stages} R={sched.n_microbatches} "
              f"v={sched.virtual_stages} ticks={sched.n_ticks}")
        session.start(jax.random.key(0))
        tokens = jnp.asarray(start.reshape(
            session.prefill_specs["tokens"].shape))
        toks = [np.asarray(session.prefill({"tokens": tokens}))]
        for _ in range(STEPS):
            toks.append(np.asarray(session.decode(jnp.asarray(toks[-1]))))
        out = np.stack(toks)
        assert out.shape == (STEPS + 1, BATCH), out.shape
        assert ((out >= 0) & (out < spec.vocab)).all()
        print(f"   tokens[:, 0] = {out[:, 0]}")
        outs[name] = out

    if not np.array_equal(outs["serve_1f"], outs["serve_interleaved"]):
        print("SERVE SMOKE FAILED: serve_interleaved != serve_1f")
        return 1
    print("\nserve smoke OK (interleaved == 1f, bit-exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
