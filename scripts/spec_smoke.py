#!/usr/bin/env python
"""spec-smoke: speculative draft–verify decode end-to-end on CPU (CI gate).

Three bit-exactness proofs over a 2-stage pipe, each against the same
request run through a fresh non-speculative ``serve_1f`` session:

  1. the staggered 3-request continuous-batching trace of batch_smoke,
     served by the SELF-drafting spec session (head-only ``draft()`` +
     pipelined ``verify()``), dense and paged — mid-stream admission
     into a freed slot must not perturb any stream, and the paged run
     must hand every page back;
  2. the same staggered trace with an INJECTED oracle draft function
     that gives each resident request a different draft quality — one
     slot totally rejected every round, one fully accepted every round,
     one partially accepted with a per-round varying prefix — so
     per-slot acceptance, rejected-suffix rollback, and the bonus-token
     floor are all exercised in one batch;
  3. the down-then-up bucket trace of batch_smoke over R = 4 slots with
     ``buckets=True``: evictions must shrink the verify bucket,
     the late admission must grow it back, and every stream must match
     the full-R spec run and the solo session (dense and paged).

Greedy speculative decode is exact by construction — any draft quality
only changes how many rounds the same tokens take.  Run via
``make spec-smoke`` (wired into scripts/tier1.sh).
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.models import spec as spec_lib                     # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.batcher import ContinuousBatchingSession, Request  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

PP, R, PREFILL, CACHE, VOCAB = 2, 2, 8, 64, 256
K = 3


def make_session(schedule="auto", spec_k=None, page_size=0, n_slots=R,
                 buckets=False):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(PP * 2))
    spec = spec_lib.ModelSpec(
        name="spec-smoke", d_model=64, n_layers=len(blocks), n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=VOCAB, blocks=blocks,
        norm="rmsnorm", act="silu")
    mesh = make_host_mesh(data=1, model=PP)
    dmesh = split_model_axis(mesh, PP, 1)
    plan = ParallelismPlan(pp=PP, tp=1, microbatches=n_slots,
                           decode_microbatches=n_slots, schedule=schedule)
    return spec, build_serving(spec, plan, dmesh, cache_len=CACHE,
                               global_batch=n_slots, prefill_len=PREFILL,
                               compute_dtype=jnp.float32,
                               page_size=page_size, buckets=buckets,
                               spec_k=spec_k)


def solo_tokens(prompt, n_tokens, n_slots=R):
    """The request alone through a fresh one-shot serve_1f session."""
    _, sess = make_session(n_slots=n_slots)
    sess.start(jax.random.key(0))
    tokens = jnp.asarray(np.broadcast_to(prompt, (n_slots, 1, PREFILL)))
    toks = [np.asarray(sess.prefill({"tokens": tokens}))[0]]
    for _ in range(n_tokens - 1):
        last = jnp.asarray(np.full((n_slots,), toks[-1], np.int32))
        toks.append(np.asarray(sess.decode(last))[0])
    return [int(t) for t in toks]


def staggered_trace(prompts):
    return [
        Request(rid=0, prompt=prompts[0], max_new_tokens=3, arrival=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=10, arrival=0),
        Request(rid=2, prompt=prompts[2], max_new_tokens=6, arrival=1),
    ]


def self_draft_main() -> int:
    """Staggered trace, self-drafting spec session, dense + paged."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, VOCAB, PREFILL).astype(np.int32)
               for _ in range(3)]
    solos = {i: solo_tokens(p, staggered_trace(prompts)[i].max_new_tokens)
             for i, p in enumerate(prompts)}
    ok = True
    for label, kw in (("dense", {}), ("paged", {"page_size": 16})):
        trace = staggered_trace(prompts)
        _, sess = make_session(schedule="serve_spec_1f", spec_k=K, **kw)
        sess.start(jax.random.key(0))
        report = ContinuousBatchingSession(sess).run(trace)
        assert len(report.completed) == 3, (label, report.summary())
        assert trace[2].step_admitted > trace[0].step_done, (
            "request 2 must admit mid-stream into request 0's freed slot")
        assert report.spec_rounds == report.decode_rounds > 0, (
            label, report.summary())
        # every token a request keeps came from an accepted verify
        # column (the admission round contributes exactly one each)
        assert report.accepted_tokens == report.completed_tokens - 3, (
            label, report.summary())
        for r in trace:
            mark = "==" if r.tokens == solos[r.rid] else "!="
            print(f"  [{label}] request {r.rid}: spec {r.tokens} {mark} "
                  f"solo {solos[r.rid]}")
            ok &= r.tokens == solos[r.rid]
        print(f"  [{label}] spec_rounds={report.spec_rounds} "
              f"acc_rate={report.acceptance_rate:.2f} "
              f"tok/round={report.accepted_per_round:.2f}")
        if kw.get("page_size"):
            sess._alloc.check()
            assert sess._alloc.live_pages == 0, sess._alloc.tables
    if not ok:
        print("SPEC SMOKE FAILED: self-drafted decode is not bit-exact")
        return 1
    print("spec smoke OK (staggered trace, self-draft, dense + paged "
          "bit-exact vs solo)\n")
    return mixed_main()


def oracle_draft_fn(server, refs, modes, spec_k):
    """Per-request draft quality injection.

    ``refs[rid]`` is the request's true greedy stream (solo run, padded
    ``spec_k`` past max_new_tokens); each lane's next true tokens are
    ``refs[rid][len(r.tokens):]``.  ``modes[rid]``: ``"reject"`` drafts
    are wrong at every position (``+1 mod vocab`` of the truth),
    ``"accept"`` drafts are the truth, ``"mixed"`` drafts are correct
    for a prefix that cycles 0..spec_k-1 across rounds.
    """
    state = {"round": 0}

    def draft(last):
        flat = np.asarray(last).reshape(-1)
        out = np.ones((flat.size, spec_k), np.int32)
        for s in server.slots:
            for lane, r in enumerate(s.requests):
                if r is None:
                    continue
                i = len(r.tokens)
                true = np.asarray(refs[r.rid][i:i + spec_k], np.int32)
                mode = modes[r.rid]
                if mode == "reject":
                    d = (true + 1) % VOCAB
                elif mode == "accept":
                    d = true
                else:
                    n_ok = state["round"] % spec_k
                    d = np.where(np.arange(spec_k) < n_ok, true,
                                 (true + 1) % VOCAB)
                out[s.index * s.lanes + lane] = d
        state["round"] += 1
        return out

    return draft


def mixed_main() -> int:
    """One batch, three draft qualities: reject-all / accept-all / mixed."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, VOCAB, PREFILL).astype(np.int32)
               for _ in range(3)]
    trace = [
        Request(rid=0, prompt=prompts[0], max_new_tokens=8, arrival=0),
        Request(rid=1, prompt=prompts[1], max_new_tokens=8, arrival=0),
        Request(rid=2, prompt=prompts[2], max_new_tokens=6, arrival=1),
    ]
    modes = {0: "reject", 1: "accept", 2: "mixed"}
    refs = {r.rid: solo_tokens(r.prompt, r.max_new_tokens + K)
            for r in trace}
    _, sess = make_session(schedule="serve_spec_1f", spec_k=K)
    sess.start(jax.random.key(0))
    server = ContinuousBatchingSession(sess)
    server.draft_fn = oracle_draft_fn(server, refs, modes, K)
    report = server.run(trace)
    assert len(report.completed) == 3, report.summary()
    ok = True
    for r in trace:
        want = refs[r.rid][:r.max_new_tokens]
        mark = "==" if r.tokens == want else "!="
        print(f"  [{modes[r.rid]:>6}] request {r.rid}: {r.tokens} {mark} "
              f"solo {want}")
        ok &= r.tokens == want
    # the reject-all slot advances one bonus token per round, the
    # accept-all slot spec_k + 1 — same output length, ~4x the rounds
    rounds = {r.rid: r.step_done - r.step_admitted for r in trace}
    assert rounds[0] > 2 * rounds[1], rounds
    assert 0.0 < report.acceptance_rate < 1.0, report.summary()
    if not ok:
        print("SPEC SMOKE FAILED: injected-draft decode is not bit-exact")
        return 1
    print(f"spec smoke OK (mixed draft quality in one batch: reject-all "
          f"took {rounds[0]} rounds vs accept-all {rounds[1]}, batch "
          f"acc_rate={report.acceptance_rate:.2f}, all bit-exact)\n")
    return bucket_main()


def bucket_main() -> int:
    """Mid-stream bucket switches under verify, bit-exact vs full-R."""
    R4 = 4
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, VOCAB, PREFILL).astype(np.int32)
               for _ in range(5)]

    def trace():
        return [
            Request(rid=0, prompt=prompts[0], max_new_tokens=3, arrival=0),
            Request(rid=1, prompt=prompts[1], max_new_tokens=3, arrival=0),
            Request(rid=2, prompt=prompts[2], max_new_tokens=24, arrival=0),
            Request(rid=3, prompt=prompts[3], max_new_tokens=24, arrival=0),
            Request(rid=4, prompt=prompts[4], max_new_tokens=4, arrival=3),
        ]

    runs = {}
    for name, kw in (("full_R", {}),
                     ("bucketed", {"buckets": True}),
                     ("bucketed_paged", {"buckets": True, "page_size": 16})):
        t = trace()
        _, sess = make_session(schedule="serve_spec_1f", spec_k=K,
                               n_slots=R4, **kw)
        sess.start(jax.random.key(0))
        report = ContinuousBatchingSession(sess).run(t)
        assert len(report.completed) == 5, (name, report.summary())
        assert report.spec_rounds > 0, (name, report.summary())
        runs[name] = t
        if kw.get("buckets"):
            log = sess._bucket_log
            shrank = any(b2 < b1 for b1, b2 in zip(log, log[1:]))
            grew = any(b2 > b1 for b1, b2 in zip(log, log[1:]))
            assert len(set(log)) >= 2 and shrank and grew, (
                f"{name}: trace must switch buckets both ways, log={log}")
            print(f"  {name} bucket log: {log}")
        if kw.get("page_size"):
            sess._alloc.check()
            assert sess._alloc.live_pages == 0, sess._alloc.tables
    ok = True
    for r_full, r_bkt, r_pg in zip(runs["full_R"], runs["bucketed"],
                                   runs["bucketed_paged"]):
        solo = solo_tokens(r_full.prompt, r_full.max_new_tokens,
                           n_slots=R4)
        same = (r_full.tokens == r_bkt.tokens == r_pg.tokens == solo)
        mark = "==" if same else "!="
        print(f"  request {r_full.rid}: full-R {r_full.tokens} {mark} "
              f"bucketed {r_bkt.tokens} (paged {r_pg.tokens}, "
              f"solo {solo})")
        ok &= same
    if not ok:
        print("SPEC SMOKE FAILED: verify bucket switches are not bit-exact")
        return 1
    print("\nspec smoke OK (verify bucket shrink/grow mid-stream, "
          "bit-exact vs full-R and solo, dense + paged)")
    return 0


if __name__ == "__main__":
    sys.exit(self_draft_main())
