#!/usr/bin/env bash
# Tier-1 verify (ROADMAP): fast default selection, bounded time.
#   scripts/tier1.sh            # fast set (pytest.ini deselects -m slow)
#   scripts/tier1.sh --full     # everything, including the slow SPMD matrix
# Both variants first run the plan_search smoke (scripts/plan_smoke.py)
# — the chosen plan for qwen3 + olmoe must fit the config's HBM budget —
# the serve smoke (scripts/serve_smoke.py): both serving schedules
# through EngineSession.prefill + 4 decode steps, bit-identical —
# the batch smoke (scripts/batch_smoke.py): a staggered 3-request trace
# through the continuous-batching slot scheduler, every request
# bit-identical to its solo run —
# the page smoke (scripts/page_smoke.py): paged-KV allocator invariant
# fuzz plus an undersized-pool run where exhaustion queues admissions
# instead of crashing — the docs-check gate
# (scripts/docs_check.py): every `path.py::symbol` reference in
# docs/*.md + README.md must resolve against the source tree, so
# renamed symbols fail fast — and the bench-check gate
# (scripts/bench_check.py): every committed BENCH_*.json artifact must
# parse, carry its expected columns and hold only finite numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${1:-}" == "--full" ]]; then
    shift
    ARGS+=(-m "")
fi
python scripts/plan_smoke.py
python scripts/serve_smoke.py
python scripts/batch_smoke.py
python scripts/page_smoke.py
python scripts/docs_check.py
python scripts/bench_check.py
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest "${ARGS[@]}" "$@"
