#!/usr/bin/env bash
# Tier-1 verify (ROADMAP): fast default selection, bounded time.
#   scripts/tier1.sh            # fast set (pytest.ini deselects -m slow)
#   scripts/tier1.sh --full     # everything, including the slow SPMD matrix
#   scripts/tier1.sh --coverage # + pytest-cov over repro.core/serving with
#                               # a COV_FLOOR (default 80) line floor; needs
#                               # pytest-cov (requirements-dev.txt), skipped
#                               # with a notice when not importable
# Both variants first run the plan_search smoke (scripts/plan_smoke.py)
# — the chosen plan for qwen3 + olmoe must fit the config's HBM budget —
# the serve smoke (scripts/serve_smoke.py): both serving schedules
# through EngineSession.prefill + 4 decode steps, bit-identical —
# the batch smoke (scripts/batch_smoke.py): a staggered 3-request trace
# through the continuous-batching slot scheduler, every request
# bit-identical to its solo run —
# the page smoke (scripts/page_smoke.py): paged-KV allocator invariant
# fuzz plus an undersized-pool run where exhaustion queues admissions
# instead of crashing —
# the spec smoke (scripts/spec_smoke.py): speculative draft–verify
# decode (self-draft, injected mixed/total-rejection/full-acceptance
# drafts, verify bucket switches) bit-identical to non-speculative
# decode, dense and paged —
# the convert smoke (scripts/convert_smoke.py): synthetic HF fixture ->
# storage-chunk conversion at (pp=2, v=2) -> engine load_params ->
# greedy decode bit-identical to the direct in-memory load, plus the
# int8-weight/int8-KV engine tracking it —
# the obs smoke (scripts/obs_smoke.py): the observability subsystem on
# the analytic clock — trace JSON schema-valid, per-stage span counts
# equal the table's non-bubble cells, measured-vs-predicted round ratio
# exactly 1.0, bucketed span tags matching pick_bucket, metrics
# snapshot schema-clean — the docs-check gate
# (scripts/docs_check.py): every `path.py::symbol` reference in
# docs/*.md + README.md must resolve against the source tree, so
# renamed symbols fail fast — and the bench-check gate
# (scripts/bench_check.py): every committed BENCH_*.json artifact must
# parse, carry its expected columns and hold only finite numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
COV=0
while [[ "${1:-}" == "--full" || "${1:-}" == "--coverage" ]]; do
    case "$1" in
        --full) ARGS+=(-m "") ;;
        --coverage) COV=1 ;;
    esac
    shift
done
if [[ "$COV" == 1 ]]; then
    # opt-in (make coverage) so the fast default never pays the tracer;
    # pytest-cov is a dev-only extra (requirements-dev.txt) — gate on
    # importability instead of failing environments that lack it
    if python -c "import pytest_cov" 2>/dev/null; then
        ARGS+=(--cov=repro.core --cov=repro.serving
               --cov-report=term-missing:skip-covered
               --cov-fail-under="${COV_FLOOR:-80}")
    else
        echo "tier1: pytest-cov not importable; running without coverage" >&2
    fi
fi
python scripts/plan_smoke.py
python scripts/serve_smoke.py
python scripts/batch_smoke.py
python scripts/page_smoke.py
python scripts/spec_smoke.py
python scripts/convert_smoke.py
python scripts/obs_smoke.py
python scripts/docs_check.py
python scripts/bench_check.py
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest "${ARGS[@]}" "$@"
