#!/usr/bin/env bash
# Tier-1 verify (ROADMAP): fast default selection, bounded time.
#   scripts/tier1.sh            # fast set (pytest.ini deselects -m slow)
#   scripts/tier1.sh --full     # everything, including the slow SPMD matrix
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
if [[ "${1:-}" == "--full" ]]; then
    shift
    ARGS+=(-m "")
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest "${ARGS[@]}" "$@"
