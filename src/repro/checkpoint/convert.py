"""HF safetensors -> storage-chunk checkpoint converter (+ inverse export).

The partitioner can place a model onto any (pp, tp, virtual_stages) plan,
but until now every weight in the repo was *synthesized*.  This module
ingests real HuggingFace-format checkpoints:

  * A **declarative mapping table** per config family (qwen3, olmoe)
    maps each HF tensor name to a path in our parameter tree plus a
    transform ("transpose", head-dim reshapes to our ``(d, h, dh)``
    layouts, vocab padding, per-expert accumulation into the stacked
    ``(E, d, d_expert)`` MoE arrays).
  * ``convert`` streams the safetensors shard(s) **tensor by tensor**
    (never materializing the full model): each tensor is routed to its
    (chunk, position, dest) slot, and a chunk file is flushed to disk the
    moment its last expected tensor arrives.
  * Chunk files are written in **storage order** — file ``chunk_<p>.npz``
    holds model chunk ``(p % v) * pp + p // v`` (the row p = s·v + j of
    the stage-stacked arrays holds model chunk j·S + s, exactly
    ``ScheduleInterleaved1F1B.storage_chunk_order``), so ``load_converted``
    is a pure stack: no permute at load time, for ANY (pp, tp, v) plan.
  * TP is validated at convert time (divisibility of heads / kv heads /
    ffn / experts); the files store full-width tensors and the actual
    split happens when the engine device_puts with its NamedShardings.
  * ``export_checkpoint`` is the inverse path: converted chunks back to
    a single HF-named safetensors file (round-trip golden in
    tests/test_convert.py).

Every failure raises :class:`ConvertError` (a ``ValueError``) naming the
offending key / shapes / axis / file so conversion bugs are diagnosable
from the message alone.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

try:  # baked into the image; gate anyway so import never hard-fails
    from safetensors import safe_open
    from safetensors.numpy import save_file as _st_save
    HAVE_SAFETENSORS = True
except ImportError:  # pragma: no cover
    safe_open = None
    _st_save = None
    HAVE_SAFETENSORS = False

from repro.models import spec as spec_lib
from repro.models.init import padded_vocab

MANIFEST_NAME = "CONVERT_MANIFEST.json"


class ConvertError(ValueError):
    """Typed conversion failure: unknown key, shape mismatch, tp that does
    not divide an axis, or a missing safetensors shard."""


# --------------------------------------------------------------------------
# Storage layout (the schedule-side contract, restated as pure arithmetic)
# --------------------------------------------------------------------------

def storage_order(pp: int, v: int) -> List[int]:
    """Model chunk held by each storage row p = s·v + j (chunk j·pp + s).

    Mirrors ``ScheduleInterleaved1F1B.storage_chunk_order`` — kept as
    plain arithmetic here so the converter does not need a schedule
    object (tests cross-check the two).
    """
    return [(p % v) * pp + p // v for p in range(pp * v)]


# --------------------------------------------------------------------------
# Mapping tables
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    """One HF-name pattern -> tree destination.

    ``pattern`` may bind named groups ``layer`` and ``expert``.
    ``transform`` is one of the registered names below; ``tp_axis`` names
    the logical axis tensor-parallelism splits this leaf over (validated
    for divisibility at convert time).  ``shared=True`` leaves live
    outside the pipeline (embed / head / final norm).
    """

    pattern: str
    dest: Tuple[str, ...]
    transform: str
    tp_axis: Optional[str] = None
    shared: bool = False

    def regex(self) -> "re.Pattern[str]":
        return re.compile(self.pattern + r"\Z")


_L = r"model\.layers\.(?P<layer>\d+)\."

_ATTN_RULES = (
    Rule(_L + r"input_layernorm\.weight", ("norm1", "scale"), "copy"),
    Rule(_L + r"self_attn\.q_proj\.weight", ("attn", "wq"), "qheads",
         "heads"),
    Rule(_L + r"self_attn\.k_proj\.weight", ("attn", "wk"), "kvheads",
         "kv_heads"),
    Rule(_L + r"self_attn\.v_proj\.weight", ("attn", "wv"), "kvheads",
         "kv_heads"),
    Rule(_L + r"self_attn\.o_proj\.weight", ("attn", "wo"), "transpose",
         "heads"),
    Rule(_L + r"self_attn\.q_norm\.weight", ("attn", "q_norm"), "copy"),
    Rule(_L + r"self_attn\.k_norm\.weight", ("attn", "k_norm"), "copy"),
    Rule(_L + r"post_attention_layernorm\.weight", ("norm2", "scale"),
         "copy"),
)

_SHARED_RULES = (
    Rule(r"model\.embed_tokens\.weight", ("embed",), "embed_pad",
         shared=True),
    Rule(r"model\.norm\.weight", ("final_norm", "scale"), "copy",
         shared=True),
    Rule(r"lm_head\.weight", ("head",), "head_pad", shared=True),
)

MAPPINGS: Dict[str, Tuple[Rule, ...]] = {
    "qwen3": _SHARED_RULES + _ATTN_RULES + (
        Rule(_L + r"mlp\.gate_proj\.weight", ("mlp", "w1"), "transpose",
             "ffn"),
        Rule(_L + r"mlp\.up_proj\.weight", ("mlp", "w3"), "transpose",
             "ffn"),
        Rule(_L + r"mlp\.down_proj\.weight", ("mlp", "w2"), "transpose",
             "ffn"),
    ),
    "olmoe": _SHARED_RULES + _ATTN_RULES + (
        Rule(_L + r"mlp\.gate\.weight", ("moe", "router"), "transpose"),
        Rule(_L + r"mlp\.experts\.(?P<expert>\d+)\.gate_proj\.weight",
             ("moe", "w1"), "transpose", "experts"),
        Rule(_L + r"mlp\.experts\.(?P<expert>\d+)\.up_proj\.weight",
             ("moe", "w3"), "transpose", "experts"),
        Rule(_L + r"mlp\.experts\.(?P<expert>\d+)\.down_proj\.weight",
             ("moe", "w2"), "transpose", "experts"),
    ),
}


def family_for(spec: spec_lib.ModelSpec) -> str:
    return "olmoe" if spec.moe is not None else "qwen3"


# --------------------------------------------------------------------------
# Shapes and transforms
# --------------------------------------------------------------------------

def _dest_shape(spec: spec_lib.ModelSpec, dest: Tuple[str, ...]
                ) -> Tuple[int, ...]:
    """Per-layer (no stage dim) shape of a destination leaf."""
    d, h, kv, dh = spec.d_model, spec.n_heads, spec.n_kv, spec.d_head
    vpad = padded_vocab(spec.vocab)
    table = {
        ("embed",): (vpad, d),
        ("head",): (d, vpad),
        ("final_norm", "scale"): (d,),
        ("norm1", "scale"): (d,),
        ("norm2", "scale"): (d,),
        ("attn", "wq"): (d, h, dh),
        ("attn", "wk"): (d, kv, dh),
        ("attn", "wv"): (d, kv, dh),
        ("attn", "wo"): (h * dh, d),
        ("attn", "q_norm"): (dh,),
        ("attn", "k_norm"): (dh,),
        ("mlp", "w1"): (d, spec.d_ff),
        ("mlp", "w3"): (d, spec.d_ff),
        ("mlp", "w2"): (spec.d_ff, d),
    }
    if spec.moe is not None:
        m = spec.moe
        table.update({
            ("moe", "router"): (d, m.n_experts),
            ("moe", "w1"): (m.n_experts, d, m.d_expert),
            ("moe", "w3"): (m.n_experts, d, m.d_expert),
            ("moe", "w2"): (m.n_experts, m.d_expert, d),
        })
    return table[dest]


def _expected_hf_shape(spec, rule: Rule, per_expert: bool
                       ) -> Tuple[int, ...]:
    out = _dest_shape(spec, rule.dest)
    if per_expert:
        out = out[1:]                    # one expert's slice
    if rule.transform == "copy":
        return out
    if rule.transform == "transpose":
        return tuple(reversed(out))
    if rule.transform == "qheads":       # (d, h, dh) <- HF (h*dh, d)
        return (out[1] * out[2], out[0])
    if rule.transform == "kvheads":
        return (out[1] * out[2], out[0])
    if rule.transform in ("embed_pad", "head_pad"):
        return (spec.vocab, spec.d_model)
    raise ConvertError(f"unknown transform {rule.transform!r}")


def _apply_transform(arr: np.ndarray, spec, rule: Rule) -> np.ndarray:
    """HF layout -> our layout (validated shapes; float32 output)."""
    arr = np.asarray(arr, np.float32)
    t = rule.transform
    if t == "copy":
        return arr
    if t == "transpose":
        return arr.T
    if t == "qheads":
        return arr.T.reshape(spec.d_model, spec.n_heads, spec.d_head)
    if t == "kvheads":
        return arr.T.reshape(spec.d_model, spec.n_kv, spec.d_head)
    if t == "embed_pad":
        vpad = padded_vocab(spec.vocab)
        return np.pad(arr, ((0, vpad - arr.shape[0]), (0, 0)))
    if t == "head_pad":
        vpad = padded_vocab(spec.vocab)
        return np.pad(arr.T, ((0, 0), (0, vpad - arr.shape[0])))
    raise ConvertError(f"unknown transform {t!r}")


def _invert_transform(arr: np.ndarray, spec, rule: Rule) -> np.ndarray:
    """Our layout -> HF layout (the export direction)."""
    arr = np.asarray(arr, np.float32)
    t = rule.transform
    if t == "copy":
        return arr
    if t == "transpose":
        return arr.T
    if t in ("qheads", "kvheads"):
        return arr.reshape(spec.d_model, -1).T
    if t == "embed_pad":
        return arr[: spec.vocab]
    if t == "head_pad":
        return arr[:, : spec.vocab].T
    raise ConvertError(f"unknown transform {t!r}")


def validate_tp(spec: spec_lib.ModelSpec, tp: int, family: str):
    """TP divisibility for every axis the family's mapping table splits.

    Raises :class:`ConvertError` naming the failing axis (satellite:
    "tp that doesn't divide heads/ffn names the axis").
    """
    if tp <= 1:
        return
    checks = {"heads": spec.n_heads, "ffn": spec.d_ff}
    if spec.moe is not None:
        checks["experts"] = spec.moe.n_experts
        del checks["ffn"]
    for axis, size in checks.items():
        if size % tp:
            raise ConvertError(
                f"tp={tp} does not divide axis {axis!r} (size {size}) "
                f"for family {family!r} / spec {spec.name!r}")
    # kv heads follow the engine's rule: kv % tp == 0 or tp % kv == 0
    if spec.n_kv % tp and tp % spec.n_kv:
        raise ConvertError(
            f"tp={tp} does not divide axis 'kv_heads' (size {spec.n_kv}) "
            f"and is not a multiple of it, for family {family!r} / "
            f"spec {spec.name!r}")


# --------------------------------------------------------------------------
# Routing (shared by streaming convert and in-memory direct load)
# --------------------------------------------------------------------------

def _layer_dests(spec: spec_lib.ModelSpec, blk) -> Dict[Tuple[str, ...], int]:
    """Expected leaves of one layer -> number of HF tensors feeding each."""
    if spec.norm != "rmsnorm" or spec.act != "silu":
        raise ConvertError(
            f"mapping tables cover rmsnorm+silu families only, got "
            f"norm={spec.norm!r} act={spec.act!r} for {spec.name!r}")
    dests: Dict[Tuple[str, ...], int] = {
        ("norm1", "scale"): 1, ("norm2", "scale"): 1,
        ("attn", "wq"): 1, ("attn", "wk"): 1,
        ("attn", "wv"): 1, ("attn", "wo"): 1,
    }
    if spec.qk_norm:
        dests[("attn", "q_norm")] = 1
        dests[("attn", "k_norm")] = 1
    if blk.ffn == "dense":
        dests[("mlp", "w1")] = dests[("mlp", "w2")] = dests[("mlp", "w3")] = 1
    elif blk.ffn == "moe":
        e = spec.moe.n_experts
        dests[("moe", "router")] = 1
        dests[("moe", "w1")] = dests[("moe", "w2")] = dests[("moe", "w3")] = e
    else:
        raise ConvertError(
            f"mapping tables cover dense/moe ffn only, got {blk.ffn!r} "
            f"for {spec.name!r}")
    return dests


class _Assembler:
    """Routes HF tensors into per-chunk layer dicts, flushing each chunk
    the moment it completes (``sink`` callback) — the streaming core
    shared by :func:`convert` (disk sink) and :func:`hf_to_params`
    (in-memory sink)."""

    def __init__(self, spec: spec_lib.ModelSpec, *, pp: int, tp: int,
                 v: int, family: Optional[str] = None, sink=None):
        self.spec = spec
        self.family = family or family_for(spec)
        if self.family not in MAPPINGS:
            raise ConvertError(
                f"unknown mapping table {self.family!r}; available: "
                f"{sorted(MAPPINGS)}")
        validate_tp(spec, tp, self.family)
        n_chunks = pp * v
        if spec.n_layers % n_chunks:
            raise ConvertError(
                f"n_layers={spec.n_layers} not divisible by "
                f"pp*v={n_chunks} for {spec.name!r}")
        self.pp, self.tp, self.v = pp, tp, v
        self.n_chunks = n_chunks
        self.lpc = spec.n_layers // n_chunks      # layers per chunk
        self.order = storage_order(pp, v)         # row -> model chunk
        self.row_of = {c: p for p, c in enumerate(self.order)}
        self.rules = [(r, r.regex()) for r in MAPPINGS[self.family]]
        program = spec.stage_program(n_chunks)
        self.expected = [_layer_dests(spec, blk) for blk in program]
        self.sink = sink or (lambda row, chunk: None)
        # chunk id -> {"layer_<pos>/<dest...>": array or (E, ...) buffer}
        self._buf: Dict[int, Dict[str, np.ndarray]] = {}
        self._remaining: Dict[int, Dict[str, int]] = {}
        self._shared: Dict[str, np.ndarray] = {}
        self._shared_remaining = {"embed": 1, "final_norm/scale": 1,
                                  "head": 1}
        self.flushed: List[int] = []

    def _match(self, key: str):
        for rule, rx in self.rules:
            m = rx.match(key)
            if m:
                return rule, m
        raise ConvertError(
            f"unknown checkpoint key {key!r}: no rule in mapping table "
            f"{self.family!r} matches it")

    def _chunk_init(self, c: int):
        self._buf[c] = {}
        self._remaining[c] = {}
        for pos in range(self.lpc):
            for dest, n in self.expected[pos].items():
                self._remaining[c]["/".join((f"layer_{pos}",) + dest)] = n

    def add(self, key: str, arr: np.ndarray):
        rule, m = self._match(key)
        gd = m.groupdict()
        per_expert = "expert" in gd
        want = _expected_hf_shape(self.spec, rule, per_expert)
        if tuple(arr.shape) != want:
            raise ConvertError(
                f"{key}: tensor shape {tuple(arr.shape)} does not match "
                f"expected shape {want} for {self.family}:"
                f"{'/'.join(rule.dest)}")
        out = _apply_transform(arr, self.spec, rule)

        if rule.shared:
            flat = "/".join(rule.dest)
            self._shared[flat] = out
            self._shared_remaining[flat] = 0
            return

        layer = int(gd["layer"])
        if layer >= self.spec.n_layers:
            raise ConvertError(
                f"{key}: layer index {layer} out of range for "
                f"{self.spec.name!r} (n_layers={self.spec.n_layers})")
        c, pos = divmod(layer, self.lpc)
        if c not in self._buf:
            if c in self.flushed:
                raise ConvertError(
                    f"{key}: duplicate tensor for already-flushed chunk {c}")
            self._chunk_init(c)
        flat = "/".join((f"layer_{pos}",) + rule.dest)
        if flat not in self._remaining[c]:
            raise ConvertError(
                f"unknown checkpoint key {key!r}: destination {flat!r} is "
                f"not expected by mapping table {self.family!r} for "
                f"{self.spec.name!r}")
        if per_expert:
            e = int(gd["expert"])
            full = _dest_shape(self.spec, rule.dest)
            if e >= full[0]:
                raise ConvertError(
                    f"{key}: expert index {e} out of range "
                    f"(n_experts={full[0]})")
            if flat not in self._buf[c]:
                self._buf[c][flat] = np.zeros(full, np.float32)
            self._buf[c][flat][e] = out
        else:
            self._buf[c][flat] = out
        self._remaining[c][flat] -= 1
        if all(n <= 0 for n in self._remaining[c].values()):
            row = self.row_of[c]
            self.sink(row, self._buf.pop(c))
            del self._remaining[c]
            self.flushed.append(c)

    def finish(self) -> Dict[str, np.ndarray]:
        missing = []
        for c, rem in sorted(self._remaining.items()):
            for flat, n in sorted(rem.items()):
                if n > 0:
                    missing.append(f"chunk {c}: {flat} ({n} tensor(s))")
        missing += [f"shared: {k}" for k, n in
                    sorted(self._shared_remaining.items()) if n > 0]
        unstarted = [c for c in range(self.n_chunks)
                     if c not in self.flushed and c not in self._buf]
        missing += [f"chunk {c}: no tensors seen" for c in unstarted]
        if missing:
            head = "; ".join(missing[:6])
            more = f" (+{len(missing) - 6} more)" if len(missing) > 6 else ""
            raise ConvertError(
                f"incomplete checkpoint for {self.spec.name!r}: missing "
                f"{head}{more}")
        return self._shared


# --------------------------------------------------------------------------
# Shard resolution + streaming iteration
# --------------------------------------------------------------------------

def _require_safetensors():
    if not HAVE_SAFETENSORS:
        raise ConvertError(
            "the 'safetensors' package is required for checkpoint "
            "conversion but is not importable in this environment")


def resolve_shards(src: str) -> List[str]:
    """Shard file list for a checkpoint path (file, or dir with either a
    ``model.safetensors`` or a ``model.safetensors.index.json``)."""
    if os.path.isfile(src):
        return [src]
    if os.path.isdir(src):
        idx = os.path.join(src, "model.safetensors.index.json")
        if os.path.exists(idx):
            with open(idx) as f:
                index = json.load(f)
            names = sorted(set(index.get("weight_map", {}).values()))
            shards = [os.path.join(src, n) for n in names]
            for s in shards:
                if not os.path.exists(s):
                    raise ConvertError(
                        f"missing safetensors shard {s!r} (referenced by "
                        f"{idx!r})")
            return shards
        single = os.path.join(src, "model.safetensors")
        if os.path.exists(single):
            return [single]
        raise ConvertError(
            f"missing safetensors shard {single!r}: directory {src!r} has "
            f"neither model.safetensors nor model.safetensors.index.json")
    raise ConvertError(f"missing safetensors shard {src!r}: no such "
                       f"file or directory")


def _iter_tensors(shards: List[str]):
    """Yield (key, np.ndarray) one tensor at a time across shards."""
    _require_safetensors()
    for path in shards:
        with safe_open(path, framework="numpy") as f:
            for key in f.keys():
                yield key, f.get_tensor(key)


# --------------------------------------------------------------------------
# Public API: convert / load / direct / export
# --------------------------------------------------------------------------

def convert(src: str, dest_dir: str, spec: spec_lib.ModelSpec, *,
            pp: int, tp: int = 1, virtual_stages: int = 1,
            family: Optional[str] = None,
            config: Optional[str] = None) -> Dict[str, Any]:
    """Stream an HF safetensors checkpoint into storage-chunk files.

    Writes ``chunk_<row>.npz`` per storage row (flushed as soon as the
    chunk's tensors have all arrived), ``shared.npz`` and a manifest.
    Returns the manifest dict.
    """
    shards = resolve_shards(src)
    os.makedirs(dest_dir, exist_ok=True)

    def sink(row: int, chunk: Dict[str, np.ndarray]):
        np.savez(os.path.join(dest_dir, f"chunk_{row:04d}.npz"), **chunk)

    asm = _Assembler(spec, pp=pp, tp=tp, v=virtual_stages, family=family,
                     sink=sink)
    for key, arr in _iter_tensors(shards):
        asm.add(key, arr)
    shared = asm.finish()
    np.savez(os.path.join(dest_dir, "shared.npz"), **shared)

    manifest = {
        "format": "repro-chunks-v1",
        "family": asm.family,
        "spec": spec.name,
        "config": config,
        "pp": pp, "tp": tp, "virtual_stages": virtual_stages,
        "n_chunks": asm.n_chunks,
        "layers_per_chunk": asm.lpc,
        "storage_order": asm.order,
        "vocab": spec.vocab,
        "dtype": "float32",
        "source": [os.path.basename(s) for s in shards],
    }
    tmp = os.path.join(dest_dir, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(dest_dir, MANIFEST_NAME))
    return manifest


def _unflatten(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for key, arr in flat.items():
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


def _finalize_params(rows: List[Dict[str, Any]], shared: Dict[str, Any],
                     spec: spec_lib.ModelSpec, order: List[int]
                     ) -> Dict[str, Any]:
    """Stack per-row chunk dicts (already storage order) into the engine's
    stage-stacked params tree, attaching shared leaves and the per-chunk
    window/theta scalars (permuted to storage order like the engine's
    ``init_state`` does)."""
    import jax

    stages = jax.tree.map(lambda *xs: np.stack(xs, axis=0), *rows)
    windows, thetas = spec_lib.stage_varying_scalars(spec, len(order))
    perm = np.asarray(order)
    params: Dict[str, Any] = {
        "embed": shared["embed"],
        "head": shared["head"],
        "final_norm": {"scale": shared["final_norm"]["scale"]},
        "stages": stages,
        "layer_windows": np.asarray(windows, np.int32)[perm],
        "layer_thetas": np.asarray(thetas, np.float32)[perm],
    }
    return params


def load_converted(ckpt_dir: str, spec: spec_lib.ModelSpec
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Load a converted checkpoint directory into the engine's params
    tree (storage chunk order, full width — the engine's device_put
    applies the tensor-parallel split).  Returns (params, manifest)."""
    mf = os.path.join(ckpt_dir, MANIFEST_NAME)
    if not os.path.exists(mf):
        raise ConvertError(f"missing manifest {mf!r}: not a converted "
                           f"checkpoint directory")
    with open(mf) as f:
        manifest = json.load(f)
    if manifest.get("spec") != spec.name:
        raise ConvertError(
            f"checkpoint {ckpt_dir!r} was converted for spec "
            f"{manifest.get('spec')!r}, not {spec.name!r}")
    rows = []
    for row in range(manifest["n_chunks"]):
        path = os.path.join(ckpt_dir, f"chunk_{row:04d}.npz")
        if not os.path.exists(path):
            raise ConvertError(f"missing chunk file {path!r} (manifest "
                               f"lists {manifest['n_chunks']} chunks)")
        rows.append(_unflatten(dict(np.load(path))))
    shared = _unflatten(dict(np.load(os.path.join(ckpt_dir, "shared.npz"))))
    params = _finalize_params(rows, shared, spec,
                              manifest["storage_order"])
    return params, manifest


def hf_to_params(tensors: Dict[str, np.ndarray], spec: spec_lib.ModelSpec,
                 *, pp: int, tp: int = 1, virtual_stages: int = 1,
                 family: Optional[str] = None) -> Dict[str, Any]:
    """Direct in-memory HF-dict -> params tree (same routing, no disk).

    The round-trip golden compares ``convert`` + ``load_converted``
    against this path bit-for-bit.
    """
    rows: Dict[int, Dict[str, Any]] = {}

    def sink(row: int, chunk: Dict[str, np.ndarray]):
        rows[row] = _unflatten(chunk)

    asm = _Assembler(spec, pp=pp, tp=tp, v=virtual_stages, family=family,
                     sink=sink)
    for key in sorted(tensors):
        asm.add(key, tensors[key])
    shared = _unflatten(asm.finish())
    return _finalize_params([rows[r] for r in range(asm.n_chunks)],
                            shared, spec, asm.order)


def _hf_name(rule: Rule, layer: Optional[int] = None,
             expert: Optional[int] = None) -> str:
    """Reconstruct the concrete HF tensor name a rule's pattern matches."""
    pat = rule.pattern
    if layer is not None:
        pat = pat.replace(r"(?P<layer>\d+)", str(layer))
    if expert is not None:
        pat = pat.replace(r"(?P<expert>\d+)", str(expert))
    return pat.replace("\\.", ".")


def export_checkpoint(ckpt_dir: str, out_path: str,
                      spec: spec_lib.ModelSpec) -> Dict[str, np.ndarray]:
    """Inverse path: converted chunks back to one HF-named safetensors
    file.  Returns the exported tensor dict."""
    _require_safetensors()
    params, manifest = load_converted(ckpt_dir, spec)
    family = manifest["family"]
    rule_of = {r.dest: r for r in MAPPINGS[family]}
    lpc = manifest["layers_per_chunk"]
    order = manifest["storage_order"]

    def get(tree, dest):
        for k in dest:
            tree = tree[k]
        return tree

    out: Dict[str, np.ndarray] = {}
    for dest in [("embed",), ("final_norm", "scale"), ("head",)]:
        rule = rule_of[dest]
        out[_hf_name(rule)] = _invert_transform(get(params, dest), spec,
                                                rule)

    for row, chunk in enumerate(order):
        for pos in range(lpc):
            g = chunk * lpc + pos                 # global layer
            lp = jax_tree_row(params["stages"][f"layer_{pos}"], row)
            blk = spec.blocks[g]
            dests = _layer_dests(spec, blk)
            for dest in dests:
                rule = rule_of[dest]
                ours = get(lp, dest)
                if "expert" in rule.pattern:
                    for e in range(ours.shape[0]):
                        out[_hf_name(rule, g, e)] = _invert_transform(
                            ours[e], spec, rule)
                else:
                    out[_hf_name(rule, g)] = _invert_transform(
                        ours, spec, rule)
    _st_save(out, out_path)
    return out


def jax_tree_row(tree, row: int):
    """Slice row ``row`` off every leaf of a stacked layer dict."""
    import jax
    return jax.tree.map(lambda a: a[row], tree)


# --------------------------------------------------------------------------
# Synthetic fixture (tests + convert_smoke)
# --------------------------------------------------------------------------

def make_synthetic_checkpoint(path: str, spec: spec_lib.ModelSpec, *,
                              seed: int = 0, shards: int = 1,
                              family: Optional[str] = None
                              ) -> Dict[str, np.ndarray]:
    """Write a tiny random HF-format safetensors checkpoint for ``spec``.

    ``shards > 1`` splits the tensors across files plus an index.json —
    exercising the sharded-resolution path.  Returns the tensor dict.
    """
    _require_safetensors()
    family = family or family_for(spec)
    rng = np.random.default_rng(seed)
    d, h, kv, dh = spec.d_model, spec.n_heads, spec.n_kv, spec.d_head

    def r(*shape):
        return (0.05 * rng.standard_normal(shape)).astype(np.float32)

    out: Dict[str, np.ndarray] = {
        "model.embed_tokens.weight": r(spec.vocab, d),
        "model.norm.weight": 1.0 + 0.01 * r(d),
        "lm_head.weight": r(spec.vocab, d),
    }
    for i, blk in enumerate(spec.blocks):
        p = f"model.layers.{i}."
        out[p + "input_layernorm.weight"] = 1.0 + 0.01 * r(d)
        out[p + "post_attention_layernorm.weight"] = 1.0 + 0.01 * r(d)
        out[p + "self_attn.q_proj.weight"] = r(h * dh, d)
        out[p + "self_attn.k_proj.weight"] = r(kv * dh, d)
        out[p + "self_attn.v_proj.weight"] = r(kv * dh, d)
        out[p + "self_attn.o_proj.weight"] = r(d, h * dh)
        if spec.qk_norm:
            out[p + "self_attn.q_norm.weight"] = 1.0 + 0.01 * r(dh)
            out[p + "self_attn.k_norm.weight"] = 1.0 + 0.01 * r(dh)
        if blk.ffn == "dense":
            out[p + "mlp.gate_proj.weight"] = r(spec.d_ff, d)
            out[p + "mlp.up_proj.weight"] = r(spec.d_ff, d)
            out[p + "mlp.down_proj.weight"] = r(d, spec.d_ff)
        elif blk.ffn == "moe":
            m = spec.moe
            out[p + "mlp.gate.weight"] = r(m.n_experts, d)
            for e in range(m.n_experts):
                q = f"{p}mlp.experts.{e}."
                out[q + "gate_proj.weight"] = r(m.d_expert, d)
                out[q + "up_proj.weight"] = r(m.d_expert, d)
                out[q + "down_proj.weight"] = r(d, m.d_expert)

    if shards <= 1:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if os.path.isdir(path):
            path = os.path.join(path, "model.safetensors")
        _st_save(out, path)
        return out

    os.makedirs(path, exist_ok=True)
    keys = sorted(out)
    per = -(-len(keys) // shards)
    weight_map = {}
    for si in range(shards):
        name = f"model-{si + 1:05d}-of-{shards:05d}.safetensors"
        part = {k: out[k] for k in keys[si * per: (si + 1) * per]}
        _st_save(part, os.path.join(path, name))
        weight_map.update({k: name for k in part})
    with open(os.path.join(path, "model.safetensors.index.json"), "w") as f:
        json.dump({"weight_map": weight_map}, f)
    return out


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _resolve_spec(config: str, smoke: bool):
    from repro import configs
    mod = configs.get(config)
    return mod.smoke_spec() if smoke else mod.spec()


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="HF safetensors <-> storage-chunk checkpoint converter")
    ap.add_argument("--src", required=True,
                    help="safetensors file/dir (convert) or converted "
                         "chunk dir (--export)")
    ap.add_argument("--dest", required=True,
                    help="output chunk dir (convert) or output "
                         ".safetensors path (--export)")
    ap.add_argument("--config", required=True,
                    help="config family module, e.g. qwen3_14b / olmoe_1b_7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the config's smoke_spec()")
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--virtual-stages", type=int, default=1)
    ap.add_argument("--family", default=None,
                    help="mapping table override (default: from spec)")
    ap.add_argument("--export", action="store_true",
                    help="inverse direction: chunk dir -> safetensors")
    args = ap.parse_args(argv)

    spec = _resolve_spec(args.config, args.smoke)
    if args.export:
        tensors = export_checkpoint(args.src, args.dest, spec)
        print(f"exported {len(tensors)} tensors -> {args.dest}")
    else:
        manifest = convert(args.src, args.dest, spec, pp=args.pp,
                           tp=args.tp, virtual_stages=args.virtual_stages,
                           family=args.family, config=args.config)
        print(f"converted {manifest['spec']} -> {args.dest} "
              f"(pp={args.pp}, tp={args.tp}, v={args.virtual_stages}, "
              f"{manifest['n_chunks']} chunks)")


if __name__ == "__main__":
    main()
