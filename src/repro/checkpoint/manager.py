"""Per-stage local checkpointing (paper §4, "Checkpointing").

The paper: "Checkpoints don't require expensive global coordination; each
stage locally decides to dump its model parameters … Restarting entails
starting from the last epoch successfully checkpointed by all stages."

Layout on disk:
    <dir>/round_<n>/stage_<s>.npz     one file per pipeline stage
    <dir>/round_<n>/shared.npz        embed / head / final_norm / encoder
    <dir>/round_<n>/opt.npz           optimizer + stash ring + step
    <dir>/round_<n>/MANIFEST.json     {"round": n, "stages": [...], "done": bool}

``latest_complete_round`` scans manifests and returns the newest round for
which every stage file landed — a stage failure mid-dump leaves an
incomplete manifest that restart skips, exactly the paper's semantics.

``reshard_stages`` re-groups stage-stacked leaves when the pipeline depth
changes (elastic scaling): parameters are keyed by global layer index, so
moving stage boundaries is a pure reshape.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# npz cannot represent the ml_dtypes extension floats: np.savez silently
# degrades bfloat16 to a raw void ``|V2`` (and fp8 to ``|V1``), which
# jnp.asarray then rejects on restore.  Dump those leaves as their uint
# payload instead and view them back through the restore template, which
# knows the true dtype.  Gated on ml_dtypes importability so the manager
# keeps working (fp32-only) in environments without it.
try:
    import ml_dtypes
    _EXT_PAYLOAD = {np.dtype(ml_dtypes.bfloat16): np.uint16,
                    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
                    np.dtype(ml_dtypes.float8_e5m2): np.uint8}
except ImportError:              # pragma: no cover - baked into the image
    _EXT_PAYLOAD = {}


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        a = np.asarray(tree)
        if a.dtype in _EXT_PAYLOAD:
            a = a.view(_EXT_PAYLOAD[a.dtype])
        out[prefix[:-1]] = a
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    arr = np.asarray(flat[prefix[:-1]])
    tdt = np.dtype(template.dtype)
    if tdt in _EXT_PAYLOAD and arr.dtype != tdt:
        # uint payload written by _flatten (or a legacy void dump):
        # reinterpret the bits — astype would numerically convert
        arr = arr.view(tdt)
    return jnp.asarray(arr).astype(template.dtype)


class CheckpointManager:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _round_dir(self, rnd: int) -> str:
        return os.path.join(self.dir, f"round_{rnd:08d}")

    @staticmethod
    def _write_manifest(d: str, manifest: Dict[str, Any]):
        """Atomic manifest update: tmp file + os.replace, so a crash
        mid-write leaves either the previous manifest or none — never a
        truncated JSON that poisons every later restart scan."""
        tmp = os.path.join(d, "MANIFEST.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(d, "MANIFEST.json"))

    # ---------------- save ------------------------------------------------

    def save(self, rnd: int, state: Dict[str, Any], n_stages: int,
             fail_after_stage: Optional[int] = None):
        """Per-stage dump. ``fail_after_stage`` simulates a crash mid-save
        (used by the fault-tolerance tests): stages > that index are not
        written and the manifest stays incomplete."""
        d = self._round_dir(rnd)
        os.makedirs(d, exist_ok=True)
        state = jax.device_get(state)
        stages = state["params"]["stages"]
        written: List[int] = []
        manifest = {"round": rnd, "stages": [], "n_stages": n_stages,
                    "done": False}

        for s in range(n_stages):
            if fail_after_stage is not None and s > fail_after_stage:
                break
            part = jax.tree.map(lambda a: np.asarray(a[s:s + 1]), stages)
            np.savez(os.path.join(d, f"stage_{s}.npz"), **_flatten(part))
            written.append(s)
            manifest["stages"] = written
            self._write_manifest(d, manifest)

        if len(written) == n_stages:
            shared = {k: v for k, v in state["params"].items()
                      if k != "stages"}
            np.savez(os.path.join(d, "shared.npz"), **_flatten(shared))
            rest = {k: v for k, v in state.items() if k != "params"}
            np.savez(os.path.join(d, "opt.npz"), **_flatten(rest))
            manifest["done"] = True
            self._write_manifest(d, manifest)

    # ---------------- restore --------------------------------------------

    def latest_complete_round(self) -> Optional[int]:
        best = None
        for name in os.listdir(self.dir):
            mf = os.path.join(self.dir, name, "MANIFEST.json")
            if not os.path.exists(mf):
                continue
            try:
                with open(mf) as f:
                    m = json.load(f)
            except (OSError, ValueError):
                # truncated / corrupt manifest (crash mid-write on a
                # pre-atomic layout, disk fault): treat the round as
                # incomplete instead of killing the restart scan
                continue
            if isinstance(m, dict) and m.get("done"):
                best = max(best or -1, m["round"])
        return best

    def restore(self, rnd: int, state_template: Dict[str, Any]
                ) -> Dict[str, Any]:
        d = self._round_dir(rnd)
        n_stages = jax.tree.leaves(
            state_template["params"]["stages"])[0].shape[0]
        shared = dict(np.load(os.path.join(d, "shared.npz")))
        rest = dict(np.load(os.path.join(d, "opt.npz")))

        parts = []
        for s in range(n_stages):
            parts.append(dict(np.load(os.path.join(d, f"stage_{s}.npz"))))
        stage_flat = {k: np.concatenate([p[k] for p in parts], axis=0)
                      for k in parts[0]}

        params_t = state_template["params"]
        params = {
            "stages": _unflatten_into(params_t["stages"], stage_flat),
            **_unflatten_into({k: v for k, v in params_t.items()
                               if k != "stages"}, shared),
        }
        out = _unflatten_into({k: v for k, v in state_template.items()
                               if k != "params"}, rest)
        out["params"] = params
        return out


# --------------------------------------------------------------------------
# Elastic resharding: move stage boundaries (pp -> pp')
# --------------------------------------------------------------------------

def reshard_stages(stages_tree: Dict[str, Any], old_pp: int, new_pp: int
                   ) -> Dict[str, Any]:
    """Re-group per-(stage, position) leaves for a new pipeline depth.

    Old layout: stages['layer_i'][leaf] has shape [old_pp, ...], holding
    global layer (s*lps_old + i).  New layout must satisfy
    n_layers % new_pp == 0 and the stage-program pattern must still align
    (validated by the caller via spec.stage_program(new_pp)).
    """
    old_positions = sorted(stages_tree.keys(),
                           key=lambda k: int(k.split("_")[1]))
    lps_old = len(old_positions)
    n_layers = lps_old * old_pp
    assert n_layers % new_pp == 0, (n_layers, new_pp)
    lps_new = n_layers // new_pp

    # global layer -> leaf arrays
    def global_layer(leaf_name):
        def get(gl):
            s, i = divmod(gl, lps_old)
            return jax.tree.map(lambda a: a[s],
                                stages_tree[f"layer_{i}"])
        return get

    out: Dict[str, Any] = {}
    for i_new in range(lps_new):
        per_stage = []
        for s_new in range(new_pp):
            gl = s_new * lps_new + i_new
            s_old, i_old = divmod(gl, lps_old)
            per_stage.append(jax.tree.map(lambda a: a[s_old],
                                          stages_tree[f"layer_{i_old}"]))
        out[f"layer_{i_new}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *per_stage)
    return out
