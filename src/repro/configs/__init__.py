"""Architecture registry: 10 assigned archs × 4 input shapes.

Every config module exposes:
  full_spec()   — the exact published configuration (models.spec.ModelSpec)
  smoke_spec()  — reduced same-family config for CPU smoke tests
  PLAN          — production ParallelismPlan (pp·tp == 16 model shards)
  SMOKE_PLAN    — small-plan used by the smoke tests
  OPTIMIZER     — (name, lr) the end-to-end examples default to
and optionally:
  INTERLEAVED_PLAN — virtual-stage (Megatron-interleaved) synchronous
                     alternate, for archs whose layer count divides
                     pp × virtual_stages (see core/schedule.py)

Shape semantics (task spec):
  train_4k     seq 4 096 × batch 256   -> pipelined train_step
  prefill_32k  seq 32 768 × batch 32   -> pipelined prefill_step
  decode_32k   seq 32 768 × batch 128  -> pipelined decode_step (1 new token,
                                          KV cache of seq_len)
  long_500k    seq 524 288 × batch 1   -> sequence-parallel decode_step;
                                          only sub-quadratic-memory archs
                                          (spec.subquadratic) run it.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Iterator, Optional, Tuple

ARCH_IDS = (
    "qwen3_14b",
    "gemma3_4b",
    "chatglm3_6b",
    "h2o_danube3_4b",
    "llava_next_34b",
    "olmoe_1b_7b",
    "deepseek_moe_16b",
    "whisper_medium",
    "rwkv6_1b6",
    "jamba_v01_52b",
)

# CLI ids (dashes) -> module names
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "qwen3-14b": "qwen3_14b",
    "gemma3-4b": "gemma3_4b",
    "chatglm3-6b": "chatglm3_6b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "llava-next-34b": "llava_next_34b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-medium": "whisper_medium",
    "rwkv6-1.6b": "rwkv6_1b6",
    "jamba-v0.1-52b": "jamba_v01_52b",
})


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "long_decode", 524288, 1),
}


def resolve(arch: str) -> str:
    key = _ALIASES.get(arch, arch)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    return key


def get(arch: str):
    """Return the config module for an arch id (dash or underscore form)."""
    return importlib.import_module(f"repro.configs.{resolve(arch)}")


def supports(arch: str, shape: str) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k requires sub-quadratic KV memory."""
    cfg = get(arch)
    spec = cfg.full_spec()
    if shape == "long_500k" and not spec.subquadratic:
        return False, ("quadratic full-attention KV at 524k tokens "
                       "(skip noted in DESIGN.md §8)")
    return True, ""


def cells() -> Iterator[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape) cells with their skip status."""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = supports(arch, shape)
            yield arch, shape, ok, why
