"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d (half-rotary) RoPE, GQA.  [arXiv:2406.12793; hf]

kv=2 < tp=4: KV projections are replicated over the tensor axis and each
device slices its head group (models/init.py::attn_static).
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 3e-4)

PLAN = ParallelismPlan(pp=4, tp=4, microbatches=8, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", rope_theta=1e4)
                   for _ in range(28))
    return S.ModelSpec(
        name="chatglm3-6b", d_model=4096, n_layers=28, n_heads=32, n_kv=2,
        d_head=128, d_ff=13696, vocab=65024, blocks=blocks,
        norm="rmsnorm", act="silu", rope_2d=True,
        family="dense", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense") for _ in range(4))
    return S.ModelSpec(
        name="chatglm3-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu", rope_2d=True,
        family="dense", subquadratic=False)
