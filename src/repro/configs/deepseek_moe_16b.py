"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16, MHA)
d_ff(expert)=1408 vocab=102400, MoE: 2 shared + 64 routed top-6
(fine-grained expert segmentation).  [arXiv:2401.06066; hf]

Deviation noted in DESIGN.md: the released model's layer 0 uses a dense
FFN; the SPMD stage program requires a uniform block pattern, so all 28
layers are MoE here (params +0.3%).

16.8 B params ⇒ pp=2 keeps the faithful stash ring at V=3
(4 weight copies = 8.4 GB/dev), tp=8 gives 8 routed experts per device.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 3e-4)

PLAN = ParallelismPlan(pp=2, tp=8, microbatches=8, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="moe") for _ in range(28))
    return S.ModelSpec(
        name="deepseek-moe-16b", d_model=2048, n_layers=28, n_heads=16,
        n_kv=16, d_head=128, d_ff=1408, vocab=102400, blocks=blocks,
        norm="rmsnorm", act="silu",
        moe=S.MoESpec(n_experts=64, top_k=6, d_expert=1408,
                      n_shared=2, d_shared=1408),
        family="moe", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="moe") for _ in range(4))
    return S.ModelSpec(
        name="dsmoe-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=4,
        d_head=16, d_ff=32, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu",
        moe=S.MoESpec(n_experts=8, top_k=2, d_expert=32,
                      n_shared=1, d_shared=32),
        family="moe", subquadratic=False)
