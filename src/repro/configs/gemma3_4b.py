"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

The 5:1 interleave is pure *data* in this framework: every 6th layer is
global (window=-1, rope theta 1e6), the rest use a 1024-token sliding
window (theta 1e4) — block kinds stay identical so any pp divides.
long_500k RUNS: only ~6 global layers hold full-length KV (SP-sharded);
the other 28 keep a 1024-slot ring.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 3e-4)

LOCAL_WINDOW = 1024
GLOBAL_EVERY = 6  # layer i is global iff i % 6 == 5

PLAN = ParallelismPlan(pp=2, tp=8, microbatches=8, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def _block(i: int) -> S.BlockSpec:
    if i % GLOBAL_EVERY == GLOBAL_EVERY - 1:
        return S.BlockSpec(mixer="attn", ffn="dense",
                           window=S.GLOBAL_WINDOW, rope_theta=1e6)
    return S.BlockSpec(mixer="attn", ffn="dense",
                       window=LOCAL_WINDOW, rope_theta=1e4)


def full_spec() -> S.ModelSpec:
    return S.ModelSpec(
        name="gemma3-4b", d_model=2560, n_layers=34, n_heads=8, n_kv=4,
        d_head=256, d_ff=10240, vocab=262144,
        blocks=tuple(_block(i) for i in range(34)),
        norm="rmsnorm", act="gelu", qk_norm=True, tie_embeddings=False,
        family="dense", subquadratic=True)


def smoke_spec() -> S.ModelSpec:
    return S.ModelSpec(
        name="gemma3-smoke", d_model=64, n_layers=6, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256,
        blocks=tuple(
            S.BlockSpec(mixer="attn", ffn="dense",
                        window=(S.GLOBAL_WINDOW if i % 3 == 2 else 8),
                        rope_theta=(1e6 if i % 3 == 2 else 1e4))
            for i in range(6)),
        norm="rmsnorm", act="gelu", qk_norm=True,
        family="dense", subquadratic=True)
