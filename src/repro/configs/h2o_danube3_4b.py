"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

Every layer uses a 4096-token mistral-style sliding window, so KV memory
is O(window): long_500k RUNS with ring-buffer caches.  Deepest faithful
pipeline of the pool (pp=8 → stash ring V=15) — the stress test for the
paper's weight-stashing memory model.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 3e-4)

SWA_WINDOW = 4096

PLAN = ParallelismPlan(pp=8, tp=2, microbatches=16, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense",
                               window=SWA_WINDOW, rope_theta=5e5)
                   for _ in range(24))
    return S.ModelSpec(
        name="h2o-danube-3-4b", d_model=3840, n_layers=24, n_heads=32,
        n_kv=8, d_head=120, d_ff=10240, vocab=32000, blocks=blocks,
        norm="rmsnorm", act="silu",
        family="dense", subquadratic=True)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", window=8)
                   for _ in range(4))
    return S.ModelSpec(
        name="danube3-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu",
        family="dense", subquadratic=True)
