"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Layer period 8 (the released model's "Jamba block"): attention at
position 4, Mamba elsewhere; MoE FFN on odd layers, dense on even.
pp=4 splits the 32 layers into 4 identical period-8 stages.

51.6 B params (2.8 B active-FFN equivalent per token): weights alone are
6.4 GB/dev at 16-way model sharding, so flush mode (no stash ring) +
ZeRO-1 — documented in DESIGN.md §6/§8.  long_500k RUNS: only the 4
attention layers hold full-length KV (SP-sharded); Mamba state is O(1).
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 1.5e-4)

PLAN = ParallelismPlan(pp=4, tp=4, microbatches=8, stash_mode="flush",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="flush",
                             zero1=False)


def _block(i: int) -> S.BlockSpec:
    mixer = "attn" if i % 8 == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return S.BlockSpec(mixer=mixer, ffn=ffn)


def full_spec() -> S.ModelSpec:
    return S.ModelSpec(
        name="jamba-v0.1-52b", d_model=4096, n_layers=32, n_heads=32,
        n_kv=8, d_head=128, d_ff=14336, vocab=65536,
        blocks=tuple(_block(i) for i in range(32)),
        norm="rmsnorm", act="silu",
        moe=S.MoESpec(n_experts=16, top_k=2, d_expert=14336),
        mamba=S.MambaSpec(d_state=16, d_conv=4, expand=2),
        family="hybrid", subquadratic=True)


def smoke_spec() -> S.ModelSpec:
    def blk(i):
        return S.BlockSpec(mixer=("attn" if i % 4 == 0 else "mamba"),
                           ffn=("moe" if i % 2 == 1 else "dense"))
    return S.ModelSpec(
        name="jamba-smoke", d_model=64, n_layers=8, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256,
        blocks=tuple(blk(i) for i in range(8)),
        norm="rmsnorm", act="silu",
        moe=S.MoESpec(n_experts=4, top_k=2, d_expert=32),
        mamba=S.MambaSpec(d_state=4, d_conv=4, expand=2),
        family="hybrid", subquadratic=True)
