"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]

The modality frontend is a STUB per the task spec: input_specs() provides
576 precomputed patch embeddings (one 24×24 CLIP tile) at d_model,
prepended to the text sequence; anyres would only change n_patches.

34.3 B params: a full stash ring cannot fit 16 GB HBM at 16-way model
sharding (V=3 ⇒ 16.7 GB of weights alone), so this arch uses the
synchronous flush mode (PipeDream-flush, the authors' follow-up) with the
no-ring optimization + ZeRO-1 — see DESIGN.md §6/§8.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 1.5e-4)

N_PATCHES = 576

PLAN = ParallelismPlan(pp=2, tp=8, microbatches=8, stash_mode="flush",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="flush",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", rope_theta=5e6)
                   for _ in range(60))
    return S.ModelSpec(
        name="llava-next-34b", d_model=7168, n_layers=60, n_heads=56,
        n_kv=8, d_head=128, d_ff=20480, vocab=64000, blocks=blocks,
        norm="rmsnorm", act="silu", frontend="vision", n_patches=N_PATCHES,
        family="vlm", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense") for _ in range(4))
    return S.ModelSpec(
        name="llava-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu", frontend="vision", n_patches=8,
        family="vlm", subquadratic=False)
