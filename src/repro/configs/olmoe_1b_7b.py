"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16, i.e. MHA)
d_ff(expert)=1024 vocab=50304, MoE 64 experts top-8.
[arXiv:2409.02060; hf]

Experts shard over the tensor axis (EP: 16 experts/device at tp=4);
token dispatch is capacity-bounded sort-based (models/nn.py::moe).
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 4e-4)

PLAN = ParallelismPlan(pp=4, tp=4, microbatches=8, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)
# Synchronous high-throughput alternate: 16 layers = 4 stages x 2 virtual
# chunks of 2 layers; bubble 0.385 vs plain 1F1B-flush 0.429 at R=8
# (select with --schedule interleaved on launch/train or launch/dryrun).
INTERLEAVED_PLAN = ParallelismPlan(pp=4, tp=4, microbatches=8,
                                   stash_mode="flush",
                                   schedule="interleaved", virtual_stages=2,
                                   zero1=True, remat=True)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="moe") for _ in range(16))
    return S.ModelSpec(
        name="olmoe-1b-7b", d_model=2048, n_layers=16, n_heads=16, n_kv=16,
        d_head=128, d_ff=1024, vocab=50304, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True,
        moe=S.MoESpec(n_experts=64, top_k=8, d_expert=1024),
        family="moe", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="moe") for _ in range(4))
    return S.ModelSpec(
        name="olmoe-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=4,
        d_head=16, d_ff=32, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True,
        moe=S.MoESpec(n_experts=8, top_k=2, d_expert=32),
        family="moe", subquadratic=False)
