"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]

Plan: pp=2 × tp=8, full weight stashing (V = 3 ring slots), ZeRO-1.
Memory (v5e, bf16): stage weights 1.65 GB/dev × 4 copies (current + ring)
= 6.6 GB; Adam state ZeRO-1-sharded 0.41 GB; fits 16 GB HBM.  long_500k
skipped: full causal attention, 40 layers × 524k KV is quadratic-memory.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 3e-4)

PLAN = ParallelismPlan(pp=2, tp=8, microbatches=8, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)
# Synchronous high-throughput alternate: deeper pipe (pp=4 x tp=4), 40
# layers = 4 stages x 2 virtual chunks of 5 layers; bubble 0.385 vs
# 0.429 for plain flush at the same (S=4, R=8).
INTERLEAVED_PLAN = ParallelismPlan(pp=4, tp=4, microbatches=8,
                                   stash_mode="flush",
                                   schedule="interleaved", virtual_stages=2,
                                   zero1=True, remat=True)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense",
                               window=S.GLOBAL_WINDOW, rope_theta=1e6)
                   for _ in range(40))
    return S.ModelSpec(
        name="qwen3-14b", d_model=5120, n_layers=40, n_heads=40, n_kv=8,
        d_head=128, d_ff=17408, vocab=151936, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True,
        family="dense", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", rope_theta=1e6)
                   for _ in range(4))
    return S.ModelSpec(
        name="qwen3-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True,
        family="dense", subquadratic=False)
