"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: time-mix with data-dependent decay + channel-mix.
[arXiv:2404.05892; unverified]

O(1) recurrent decode state (one d×d matrix-valued WKV state per head) ⇒
long_500k runs with constant memory.  The chunked WKV6 scan is the
Pallas-kernel hot-spot (kernels/wkv6.py; jnp twin in models/nn.py).
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 5e-4)

PLAN = ParallelismPlan(pp=8, tp=2, microbatches=16, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="rwkv", ffn="rwkv_cmix")
                   for _ in range(24))
    return S.ModelSpec(
        name="rwkv6-1.6b", d_model=2048, n_layers=24, n_heads=32, n_kv=0,
        d_head=64, d_ff=7168, vocab=65536, blocks=blocks,
        norm="layernorm", act="silu",
        rwkv=S.RWKVSpec(head_dim=64, decay_lora=64, tmix_lora=32),
        family="ssm", subquadratic=True)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="rwkv", ffn="rwkv_cmix")
                   for _ in range(4))
    return S.ModelSpec(
        name="rwkv6-smoke", d_model=64, n_layers=4, n_heads=8, n_kv=0,
        d_head=8, d_ff=224, vocab=256, blocks=blocks,
        norm="layernorm", act="silu",
        rwkv=S.RWKVSpec(head_dim=8, decay_lora=8, tmix_lora=4),
        family="ssm", subquadratic=True)
