"""whisper-medium [audio] — 24L (decoder) d_model=1024 16H (MHA) d_ff=4096
vocab=51865 — encoder-decoder, conv frontend stubbed.
[arXiv:2212.04356; unverified]

The transformer BACKBONE is the 24-layer decoder (pipelined, cross-attends
into the encoder output every layer).  The 24-layer encoder runs
tensor-sharded *before* the pipeline (models/stage.py::encoder_fwd); the
conv1d/log-mel frontend is a STUB — input_specs() provides 1500
precomputed frame embeddings.  Adaptation noted in DESIGN.md: learned
absolute positions are replaced by RoPE in the decoder.
"""
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

OPTIMIZER = ("adam", 1e-3)

SOURCE_LEN = 1500  # 30 s of audio after the (stubbed) 2× conv downsampling

PLAN = ParallelismPlan(pp=8, tp=2, microbatches=16, stash_mode="stash",
                       zero1=True, remat=True)
SMOKE_PLAN = ParallelismPlan(pp=2, tp=1, microbatches=2, stash_mode="stash",
                             zero1=False)


def full_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", cross_attn=True)
                   for _ in range(24))
    return S.ModelSpec(
        name="whisper-medium", d_model=1024, n_layers=24, n_heads=16,
        n_kv=16, d_head=64, d_ff=4096, vocab=51865, blocks=blocks,
        norm="layernorm", act="gelu",
        encoder=S.EncoderSpec(n_layers=24, d_model=1024, n_heads=16,
                              d_ff=4096, source_len=SOURCE_LEN),
        frontend="audio", family="audio", subquadratic=False)


def smoke_spec() -> S.ModelSpec:
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", cross_attn=True)
                   for _ in range(4))
    return S.ModelSpec(
        name="whisper-smoke", d_model=64, n_layers=4, n_heads=4, n_kv=4,
        d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="layernorm", act="gelu",
        encoder=S.EncoderSpec(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                              source_len=16),
        frontend="audio", family="audio", subquadratic=False)
