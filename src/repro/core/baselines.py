"""Baselines the paper compares against (§5).

  * BSP data parallelism — model replicated, batch over every mesh axis,
    gradients all-reduced each minibatch (the paper's main baseline).
  * ASP — relaxed sync, adapted to SPMD as local-SGD: workers apply local
    updates and synchronize parameters every ``sync_every`` rounds (the
    paper's ASP has no sync point at all; lockstep SPMD needs one, so this
    is the closest TPU-idiomatic equivalent — see DESIGN.md).
  * Model parallelism without pipelining — the pipeline with R=1: one
    minibatch in flight, ≤1 stage busy at a time (paper Figure 3).

BSP runs at pjit level (no shard_map): XLA inserts the gradient
all-reduce, which is exactly the communication the paper measures.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import lm_head
from repro.models import spec as spec_lib
from repro.models.init import init_params
from repro.models.stage import full_transformer, make_statics
from repro.parallel.mesh import ParallelismPlan


def build_bsp(spec: spec_lib.ModelSpec, mesh: Mesh, *, seq_len: int,
              global_batch: int, optimizer, sync_every: int = 1,
              compute_dtype=jnp.bfloat16, aux_weight: float = 0.01):
    """Pure data-parallel BSP (sync_every=1) or ASP-like local SGD (>1).

    Batch is sharded over every mesh axis; parameters are replicated.
    Returns (train_step, init_state, state_shardings, batch_specs).
    """
    all_axes = tuple(mesh.axis_names)
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1, stash_mode="flush")
    statics = make_statics(spec, plan, tokens_per_mb=seq_len)
    asp = sync_every > 1

    def loss_fn(params, tokens, labels):
        embeds = lm_head.embed_tokens(params["embed"], tokens)
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                               tokens.shape)
        h, aux = full_transformer(params, embeds.astype(compute_dtype),
                                  statics, positions=pos)
        vmask = (labels >= 0).astype(jnp.float32)
        loss, _ = lm_head.head_loss(
            params["head"], params["final_norm"]["scale"], h,
            jnp.maximum(labels, 0), norm_kind=spec.norm,
            norm_bias=params["final_norm"].get("bias"), valid_mask=vmask,
            vocab=spec.vocab)
        return loss + aux_weight * aux, (loss, aux)

    def train_step(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]
        diffable = {k: v for k, v in params.items()
                    if k not in ("layer_windows", "layer_thetas")}
        statics_p = {k: v for k, v in params.items()
                     if k in ("layer_windows", "layer_thetas")}

        def f(dp):
            return loss_fn({**dp, **statics_p}, batch["tokens"],
                           batch["labels"])

        (total, (loss, aux)), grads = jax.value_and_grad(
            f, has_aux=True)(diffable)
        new_p, new_opt = optimizer.update(grads, opt, diffable, step)
        params = {**new_p, **statics_p}
        return ({"params": params, "opt": new_opt, "step": step + 1},
                {"loss": loss, "aux": aux})

    def init_state(key):
        params, _ = init_params(spec, plan, key, compute_dtype)
        diffable = {k: v for k, v in params.items()
                    if k not in ("layer_windows", "layer_thetas")}
        return {"params": params, "opt": optimizer.init(diffable),
                "step": jnp.zeros((), jnp.int32)}

    # parameters replicated; batch over all axes
    def _state_pspecs():
        _box = {}

        def go():
            p, s = init_params(spec, plan, jax.random.key(0), compute_dtype)
            _box["s"] = s
            return p

        pshape = jax.eval_shape(go)
        rep = jax.tree.map(lambda _: P(), pshape)
        diffable = {k: v for k, v in pshape.items()
                    if k not in ("layer_windows", "layer_thetas")}
        opt_shape = jax.eval_shape(lambda: optimizer.init(diffable))
        return {"params": rep,
                "opt": jax.tree.map(lambda _: P(), opt_shape),
                "step": P()}

    state_pspecs = _state_pspecs()
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    bsh = NamedSharding(mesh, P(all_axes, None))
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32,
                                       sharding=bsh),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32,
                                       sharding=bsh),
    }
    return train_step, init_state, state_sh, batch_specs


def build_model_parallel(spec, plan, mesh, **kw):
    """Paper Figure 3: model parallelism without pipelining = R=1 flush."""
    from repro.core.pipeline import build_pipeline

    mp_plan = plan.with_(microbatches=1, stash_mode="flush")
    return build_pipeline(spec, mp_plan, mesh, **kw)
