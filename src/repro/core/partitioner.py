"""PipeDream's partitioning algorithm (paper §3.2) — exact DP.

A(j, m): time of the slowest stage in the optimal pipeline over layers
1..j using m machines.  Either one stage replicated m ways (Case 1) or an
optimal sub-pipeline over 1..i with m−m' machines followed by one stage
over i+1..j replicated m' ways (Case 2):

    T(i→j, m) = (1/m) · max(Σ T_l, Σ W_l^m)
    A(j, m)   = min_{i,m'} max( A(i, m−m'), 2·C_i, T(i+1→j, m') )

O(N²M²) as in the paper.  ``general`` mode reproduces the paper's
non-uniform replication configs (e.g. 7-1, 9-5-1-1); ``rectangular`` mode
constrains replication to be uniform (the TPU data axis) and only splits
layers into S balanced stages.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiler import (Hardware, LayerProfile,
                                 comm_time_activations, comm_time_tp_allreduce,
                                 comm_time_weight_sync, profile_analytic)
from repro.core.schedule import (SCHEDULES, MemoryModel, bucket_lattice,
                                 fit_serving_microbatches, make_schedule,
                                 make_serving_schedule, paper_noam,
                                 pick_bucket, plan_kwargs_for_schedule,
                                 serve_ttft, weighted_round_time)


@dataclasses.dataclass(frozen=True)
class Stage:
    start: int                 # first layer index (inclusive)
    end: int                   # last layer index (inclusive)
    replicas: int

    def __str__(self):
        return f"[{self.start}..{self.end}]x{self.replicas}"


@dataclasses.dataclass(frozen=True)
class Partition:
    stages: Tuple[Stage, ...]
    bottleneck_time: float     # A(N, M): slowest-stage time
    noam: int

    @property
    def config_string(self) -> str:
        """Paper notation, e.g. '7-1' = 7 replicas then 1."""
        return "-".join(str(s.replicas) for s in self.stages)


def _prefix_sums(profiles: Sequence[LayerProfile]):
    t = np.concatenate([[0.0], np.cumsum([p.t_total for p in profiles])])
    w = np.concatenate([[0.0], np.cumsum([p.w_params for p in profiles])])
    return t, w


def stage_time(profiles: Sequence[LayerProfile], i: int, j: int, m: int,
               hw: Hardware, prefix=None) -> float:
    """T(i→j, m), layers i..j inclusive (0-indexed)."""
    if prefix is None:
        t_sum = sum(p.t_total for p in profiles[i:j + 1])
        w_sum = sum(p.w_params for p in profiles[i:j + 1])
    else:
        tp, wp = prefix
        t_sum = tp[j + 1] - tp[i]
        w_sum = wp[j + 1] - wp[i]
    sync = comm_time_weight_sync(w_sum, m, hw)
    return max(t_sum, sync) / m


def _stage_time_table(profiles: Sequence[LayerProfile], machines: int,
                      hw: Hardware, prefix) -> np.ndarray:
    """T[i, j, m] = T(i→j, m) for all layer spans and machine counts.

    Vectorized form of :func:`stage_time`: sums from the prefix arrays,
    sync from the closed-form ps_factor·(m−1)·bytes/m/bw (0 at m=1).
    Shape [n, n, M+1]; column m=0 unused.
    """
    n = len(profiles)
    tp, wp = prefix
    t_sum = tp[None, 1:] - tp[:-1, None]            # [i, j] layers i..j
    w_sum = wp[None, 1:] - wp[:-1, None]
    m = np.arange(machines + 1, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        sync = (hw.ps_factor * (m - 1)[None, None, :]
                * w_sum[:, :, None] * hw.param_bytes / np.maximum(m, 1)
                / hw.sync_bw)
    sync[:, :, :2] = 0.0                            # m <= 1: no sync
    T = np.maximum(t_sum[:, :, None], sync) / np.maximum(m, 1)
    T[:, :, 0] = np.inf
    return T


def partition(profiles: Sequence[LayerProfile], machines: int,
              hw: Hardware) -> Partition:
    """The paper's DP (general mode, per-stage replication).

    The O(N²M²) recurrence with the inner machine-split loop vectorized
    over m' in numpy; bit-identical to :func:`partition_scalar` (the
    original pure-Python DP, kept as the benchmark/test oracle) —
    including its first-strict-improvement-by-1e-15 tie-breaking.
    """
    n = len(profiles)
    M = machines
    prefix = _prefix_sums(profiles)
    c = [comm_time_activations(p.a_bytes, hw) for p in profiles]
    T = _stage_time_table(profiles, M, hw, prefix)

    INF = float("inf")
    A = np.full((n + 1, M + 1), INF)
    # split[j][m] = (i, m') chosen, or None for single stage
    split: List[List[Optional[Tuple[int, int]]]] = [
        [None] * (M + 1) for _ in range(n + 1)]

    A[1][1:] = T[0, 0, 1:]
    A[1:, 1] = T[0, :, 1]

    comm = 2.0 * np.asarray(c, np.float64)
    for j in range(2, n + 1):
        for m in range(2, M + 1):
            best = float(T[0, j - 1, m])                        # Case 1
            arg = None
            # Case 2 over all (i, m') at once: one stage i..j-1 on m'
            # machines after an optimal sub-pipeline over 1..i on m - m'.
            cand = np.maximum(A[1:j, m - 1:0:-1],
                              np.maximum(comm[0:j - 1, None],
                                         T[1:j, j - 1, 1:m]))
            flat = cand.ravel()
            # row-major order == the scalar loop's (i asc, m' asc) visit
            # order, so replaying only the improving entries reproduces
            # its running-best tie-breaking exactly.
            for k in np.flatnonzero(flat < best - 1e-15):
                if flat[k] < best - 1e-15:
                    best = float(flat[k])
                    arg = (int(k) // (m - 1) + 1, int(k) % (m - 1) + 1)
            A[j][m] = best
            split[j][m] = arg

    # Reconstruct
    stages: List[Stage] = []
    j, m = n, M
    while j > 0:
        arg = split[j][m]
        if arg is None:
            stages.append(Stage(0, j - 1, m))
            break
        i, mp = arg
        stages.append(Stage(i, j - 1, mp))
        j, m = i, m - mp
    stages.reverse()
    noam = paper_noam(machines, stages[0].replicas)
    return Partition(tuple(stages), float(A[n][M]), noam)


def partition_scalar(profiles: Sequence[LayerProfile], machines: int,
                     hw: Hardware) -> Partition:
    """Original pure-Python O(N²M²) DP — oracle for :func:`partition`."""
    n = len(profiles)
    M = machines
    prefix = _prefix_sums(profiles)
    c = [comm_time_activations(p.a_bytes, hw) for p in profiles]

    INF = float("inf")
    A = np.full((n + 1, M + 1), INF)
    split: List[List[Optional[Tuple[int, int]]]] = [
        [None] * (M + 1) for _ in range(n + 1)]

    for m in range(1, M + 1):
        A[1][m] = stage_time(profiles, 0, 0, m, hw, prefix)
    for j in range(1, n + 1):
        A[j][1] = stage_time(profiles, 0, j - 1, 1, hw, prefix)

    for j in range(2, n + 1):
        for m in range(2, M + 1):
            best = stage_time(profiles, 0, j - 1, m, hw, prefix)  # Case 1
            arg = None
            for i in range(1, j):
                for mp in range(1, m):
                    cand = max(A[i][m - mp],
                               2.0 * c[i - 1],
                               stage_time(profiles, i, j - 1, mp, hw, prefix))
                    if cand < best - 1e-15:
                        best, arg = cand, (i, mp)
            A[j][m] = best
            split[j][m] = arg

    stages: List[Stage] = []
    j, m = n, M
    while j > 0:
        arg = split[j][m]
        if arg is None:
            stages.append(Stage(0, j - 1, m))
            break
        i, mp = arg
        stages.append(Stage(i, j - 1, mp))
        j, m = i, m - mp
    stages.reverse()
    noam = paper_noam(machines, stages[0].replicas)
    return Partition(tuple(stages), float(A[n][M]), noam)


def partition_brute_force(profiles: Sequence[LayerProfile], machines: int,
                          hw: Hardware) -> float:
    """Exhaustive optimum (tiny instances only) — test oracle for the DP."""
    n = len(profiles)
    prefix = _prefix_sums(profiles)
    c = [comm_time_activations(p.a_bytes, hw) for p in profiles]
    best = [float("inf")]

    def rec(layer: int, machines_left: int, cur_max: float):
        if cur_max >= best[0]:
            return
        if layer == n:
            if machines_left == 0:
                best[0] = cur_max
            return
        for j in range(layer, n):
            comm = 2.0 * c[j] if j + 1 < n else 0.0
            for m in range(1, machines_left + 1):
                t = stage_time(profiles, layer, j, m, hw, prefix)
                rec(j + 1, machines_left - m, max(cur_max, t, comm))

    rec(0, machines, 0.0)
    return best[0]


# --------------------------------------------------------------------------
# Rectangular mode: uniform replication (TPU data axis), S stages
# --------------------------------------------------------------------------

def partition_rectangular(profiles: Sequence[LayerProfile], n_stages: int,
                          data_replicas: int, hw: Hardware) -> Partition:
    """Balanced contiguous split into exactly ``n_stages`` stages.

    Replication is uniform (= the data mesh axis), so the objective is the
    paper's with m' fixed: minimize max(stage compute, uniform sync, 2·C
    at each boundary).  DP over (layer, stage) in O(N²S).
    """
    n = len(profiles)
    prefix = _prefix_sums(profiles)
    c = [comm_time_activations(p.a_bytes, hw) for p in profiles]

    def seg(i, j):  # layers i..j inclusive
        tp, wp = prefix
        t_sum = tp[j + 1] - tp[i]
        sync = comm_time_weight_sync(wp[j + 1] - wp[i], data_replicas, hw)
        return max(t_sum, sync)

    INF = float("inf")
    A = np.full((n + 1, n_stages + 1), INF)
    arg = np.full((n + 1, n_stages + 1), -1, np.int64)
    A[0][0] = 0.0
    for j in range(1, n + 1):
        for k in range(1, min(j, n_stages) + 1):
            for i in range(k - 1, j):
                boundary = 2.0 * c[i - 1] if i > 0 else 0.0
                cand = max(A[i][k - 1], boundary, seg(i, j - 1))
                if cand < A[j][k]:
                    A[j][k] = cand
                    arg[j][k] = i

    stages: List[Stage] = []
    j, k = n, n_stages
    while k > 0:
        i = int(arg[j][k])
        stages.append(Stage(i, j - 1, data_replicas))
        j, k = i, k - 1
    stages.reverse()
    return Partition(tuple(stages), float(A[n][n_stages]),
                     paper_noam(n_stages, 1))


def uniform_layer_split(n_layers: int, n_stages: int) -> List[Tuple[int, int]]:
    """Equal-count contiguous split (what the mesh path uses when all
    blocks are homogeneous — the rectangular DP reduces to this)."""
    assert n_layers % n_stages == 0
    lps = n_layers // n_stages
    return [(s * lps, (s + 1) * lps - 1) for s in range(n_stages)]


# --------------------------------------------------------------------------
# Schedule-aware, memory-aware plan search
# --------------------------------------------------------------------------
#
# The paper's DP minimizes the steady-state bottleneck; with schedules
# pluggable (core/schedule.py) that objective is blind to the two things
# that differ per schedule: the bubble and the HBM footprint.  plan_search
# sweeps (pp, tp, schedule, virtual_stages) over feasible candidates,
# scores each by the simulated time-weighted round_time of its schedule
# tables over the rectangular-DP partition, and rejects any candidate
# whose MemoryModel exceeds the device HBM budget — the PipeDream-2BW /
# BaPipe "joint planner" move.

@dataclasses.dataclass(frozen=True)
class PlanChoice:
    """One scored (pp, tp, schedule, v) candidate.

    ``round_time`` is the ranking score for the candidate's workload:
    the simulated train round for ``workload='train'``, the per-token
    decode round for ``'decode'``, and the weighted time-to-first-token
    (ramp ticks) for ``'prefill'``.
    """

    plan: object                   # ParallelismPlan
    partition: Partition           # rectangular split into pp·v chunks
    round_time: float              # simulated wall-clock of one round [s]
    bubble_fraction: float         # time-weighted idle fraction
    memory: MemoryModel
    hbm_bytes: float               # budget the candidate was checked against
    feasible: bool                 # memory.total_bytes <= hbm_bytes
    workload: str = "train"        # train | prefill | decode
    occupancy: float = 1.0         # expected live-slot fraction (decode)
    # the bucket-lattice variant the round_time was scored on: the
    # smallest compacted size >= occupancy·R slots (R at occupancy 1)
    bucket: Optional[int] = None
    # speculative decode: the draft depth this candidate was priced at
    # (None = non-speculative); round_time is then per *accepted* token
    spec_k: Optional[int] = None
    # quantized storage the candidate was priced at (repro.quant):
    # weight payload dtype and KV-cache storage dtype (None = compute)
    weight_dtype: Optional[str] = None
    kv_dtype: Optional[str] = None

    @property
    def per_microbatch(self) -> float:
        return self.round_time / self.plan.microbatches

    def describe(self) -> str:
        ok = "fits" if self.feasible else "OVER BUDGET"
        score = "ttft" if self.workload == "prefill" else "round"
        return (f"pp={self.plan.pp} tp={self.plan.tp} "
                f"sched={self.plan.schedule}/{self.plan.stash_mode}"
                f"{f' v={self.plan.virtual_stages}' if self.plan.virtual_stages > 1 else ''}"
                f"{f' k={self.spec_k}' if self.spec_k is not None else ''}"
                f"{f' w={self.weight_dtype}' if self.weight_dtype else ''}"
                f"{f' kv={self.kv_dtype}' if self.kv_dtype else ''}"
                f" {score}={self.round_time * 1e3:.3f} ms"
                f" bubble={self.bubble_fraction:.3f}"
                f" hbm={self.memory.total_bytes / 1e9:.2f}"
                f"/{self.hbm_bytes / 1e9:.1f} GB [{ok}]")


def _candidate_plan(base_plan, pp: int, tp: int, name: str, v: int):
    """base_plan rewritten to one (pp, tp, schedule, v) candidate.

    The schedule -> (stash_mode, virtual_stages) policy lives on the
    registry classes (core.schedule.plan_kwargs_for_schedule), so a
    newly registered schedule is picked up here without edits.
    """
    kw = plan_kwargs_for_schedule(name, virtual_stages=v,
                                  stash_mode=base_plan.stash_mode)
    return base_plan.with_(pp=pp, tp=tp, **kw)


def stage_phase_times(profiles: Sequence[LayerProfile], part: Partition,
                      pp: int, tp: int, hw: Hardware, *,
                      data_replicas: int = 1):
    """Per-physical-stage (t_fwd, t_bwd) seconds for a chunked partition.

    ``part`` splits the profiles into pp·v chunks (layer order); chunk c
    runs on stage c % pp (the interleaved placement; v=1 reduces to the
    identity).  Compute divides by tp, each layer pays the tp all-reduce
    both directions, and the wait-free weight sync floors the stage's
    total (the paper's max(compute, sync) overlap model).
    """
    tf = np.zeros(pp)
    tb = np.zeros(pp)
    w = np.zeros(pp)
    for c, st in enumerate(part.stages):
        s = c % pp
        span = profiles[st.start:st.end + 1]
        ar = sum(comm_time_tp_allreduce(p.a_bytes, tp, hw) for p in span)
        tf[s] += sum(p.t_fwd for p in span) / tp + ar
        tb[s] += sum(p.t_bwd for p in span) / tp + ar
        w[s] += sum(p.w_params for p in span) / tp
    for s in range(pp):
        sync = comm_time_weight_sync(w[s], data_replicas, hw)
        tot = tf[s] + tb[s]
        if sync > tot > 0:
            tf[s] *= sync / tot
            tb[s] *= sync / tot
    return tf, tb


def plan_search(spec, base_plan, model_axis: int, hw: Hardware, *,
                minibatch_tokens: int, data_replicas: int = 1,
                profiles: Optional[Sequence[LayerProfile]] = None,
                schedules: Optional[Sequence[str]] = None,
                max_virtual_stages: int = 4,
                hbm_bytes: Optional[float] = None,
                return_all: bool = False,
                workload: str = "train",
                cache_len: Optional[int] = None,
                global_batch: Optional[int] = None,
                sp: bool = False,
                occupancy: float = 1.0,
                page_size: int = 0,
                spec_k: Optional[int] = None,
                spec_acceptance: float = 0.8,
                spec_draft_cost: float = 0.05,
                spec_verify_cost: float = 0.15,
                weight_dtype: Optional[str] = None,
                kv_dtype: Optional[str] = None):
    """Jointly pick (pp, tp, schedule, virtual_stages) for a model axis.

    Enumerates every pp dividing ``model_axis`` whose chunk count
    divides the layer stack (and whose tp divides the heads), builds the
    candidate's schedule tables, and scores it by the simulated
    time-weighted round_time of those tables over the rectangular-DP
    partition.  Candidates whose :class:`~repro.core.schedule.MemoryModel`
    exceeds the HBM budget (``hw.hbm_bytes`` unless overridden) are
    rejected outright — a plan that does not fit is not a plan.

    ``workload`` selects the execution mode being planned:

    * ``"train"`` — the training registry schedules, scored by the
      simulated round_time (the default, unchanged behaviour);
    * ``"decode"`` — the serving schedules (``serve_1f``,
      ``serve_interleaved``), scored by the per-token round time of the
      forward-only tables, with the attention span pinned to
      ``cache_len`` in the analytic profile;
    * ``"prefill"`` — the serving schedules scored by
      :func:`~repro.core.schedule.serve_ttft` (weighted ramp ticks —
      the worst request's time-to-first-token).

    Serving workloads require ``cache_len=`` and ``global_batch=`` (and
    honor ``sp=``): the MemoryModel then carries the KV/SSM cache term,
    so a decode plan is budgeted exactly like a training plan —
    including rejection when the cache does not fit.  The microbatch
    count is the one the engine will actually run
    (:func:`~repro.core.schedule.fit_serving_microbatches`: batch-fitted
    against ``data_replicas``, 1 under ``sp``), so ramp, workspace and
    TTFT describe the executed tables, not the config's nominal R.

    ``occupancy`` (decode only, 0 < occupancy <= 1) prices a
    continuously batched server at its *expected* live-slot fraction
    instead of assuming a full batch: the expected live count
    ``ceil(occupancy · R)`` is ceiled to the engine's bucket lattice
    (:func:`~repro.core.schedule.pick_bucket` over
    :func:`~repro.core.schedule.bucket_lattice`) and the round is
    scored over that bucket's compacted tables
    (:meth:`~repro.core.schedule.ServingSchedule.bucketed` — provably
    the full-R tables with dead slots deleted), while the MemoryModel
    keeps budgeting the full-R capacity the engine actually allocates.
    This is the round the liveness-aware executor *executes*, not an
    analytic bound: ``build_serving(buckets=True)`` runs exactly the
    bucket-sized program the score walks (serving/engine.py), including
    the slot-ceiling — a 25%-occupancy batch on an R = 8 lattice runs
    the 2-slot bucket, not a hypothetical 2.0-slot table.  The chosen
    bucket is recorded on :attr:`PlanChoice.bucket`.  At occupancy 1
    the behaviour is unchanged (the lattice tops out at R).

    ``page_size`` (serving only) prices the paged KV cache the engine
    allocates under ``build_serving(page_size=...)``: full-length
    attention KV is budgeted by pages in use — ``occupancy`` worth of
    slots, rounded up to whole slots — instead of full-R capacity,
    while recurrent state and windowed ring buffers stay dense
    (:func:`~repro.core.schedule.serving_cache_bytes`).  A decode plan
    that is HBM-infeasible dense can therefore fit paged at the same R.
    Rejected with ``sp`` (the engine refuses that combination too).

    ``spec_k`` (decode only) prices the speculative draft–verify
    schedules (``serve_spec_1f``, ``serve_spec_interleaved``) alongside
    the plain ones: every draft depth k in ``1..spec_k`` becomes a
    candidate, scored per *accepted* token — the verify round is
    stretched by the k extra query positions
    (``1 + k·spec_verify_cost``) plus k head-only draft steps
    (``k·spec_draft_cost`` of a mean stage forward), then divided by
    the expected advance under the acceptance-rate parameter
    ``spec_acceptance`` (alpha):

        E[advance] = (1 - alpha^(k+1)) / (1 - alpha)

    the standard speculative-decoding expectation (Leviathan et al.) —
    at alpha = 0.7, k = 4 one verify round commits ~2.77 tokens.  The
    chosen depth lands on :attr:`PlanChoice.spec_k`; plain schedules
    stay in the pool, so a low ``spec_acceptance`` simply prices
    speculation out of the ranking instead of forcing it.

    Pass measured-calibrated ``profiles``
    (profiler.scale_profiles_to_measurements) to make the search respond
    to live straggler measurements.  Tie-breaking is deterministic:
    round_time, then keeping the base plan's schedule, then lower HBM,
    then shallower pipe.

    Returns the best :class:`PlanChoice` (``return_all=True``: the full
    ranked candidate list instead, infeasible ones included).
    """
    assert workload in ("train", "prefill", "decode"), workload
    assert 0.0 < occupancy <= 1.0, occupancy
    assert occupancy == 1.0 or workload == "decode", (
        "occupancy < 1 models a partially live decode batch; prefill "
        "and train rounds are full by construction")
    serving = workload != "train"
    if serving:
        assert cache_len is not None and global_batch is not None, (
            f"plan_search(workload={workload!r}) needs cache_len= and "
            "global_batch= to size the KV/SSM cache term")
    assert page_size == 0 or serving, (
        "page_size prices the serving engine's paged KV cache; training "
        "plans have no KV cache")
    assert (weight_dtype is None and kv_dtype is None) or serving, (
        "weight_dtype/kv_dtype price quantized *serving* storage; "
        "training keeps full-precision weights")
    assert not (page_size and sp), (
        "paged KV and sequence-parallel decode are mutually exclusive "
        "(the engine rejects the combination)")
    if spec_k is not None:
        assert workload == "decode", (
            "spec_k prices speculative draft-verify decode; prefill and "
            "train rounds have no draft loop")
        assert spec_k >= 1, f"spec_k must be >= 1, got {spec_k}"
        assert 0.0 < spec_acceptance <= 1.0, spec_acceptance
    if profiles is None:
        profiles = profile_analytic(
            spec, hw, minibatch_tokens=minibatch_tokens,
            kv_len=cache_len if workload == "decode" else None)
    budget = float(hw.hbm_bytes if hbm_bytes is None else hbm_bytes)
    if serving:
        # price the R the engine will actually run: batch-fitted, and 1
        # under sequence-parallel decode (rows replicate) — not the
        # config's nominal decode_microbatches
        R = fit_serving_microbatches(base_plan.decode_microbatches,
                                     global_batch, max(data_replicas, 1),
                                     sp=sp)
        base_plan = base_plan.with_(decode_microbatches=R)
    else:
        R = base_plan.microbatches
    names = tuple(schedules) if schedules else (
        (("serve_1f", "serve_interleaved")
         + (("serve_spec_1f", "serve_spec_interleaved")
            if workload == "decode" and spec_k else ()))
        if serving
        else ("1f1b", "gpipe", "interleaved", "interleaved_async"))
    if spec_k is None and any(
            getattr(SCHEDULES.get(n), "is_speculative", False)
            for n in names):
        raise ValueError(
            "speculative schedules in schedules= need spec_k= (the max "
            "draft depth to price); got spec_k=None")
    base_name = (make_serving_schedule(base_plan).name if serving
                 else make_schedule(base_plan).name)
    cands: List[PlanChoice] = []
    parts: dict = {}        # n_chunks -> Partition (schedule-independent)
    phases: dict = {}       # (pp, v, tp) -> (t_fwd, t_bwd)
    for pp in range(1, model_axis + 1):
        if model_axis % pp:
            continue
        tp = model_axis // pp
        if spec.n_heads and spec.n_heads % tp:
            continue
        for name in names:
            cls = SCHEDULES.get(name)
            assert cls is not None, (
                f"unknown schedule {name!r}; registered: "
                f"{sorted(SCHEDULES)}")
            assert cls.is_serving == serving, (
                f"schedule {name!r} does not run the {workload!r} "
                "workload")
            vs = (tuple(range(2, max_virtual_stages + 1))
                  if cls.takes_virtual_stages else (1,))
            for v in vs:
                n_chunks = pp * v
                if spec.n_layers % n_chunks:
                    continue
                # training interleaved family: microbatch groups need
                # R % S == 0 (the serving family lifts this — fwd-only)
                if cls.takes_virtual_stages \
                        and cls.needs_group_microbatches and R % pp:
                    continue
                try:
                    spec.stage_program(n_chunks)
                except AssertionError:
                    continue
                plan = _candidate_plan(base_plan, pp, tp, name, v)
                base_sched = plan.make_schedule()
                part = parts.get(n_chunks)
                if part is None:
                    part = parts[n_chunks] = partition_rectangular(
                        profiles, n_chunks, data_replicas, hw)
                key = (pp, v, tp)
                if key not in phases:
                    phases[key] = stage_phase_times(
                        profiles, part, pp, tp, hw,
                        data_replicas=data_replicas)
                tf, tb = phases[key]
                # a speculative schedule is one candidate per draft
                # depth k in 1..spec_k; plain schedules sweep (None,)
                ks = (tuple(range(1, spec_k + 1))
                      if getattr(cls, "is_speculative", False)
                      else (None,))
                for kk in ks:
                    sched = (base_sched if kk is None else
                             dataclasses.replace(base_sched, spec_k=kk))
                    if serving:
                        mm = sched.memory_model(
                            spec, plan, hw,
                            microbatch_tokens=minibatch_tokens,
                            data_replicas=data_replicas,
                            cache_len=cache_len,
                            global_batch=global_batch, sp=sp,
                            prefill=(workload == "prefill"),
                            page_size=page_size, kv_occupancy=occupancy,
                            weight_dtype=weight_dtype, kv_dtype=kv_dtype)
                    else:
                        mm = sched.memory_model(
                            spec, plan, hw,
                            microbatch_tokens=minibatch_tokens,
                            data_replicas=data_replicas)
                    scored = sched
                    bucket = None
                    if serving and occupancy < 1.0:
                        # price what the bucketed executor executes: the
                        # smallest compacted variant covering the
                        # expected live count, not a fractional-slot
                        # analytic bound
                        n_live = max(1, math.ceil(occupancy * R))
                        bucket = pick_bucket(n_live, bucket_lattice(R))
                        scored = sched.bucketed(bucket)
                    rt, bubble = weighted_round_time(scored, tf, tb)
                    if workload == "prefill":
                        rt = serve_ttft(scored, tf)
                    if kk is not None:
                        # per-ACCEPTED-token round: stretch the verify
                        # round for the k extra query positions, add k
                        # head-only draft steps, divide by the expected
                        # advance under the acceptance rate alpha
                        alpha = spec_acceptance
                        exp_adv = (float(kk + 1) if alpha >= 1.0 else
                                   (1.0 - alpha ** (kk + 1))
                                   / (1.0 - alpha))
                        rt = (rt * (1.0 + kk * spec_verify_cost)
                              + kk * spec_draft_cost
                              * float(np.mean(tf))) / exp_adv
                    cands.append(PlanChoice(plan, part, rt, bubble, mm,
                                            budget,
                                            feasible=mm.fits(budget),
                                            workload=workload,
                                            occupancy=occupancy,
                                            bucket=bucket, spec_k=kk,
                                            weight_dtype=weight_dtype,
                                            kv_dtype=kv_dtype))
    assert cands, f"no structurally valid plan for model_axis={model_axis}"

    def rank(c: PlanChoice):
        return (c.round_time, c.plan.schedule != base_name,
                c.memory.total_bytes, c.plan.pp, c.plan.virtual_stages)

    cands.sort(key=rank)
    if return_all:
        return cands
    feasible = [c for c in cands if c.feasible]
    assert feasible, (
        f"no plan fits the {budget / 1e9:.1f} GB HBM budget; closest: "
        f"{min(cands, key=lambda c: c.memory.total_bytes).describe()}")
    return feasible[0]
