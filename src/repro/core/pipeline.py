"""PipeDream pipelined training as one jit'd SPMD step (paper §3.3–3.5).

One ``train_step`` = one *round* of R microbatches through a pluggable
:class:`~repro.core.schedule.PipelineSchedule`.  The scan body is one
double-tick:

  F shard_map   every stage gathers its row of the schedule's forward
                table — (microbatch, local chunk, input source, stash
                slot, weight-version slot, residual slot) — forwards
                that chunk, records weights/residuals into the slots the
                table names, and ppermutes activations downstream.
  head/loss     (pjit level, vocab-sharded over the whole model axis)
                the microbatch the schedule's exit table names gets its
                loss and d(loss)/d(hidden); the owning stage starts its
                backward in the same tick — Figure 8's F(m),B(m)
                adjacency.
  B shard_map   every stage gathers its backward-table row, re-runs the
                chunk forward under jax.vjp with the *table-named*
                weight version and residual (stage-granular remat),
                psums/reduce-scatters stage grads over the replica axis
                (replicated stages, §3.2), and either applies its update
                immediately (asynchronous per-stage updates) or
                accumulates for a round-end flush, then ppermutes input
                grads upstream.

All microbatch/slot indices come from gathered schedule-table rows —
there is no tick/stage index arithmetic in this module; adding a
schedule means subclassing PipelineSchedule, not editing this file.
The schedule registry (core/schedule.py) maps ``plan.schedule`` /
``plan.stash_mode`` onto:

  1f1b         paper default (policy 'stash': F latest, B stashed; or
               'vertical': uniform delayed version), update per mb.
  gpipe        flush family — 1F1B timing, grads accumulated, one
               synchronous update per round ('flush' = 1 weight
               version, '2bw' = PipeDream-2BW-style double buffer).
  interleaved  Megatron-style virtual stages: each physical stage holds
               ``plan.virtual_stages`` model chunks (stage-stacked
               params carry S·v rows in storage order s·v+j -> chunk
               j·S+s), shrinking the bubble for S >= 3.  Flush
               semantics (accumulate).
  interleaved_async
               the same interleaved timing with per-microbatch updates:
               each chunk keeps its own weight-version ring, stored
               chunk-major ([V, S·v, ...] — slot, then storage row), F
               records the chunk's live weights into (slot, chunk) and
               B re-reads exactly that version, then updates only that
               chunk's weight/optimizer rows.

Weight-stash ring primitives and the ZeRO-1 sharded-optimizer update
live in core/versioning.py.  Boundary ticks run the same program on
masked data — the pipeline bubble costs real slots, exactly as on
hardware.  Embedding updates apply once per round; head/final-norm
update per tick (output-stage semantics).  See DESIGN.md §5/§7.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import schedule as sched_lib
from repro.core.schedule import (B_CHUNK, B_FROM_HEAD, B_MB, B_RESID_READ,
                                 B_VERSION, F_CHUNK, F_FROM_EMBEDS, F_MB,
                                 F_RESID_WRITE, F_STASH_WRITE, F_VERSION,
                                 PipelineSchedule)
from repro.core.versioning import (replicated_microbatch_update, tree_add,
                                   tree_chunk, tree_chunk_add,
                                   tree_chunk_ring_read,
                                   tree_chunk_ring_write, tree_chunk_write,
                                   tree_ring_read, tree_ring_write,
                                   tree_scale, tree_select, zero1_axes,
                                   zero1_microbatch_update, zero1_opt_pspec)
from repro.models import lm_head
from repro.models import spec as spec_lib
from repro.models.init import init_params
from repro.models.stage import StageStatics, encoder_fwd, make_statics, stage_fwd
from repro.parallel.compat import shard_map
from repro.parallel.mesh import AXIS_STAGE, AXIS_TENSOR, ParallelismPlan, data_axes


def _is_pspec(x):
    return isinstance(x, P)


# --------------------------------------------------------------------------
# Bundle
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineBundle:
    spec: spec_lib.ModelSpec
    plan: ParallelismPlan
    mesh: Mesh
    statics: StageStatics
    sched: PipelineSchedule
    train_step: Callable            # (state, batch) -> (state, metrics)
    init_state: Callable            # (key) -> state
    state_pspecs: Any
    batch_pspecs: Dict[str, P]
    batch_shapes: Dict[str, jax.ShapeDtypeStruct]
    seq_len: int
    microbatch_size: int
    # observability hook (repro.obs.Observability or None = off): the
    # driver reports one on_round("train", sched, ...) per executed
    # round against this bundle's schedule table
    obs: Any = None

    def state_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_pspecs, is_leaf=_is_pspec)

    def batch_shardings(self):
        return {k: NamedSharding(self.mesh, v)
                for k, v in self.batch_pspecs.items()}

    def batch_specs(self):
        sh = self.batch_shardings()
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=sh[k])
                for k, v in self.batch_shapes.items()}


def build_pipeline(spec: spec_lib.ModelSpec, plan: ParallelismPlan,
                   mesh: Mesh, *, seq_len: int, global_batch: int,
                   optimizer, aux_weight: float = 0.01,
                   compute_dtype=jnp.bfloat16, obs=None) -> PipelineBundle:
    """Construct the pipelined train step for one (arch, shape, mesh)."""
    S = plan.pp
    R = plan.microbatches
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.devices.shape[mesh.axis_names.index(a)]
                      for a in daxes]))
    assert global_batch % (dp * R) == 0, (global_batch, dp, R)
    mb = global_batch // (dp * R)          # per-replica microbatch size
    bmb = global_batch // R                # global rows per microbatch

    sched = sched_lib.make_schedule(plan)
    assert not sched.is_serving, (
        f"schedule {sched.name!r} is forward-only (serving): it has no "
        "backward slots to train with — drive it through "
        "serving/engine.py::build_serving instead")
    sched.validate()
    vs = sched.virtual_stages               # local chunks per stage
    n_chunks = sched.n_chunks
    V = sched.stash_slots                   # weight-version ring size
    Vr = sched.resid_slots                  # residual ring size
    use_ring = sched.uses_stash_ring
    accumulate = sched.accumulate or plan.grad_sync == "per_round"
    # vs > 1 with a ring is the async interleaved schedule: the stash is
    # chunk-major ([V, S·v, ...]) and F/B index it by the table's
    # (version-slot, chunk) column pair.  No schedule forwards *from*
    # the stash at virtual stages (vertical sync is vs == 1 only).
    assert not (sched.fwd_from_stash and vs > 1), sched.name
    # Static schedule tables; gathered per (tick, stage) inside the
    # shard_map bodies — they become tiny jaxpr constants.
    tabs = sched.tables()
    FT, BT = np.asarray(tabs.fwd), np.asarray(tabs.bwd)
    EXIT_T, DEMB_T = np.asarray(tabs.exit_mb), np.asarray(tabs.demb_mb)
    # The model is cut into n_chunks pieces; all model-side construction
    # (init, statics, per-layer scalars) sees the chunk count as "pp".
    mplan = plan.with_(pp=n_chunks, schedule="auto", virtual_stages=1) \
        if vs > 1 else plan

    tp_axis = AXIS_TENSOR if plan.tp > 1 else None
    # ZeRO-1: opt-state sharding over data applies in every mode; the
    # manual reduce-scatter/all-gather update is only needed on the
    # per-microbatch (non-accumulate) path — the round-end pjit update
    # is partitioned by XLA from the pspecs alone.
    zero1_shard = plan.zero1 and dp > 1
    zero1_manual = zero1_shard and not accumulate
    is_vlm = spec.frontend == "vision"
    has_enc = spec.encoder is not None
    n_patch = spec.n_patches if is_vlm else 0
    text_len = seq_len - n_patch

    statics = make_statics(spec, mplan, tokens_per_mb=mb * seq_len)
    dnames = daxes if len(daxes) > 1 else daxes[0]

    enc_len = spec.encoder.source_len if has_enc else 1
    d_enc = spec.encoder.d_model if has_enc else 1

    def run_stage(w_stage, x, windows, thetas, cross_x=None):
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                               (x.shape[0], seq_len))
        h, _, aux = stage_fwd(w_stage, x, statics, positions=pos,
                              windows=windows, thetas=thetas,
                              tp_axis=tp_axis, cross_x=cross_x)
        return h, aux

    if vs > 1:
        # chunk transitions wrap from the last stage back to stage 0
        fwd_perm = [(i, (i + 1) % S) for i in range(S)] if S > 1 else []
        bwd_perm = [((i + 1) % S, i) for i in range(S)] if S > 1 else []
    else:
        fwd_perm = [(i, i + 1) for i in range(S - 1)]
        bwd_perm = [(i + 1, i) for i in range(S - 1)]

    def gather_row(table, tick):
        """Row of a [T, S, C] schedule table for (tick, this stage)."""
        s = jax.lax.axis_index(AXIS_STAGE)
        rows = jax.lax.dynamic_index_in_dim(jnp.asarray(table), tick, 0,
                                            keepdims=False)
        return jax.lax.dynamic_index_in_dim(rows, s, 0, keepdims=False)

    def local_chunk(weights, windows, thetas, chunk):
        """This tick's chunk view of the stage-local stacked params."""
        if vs == 1:
            return weights, windows[0], thetas[0]
        return (tree_chunk(weights, chunk),
                jax.lax.dynamic_index_in_dim(windows, chunk, 0,
                                             keepdims=False),
                jax.lax.dynamic_index_in_dim(thetas, chunk, 0,
                                             keepdims=False))

    # ======================= F phase (shard_map body) ===================
    def f_phase(tick, weights, stash, resid, recv_f, embeds, windows,
                thetas, enc_ring):
        row = gather_row(FT, tick)
        f = row[F_MB]
        valid = f >= 0
        fsafe = jnp.clip(f, 0, R - 1)

        w_loc, win_loc, th_loc = local_chunk(weights, windows, thetas,
                                             row[F_CHUNK])
        x0 = jax.lax.dynamic_index_in_dim(embeds, fsafe, 0, keepdims=False)
        x_in = jnp.where(row[F_FROM_EMBEDS] > 0, x0, recv_f[0])
        if use_ring:
            stash = (tree_ring_write(stash, row[F_STASH_WRITE], w_loc,
                                     valid)
                     if vs == 1 else
                     tree_chunk_ring_write(stash, row[F_STASH_WRITE],
                                           row[F_CHUNK], w_loc, valid))
        if sched.fwd_from_stash:
            w_f = tree_ring_read(stash, row[F_VERSION])
        else:
            w_f = w_loc
        cross = None
        if has_enc:
            cross = jax.lax.dynamic_index_in_dim(enc_ring, fsafe, 0,
                                                 keepdims=False)
        h, aux = run_stage(w_f, x_in, win_loc, th_loc, cross)
        slot = row[F_RESID_WRITE]
        old = jax.lax.dynamic_index_in_dim(resid, slot, 0, keepdims=False)
        resid = jax.lax.dynamic_update_index_in_dim(
            resid, jnp.where(valid, x_in[None].astype(resid.dtype), old),
            slot, 0)
        h_send = jax.lax.ppermute(h, AXIS_STAGE, fwd_perm) if S > 1 else h
        aux = aux * valid.astype(aux.dtype)
        return stash, resid, h_send[None], h[None], aux[None]

    # ======================= B phase (shard_map body) ===================
    def b_phase(tick, step, weights, stash, opt_state, resid, recv_b,
                g_exit, grad_acc, windows, thetas, enc_ring, denc_ring):
        row = gather_row(BT, tick)
        b = row[B_MB]
        valid = b >= 0
        bsafe = jnp.clip(b, 0, R - 1)

        w_loc, win_loc, th_loc = local_chunk(weights, windows, thetas,
                                             row[B_CHUNK])
        g_in = jnp.where(row[B_FROM_HEAD] > 0, g_exit, recv_b[0])
        if use_ring:
            w_used = (tree_ring_read(stash, row[B_VERSION]) if vs == 1
                      else tree_chunk_ring_read(stash, row[B_VERSION],
                                                row[B_CHUNK]))
        else:
            w_used = w_loc
        x_saved = jax.lax.dynamic_index_in_dim(
            resid, row[B_RESID_READ], 0, keepdims=False)[0]
        # g_exit carries global-batch normalization (head loss is a mean
        # over all Bmb rows), so psum of per-replica partial dW is already
        # the exact global gradient; aux is averaged over replicas.
        aux_ct = jnp.float32(aux_weight / dp) * valid.astype(jnp.float32)

        if has_enc:
            cross = jax.lax.dynamic_index_in_dim(enc_ring, bsafe, 0,
                                                 keepdims=False)

            def f_full(w, x, cx):
                return run_stage(w, x, win_loc, th_loc, cx)

            _, vjp = jax.vjp(f_full, w_used, x_saved, cross)
            dW, dx, dcx = vjp((g_in.astype(x_saved.dtype), aux_ct))
            old = jax.lax.dynamic_index_in_dim(denc_ring[0], bsafe, 0,
                                               keepdims=False)
            dcx = jnp.where(valid, dcx.astype(denc_ring.dtype), old)
            denc_ring = jax.lax.dynamic_update_index_in_dim(
                denc_ring[0], dcx, bsafe, 0)[None]
        else:
            def f_txt(w, x):
                return run_stage(w, x, win_loc, th_loc)

            _, vjp = jax.vjp(f_txt, w_used, x_saved)
            dW, dx = vjp((g_in.astype(x_saved.dtype), aux_ct))

        dW = tree_scale(dW, valid.astype(jnp.float32))
        dx = dx * valid.astype(dx.dtype)

        if accumulate:
            if vs == 1:
                grad_acc = tree_add(grad_acc, dW)
            else:
                grad_acc = tree_chunk_add(grad_acc, dW, row[B_CHUNK])
            new_w, new_opt = weights, opt_state
        else:
            # per-microbatch update of exactly the chunk this B row
            # names: vs == 1 updates the whole stage block in place;
            # vs > 1 (async interleaved) reads the chunk's weight and
            # optimizer rows, updates them, and writes them back — the
            # stage's other chunks are untouched this tick.
            upd_o = (tree_chunk(opt_state, row[B_CHUNK]) if vs > 1
                     else opt_state)
            upd_w = w_loc if vs > 1 else weights
            if zero1_manual:
                upd_w, upd_o = zero1_microbatch_update(
                    optimizer, dW, upd_o, upd_w, step, valid,
                    z1_axes=z1_axes, daxes=daxes, dnames=dnames, dp=dp)
            else:
                upd_w, upd_o = replicated_microbatch_update(
                    optimizer, dW, upd_o, upd_w, step, valid,
                    dnames=dnames)
            if vs > 1:
                new_w = tree_chunk_write(weights, row[B_CHUNK], upd_w)
                new_opt = tree_chunk_write(opt_state, row[B_CHUNK], upd_o)
            else:
                new_w, new_opt = upd_w, upd_o

        g_send = jax.lax.ppermute(dx, AXIS_STAGE, bwd_perm) if S > 1 else dx
        return new_w, new_opt, g_send[None], grad_acc, dx[None], denc_ring

    # ======================= pspecs =====================================
    _box = {}

    def _init_for_shapes():
        p, s = init_params(spec, mplan, jax.random.key(0), compute_dtype)
        _box["pspecs"] = s  # pspecs are static; capture via side channel
        return p

    params_shape = jax.eval_shape(_init_for_shapes)
    pspecs = _box["pspecs"]

    stage_pspec = pspecs["stages"]
    stash_pspec = (jax.tree.map(lambda p: P(None, *p), stage_pspec,
                                is_leaf=_is_pspec)
                   if use_ring else {"_": P()})
    act_pspec = P(AXIS_STAGE, dnames, None, None)         # (pp,Bmb,S,d)
    resid_pspec = P(None, AXIS_STAGE, dnames, None, None)  # (Vr,pp,Bmb,S,d)
    emb_pspec = P(None, dnames, None, None)               # (R,Bmb,S,d)
    gexit_pspec = P(dnames, None, None)
    win_pspec = P(AXIS_STAGE, None)
    scalar_pspec = P()

    enc_pspec = P(None, dnames, None, None)
    denc_pspec = (P(AXIS_STAGE, None, dnames, None, None) if has_enc
                  else P(AXIS_STAGE, None, None, None, None))

    z1_axes = (zero1_axes(params_shape["stages"], stage_pspec, mesh, dp)
               if zero1_shard else
               jax.tree.map(lambda _: -1, params_shape["stages"]))
    opt_leaf_pspec = (zero1_opt_pspec(stage_pspec, z1_axes, daxes)
                      if zero1_shard else stage_pspec)
    opt_st_shape = jax.eval_shape(
        lambda: optimizer.init(params_shape["stages"]))
    opt_stage_pspec = {slot: opt_leaf_pspec for slot in opt_st_shape}

    if accumulate:
        gacc_pspec = jax.tree.map(lambda p: P(dnames, *p), stage_pspec,
                                  is_leaf=_is_pspec)
    else:
        gacc_pspec = {"_": P(dnames, None)}

    f_sharded = shard_map(
        f_phase, mesh=mesh,
        in_specs=(scalar_pspec, stage_pspec, stash_pspec, resid_pspec,
                  act_pspec, emb_pspec, win_pspec, win_pspec, enc_pspec),
        out_specs=(stash_pspec, resid_pspec, act_pspec, act_pspec,
                   P(AXIS_STAGE)),
        check_vma=False)

    b_sharded = shard_map(
        b_phase, mesh=mesh,
        in_specs=(scalar_pspec, scalar_pspec, stage_pspec, stash_pspec,
                  opt_stage_pspec, resid_pspec, act_pspec, gexit_pspec,
                  gacc_pspec, win_pspec, win_pspec, enc_pspec, denc_pspec),
        out_specs=(stage_pspec, opt_stage_pspec, act_pspec, gacc_pspec,
                   act_pspec, denc_pspec),
        check_vma=False)

    # ======================= the train step =============================
    def train_step(state, batch):
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]  # (R,Bmb,text)
        step = state["step"]

        text_embeds = lm_head.embed_tokens(params["embed"], tokens)
        if is_vlm:
            embeds = jnp.concatenate(
                [batch["patches"].astype(text_embeds.dtype), text_embeds],
                axis=2)
            lab_full = jnp.concatenate(
                [jnp.full((R, bmb, n_patch), -1, labels.dtype), labels],
                axis=2)
        else:
            embeds, lab_full = text_embeds, labels
        embeds = jax.lax.with_sharding_constraint(
            embeds.astype(compute_dtype), NamedSharding(mesh, emb_pspec))

        enc_vjp = None
        if has_enc:
            fr = batch["frames"].reshape(R * bmb, enc_len, d_enc)
            enc_out_flat, enc_vjp = jax.vjp(
                lambda ep, fx: encoder_fwd(ep, fx, spec),
                params["encoder"], fr.astype(compute_dtype))
            enc_ring = jax.lax.with_sharding_constraint(
                enc_out_flat.reshape(R, bmb, enc_len, d_enc),
                NamedSharding(mesh, enc_pspec))
        else:
            enc_ring = jnp.zeros((1, bmb, 1, 1), compute_dtype)

        zeros_act = jnp.zeros((S, bmb, seq_len, spec.d_model), compute_dtype)
        carry = {
            "w": state["stash"]["current"],
            "stash": (state["stash"]["ring"] if use_ring
                      else {"_": jnp.zeros((1,), jnp.float32)}),
            "opt": state["opt_stages"],
            "head": params["head"],
            "fnorm": params["final_norm"],
            "head_opt": state["opt_head"],
            "recv_f": zeros_act,
            "recv_b": zeros_act,
            "resid": jnp.zeros((Vr, S, bmb, seq_len, spec.d_model),
                               compute_dtype),
            "gacc": (jax.tree.map(
                lambda a: jnp.zeros((dp,) + a.shape, jnp.float32),
                params["stages"]) if accumulate
                else {"_": jnp.zeros((dp, 1), jnp.float32)}),
            "dhead_acc": (jnp.zeros(params["head"].shape, jnp.float32)
                          if accumulate else jnp.zeros((1,), jnp.float32)),
            "dfnorm_acc": (jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32),
                params["final_norm"]) if accumulate
                else jnp.zeros((1,), jnp.float32)),
            "d_embeds": jnp.zeros((R, bmb, seq_len, spec.d_model),
                                  compute_dtype),
            "denc": (jnp.zeros((S, R, bmb, enc_len, d_enc), compute_dtype)
                     if has_enc
                     else jnp.zeros((S, 1, 1, 1, 1), compute_dtype)),
            "loss_sum": jnp.zeros((), jnp.float32),
            "aux_sum": jnp.zeros((), jnp.float32),
        }

        win, th = params["layer_windows"], params["layer_thetas"]

        def tick_body(carry, tick):
            stash, resid, recv_f, h_all, aux = f_sharded(
                tick, carry["w"], carry["stash"], carry["resid"],
                carry["recv_f"], embeds, win, th, enc_ring)
            carry["stash"], carry["resid"], carry["recv_f"] = \
                stash, resid, recv_f
            carry["aux_sum"] = carry["aux_sum"] + aux.sum()

            # ---- head + loss for the exiting microbatch ----------------
            m_exit = jax.lax.dynamic_index_in_dim(
                jnp.asarray(EXIT_T), tick, 0, keepdims=False)
            valid_e = m_exit >= 0
            msafe = jnp.clip(m_exit, 0, R - 1)
            h_exit = h_all[S - 1]
            lab = jax.lax.dynamic_index_in_dim(lab_full, msafe, 0,
                                               keepdims=False)
            vmask = (lab >= 0).astype(jnp.float32)
            lab_safe = jnp.maximum(lab, 0)

            def loss_fn(head, fnorm, h):
                loss, _ = lm_head.head_loss(
                    head, fnorm["scale"], h, lab_safe, norm_kind=spec.norm,
                    norm_bias=fnorm.get("bias"), valid_mask=vmask,
                    vocab=spec.vocab)
                return loss

            loss, (dhead, dfnorm, dh) = jax.value_and_grad(
                loss_fn, argnums=(0, 1, 2))(
                carry["head"], carry["fnorm"], h_exit)
            ve = valid_e.astype(jnp.float32)
            carry["loss_sum"] = carry["loss_sum"] + loss * ve
            g_exit = (dh.astype(jnp.float32) * ve).astype(compute_dtype)

            if accumulate:
                carry["dhead_acc"] = carry["dhead_acc"] + \
                    dhead.astype(jnp.float32) * ve
                carry["dfnorm_acc"] = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) * ve,
                    carry["dfnorm_acc"], dfnorm)
            else:
                hf_new, hf_opt = optimizer.update(
                    {"h": dhead, "f": dfnorm}, carry["head_opt"],
                    {"h": carry["head"], "f": carry["fnorm"]}, step)
                carry["head"] = tree_select(valid_e, hf_new["h"],
                                            carry["head"])
                carry["fnorm"] = tree_select(valid_e, hf_new["f"],
                                             carry["fnorm"])
                carry["head_opt"] = tree_select(valid_e, hf_opt,
                                                carry["head_opt"])

            # ---- backward phase -----------------------------------------
            new_w, new_opt, recv_b, gacc, dx_all, denc = b_sharded(
                tick, step, carry["w"], carry["stash"], carry["opt"],
                carry["resid"], carry["recv_b"], g_exit, carry["gacc"],
                win, th, enc_ring, carry["denc"])
            carry["w"], carry["opt"], carry["recv_b"] = new_w, new_opt, recv_b
            carry["gacc"], carry["denc"] = gacc, denc

            # stage 0's dx is d(embeddings) when its backward finishes a
            # microbatch's first chunk (schedule demb table)
            b0 = jax.lax.dynamic_index_in_dim(
                jnp.asarray(DEMB_T), tick, 0, keepdims=False)
            valid_b0 = b0 >= 0
            b0safe = jnp.clip(b0, 0, R - 1)
            prev = jax.lax.dynamic_index_in_dim(carry["d_embeds"], b0safe, 0,
                                                keepdims=False)
            upd = jnp.where(valid_b0, dx_all[0], prev)
            carry["d_embeds"] = jax.lax.dynamic_update_index_in_dim(
                carry["d_embeds"], upd, b0safe, 0)
            return carry, None

        carry, _ = jax.lax.scan(tick_body, carry,
                                jnp.arange(sched.n_ticks, dtype=jnp.int32))

        # ---- round-end updates -------------------------------------------
        new_params = dict(params)
        new_state = dict(state)
        step = state["step"]

        if accumulate:
            g_st = jax.tree.map(lambda a: jnp.sum(a, axis=0) / R,
                                carry["gacc"])
            carry["w"], carry["opt"] = optimizer.update(
                g_st, carry["opt"], carry["w"], step)
            hf_new, hf_opt = optimizer.update(
                {"h": carry["dhead_acc"] / R,
                 "f": jax.tree.map(lambda a: a / R, carry["dfnorm_acc"])},
                carry["head_opt"],
                {"h": carry["head"], "f": carry["fnorm"]}, step)
            carry["head"], carry["fnorm"] = hf_new["h"], hf_new["f"]
            carry["head_opt"] = hf_opt

        # embedding update, once per round (DESIGN.md §7)
        demb = carry["d_embeds"][:, :, n_patch:, :] if is_vlm \
            else carry["d_embeds"]
        d_table = lm_head.embed_bwd(params["embed"], tokens,
                                    demb.astype(jnp.float32)) / R
        emb2, eopt2 = optimizer.update(d_table, state["opt_embed"],
                                       params["embed"], step)
        new_params["embed"] = emb2
        new_state["opt_embed"] = eopt2

        if has_enc:
            denc_sum = jnp.sum(carry["denc"].astype(jnp.float32), axis=0)
            (denc_params, _) = enc_vjp(
                denc_sum.reshape(R * bmb, enc_len, d_enc).astype(
                    compute_dtype))
            encp2, encopt2 = optimizer.update(
                jax.tree.map(lambda a: a.astype(jnp.float32) / R,
                             denc_params),
                state["opt_encoder"], params["encoder"], step)
            new_params["encoder"] = encp2
            new_state["opt_encoder"] = encopt2

        new_params["head"] = carry["head"]
        new_params["final_norm"] = carry["fnorm"]
        new_params["stages"] = carry["w"]
        new_state["params"] = new_params
        new_state["stash"] = ({"current": carry["w"], "ring": carry["stash"]}
                              if use_ring else {"current": carry["w"]})
        new_state["opt_stages"] = carry["opt"]
        new_state["opt_head"] = carry["head_opt"]
        new_state["step"] = step + 1

        metrics = {"loss": carry["loss_sum"] / R,
                   "aux": carry["aux_sum"] / R}
        return new_state, metrics

    # ======================= state init + pspecs ========================
    def init_state(key):
        params, _ = init_params(spec, mplan, key, compute_dtype)
        if vs > 1:
            # storage order: row s*v + j holds model chunk j*S + s, so
            # the contiguous stage shard owns its interleaved chunks
            perm = jnp.asarray(sched.storage_chunk_order())
            params = dict(params)
            params["stages"] = jax.tree.map(lambda a: a[perm],
                                            params["stages"])
            params["layer_windows"] = params["layer_windows"][perm]
            params["layer_thetas"] = params["layer_thetas"][perm]
        stages = params["stages"]
        stash = {"current": stages}
        if use_ring:
            stash["ring"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (V,) + a.shape) + 0,
                stages)
        state = {
            "params": params,
            "stash": stash,
            "opt_stages": optimizer.init(stages),
            "opt_head": optimizer.init({"h": params["head"],
                                        "f": params["final_norm"]}),
            "opt_embed": optimizer.init(params["embed"]),
            "step": jnp.zeros((), jnp.int32),
        }
        if has_enc:
            state["opt_encoder"] = optimizer.init(params["encoder"])
        return state

    opt_hf_shape = jax.eval_shape(lambda: optimizer.init(
        {"h": params_shape["head"], "f": params_shape["final_norm"]}))
    opt_head_pspec = {slot: {"h": pspecs["head"], "f": pspecs["final_norm"]}
                      for slot in opt_hf_shape}
    opt_emb_shape = jax.eval_shape(
        lambda: optimizer.init(params_shape["embed"]))
    opt_emb_pspec = {slot: pspecs["embed"] for slot in opt_emb_shape}

    state_pspecs = {
        "params": pspecs,
        "stash": ({"current": stage_pspec, "ring": stash_pspec}
                  if use_ring else {"current": stage_pspec}),
        "opt_stages": opt_stage_pspec,
        "opt_head": opt_head_pspec,
        "opt_embed": opt_emb_pspec,
        "step": P(),
    }
    if has_enc:
        opt_enc_shape = jax.eval_shape(
            lambda: optimizer.init(params_shape["encoder"]))
        state_pspecs["opt_encoder"] = {slot: pspecs["encoder"]
                                       for slot in opt_enc_shape}

    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((R, bmb, text_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((R, bmb, text_len), jnp.int32),
    }
    batch_pspecs = {
        "tokens": P(None, dnames, None),
        "labels": P(None, dnames, None),
    }
    if is_vlm:
        batch_shapes["patches"] = jax.ShapeDtypeStruct(
            (R, bmb, n_patch, spec.d_model), compute_dtype)
        batch_pspecs["patches"] = P(None, dnames, None, None)
    if has_enc:
        batch_shapes["frames"] = jax.ShapeDtypeStruct(
            (R, bmb, enc_len, d_enc), compute_dtype)
        batch_pspecs["frames"] = P(None, dnames, None, None)

    return PipelineBundle(
        spec=spec, plan=plan, mesh=mesh, statics=statics, sched=sched,
        train_step=train_step, init_state=init_state,
        state_pspecs=state_pspecs, batch_pspecs=batch_pspecs,
        batch_shapes=batch_shapes, seq_len=seq_len, microbatch_size=mb,
        obs=obs)
