"""Layer profiler (paper §3.2, Figure 7).

The paper profiles 1000 minibatches on one GPU to estimate, per layer l:
  T_l  — fwd+bwd compute time,
  a_l  — activation bytes out of the layer (== bwd gradient bytes in),
  w_l  — parameter count.

Two modes:
  * analytic  — FLOP/byte counts from the layer spec divided by hardware
    peak × an efficiency factor (used for TPU planning; no GPU here).
  * measured  — wall-clock timing of jit'd layer fns (CPU, tiny configs;
    exercised in tests to keep the paper's measurement path honest).

The partitioner consumes the same LayerProfile either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.models import spec as spec_lib


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops_peak: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s
    link_bw: float             # bytes/s per ICI link
    mfu: float = 0.5           # sustained fraction of peak for dense matmul
    net_bw: Optional[float] = None  # data-parallel sync bandwidth (defaults link)
    param_bytes: float = 4.0   # fp32 on the paper's GPU clusters
    ps_factor: float = 4.0     # paper §3.2: PS traffic = 4(m−1)|w|/m;
    #                            TPU all-reduce (ring) = 2(m−1)|w|/m
    hbm_bytes: float = 16e9    # device memory budget; the planner rejects
    #                            plans whose MemoryModel exceeds it

    @property
    def sync_bw(self) -> float:
        return self.net_bw or self.link_bw


#: activation element size assumed by the analytic memory/comm models
ACT_BYTES = 2.0   # bf16

TPU_V5E = Hardware("tpu-v5e", flops_peak=197e12, hbm_bw=819e9, link_bw=50e9,
                   param_bytes=2.0, ps_factor=2.0, hbm_bytes=16e9)


def _host_chain(nic_bw: float, host_bw: float = 3e9) -> float:
    """Paper §3.2: all comm is GPU→CPU→NIC→CPU→GPU; the host copy
    (~3 GB/s pinned-memory memcpy) chains with the NIC."""
    return 1.0 / (1.0 / nic_bw + 1.0 / host_bw)


# Paper clusters (Table-1 reproduction).  Cluster-A: Titan X (Maxwell,
# 6.7 TFLOP/s fp32) with the 25 GbE NIC shared by the machine's workers
# (§2.1 footnote: a machine may run multiple GPU workers) ⇒ ~6.25 Gbps
# per worker; Cluster-B: AWS p3.2xlarge = ONE V100 per 10 Gbps NIC.
# ps_factor=2: each worker sends its gradient shards and receives fresh
# params (2(m−1)|w|/m on the wire).  These four constants were fixed
# once against the published Figure-1 overheads and never re-tuned per
# row — see benchmarks/table1.py.
CLUSTER_A = Hardware("titanx-6.25gbe", flops_peak=6.7e12, hbm_bw=336e9,
                     link_bw=25e9 / 8, mfu=0.35,
                     net_bw=_host_chain(25e9 / 8 / 4), ps_factor=2.0,
                     hbm_bytes=12e9)
CLUSTER_B = Hardware("v100-10gbe", flops_peak=15.7e12, hbm_bw=900e9,
                     link_bw=10e9 / 8, mfu=0.45,
                     net_bw=_host_chain(10e9 / 8), ps_factor=2.0,
                     hbm_bytes=16e9)


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    name: str
    t_fwd: float               # seconds
    t_bwd: float
    a_bytes: float             # activation bytes out (per minibatch)
    w_params: float            # parameter count

    @property
    def t_total(self) -> float:
        return self.t_fwd + self.t_bwd


# --------------------------------------------------------------------------
# Analytic per-layer FLOPs for the LM layer zoo
# --------------------------------------------------------------------------

def block_flops_fwd(spec: spec_lib.ModelSpec, blk: spec_lib.BlockSpec,
                    tokens: int, kv_len: Optional[int] = None) -> float:
    """Forward FLOPs for one block over ``tokens`` query tokens."""
    d = spec.d_model
    f = 0.0
    if blk.mixer == "attn":
        h, kv, dh = spec.n_heads, spec.n_kv, spec.d_head
        f += 2 * tokens * d * (h + 2 * kv) * dh      # qkv
        f += 2 * tokens * h * dh * d                 # out proj
        span = kv_len if kv_len is not None else tokens
        if blk.window > 0:
            span = min(span, blk.window)
        f += 2 * 2 * tokens * span * h * dh          # scores + weighted sum
        if blk.cross_attn:
            src = spec.encoder.source_len if spec.encoder else tokens
            f += 2 * tokens * d * (h + 2 * kv) * dh + 2 * tokens * h * dh * d
            f += 2 * 2 * tokens * src * h * dh
    elif blk.mixer == "mamba":
        ms = spec.mamba
        ci = ms.expand * d
        dt_rank = ms.dt_rank or -(-d // 16)
        f += 2 * tokens * d * 2 * ci                 # in projections
        f += 2 * tokens * ci * ms.d_conv             # conv
        f += 2 * tokens * ci * (dt_rank + 2 * ms.d_state)
        f += 2 * tokens * dt_rank * ci
        f += 6 * tokens * ci * ms.d_state            # scan update + readout
        f += 2 * tokens * ci * d                     # out proj
    elif blk.mixer == "rwkv":
        rs = spec.rwkv
        f += 2 * tokens * d * d * 5                  # r,k,v,g,o
        f += 2 * tokens * d * (rs.decay_lora * 2 + rs.tmix_lora * 10)
        f += 4 * tokens * d * rs.head_dim            # wkv state update+read
    if blk.ffn == "dense":
        mats = 3 if spec.act == "silu" else 2
        f += 2 * tokens * d * spec.d_ff * mats
    elif blk.ffn == "moe":
        m = spec.moe
        f += 2 * tokens * d * m.n_experts            # router
        f += 2 * tokens * m.top_k * d * m.d_expert * 3
        f += 2 * tokens * m.n_shared * d * m.d_shared * 3
    elif blk.ffn == "rwkv_cmix":
        f += 2 * tokens * d * spec.d_ff * 2 + 2 * tokens * d * d
    return f


def head_flops(spec: spec_lib.ModelSpec, tokens: int) -> float:
    return 2 * tokens * spec.d_model * spec.vocab


def model_flops_train(spec: spec_lib.ModelSpec, tokens: int) -> float:
    """MODEL_FLOPS: 6·N_active·D convention (fwd 2ND + bwd 4ND)."""
    return 6 * spec.active_param_count() * tokens


def profile_analytic(spec: spec_lib.ModelSpec, hw: Hardware, *,
                     minibatch_tokens: int, bwd_factor: float = 2.0,
                     kv_len: Optional[int] = None) -> List[LayerProfile]:
    """Per-layer profiles for the partitioner (embed/head folded into ends).

    ``kv_len`` sets the attention span independently of the query token
    count — the decode-workload case (1 query token per row against a
    ``cache_len``-deep KV cache); ``None`` keeps the training/prefill
    self-attention span (= ``minibatch_tokens``).
    """
    out: List[LayerProfile] = []
    d = spec.d_model
    act_bytes = minibatch_tokens * d * ACT_BYTES
    eff = spec_lib  # noqa: F841  (keep namespace; efficiency via hw.mfu)

    embed_t = 0.0  # gather-dominated; negligible FLOPs
    out.append(LayerProfile("embed", embed_t, embed_t,
                            act_bytes, spec.vocab * d))
    for i, blk in enumerate(spec.blocks):
        f = block_flops_fwd(spec, blk, minibatch_tokens, kv_len)
        t_f = f / (hw.flops_peak * hw.mfu)
        out.append(LayerProfile(
            f"block_{i}", t_f, bwd_factor * t_f, act_bytes,
            spec_lib._block_params(spec, blk)))
    hf = head_flops(spec, minibatch_tokens)
    t_h = hf / (hw.flops_peak * hw.mfu)
    out.append(LayerProfile("head", t_h, bwd_factor * t_h,
                            minibatch_tokens * spec.vocab * 4,
                            spec.vocab * d))
    return out


# --------------------------------------------------------------------------
# Measured mode — times a list of callables, paper-style repeated runs
# --------------------------------------------------------------------------

def profile_measured(layer_fns: Sequence[Callable[[], None]],
                     names: Sequence[str],
                     a_bytes: Sequence[float],
                     w_params: Sequence[float],
                     *, warmup: int = 2, iters: int = 10,
                     bwd_factor: float = 2.0) -> List[LayerProfile]:
    """Wall-clock profiling of forward callables (the 1000-minibatch run,
    scaled down).  bwd is estimated as bwd_factor × fwd, matching the
    paper's observation that backward ≈ 2× forward."""
    out = []
    for fn, name, ab, wp in zip(layer_fns, names, a_bytes, w_params):
        for _ in range(warmup):
            fn()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        t = (time.perf_counter() - t0) / iters
        out.append(LayerProfile(name, t, bwd_factor * t, ab, wp))
    return out


# --------------------------------------------------------------------------
# Communication-time estimates (paper §3.2)
# --------------------------------------------------------------------------

def comm_time_activations(a_bytes: float, hw: Hardware) -> float:
    """C_l: activation transfer layer l -> l+1."""
    return a_bytes / hw.sync_bw


def comm_time_weight_sync(w_params: float, m: int, hw: Hardware) -> float:
    """W_l^m: per-worker sync bytes for |w_l| = w_params parameters.

    Paper §3.2 (parameter server, fp32): 4(m−1)·|w_l|_bytes/m.
    TPU (bf16 ring all-reduce): 2(m−1)·|w_l|_bytes/m.
    Both via hw.ps_factor/param_bytes.
    """
    if m <= 1:
        return 0.0
    return (hw.ps_factor * (m - 1) * w_params * hw.param_bytes
            / m / hw.sync_bw)


def comm_time_tp_allreduce(a_bytes: float, tp: int, hw: Hardware) -> float:
    """Per-layer tensor-parallel all-reduce time (one direction).

    Megatron-style row/column sharding all-reduces the layer's activation
    once per block per pass: ring cost 2(tp−1)·a_bytes/tp over the ICI
    link.  0 at tp=1 — this is what makes tensor parallelism non-free in
    the planner, so deep pipelines (less tp, more bubble) can win when
    activations are large relative to compute.
    """
    if tp <= 1:
        return 0.0
    return 2.0 * (tp - 1) * a_bytes / tp / hw.link_bw


# --------------------------------------------------------------------------
# Measured-profile calibration (straggler rebalancing)
# --------------------------------------------------------------------------

def profile_stage_spans(n_profiles: int, n_stages: int) -> List[range]:
    """Profile-index span of each physical stage under the uniform stack.

    Profiles are [embed, block_0..block_{L-1}, head]; embed rides with
    stage 0 and head with the last stage (the executor folds them into
    the end stages the same way).  ``n_stages`` here means *physical*
    stages: with virtual stages, chunk j·S + s belongs to stage s, so a
    stage's layer set is the union of its chunks — computed by the
    caller via chunk spans with ``n_stages = S·v`` and ``c % S``.
    """
    n_layers = n_profiles - 2
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    lps = n_layers // n_stages
    spans = []
    for s in range(n_stages):
        lo = 1 + s * lps
        hi = 1 + (s + 1) * lps
        if s == 0:
            lo = 0                      # embed
        if s == n_stages - 1:
            hi = n_profiles             # head
        spans.append(range(lo, hi))
    return spans


def scale_profiles_to_measurements(profiles: Sequence[LayerProfile],
                                   measured_stage_seconds: Sequence[float],
                                   *, n_stages: int, virtual_stages: int = 1
                                   ) -> List[LayerProfile]:
    """Fold measured per-stage times back into the analytic profile.

    Each layer's t_fwd/t_bwd is scaled by the measured/predicted ratio of
    the stage that currently runs it (chunk c of the uniform S·v split
    belongs to physical stage c % S).  Ratios are normalized by their
    median so only the *relative* skew transfers — absolute wall-clock
    from a different machine class must not swamp the analytic comm
    terms.  This is the fix for the replanner ignoring its own
    measurements: the DP then sees the straggler's layers as genuinely
    slower and rebalances around them.
    """
    times = np.asarray(measured_stage_seconds, float)
    assert len(times) == n_stages, (len(times), n_stages)
    n_chunks = n_stages * virtual_stages
    chunk_spans = profile_stage_spans(len(profiles), n_chunks)
    predicted = np.zeros(n_stages)
    layer_stage = np.zeros(len(profiles), np.int64)
    for c, span in enumerate(chunk_spans):
        s = c % n_stages
        predicted[s] += sum(profiles[i].t_total for i in span)
        for i in span:
            layer_stage[i] = s
    assert (predicted > 0).all(), "degenerate profile: zero-time stage"
    ratio = times / predicted
    med = float(np.median(ratio))
    assert med > 0, "measured stage times must be positive"
    ratio = ratio / med
    out = []
    for i, p in enumerate(profiles):
        r = float(ratio[layer_stage[i]])
        out.append(dataclasses.replace(p, t_fwd=p.t_fwd * r,
                                       t_bwd=p.t_bwd * r))
    return out
