"""Sequential oracle for the pipelined train step.

Executes the identical double-tick schedule, weight stashing, and
per-microbatch updates with plain Python loops on one device — no
shard_map, no collectives — driven by the SAME
:class:`~repro.core.schedule.PipelineSchedule` tables the SPMD executor
gathers.  Bit-exact (fp32) against core/pipeline.py on a single data
replica; used by the semantics tests.  Flush-interleaved plans can be
exercised two ways: by building the reference with pp = S·v (a
chunk-level plan — flush semantics make the update schedule-independent,
so the interleaved SPMD pipeline must match the chunked sequential flush
oracle exactly), or by passing the interleaved plan itself — the oracle
walks virtual stages natively, including the async interleaved
schedule's per-chunk weight-version rings and per-microbatch updates
(state rows in the executor's storage order p = s·v + j).

Also provides ``staleness_formula_step``: a *third*, independent
implementation that applies the paper's §3.4 update rule directly
(gradients of the full model evaluated at per-stage delayed weight
versions) — validating that 1F1B + weight stashing implements
    w^(t+1) = w^(t) − ν·∇f(w_1^(t−n+1), …, w_n^(t))
"""
from __future__ import annotations

import copy
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.core.schedule import (B_CHUNK, B_FROM_HEAD, B_MB, B_RESID_READ,
                                 B_VERSION, F_CHUNK, F_FROM_EMBEDS, F_MB,
                                 F_RESID_WRITE, F_STASH_WRITE, F_VERSION,
                                 make_schedule)
from repro.models import lm_head
from repro.models.stage import make_statics, stage_fwd
from repro.parallel.mesh import ParallelismPlan


def reference_init_state(spec, plan: ParallelismPlan, optimizer, key,
                         dtype=jnp.float32):
    """Single-device state matching core/pipeline.py::init_state.

    For virtual-stage plans the stage-stacked rows follow the
    executor's storage order (row s·v + j holds chunk j·S + s).
    """
    import numpy as np

    from repro.models.init import init_params

    sched = make_schedule(plan)
    mplan = (plan.with_(pp=sched.n_chunks, schedule="auto",
                        virtual_stages=1)
             if sched.virtual_stages > 1 else plan)
    params, _ = init_params(spec, mplan, key, dtype)
    if sched.virtual_stages > 1:
        perm = np.asarray(sched.storage_chunk_order())
        params = dict(params)
        params["stages"] = jax.tree.map(lambda a: a[perm],
                                        params["stages"])
        params["layer_windows"] = params["layer_windows"][perm]
        params["layer_thetas"] = params["layer_thetas"][perm]
    stages = params["stages"]
    stash = {"current": stages}
    if sched.uses_stash_ring:
        stash["ring"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (sched.stash_slots,) + a.shape) + 0,
            stages)
    state = {
        "params": params,
        "stash": stash,
        "opt_stages": optimizer.init(stages),
        "opt_head": optimizer.init({"h": params["head"],
                                    "f": params["final_norm"]}),
        "opt_embed": optimizer.init(params["embed"]),
        "step": jnp.zeros((), jnp.int32),
    }
    if spec.encoder is not None:
        state["opt_encoder"] = optimizer.init(params["encoder"])
    return state


def _stage_slice(tree, s):
    return jax.tree.map(lambda a: a[s:s + 1], tree)


def _stage_unslice(full, s, part):
    return jax.tree.map(
        lambda a, p: a.at[s:s + 1].set(p.astype(a.dtype)), full, part)


def reference_train_step(spec, plan: ParallelismPlan, state, batch,
                         optimizer, aux_weight: float = 0.01):
    """Mirror of core/pipeline.py train_step, sequential, 1 data replica.

    Virtual-stage plans run natively: storage row p = s·v + j holds
    chunk c = j·S + s, chunk hops wrap stage S−1 → 0, and per-chunk
    stash rings back the async interleaved schedule's per-microbatch
    updates.  ``state`` rows must be in storage order (what
    :func:`reference_init_state` and the SPMD ``init_state`` produce).
    """
    S, R = plan.pp, plan.microbatches
    sched = make_schedule(plan)
    v = sched.virtual_stages
    L = sched.n_chunks                  # storage rows (S·v)
    tabs = sched.tables()
    V = sched.stash_slots
    accumulate = sched.accumulate or plan.grad_sync == "per_round"
    use_ring = sched.uses_stash_ring
    params = state["params"]
    tokens, labels = batch["tokens"], batch["labels"]   # (R, Bmb, S_text)
    step = state["step"]
    is_vlm = spec.frontend == "vision"
    has_enc = spec.encoder is not None
    n_patch = spec.n_patches if is_vlm else 0
    bmb = tokens.shape[1]
    seq_len = tokens.shape[2] + n_patch
    # The reference sees full (unsharded) parameters: tp=1 view of the
    # plan, at chunk granularity for virtual stages (like the SPMD
    # executor's mplan).
    splan = (plan.with_(tp=1, pp=L, schedule="auto", virtual_stages=1)
             if v > 1 else plan.with_(tp=1))
    statics = make_statics(spec, splan, tokens_per_mb=bmb * seq_len)

    text_embeds = lm_head.embed_tokens(params["embed"], tokens)
    if is_vlm:
        embeds = jnp.concatenate(
            [batch["patches"].astype(text_embeds.dtype), text_embeds],
            axis=2)
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], bmb, n_patch), -1, labels.dtype),
             labels], axis=2)
    else:
        embeds = text_embeds
    if has_enc:
        from repro.models.stage import encoder_fwd
        enc_len = spec.encoder.source_len
        d_enc = spec.encoder.d_model
        R_ = tokens.shape[0]
        fr = batch["frames"].reshape(R_ * bmb, enc_len, d_enc)
        enc_out_flat, enc_vjp = jax.vjp(
            lambda ep, fx: encoder_fwd(ep, fx, spec),
            params["encoder"], fr.astype(embeds.dtype))
        enc_ring = enc_out_flat.reshape(R_, bmb, enc_len, d_enc)
        denc = [None] * R_
    pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                           (bmb, seq_len))

    def run_stage(w_stage, x, p, cross=None):
        h, _, aux = stage_fwd(w_stage, x, statics, positions=pos,
                              windows=params["layer_windows"][p],
                              thetas=params["layer_thetas"][p],
                              tp_axis=None, cross_x=cross)
        return h, aux

    # per-storage-row python state; ring leaves are [V, L, ...] — the
    # chunk-major layout the SPMD executor shards over stages
    weights = [_stage_slice(state["stash"]["current"], p) for p in range(L)]
    stash: List[List[Any]] = [
        [jax.tree.map(lambda a: a[slot, p:p + 1], state["stash"]["ring"])
         for slot in range(V)] for p in range(L)] if use_ring else \
        [[None] * V for _ in range(L)]
    opt = [_opt_slice(state["opt_stages"], p) for p in range(L)]
    head, fnorm = params["head"], params["final_norm"]
    head_opt = state["opt_head"]

    recv_f = [None] * S
    recv_b = [None] * S
    resid = [[None] * sched.resid_slots for _ in range(S)]
    gacc = [None] * L
    d_embeds = [None] * R
    loss_sum = jnp.zeros((), jnp.float32)
    aux_sum = jnp.zeros((), jnp.float32)
    dhead_acc = None
    dfnorm_acc = None

    for tick in range(sched.n_ticks):
        # ---------------- F phase (all stages, pre-update weights) -------
        new_recv_f = [None] * S
        h_exit = None
        for s in range(S):
            row = tabs.fwd[tick, s]
            f = int(row[F_MB])
            if f < 0:
                continue
            c = int(row[F_CHUNK]) * S + s           # model chunk
            p = s * v + int(row[F_CHUNK])           # storage row
            x_in = embeds[f] if row[F_FROM_EMBEDS] else recv_f[s]
            if use_ring:
                stash[p][int(row[F_STASH_WRITE])] = weights[p]
            if sched.fwd_from_stash:
                w_f = stash[p][int(row[F_VERSION])]
            else:
                w_f = weights[p]
            h, aux = run_stage(w_f, x_in, p,
                               enc_ring[f] if has_enc else None)
            aux_sum = aux_sum + aux
            resid[s][int(row[F_RESID_WRITE])] = x_in
            if c == L - 1:
                h_exit = h
            else:                 # chunk hop; wraps stage S−1 -> 0
                new_recv_f[(s + 1) % S] = h
        recv_f = new_recv_f

        # ---------------- head / loss ------------------------------------
        g_exit = None
        m_exit = int(tabs.exit_mb[tick])
        if 0 <= m_exit < R:
            lab = labels[m_exit]
            vmask = (lab >= 0).astype(jnp.float32)
            lab_safe = jnp.maximum(lab, 0)

            def loss_fn(hd, fn, h):
                loss, _ = lm_head.head_loss(
                    hd, fn["scale"], h, lab_safe, norm_kind=spec.norm,
                    norm_bias=fn.get("bias"), valid_mask=vmask,
                    vocab=spec.vocab)
                return loss

            loss, (dhead, dfnorm, dh) = jax.value_and_grad(
                loss_fn, argnums=(0, 1, 2))(head, fnorm, h_exit)
            loss_sum = loss_sum + loss
            g_exit = dh.astype(h_exit.dtype)
            if not accumulate:
                hf_new, head_opt = optimizer.update(
                    {"h": dhead, "f": dfnorm}, head_opt,
                    {"h": head, "f": fnorm}, step)
                head, fnorm = hf_new["h"], hf_new["f"]
            else:
                dhead_acc = dhead if dhead_acc is None \
                    else dhead_acc + dhead
                dfnorm_acc = dfnorm if dfnorm_acc is None else jax.tree.map(
                    jnp.add, dfnorm_acc, dfnorm)

        # ---------------- B phase -----------------------------------------
        new_recv_b = [None] * S
        for s in range(S):
            row = tabs.bwd[tick, s]
            b = int(row[B_MB])
            if b < 0:
                continue
            c = int(row[B_CHUNK]) * S + s
            p = s * v + int(row[B_CHUNK])
            g_in = g_exit if row[B_FROM_HEAD] else recv_b[s]
            w_used = (stash[p][int(row[B_VERSION])] if use_ring
                      else weights[p])
            x_saved = resid[s][int(row[B_RESID_READ])]

            if has_enc:
                def f_enc(w, x, cx):
                    return run_stage(w, x, p, cx)

                _, vjp = jax.vjp(f_enc, w_used, x_saved, enc_ring[b])
                dW, dx, dcx = vjp((g_in.astype(x_saved.dtype),
                                   jnp.float32(aux_weight)))
                denc[b] = dcx if denc[b] is None else denc[b] + dcx
            else:
                def f_txt(w, x):
                    return run_stage(w, x, p)

                _, vjp = jax.vjp(f_txt, w_used, x_saved)
                dW, dx = vjp((g_in.astype(x_saved.dtype),
                              jnp.float32(aux_weight)))
            if accumulate:
                gacc[p] = dW if gacc[p] is None else jax.tree.map(
                    jnp.add, gacc[p], dW)
            else:
                new_w, new_opt = optimizer.update(dW, opt[p], weights[p], step)
                weights[p], opt[p] = new_w, new_opt
            if c == 0:
                d_embeds[b] = dx
            else:                 # gradient hop; wraps stage 0 -> S−1
                new_recv_b[(s - 1) % S] = dx
        recv_b = new_recv_b

    # ---------------- round end -------------------------------------------
    if accumulate:
        for p in range(L):
            g = jax.tree.map(lambda a: a / R, gacc[p])
            weights[p], opt[p] = optimizer.update(g, opt[p], weights[p], step)
        hf_new, head_opt = optimizer.update(
            {"h": dhead_acc / R,
             "f": jax.tree.map(lambda a: a / R, dfnorm_acc)},
            head_opt, {"h": head, "f": fnorm}, step)
        head, fnorm = hf_new["h"], hf_new["f"]

    demb = jnp.stack([d.astype(jnp.float32) for d in d_embeds])
    if is_vlm:
        demb = demb[:, :, n_patch:, :]
    d_table = lm_head.embed_bwd(params["embed"], tokens, demb) / R
    emb2, eopt2 = optimizer.update(d_table, state["opt_embed"],
                                   params["embed"], step)
    if has_enc:
        denc_sum = jnp.stack(denc).astype(jnp.float32)
        (denc_params, _) = enc_vjp(
            denc_sum.reshape(R * bmb, enc_len, d_enc).astype(embeds.dtype))
        encp2, encopt2 = optimizer.update(
            jax.tree.map(lambda a: a.astype(jnp.float32) / R, denc_params),
            state["opt_encoder"], params["encoder"], step)

    # reassemble state
    stages_full = state["stash"]["current"]
    for p in range(L):
        stages_full = _stage_unslice(stages_full, p, weights[p])
    if use_ring:
        ring_full = state["stash"]["ring"]
        for p in range(L):
            for slot in range(V):
                ring_full = jax.tree.map(
                    lambda a, q: a.at[slot, p:p + 1].set(q.astype(a.dtype)),
                    ring_full, stash[p][slot])
    opt_full = state["opt_stages"]
    for p in range(L):
        opt_full = _opt_unslice(opt_full, p, opt[p])

    new_params = dict(params)
    new_params["embed"] = emb2
    new_params["head"] = head
    new_params["final_norm"] = fnorm
    new_params["stages"] = stages_full
    new_state = dict(state)
    if has_enc:
        new_params["encoder"] = encp2
        new_state["opt_encoder"] = encopt2
    new_state["params"] = new_params
    new_state["stash"] = ({"current": stages_full, "ring": ring_full}
                          if use_ring else {"current": stages_full})
    new_state["opt_stages"] = opt_full
    new_state["opt_head"] = head_opt
    new_state["opt_embed"] = eopt2
    new_state["step"] = step + 1
    metrics = {"loss": loss_sum / R, "aux": aux_sum / R}
    return new_state, metrics


def _opt_slice(opt_tree, s):
    return jax.tree.map(lambda a: a[s:s + 1], opt_tree)


def _opt_unslice(full, s, part):
    return jax.tree.map(
        lambda a, p: a.at[s:s + 1].set(p.astype(a.dtype)), full, part)


# --------------------------------------------------------------------------
# Direct §3.4 staleness-formula implementation (straight pipeline)
# --------------------------------------------------------------------------

def staleness_formula_run(spec, plan, init_stage_weights, loss_grad_fn,
                          optimizer, opt_state, n_minibatches: int,
                          mode: str = "stash"):
    """Applies the paper's update rule directly, one minibatch at a time.

    init_stage_weights: list of per-stage weight pytrees.
    loss_grad_fn(mixed_weights, m) -> list of per-stage grads, where
        mixed_weights[s] is the version stage s uses for minibatch m.
    In 'stash' mode stage s uses the version available after its own
    update for minibatch m − delay(s), delay(s) = 2(S−1−s) in double-tick
    units; in 'vertical' mode every stage uses delay(0).

    Returns the per-stage weights after n_minibatches updates.  History is
    kept so delayed versions are exact.
    """
    S = plan.pp
    hist: List[List[Any]] = [[w] for w in init_stage_weights]  # versions
    opt = list(opt_state)

    def delay(s):
        return 2 * (S - 1 - s)

    for m in range(n_minibatches):
        mixed = []
        for s in range(S):
            d = delay(s) if mode == "stash" else delay(0)
            ver = max(m - d, 0)
            ver = min(ver, len(hist[s]) - 1)
            mixed.append(hist[s][ver])
        grads = loss_grad_fn(mixed, m)
        for s in range(S):
            new_w, opt[s] = optimizer.update(grads[s], opt[s],
                                             hist[s][-1], m)
            hist[s].append(new_w)
    return [h[-1] for h in hist], opt
