"""Pluggable pipeline schedules as device-resident static index tables.

A :class:`PipelineSchedule` describes *when* every (microbatch, chunk)
forward/backward runs on every physical stage, and *where* its weights,
residuals and weight versions live, as dense int32 tables indexed by
``(tick, stage)``.  The SPMD executor (core/pipeline.py) and the
sequential oracle (core/reference.py) both consume only these tables —
no index arithmetic lives in the execution layer, so adding a schedule
is one subclass here, not a pipeline.py surgery.

Tick model (double-tick): one tick = one F-slot followed by one B-slot
on every physical stage.  Activations produced at tick t are consumed at
tick t+1 by the neighbouring stage (ppermute latency of exactly one
tick); the microbatch exiting the last chunk gets its head loss and
starts its backward in the same tick (paper Figure 8 adjacency).  Every
schedule here is constructed so that this single-buffer dataflow holds —
``validate()`` proves it per instance.

Schedules shipped:

  Schedule1F1B            paper §3.3: F slot f = t − s, B slot
                          b = t − 2(S−1) + s, per-microbatch updates.
                          ``policy='stash'`` (paper default: F latest,
                          B stashed) or ``policy='vertical'`` (F and B
                          both use the delayed version, §3.4 vertical
                          sync) are version-slot policies over the SAME
                          timing tables.
  ScheduleGPipe           the flush family (PipeDream-flush / GPipe /
                          2BW): identical 1F1B timing — which is the
                          throughput-optimal way to run a synchronous
                          flush — but gradients accumulate and one
                          update applies per round.  ``weight_versions``
                          1 (flush) or 2 (PipeDream-2BW-style).
  ScheduleInterleaved1F1B Megatron-style virtual stages: each physical
                          stage holds ``v`` model chunks (chunk
                          c = j·S + s lives on stage s as local chunk
                          j), cutting the pipeline bubble from
                          2(S−1)/(R+2(S−1)) to
                          ((v+1)S−2)/(vR+(v+1)S−2) — strictly smaller
                          for v ≥ 2 whenever S ≥ 3 (equal at S = 2,
                          where startup and drain are already minimal in
                          the double-tick model).  Flush (accumulate)
                          semantics.
  ScheduleInterleavedAsync1F1B
                          the same interleaved timing with
                          per-microbatch updates: paper §3.3 weight
                          stashing generalized to virtual stages via
                          per-chunk weight-version rings, stored
                          chunk-major ([versions, S·v chunk rows, ...])
                          so each stage shard owns its chunks' rings
                          contiguously.
  ScheduleServe1F         forward-only serving round (prefill or one
                          decode step): stage s forwards microbatch
                          t − s, R + S − 1 ticks, no backward slots.
  ScheduleServeInterleaved
                          forward-only interleaved serving: the same
                          virtual-stage chunk placement as training
                          (chunk c = j·S + s on stage s), cutting the
                          prefill ramp from (S−1) full-stage passes to
                          (S−1)/v — lower time-to-first-token for the
                          last request in the batch at S ≥ 2, v ≥ 2.
                          No backward ⇒ no microbatch-group constraint:
                          any R ≥ 1 is valid (sp decode runs R = 1).

Registry: ``SCHEDULES`` maps names to classes; ``make_schedule(plan)``
builds the instance a :class:`~repro.parallel.mesh.ParallelismPlan`
asks for (``plan.schedule='auto'`` derives the schedule from the legacy
``stash_mode`` field, so existing configs keep working unchanged).
``make_serving_schedule(plan, R)`` is the forward-only analogue: a plan
carrying a training schedule (or 'auto') maps onto ``serve_1f`` /
``serve_interleaved`` by its ``virtual_stages``, and an unknown name is
a registry-lookup error, not an assert.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Iterable, List, Optional, Tuple, Type

import numpy as np

# ---------------------------------------------------------------------------
# Table column layout (int32).  F/B rows are gathered per (tick, stage).
# ---------------------------------------------------------------------------

#: forward-table columns
F_MB, F_CHUNK, F_FROM_EMBEDS, F_STASH_WRITE, F_VERSION, F_RESID_WRITE = \
    range(6)
F_COLS = 6

#: backward-table columns
B_MB, B_CHUNK, B_FROM_HEAD, B_VERSION, B_RESID_READ = range(5)
B_COLS = 5


@dataclasses.dataclass(frozen=True)
class ScheduleTables:
    """Dense static tables; -1 marks bubble slots / unused columns.

    fwd      [n_ticks, n_stages, F_COLS]
    bwd      [n_ticks, n_stages, B_COLS]
    exit_mb  [n_ticks]  microbatch leaving the last chunk this tick
    demb_mb  [n_ticks]  microbatch whose d(embeddings) completes this tick
    """

    fwd: np.ndarray
    bwd: np.ndarray
    exit_mb: np.ndarray
    demb_mb: np.ndarray


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Analytic per-device HBM footprint of one schedule × plan.

    All quantities are bytes on the worst (most loaded) device of the
    (stage, tensor) submesh; data replicas hold copies so the budget is
    per chip.  Cross-checked against the dry-run's
    ``compiled.memory_analysis()`` in launch/dryrun.py.
    """

    schedule: str
    weight_bytes: float        # live stage weights (+ embed/head shard)
    stash_bytes: float         # weight-version ring: stash_slots × stage blocks
    resid_bytes: float         # residual ring: resid_slots × microbatch input
    workspace_bytes: float     # in-flight fwd/bwd activations (remat-aware)
    grad_bytes: float          # gradient accumulator (flush family only)
    optimizer_bytes: float     # Adam moments (ZeRO-1 sharded when plan.zero1)
    cache_bytes: float = 0.0   # serving KV/SSM cache (worst stage, sharded
    #                            rows over dp — or positions under sp — and
    #                            KV heads over tp); 0 for training schedules

    @property
    def total_bytes(self) -> float:
        return (self.weight_bytes + self.stash_bytes + self.resid_bytes
                + self.workspace_bytes + self.grad_bytes
                + self.optimizer_bytes + self.cache_bytes)

    def fits(self, hbm_bytes: float) -> bool:
        return self.total_bytes <= hbm_bytes

    def headroom(self, hbm_bytes: float) -> float:
        return hbm_bytes - self.total_bytes

    def __str__(self):
        gb = 1 / 1e9
        cache = (f" cache {self.cache_bytes * gb:.2f}"
                 if self.cache_bytes else "")
        return (f"{self.schedule}: total {self.total_bytes * gb:.2f} GB "
                f"(weights {self.weight_bytes * gb:.2f} "
                f"stash {self.stash_bytes * gb:.2f} "
                f"resid {self.resid_bytes * gb:.2f} "
                f"work {self.workspace_bytes * gb:.2f} "
                f"grad {self.grad_bytes * gb:.2f} "
                f"opt {self.optimizer_bytes * gb:.2f}{cache})")


def _interval_color(intervals: Iterable[Tuple[int, int]]) -> Tuple[List[int],
                                                                   int]:
    """Greedy slot assignment for [write, read] lifetimes.

    Within one tick the F phase (writes) runs before the B phase (reads),
    so a slot read at tick r can only be rewritten at tick > r.  Returns
    (slot per interval in input order, number of slots).
    """
    ivs = list(intervals)
    idx = sorted(range(len(ivs)), key=lambda k: ivs[k][0])
    slots = [0] * len(ivs)
    free: List[Tuple[int, int]] = []   # (read_tick, slot)
    n_slots = 0
    for k in idx:
        w, r = ivs[k]
        if free and free[0][0] < w:
            _, s = heapq.heappop(free)
        else:
            s = n_slots
            n_slots += 1
        slots[k] = s
        heapq.heappush(free, (r, s))
    return slots, max(n_slots, 1)


def stage_weight_params(spec, plan, sched) -> Tuple[float, float]:
    """Worst-stage per-device parameter counts ``(blocks, shared)``.

    ``blocks``: the most loaded physical stage's block parameters (stage
    s owns chunks j·S + s of the S·v-way cut), divided by tp.
    ``shared``: the embed + head + final-norm shard over the full
    (stage, tensor) submesh.  Shared by the training and serving memory
    models — the weight layout is schedule-independent.
    """
    from repro.models.spec import _block_params

    S, v = sched.n_stages, sched.virtual_stages
    assert plan.pp == S and plan.virtual_stages == v, (
        "memory_model called with a plan that does not describe this "
        f"schedule: plan (pp={plan.pp}, v={plan.virtual_stages}) vs "
        f"schedule (S={S}, v={v})")
    L = sched.n_chunks
    assert spec.n_layers % L == 0, (spec.n_layers, L)
    lps = spec.n_layers // L
    tp = plan.tp
    stage_params = [0.0] * S
    for c in range(L):
        stage_params[c % S] += sum(
            _block_params(spec, spec.blocks[i])
            for i in range(c * lps, (c + 1) * lps))
    blocks = max(stage_params) / tp
    shared = (spec.vocab * spec.d_model
              * (1 if spec.tie_embeddings else 2) + spec.d_model)
    shared /= S * tp
    return blocks, shared


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """Static description of one pipelined round.

    Subclasses set the class attributes below and implement
    ``_build_tables``.  Instances are frozen and hashable — tables are
    built once and cached.
    """

    n_stages: int
    n_microbatches: int

    #: registry name
    name = "abstract"
    #: grads accumulate across the round; one synchronous update at the end
    accumulate = False
    #: stage weights are stashed in a ring of ``stash_slots`` versions
    uses_stash_ring = False
    #: F reads weights from the ring (vertical sync) instead of latest
    fwd_from_stash = False
    #: virtual chunks per physical stage (Megatron interleaving)
    virtual_stages = 1
    #: plan.stash_mode values this schedule accepts (first = default,
    #: used by :func:`plan_kwargs_for_schedule` to normalize a plan)
    plan_stash_modes: Tuple[str, ...] = ("stash", "vertical")
    #: schedule consumes plan.virtual_stages (> 1) — the interleaved family
    takes_virtual_stages = False
    #: virtual stages require microbatch groups (R % pp == 0); the
    #: forward-only serving family lifts this (no backward to interleave)
    needs_group_microbatches = True
    #: forward-only inference schedule (no B slots; memory_model takes the
    #: serving cache terms) — see :class:`ServingSchedule`
    is_serving = False
    #: speculative draft–verify serving family (``serve_spec_*``): decode
    #: rounds score ``spec_k + 1`` positions per slot and roll back
    #: rejected suffixes — see :class:`_SpeculativeServe`
    is_speculative = False
    #: draft depth (tokens proposed per verify round); 0 = not speculative
    spec_k = 0

    def __post_init__(self):
        assert self.n_stages >= 1 and self.n_microbatches >= 1

    # ---- derived sizes ---------------------------------------------------

    @property
    def n_chunks(self) -> int:
        """Model chunks = physical stages × virtual stages."""
        return self.n_stages * self.virtual_stages

    @property
    def n_ticks(self) -> int:
        raise NotImplementedError

    @property
    def stash_slots(self) -> int:
        """Weight versions kept per stage (1 = only the live weights)."""
        raise NotImplementedError

    @property
    def resid_slots(self) -> int:
        """Stage-input (residual) ring size.

        Unlike ``stash_slots`` this is a *liveness* bound — every
        residual written at F(m) must survive until B(m) — so it never
        shrinks with the weight-version policy.
        """
        return 2 * (self.n_stages - 1) + 1

    # ---- tables ----------------------------------------------------------

    @classmethod
    def from_plan(cls, plan) -> "PipelineSchedule":
        """Build this schedule from a ParallelismPlan.

        The registry dispatches here, so a registered schedule picks up
        its own plan knobs without edits to :func:`make_schedule`.
        """
        return cls(plan.pp, plan.microbatches)

    def _build_tables(self) -> ScheduleTables:
        raise NotImplementedError

    def tables(self) -> ScheduleTables:
        # per-instance memo (frozen dataclass: route around __setattr__);
        # an lru_cache on the method would pin every instance globally
        tabs = self.__dict__.get("_tables")
        if tabs is None:
            tabs = self._build_tables()
            for a in (tabs.fwd, tabs.bwd, tabs.exit_mb, tabs.demb_mb):
                a.setflags(write=False)
            object.__setattr__(self, "_tables", tabs)
        return tabs

    # ---- convenience accessors (reference executor, tests) ---------------

    def fwd_mb(self, tick: int, stage: int) -> int:
        """Microbatch this stage forwards at this tick (-1 if bubble)."""
        return int(self.tables().fwd[tick, stage, F_MB])

    def bwd_mb(self, tick: int, stage: int) -> int:
        return int(self.tables().bwd[tick, stage, B_MB])

    @property
    def bubble_fraction(self) -> float:
        """Fraction of (tick, stage, F/B-slot) triples idle over a round."""
        tabs = self.tables()
        busy = int((tabs.fwd[:, :, F_MB] >= 0).sum()
                   + (tabs.bwd[:, :, B_MB] >= 0).sum())
        total = 2 * self.n_ticks * self.n_stages
        return 1.0 - busy / total

    # ---- memory model ----------------------------------------------------

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1) -> MemoryModel:
        """Analytic worst-device HBM footprint of this schedule.

        Generic accounting: live weights + residual ring + activation
        workspace + optimizer.  Subclasses override to state their
        weight-ring / gradient-accumulator terms explicitly (1F1B: stash
        ring of ``stash_slots`` versions; flush/2bw: ``weight_versions``
        ring + round-long grad accumulator; interleaved: per-chunk
        params + the deeper interval-coloured residual ring).
        """
        return self._memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas,
            weight_ring_slots=self.stash_slots if self.uses_stash_ring
            else 0,
            grad_accum=self.accumulate)

    def _memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                      data_replicas: int, weight_ring_slots: int,
                      grad_accum: bool) -> MemoryModel:
        """Shared accounting, parameterized by the schedule's ring terms.

        Matches the executor's state layout (core/pipeline.py): a stash
        ring holds ``weight_ring_slots`` full block copies *besides*
        ``stash['current']``; the residual ring holds ``resid_slots``
        stage-input activations; flush-family schedules keep one grad
        accumulator alive across the round; Adam moments are fp32 and
        ZeRO-1-sharded over the data axis when the plan says so.
        """
        from repro.core.profiler import ACT_BYTES

        lps = spec.n_layers // self.n_chunks
        blocks, shared = stage_weight_params(spec, plan, self)
        pb = hw.param_bytes
        act = microbatch_tokens * spec.d_model * ACT_BYTES
        # remat keeps ~O(1) layer activations live during the recomputed
        # backward; without it the whole chunk's activations stay resident
        workspace = (4.0 if plan.remat else 2.0 * lps + 2.0) * act
        opt = 2.0 * (blocks + shared) * 4.0          # Adam m, v in fp32
        if plan.zero1:
            opt /= max(int(data_replicas), 1)
        return MemoryModel(
            schedule=self.name,
            weight_bytes=(blocks + shared) * pb,
            stash_bytes=weight_ring_slots * blocks * pb,
            resid_bytes=self.resid_slots * act,
            workspace_bytes=workspace,
            grad_bytes=blocks * pb if grad_accum else 0.0,
            optimizer_bytes=opt)

    # ---- structural self-check -------------------------------------------

    def validate(self) -> None:
        """Prove the tables satisfy the executor's dataflow contract."""
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        tabs = self.tables()
        T = self.n_ticks
        assert tabs.fwd.shape == (T, S, F_COLS), tabs.fwd.shape
        assert tabs.bwd.shape == (T, S, B_COLS), tabs.bwd.shape
        f_time: Dict[Tuple[int, int], int] = {}
        b_time: Dict[Tuple[int, int], int] = {}
        for t in range(T):
            for s in range(S):
                fr, br = tabs.fwd[t, s], tabs.bwd[t, s]
                if fr[F_MB] >= 0:
                    c = fr[F_CHUNK] * S + s
                    key = (int(fr[F_MB]), int(c))
                    assert key not in f_time, f"duplicate F{key}"
                    f_time[key] = t
                if br[B_MB] >= 0:
                    c = br[B_CHUNK] * S + s
                    key = (int(br[B_MB]), int(c))
                    assert key not in b_time, f"duplicate B{key}"
                    b_time[key] = t
        L = S * v
        assert len(f_time) == R * L and len(b_time) == R * L, (
            len(f_time), len(b_time), R * L)
        for m in range(R):
            for c in range(L):
                tf, tb = f_time[(m, c)], b_time[(m, c)]
                if c > 0:   # forward hop: produced tick t consumed at t+1
                    assert f_time[(m, c - 1)] == tf - 1, (m, c)
                if c < L - 1:  # backward hop, reverse direction
                    assert b_time[(m, c + 1)] == tb - 1, (m, c)
            # head adjacency: the executor recomputes (and zero-masks)
            # g_exit every tick, so B of the last chunk must run in the
            # SAME tick as its forward — strictly, not "at or after"
            assert b_time[(m, L - 1)] == f_time[(m, L - 1)], m
        # exit/demb tables must agree with the fwd/bwd tables
        for t in range(T):
            fr = tabs.fwd[t, S - 1]
            is_exit = fr[F_MB] >= 0 and fr[F_CHUNK] == v - 1
            assert tabs.exit_mb[t] == (fr[F_MB] if is_exit else -1), t
            br = tabs.bwd[t, 0]
            is_demb = br[B_MB] >= 0 and br[B_CHUNK] == 0
            assert tabs.demb_mb[t] == (br[B_MB] if is_demb else -1), t
        # residual liveness: slot written at F(m,c) survives until B(m,c)
        for s in range(S):
            live: Dict[int, Tuple[int, int]] = {}
            for t in range(T):
                fr = tabs.fwd[t, s]
                if fr[F_MB] >= 0:
                    slot = int(fr[F_RESID_WRITE])
                    assert 0 <= slot < self.resid_slots, slot
                    live[slot] = (int(fr[F_MB]), int(fr[F_CHUNK]))
                br = tabs.bwd[t, s]
                if br[B_MB] >= 0:
                    slot = int(br[B_RESID_READ])
                    assert live.get(slot) == (int(br[B_MB]),
                                              int(br[B_CHUNK])), (
                        f"stage {s} tick {t}: B reads clobbered residual "
                        f"slot {slot}: holds {live.get(slot)}, wants "
                        f"{(int(br[B_MB]), int(br[B_CHUNK]))}")


# ---------------------------------------------------------------------------
# Schedule1F1B — paper §3.3, per-microbatch updates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule1F1B(PipelineSchedule):
    """The paper's one-forward-one-backward schedule.

    ``policy='stash'``: F uses the latest weights and records them into
    ring slot m % V; B re-reads that slot (weight stashing, §3.3).
    ``policy='vertical'``: F *and* B use the version the stage had when
    microbatch m − 2s entered it — a uniform delayed version across
    stages (§3.4 vertical sync ≡ delayed BSP).
    """

    policy: str = "stash"

    name = "1f1b"
    accumulate = False
    uses_stash_ring = True
    plan_stash_modes = ("stash", "vertical")

    def __post_init__(self):
        super().__post_init__()
        assert self.policy in ("stash", "vertical"), self.policy

    @classmethod
    def from_plan(cls, plan) -> "Schedule1F1B":
        policy = "vertical" if plan.stash_mode == "vertical" else "stash"
        return cls(plan.pp, plan.microbatches, policy=policy)

    @property
    def fwd_from_stash(self) -> bool:  # type: ignore[override]
        return self.policy == "vertical"

    @property
    def n_ticks(self) -> int:
        return self.n_microbatches + 2 * (self.n_stages - 1)

    @property
    def stash_slots(self) -> int:
        """2(S−1)+1: microbatches in flight at the input stage (NOAM
        at equal F/B slot granularity)."""
        return 2 * (self.n_stages - 1) + 1

    def max_in_flight(self, stage: int) -> int:
        """Microbatches between F(m) and B(m) at this stage (incl. current)."""
        return 2 * (self.n_stages - 1 - stage) + 1

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1) -> MemoryModel:
        """Stash family: V = 2(S−1)+1 weight versions + residual ring.

        Both policies keep the same ring — ``vertical`` only changes
        which slot F reads, not how many slots exist.  Per-microbatch
        updates apply immediately, so there is no round-long gradient
        accumulator (transient grads ride in the workspace term).
        """
        return self._memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas,
            weight_ring_slots=self.stash_slots, grad_accum=False)

    def steady_state_ticks(self):
        """Tick range in which every stage has both slots busy."""
        lo = 2 * (self.n_stages - 1)
        hi = self.n_microbatches - 1
        return (lo, hi) if hi >= lo else None

    def _build_tables(self) -> ScheduleTables:
        S, R, V = self.n_stages, self.n_microbatches, self.stash_slots
        T = self.n_ticks
        fwd = np.full((T, S, F_COLS), -1, np.int32)
        bwd = np.full((T, S, B_COLS), -1, np.int32)
        vertical = self.policy == "vertical"
        for t in range(T):
            for s in range(S):
                f = t - s
                fs = min(max(f, 0), R - 1)
                fwd[t, s, F_MB] = f if 0 <= f < R else -1
                fwd[t, s, F_CHUNK] = 0
                fwd[t, s, F_FROM_EMBEDS] = 1 if s == 0 else 0
                fwd[t, s, F_STASH_WRITE] = fs % V
                fwd[t, s, F_VERSION] = (
                    min(max(f - 2 * s, 0), R - 1) % V if vertical else -1)
                fwd[t, s, F_RESID_WRITE] = fs % V

                b = t - 2 * (S - 1) + s
                bs = min(max(b, 0), R - 1)
                bwd[t, s, B_MB] = b if 0 <= b < R else -1
                bwd[t, s, B_CHUNK] = 0
                bwd[t, s, B_FROM_HEAD] = 1 if s == S - 1 else 0
                bwd[t, s, B_VERSION] = (
                    min(max(b - 2 * s, 0), R - 1) % V if vertical
                    else bs % V)
                bwd[t, s, B_RESID_READ] = bs % V
        ticks = np.arange(T)
        exit_mb = np.where((ticks - (S - 1) >= 0) & (ticks - (S - 1) < R),
                           ticks - (S - 1), -1).astype(np.int32)
        demb = np.where((ticks - 2 * (S - 1) >= 0)
                        & (ticks - 2 * (S - 1) < R),
                        ticks - 2 * (S - 1), -1).astype(np.int32)
        return ScheduleTables(fwd, bwd, exit_mb, demb)


# ---------------------------------------------------------------------------
# ScheduleGPipe — flush family (PipeDream-flush / GPipe / 2BW)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleGPipe(Schedule1F1B):
    """Synchronous flush: accumulate over the round, one update at the end.

    Runs the 1F1B timing tables — for a synchronous round that timing is
    strictly better than naive all-F-then-all-B GPipe (same bubble as
    1F1B, bounded activation memory), and is exactly PipeDream-flush.
    ``weight_versions=1`` keeps no ring at all (weights cannot change
    mid-round); ``weight_versions=2`` keeps the PipeDream-2BW-style
    double buffer (beyond-paper, for async round overlap experiments).
    """

    weight_versions: int = 1

    name = "gpipe"
    accumulate = True
    plan_stash_modes = ("flush", "2bw")
    policy: str = "stash"

    def __post_init__(self):
        super().__post_init__()
        assert self.weight_versions in (1, 2), self.weight_versions

    @classmethod
    def from_plan(cls, plan) -> "ScheduleGPipe":
        return cls(plan.pp, plan.microbatches,
                   weight_versions=2 if plan.stash_mode == "2bw" else 1)

    @property
    def fwd_from_stash(self) -> bool:  # type: ignore[override]
        return False

    @property
    def uses_stash_ring(self) -> bool:  # type: ignore[override]
        return self.weight_versions > 1

    @property
    def stash_slots(self) -> int:
        return self.weight_versions

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1) -> MemoryModel:
        """Flush family: ``weight_versions`` ring + R-bounded residuals.

        weight_versions=1 keeps no ring at all (weights cannot change
        mid-round); 2BW keeps the double buffer.  Because the flush
        timing is 1F1B's, in-flight activations are bounded by
        ``resid_slots`` = 2(S−1)+1 — not the naive GPipe R — and the
        accumulated gradient stays live for the whole round.
        """
        return self._memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas,
            weight_ring_slots=(self.weight_versions
                               if self.uses_stash_ring else 0),
            grad_accum=True)

    def _build_tables(self) -> ScheduleTables:
        tabs = super()._build_tables()
        S, R = self.n_stages, self.n_microbatches
        W, Vr = self.weight_versions, self.resid_slots
        fwd, bwd = tabs.fwd.copy(), tabs.bwd.copy()
        fs = np.clip(fwd[:, :, F_MB], 0, R - 1)
        bs = np.clip(bwd[:, :, B_MB], 0, R - 1)
        fwd[:, :, F_STASH_WRITE] = fs % W
        fwd[:, :, F_VERSION] = -1
        fwd[:, :, F_RESID_WRITE] = fs % Vr
        bwd[:, :, B_VERSION] = bs % W
        bwd[:, :, B_RESID_READ] = bs % Vr
        return ScheduleTables(fwd, bwd, tabs.exit_mb, tabs.demb_mb)


# ---------------------------------------------------------------------------
# ScheduleInterleaved1F1B — Megatron-style virtual stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleInterleaved1F1B(PipelineSchedule):
    """Interleaved (virtual-stage) 1F1B.

    The model is cut into L = S·v chunks; chunk c = j·S + s runs on
    physical stage s as its j-th local chunk (storage row s·v + j, see
    ``storage_chunk_order``).  Microbatches advance in groups of S:
    microbatch m = g·S + o forwards chunk (j, s) at tick

        t_F = s + g·v·S + j·S + o

    so every chunk hop — including the stage-(S−1) → stage-0 wrap
    between chunks — lands exactly one tick downstream, and each stage's
    F slot is saturated from tick s to s + vR − 1.  Backwards mirror the
    pattern with the last-chunk backward sharing the tick of its forward
    (head adjacency), giving

        t_B = (vS − 1) + (S−1−s) + g·v·S + (v−1−j)·S + o

    and n_ticks = vR + (v+1)S − 2 — the optimum for this engine: the
    first exit cannot precede tick vS−1 and each stage must drain vR
    backward slots.  THIS class runs flush-family versioning
    (accumulate, single weight version); per-microbatch asynchronous
    updates over the same timing are
    :class:`ScheduleInterleavedAsync1F1B`, which adds the per-chunk
    weight-version rings.

    Requires R % S == 0 (microbatch groups) and n_layers % (S·v) == 0.
    """

    virtual_stages: int = 2

    name = "interleaved"
    accumulate = True
    uses_stash_ring = False
    fwd_from_stash = False
    plan_stash_modes = ("flush",)
    takes_virtual_stages = True

    def __post_init__(self):
        super().__post_init__()
        assert self.virtual_stages >= 1, self.virtual_stages
        assert self.n_microbatches % self.n_stages == 0, (
            f"interleaved schedule needs microbatches ({self.n_microbatches})"
            f" divisible by stages ({self.n_stages})")

    @property
    def n_ticks(self) -> int:
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        return v * R + (v + 1) * S - 2

    @property
    def stash_slots(self) -> int:
        return 1

    @property
    def resid_slots(self) -> int:
        return self._layout()[1]

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1) -> MemoryModel:
        """Interleaved: per-chunk params, deeper residual ring.

        Each stage holds its v chunks' parameters (same per-stage total
        as the plain split of the same model over S stages — the win is
        bubble, not weights) but the residual ring deepens to the
        interval-coloured ``resid_slots`` (≈ v·S-scale), and flush
        semantics keep a single weight version plus the round-long grad
        accumulator.
        """
        return self._memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas,
            weight_ring_slots=0, grad_accum=True)

    def storage_chunk_order(self) -> np.ndarray:
        """chunk id held by each storage row p = s·v + j (length S·v).

        The stage-stacked parameter arrays are sharded contiguously over
        the "stage" mesh axis, so stage s owns rows [s·v, (s+1)·v); row
        s·v + j must hold model chunk j·S + s.
        """
        S, v = self.n_stages, self.virtual_stages
        return np.asarray([(p % v) * S + p // v for p in range(S * v)],
                          np.int64)

    @classmethod
    def from_plan(cls, plan) -> "ScheduleInterleaved1F1B":
        assert plan.stash_mode == "flush", (
            "schedule='interleaved' is the flush (accumulate) variant and "
            "needs stash_mode='flush'; for per-microbatch async updates "
            "use schedule='interleaved_async' (per-chunk weight-version "
            f"rings, stash_mode='stash'); got {plan.stash_mode!r}")
        return cls(plan.pp, plan.microbatches,
                   virtual_stages=getattr(plan, "virtual_stages", 2))

    def _timing(self):
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        L = S * v
        items = []       # (m, c, s, j, t_f, t_b)
        for m in range(R):
            g, o = divmod(m, S)
            for c in range(L):
                j, s = divmod(c, S)
                t_f = s + g * v * S + j * S + o
                t_b = (v * S - 1) + (S - 1 - s) + g * v * S \
                    + (v - 1 - j) * S + o
                items.append((m, c, s, j, t_f, t_b))
        return items

    def _layout(self):
        """Residual-slot assignment via interval colouring, per stage
        (memoized per instance, same pattern as tables())."""
        cached = self.__dict__.get("_layout_memo")
        if cached is not None:
            return cached
        items = self._timing()
        per_stage: Dict[int, List[int]] = {}
        for k, (m, c, s, j, t_f, t_b) in enumerate(items):
            per_stage.setdefault(s, []).append(k)
        slot_of = [0] * len(items)
        n_slots = 1
        for s, ks in per_stage.items():
            slots, n = _interval_color(
                [(items[k][4], items[k][5]) for k in ks])
            for k, sl in zip(ks, slots):
                slot_of[k] = sl
            n_slots = max(n_slots, n)
        object.__setattr__(self, "_layout_memo", (slot_of, n_slots))
        return slot_of, n_slots

    def _build_tables(self) -> ScheduleTables:
        S, v = self.n_stages, self.virtual_stages
        T, L = self.n_ticks, S * v
        items = self._timing()
        slot_of, _ = self._layout()
        fwd = np.full((T, S, F_COLS), -1, np.int32)
        bwd = np.full((T, S, B_COLS), -1, np.int32)
        exit_mb = np.full((T,), -1, np.int32)
        demb = np.full((T,), -1, np.int32)
        for k, (m, c, s, j, t_f, t_b) in enumerate(items):
            assert fwd[t_f, s, F_MB] < 0, ("F slot collision", t_f, s)
            fwd[t_f, s, F_MB] = m
            fwd[t_f, s, F_CHUNK] = j
            fwd[t_f, s, F_FROM_EMBEDS] = 1 if c == 0 else 0
            fwd[t_f, s, F_STASH_WRITE] = 0
            fwd[t_f, s, F_VERSION] = -1
            fwd[t_f, s, F_RESID_WRITE] = slot_of[k]
            assert bwd[t_b, s, B_MB] < 0, ("B slot collision", t_b, s)
            bwd[t_b, s, B_MB] = m
            bwd[t_b, s, B_CHUNK] = j
            bwd[t_b, s, B_FROM_HEAD] = 1 if c == L - 1 else 0
            bwd[t_b, s, B_VERSION] = 0
            bwd[t_b, s, B_RESID_READ] = slot_of[k]
            if c == L - 1:
                exit_mb[t_f] = m
            if c == 0:
                demb[t_b] = m
        return ScheduleTables(fwd, bwd, exit_mb, demb)


# ---------------------------------------------------------------------------
# ScheduleInterleavedAsync1F1B — per-chunk weight-version rings
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleInterleavedAsync1F1B(ScheduleInterleaved1F1B):
    """Interleaved 1F1B with per-microbatch updates and per-chunk rings.

    Same timing tables as :class:`ScheduleInterleaved1F1B` (the bubble
    win is a pure timing property), but the paper's §3.3 weight-stashing
    semantics generalized to virtual stages: every model chunk keeps its
    OWN stash ring, so F(m, chunk c) records chunk c's current weights
    into ring slot (c, m % V) and B(m, chunk c) re-reads exactly that
    version while the per-microbatch update advances the live weights in
    between.  The executor stores the rings chunk-major — one
    ``[V, S·v, ...]`` array whose row p = s·v + j is chunk j·S + s (see
    ``storage_chunk_order``), so the stage shard owns its chunks' rings
    contiguously and the table's version-slot columns index straight
    into it.

    Ring depth: chunk c is in flight for t_B − t_F = 2(S·v − 1 − c)
    ticks, and the m-th and (m+V)-th forwards of any chunk are at least
    2·v·S ticks apart when V = 2S (one microbatch-group period per S
    slots).  2S slots therefore cover the worst chunk (c = 0) for every
    v ≥ 2; v = 1 degenerates to plain 1F1B timing where the classic
    2(S−1)+1 suffices.  R caps the ring — m % V never revisits a slot
    within a round when V = R.
    """

    name = "interleaved_async"
    accumulate = False
    uses_stash_ring = True
    fwd_from_stash = False
    plan_stash_modes = ("stash",)

    @property
    def stash_slots(self) -> int:
        """Per-chunk weight versions: min(2S, R) (v ≥ 2; 2S−1 at v=1).

        Proof obligation (checked by ``validate``): the slot written at
        F(m, c) survives until B(m, c), i.e. the NEXT write of slot
        m % V — at F(m+V, c) — lands strictly after
        t_B(m, c) = t_F(m, c) + 2(vS − 1 − c).  At V = 2S, m+V is
        exactly two microbatch groups later at the same group offset,
        so t_F(m+V, c) − t_F(m, c) = 2vS > 2(vS − 1 − c) for every
        chunk.  At v = 1 the timing is plain 1F1B's (t_F = s + m), the
        spacing is V itself, and the classic V = 2S−1 > 2(S − 1 − c)
        suffices.  V = R trivially covers a round (m % R never
        revisits a slot), hence the min.
        """
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        base = 2 * S if v > 1 else 2 * S - 1
        return max(1, min(base, R))

    @classmethod
    def from_plan(cls, plan) -> "ScheduleInterleavedAsync1F1B":
        assert plan.stash_mode == "stash", (
            "schedule='interleaved_async' implements the paper's stash "
            "policy per chunk; set stash_mode='stash' (got "
            f"{plan.stash_mode!r})")
        return cls(plan.pp, plan.microbatches,
                   virtual_stages=getattr(plan, "virtual_stages", 2))

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1) -> MemoryModel:
        """Async interleaved: ring = per-chunk versions × chunk weights.

        Each of the stage's v chunks keeps ``stash_slots`` versions of
        its own block weights, so the ring totals
        stash_slots × (full stage weights) — the price of per-microbatch
        updates at virtual stages.  No round-long gradient accumulator
        (updates apply at each B; transient grads ride the workspace
        term), and the residual ring is the interleaved timing's
        interval-coloured depth, shared with the flush variant.
        """
        return self._memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas,
            weight_ring_slots=self.stash_slots, grad_accum=False)

    def _build_tables(self) -> ScheduleTables:
        tabs = super()._build_tables()
        R, V = self.n_microbatches, self.stash_slots
        fwd, bwd = tabs.fwd.copy(), tabs.bwd.copy()
        fs = np.clip(fwd[:, :, F_MB], 0, R - 1)
        bs = np.clip(bwd[:, :, B_MB], 0, R - 1)
        # slot within the row's OWN chunk ring — the executor indexes
        # the chunk-major ring by (this column, the chunk column)
        fwd[:, :, F_STASH_WRITE] = fs % V
        fwd[:, :, F_VERSION] = -1            # F uses the latest weights
        bwd[:, :, B_VERSION] = bs % V
        return ScheduleTables(fwd, bwd, tabs.exit_mb, tabs.demb_mb)

    def validate(self) -> None:
        """Structural contract + per-chunk stash-ring liveness."""
        super().validate()
        S, v = self.n_stages, self.virtual_stages
        tabs = self.tables()
        for s in range(S):
            live: Dict[Tuple[int, int], int] = {}   # (chunk, slot) -> mb
            for t in range(self.n_ticks):
                fr = tabs.fwd[t, s]
                if fr[F_MB] >= 0:
                    key = (int(fr[F_CHUNK]), int(fr[F_STASH_WRITE]))
                    assert key not in live, (
                        f"stage {s} tick {t}: F clobbers live version "
                        f"slot {key} (holds mb {live[key]})")
                    live[key] = int(fr[F_MB])
                br = tabs.bwd[t, s]
                if br[B_MB] >= 0:
                    key = (int(br[B_CHUNK]), int(br[B_VERSION]))
                    assert live.pop(key, None) == int(br[B_MB]), (
                        f"stage {s} tick {t}: B reads wrong version "
                        f"slot {key}")
            assert not live, f"stage {s}: versions never read: {live}"


# ---------------------------------------------------------------------------
# Serving schedules — forward-only rounds over the same tables
# ---------------------------------------------------------------------------

def default_cache_lens(spec, pp: int, cache_len: int) -> List[int]:
    """Per-position static KV capacities (union-max across stages).

    Windowed layers only need ``window`` slots; a position gets the max
    requirement over the stages (chunks — pass the chunk count for a
    virtual-stage split) that share it, so the capacities are
    SPMD-uniform.  Lives here because both the serving engine
    (serving/engine.py) and the serving memory model consume it.
    """
    lps = spec.layers_per_stage(pp)
    lens = []
    for i in range(lps):
        need = 0
        for s in range(pp):
            blk = spec.blocks[s * lps + i]
            if blk.mixer != "attn":
                continue
            w = blk.window
            need = max(need, cache_len if w <= 0 else min(w, cache_len))
        lens.append(max(need, 8))
    return lens


def serving_cache_bytes(spec, plan, sched, *, cache_len: int,
                        global_batch: int, sp: bool = False,
                        prefill: bool = False,
                        data_replicas: int = 1,
                        page_size: int = 0,
                        kv_occupancy: float = 1.0,
                        n_slots: Optional[int] = None,
                        kv_dtype: Optional[str] = None) -> float:
    """Worst-stage per-device KV/SSM/WKV cache bytes of one serve state.

    Mirrors the engine's cache template (serving/engine.py): stage s
    holds its chunks' recurrent state for every row it serves.  Rows
    shard over the data axes (``global_batch / dp`` rows per device);
    under sequence-parallel decode (``sp``) rows replicate and the
    full-length KV *positions* shard instead (windowed ring buffers stay
    replicated); KV heads shard over tp when divisible (GQA groups
    replicate otherwise, matching models/init.py::attn_static).  Prefill
    forces full-length caches (the contiguous qlen slab write).

    Paged KV (``page_size > 0``): full-length attention layers — the
    ones the engine pages, i.e. ``lens[i] >= cache_len`` — are priced by
    pages in use instead of slot capacity.  ``kv_occupancy`` is the
    expected fraction of KV positions actually held (mean request
    length / cache_len × live-slot fraction); with ``n_slots`` the
    fraction rounds UP to whole slots' worth of pages (the allocator
    hands out pages per slot, so sub-slot occupancies are unreachable).
    Constant-size recurrent state (mamba/rwkv/cmix) and windowed ring
    buffers stay dense — paging only thins full-length KV.  The shared
    per-slot page tables (int32, replicated across stages) are priced
    once.  Paged + sp is rejected, matching the engine.

    ``kv_dtype`` prices the KV storage dtype (repro.quant): "fp32" /
    "bf16" re-price every attention cache; "int8" prices the *paged*
    layers at one payload byte plus the amortized per-page scale —
    dense leftovers stay at the compute-dtype ACT_BYTES, exactly the
    engine's layout (int8 KV lives only in the page pools).
    """
    from repro import quant
    from repro.core.profiler import ACT_BYTES

    def _kv_elt_bytes(paged: bool) -> float:
        if kv_dtype is None:
            return ACT_BYTES
        if kv_dtype == "int8":
            return (quant.kv_byte_cost("int8", spec, page_size) if paged
                    else ACT_BYTES)
        return quant.kv_byte_cost(kv_dtype, spec, page_size)

    S, v = sched.n_stages, sched.virtual_stages
    L = S * v
    assert spec.n_layers % L == 0, (spec.n_layers, L)
    lps = spec.n_layers // L
    dp = max(int(data_replicas), 1)
    tp = plan.tp
    if page_size:
        assert not sp, "paged KV and sequence-parallel decode exclusive"
        assert cache_len % page_size == 0, (cache_len, page_size)
    if sp:
        rows = float(global_batch)               # replicated over data
    else:
        rows = global_batch / dp                 # sharded rows
    if prefill:
        lens = [cache_len] * lps
    else:
        lens = default_cache_lens(spec, L, cache_len)
    sp_flags = [sp and ln >= cache_len for ln in lens]
    paged_flags = [page_size > 0 and ln >= cache_len for ln in lens]
    if sp:
        lens = [max(-(-ln // dp), 8) if f else ln
                for ln, f in zip(lens, sp_flags)]
    kv_local = (spec.n_kv // tp if spec.n_kv and spec.n_kv % tp == 0
                else spec.n_kv)
    occ = min(max(float(kv_occupancy), 0.0), 1.0)
    if n_slots:
        # page granularity: ceil to whole slots' worth of pages
        occ = math.ceil(occ * n_slots) / n_slots
    stage_bytes = [0.0] * S
    any_paged = False
    for c in range(L):
        s = c % S
        for i in range(lps):
            blk = spec.blocks[c * lps + i]
            b = 0.0
            if blk.mixer == "attn":
                rows_eff = rows * occ if paged_flags[i] else rows
                any_paged |= paged_flags[i]
                b += 2.0 * rows_eff * lens[i] * kv_local * spec.d_head \
                    * _kv_elt_bytes(paged_flags[i])
            elif blk.mixer == "mamba":
                ms = spec.mamba
                d_inner = ms.expand * spec.d_model // tp
                b += rows * (ms.d_conv - 1) * d_inner * ACT_BYTES
                b += rows * d_inner * ms.d_state * 4.0        # fp32 scan
            elif blk.mixer == "rwkv":
                rs = spec.rwkv
                heads = spec.d_model // rs.head_dim // tp
                b += rows * spec.d_model * ACT_BYTES
                b += rows * heads * rs.head_dim * rs.head_dim * 4.0
            if blk.ffn == "rwkv_cmix":
                b += rows * spec.d_model * ACT_BYTES
            stage_bytes[s] += b
    if any_paged:
        # per-slot page tables, int32, replicated on every stage
        table_bytes = (n_slots or rows) * (cache_len // page_size) * 4.0
        stage_bytes = [b + table_bytes for b in stage_bytes]
    return max(stage_bytes)


@dataclasses.dataclass(frozen=True)
class ServingSchedule(PipelineSchedule):
    """Forward-only pipelined round: prefill, or one decode step.

    Timing is the mixed-radix decomposition of the training interleaved
    forward — microbatch m = g·S + o forwards chunk c = j·S + s at

        t_F = s + g·v·S + j·S + o

    — with NO backward slots, which removes the microbatch-group
    constraint: (g, j, o) decompose any t − s uniquely for o < S,
    j < v, so partial last groups are just bubbles and any R ≥ 1 is
    valid (sequence-parallel decode runs R = 1).  v = 1 reduces to the
    classic fwd-only 1F pipe (stage s forwards microbatch t − s,
    n_ticks = R + S − 1).  ``validate()`` proves the forward-only
    contract: exactly-once F per (microbatch, chunk), one-tick hop
    adjacency across every chunk boundary (wraps included), embeds
    consumed exactly at chunk 0, an empty backward table, and exit-table
    agreement.

    ``memory_model`` replaces the training rings with the serving cache
    term: live weights + KV/SSM cache (:func:`serving_cache_bytes`) +
    the engine's in-flight rings (embeds + hidden, R slots each).

    Slot liveness (continuous batching): ``live_slots`` — a sorted
    tuple of microbatch-slot indices — masks the tables to a partially
    occupied batch: a non-live slot's F rows and exits become bubbles
    while live slots keep their full-R timing, which is exactly how the
    continuous-batching engine runs (free slots compute garbage that is
    never written — serving/batcher.py).  ``validate()`` proves the
    forward-only contract over the live slots only; the drained ticks
    cost nothing under :func:`weighted_round_time`, which is how
    ``plan_search(occupancy=...)`` prices expected occupancy instead of
    assuming a full batch.  ``None`` (the default) means fully live.
    """

    live_slots: Optional[Tuple[int, ...]] = None

    name = "abstract_serve"
    accumulate = False
    uses_stash_ring = False
    fwd_from_stash = False
    plan_stash_modes = ("stash", "vertical", "flush", "2bw")
    needs_group_microbatches = False
    is_serving = True

    def __post_init__(self):
        super().__post_init__()
        if self.live_slots is not None:
            R = self.n_microbatches
            assert all(0 <= m < R for m in self.live_slots), (
                f"live_slots {self.live_slots} out of range for R={R}")
            assert list(self.live_slots) == sorted(set(self.live_slots)), (
                f"live_slots must be sorted and unique: {self.live_slots}")

    @property
    def live_count(self) -> int:
        """Number of live microbatch slots (R when unmasked)."""
        return (self.n_microbatches if self.live_slots is None
                else len(self.live_slots))

    def live_mask(self) -> np.ndarray:
        """Boolean [R] mask of live slots."""
        mask = np.ones(self.n_microbatches, bool)
        if self.live_slots is not None:
            mask[:] = False
            mask[list(self.live_slots)] = True
        return mask

    def with_live_slots(self, live) -> "ServingSchedule":
        """This schedule with only ``live`` microbatch slots occupied.

        ``live`` is an iterable of slot indices (or None to unmask).
        The timing of live slots is unchanged — masking only blanks the
        dead slots' rows — so the masked tables describe exactly what
        the continuous-batching engine executes between admissions.
        """
        slots = None if live is None else tuple(sorted(set(int(m)
                                                          for m in live)))
        return dataclasses.replace(self, live_slots=slots)

    def bucketed(self, n_live: int) -> "ServingSchedule":
        """The compacted ``n_live``-slot variant of this schedule.

        Where :meth:`with_live_slots` *masks* (dead slots' rows blank to
        bubbles but the round keeps full-R ticks), ``bucketed``
        *deletes*: the returned schedule is this one with
        ``n_microbatches = n_live``, so its round is the short
        ``n_live + S·v − ...`` tick program the liveness-aware engine
        actually executes for a compacted batch whose live slots occupy
        the prefix ``[0, n_live)``.

        Proof that deletion ≡ mask-then-truncate (checked here, every
        call): serve timing ``t = s + g·v·S + j·S + o`` depends only on
        a slot's own index m = g·S + o, never on R, so slot m < n_live
        keeps identical (tick, stage, chunk) placement in both tables.
        We assert the bucket's fwd/exit tables equal the full-R
        ``with_live_slots(range(n_live))`` tables truncated to the
        bucket's ``n_ticks`` — and that the masked tail past that is
        pure bubble — then run the bucket's own ``validate()``.
        """
        R = self.n_microbatches
        if not 1 <= n_live <= R:
            raise ValueError(f"bucket size {n_live} outside [1, R={R}]")
        bucket = dataclasses.replace(self, n_microbatches=n_live,
                                     live_slots=None)
        bucket.validate()
        masked = dataclasses.replace(self, live_slots=None).with_live_slots(
            range(n_live))
        bt, mt = bucket.tables(), masked.tables()
        Tb = bucket.n_ticks
        assert (bt.fwd == mt.fwd[:Tb]).all(), (
            "bucketed fwd table is not the masked full-R table with dead "
            "slots deleted")
        assert (bt.exit_mb == mt.exit_mb[:Tb]).all(), (
            "bucketed exit table diverges from the masked full-R exits")
        assert (mt.fwd[Tb:, :, F_MB] < 0).all() and (
            mt.exit_mb[Tb:] < 0).all(), (
            "masked full-R table still schedules work past the bucket's "
            "last tick — deletion would drop it")
        return bucket

    @property
    def n_ticks(self) -> int:
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        g, o = divmod(R - 1, S)
        return (S - 1) + g * v * S + (v - 1) * S + o + 1

    @property
    def stash_slots(self) -> int:
        return 1                     # live weights only; nothing stashed

    @property
    def resid_slots(self) -> int:
        return 1                     # no backward ⇒ no residual ring

    def _build_tables(self) -> ScheduleTables:
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        T = self.n_ticks
        fwd = np.full((T, S, F_COLS), -1, np.int32)
        bwd = np.full((T, S, B_COLS), -1, np.int32)
        exit_mb = np.full((T,), -1, np.int32)
        demb = np.full((T,), -1, np.int32)
        for m in range(R):
            g, o = divmod(m, S)
            for j in range(v):
                for s in range(S):
                    c = j * S + s
                    t = s + g * v * S + j * S + o
                    assert fwd[t, s, F_MB] < 0, ("F slot collision", t, s)
                    fwd[t, s, F_MB] = m
                    fwd[t, s, F_CHUNK] = j
                    fwd[t, s, F_FROM_EMBEDS] = 1 if c == 0 else 0
                    fwd[t, s, F_STASH_WRITE] = 0
                    fwd[t, s, F_VERSION] = -1
                    fwd[t, s, F_RESID_WRITE] = 0
                    if c == S * v - 1:
                        exit_mb[t] = m
        if self.live_slots is not None:
            # blank the dead slots' rows: their time slots stay bubbles
            # (live slots keep the full-R timing — the engine's tables
            # are static, a free slot simply computes unwritten garbage)
            live = self.live_mask()
            mb = fwd[:, :, F_MB]
            dead = (mb >= 0) & ~live[np.clip(mb, 0, R - 1)]
            fwd[dead] = -1
            edead = (exit_mb >= 0) & ~live[np.clip(exit_mb, 0, R - 1)]
            exit_mb[edead] = -1
        return ScheduleTables(fwd, bwd, exit_mb, demb)

    def validate(self) -> None:
        """Forward-only dataflow contract over the live slots."""
        S, R, v = self.n_stages, self.n_microbatches, self.virtual_stages
        tabs = self.tables()
        T, L = self.n_ticks, S * v
        live = self.live_mask()
        live_mbs = [m for m in range(R) if live[m]]
        assert tabs.fwd.shape == (T, S, F_COLS), tabs.fwd.shape
        assert tabs.bwd.shape == (T, S, B_COLS), tabs.bwd.shape
        assert (tabs.bwd[:, :, B_MB] < 0).all(), "serving is forward-only"
        assert (tabs.demb_mb < 0).all(), "no d(embeddings) when serving"
        f_time: Dict[Tuple[int, int], int] = {}
        for t in range(T):
            for s in range(S):
                fr = tabs.fwd[t, s]
                if fr[F_MB] < 0:
                    continue
                assert live[int(fr[F_MB])], (
                    f"tick {t} stage {s}: dead slot {int(fr[F_MB])} "
                    "scheduled")
                c = int(fr[F_CHUNK]) * S + s
                key = (int(fr[F_MB]), c)
                assert key not in f_time, f"duplicate F{key}"
                assert (fr[F_FROM_EMBEDS] == 1) == (c == 0), (t, s)
                f_time[key] = t
        assert len(f_time) == len(live_mbs) * L, (
            len(f_time), len(live_mbs) * L)
        for m in live_mbs:
            for c in range(1, L):   # one-tick hops, wrap included
                assert f_time[(m, c)] == f_time[(m, c - 1)] + 1, (m, c)
        for t in range(T):
            fr = tabs.fwd[t, S - 1]
            is_exit = fr[F_MB] >= 0 and fr[F_CHUNK] == v - 1
            assert tabs.exit_mb[t] == (fr[F_MB] if is_exit else -1), t
        assert int((tabs.exit_mb >= 0).sum()) == len(live_mbs)
        if self.live_slots is None:
            assert tabs.exit_mb[T - 1] >= 0, (
                "round must end on the last exit")
        else:
            # masking only blanks: every live slot exits at EXACTLY the
            # tick the unmasked schedule gives it (dead slots' exits
            # blank to -1); the round may drain early past the last one
            full = dataclasses.replace(self, live_slots=None)
            fx = full.tables().exit_mb
            keep = (fx >= 0) & live[np.clip(fx, 0, R - 1)]
            want = np.where(keep, fx, -1)
            assert (tabs.exit_mb == want).all(), (
                "masked exit table moved a live slot's exit tick")

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1, cache_len: int = None,
                     global_batch: int = None, sp: bool = False,
                     prefill: bool = False, page_size: int = 0,
                     kv_occupancy: float = 1.0,
                     weight_dtype: Optional[str] = None,
                     kv_dtype: Optional[str] = None) -> MemoryModel:
        """Serving footprint: weights + KV/SSM cache + in-flight rings.

        No version ring, residual ring, gradient accumulator or
        optimizer state — the serving state is {params, cache, pos,
        live} (the per-slot position/liveness vectors are R int32s,
        below noise).
        The workspace term matches the engine's rings: the R-slot embeds
        ring, the R-slot exiting-hidden ring, and one activation in
        flight per stage (each slot is one microbatch × qlen of hidden
        state — ``microbatch_tokens`` rows·qlen per device).

        ``weight_dtype`` / ``kv_dtype`` price quantized storage
        (repro.quant): int8/fp8 weights pay 1 byte + the amortized
        per-channel scale instead of ``hw.param_bytes``; int8 KV
        re-prices the paged pools.
        """
        assert cache_len is not None and global_batch is not None, (
            "serving memory_model needs cache_len= and global_batch= "
            "(the KV/SSM cache term is sized from them)")
        from repro import quant
        from repro.core.profiler import ACT_BYTES

        blocks, shared = stage_weight_params(spec, plan, self)
        act = microbatch_tokens * spec.d_model * ACT_BYTES
        cache = serving_cache_bytes(
            spec, plan, self, cache_len=cache_len,
            global_batch=global_batch, sp=sp, prefill=prefill,
            data_replicas=data_replicas, page_size=page_size,
            kv_occupancy=kv_occupancy, n_slots=self.n_microbatches,
            kv_dtype=kv_dtype)
        return MemoryModel(
            schedule=self.name,
            weight_bytes=(blocks + shared)
            * quant.weight_byte_cost(weight_dtype, spec, hw),
            stash_bytes=0.0,
            resid_bytes=0.0,
            workspace_bytes=(2.0 * self.n_microbatches + 2.0) * act,
            grad_bytes=0.0,
            optimizer_bytes=0.0,
            cache_bytes=cache)


@dataclasses.dataclass(frozen=True)
class ScheduleServe1F(ServingSchedule):
    """Forward-only 1F serving pipe: stage s forwards microbatch t − s.

    The table form of the old hand-rolled serving loop: R + S − 1
    ticks, one chunk per stage.
    """

    name = "serve_1f"

    @classmethod
    def from_plan(cls, plan) -> "ScheduleServe1F":
        return cls(plan.pp, plan.decode_microbatches)


@dataclasses.dataclass(frozen=True)
class ScheduleServeInterleaved(ServingSchedule):
    """Forward-only interleaved serving: v chunks per physical stage.

    Same chunk placement and storage order as the training interleaved
    family (chunk c = j·S + s lives on stage s as local chunk j, storage
    row s·v + j — :meth:`storage_chunk_order` is shared with
    :class:`ScheduleInterleaved1F1B`, so
    ``reshard_state_for_plan`` round-trips train → serve checkpoints
    unchanged).  A chunk slot costs 1/v of a stage pass, so the batch
    prefill completes in R + (S−1)/v stage-passes instead of 1F's
    R + (S−1): the ramp — and with it the worst request's
    time-to-first-token — shrinks by v (see :func:`serve_ttft`).
    """

    virtual_stages: int = 2

    name = "serve_interleaved"
    takes_virtual_stages = True

    def __post_init__(self):
        super().__post_init__()
        assert self.virtual_stages >= 1, self.virtual_stages

    # same storage permutation as training interleaving — the whole point
    storage_chunk_order = ScheduleInterleaved1F1B.storage_chunk_order

    @classmethod
    def from_plan(cls, plan) -> "ScheduleServeInterleaved":
        # the plan's chunking verbatim — never silently forced to 2, so
        # the schedule always describes its plan (memory_model asserts
        # exactly that); v = 1 degenerates to the serve_1f timing
        return cls(plan.pp, plan.decode_microbatches,
                   virtual_stages=getattr(plan, "virtual_stages", 1) or 1)


class _SpeculativeServe:
    """Mixin: the draft–verify accept/rollback contract for serving.

    A speculative round feeds each live slot ``spec_k + 1`` tokens —
    its current token plus ``spec_k`` drafts — and one ramp through the
    UNCHANGED serve tables (the table walk is qlen-agnostic; only the
    per-row qlen grows from 1 to ``verify_qlen``) scores all positions
    at once.  Greedy verification accepts the longest draft prefix that
    matches the verifier's own argmax, emits ``accepted + 1`` tokens
    (the matched drafts plus the verifier's bonus token — so progress
    per round is in ``[1, spec_k + 1]`` and never worse than plain
    decode), and rolls the remaining ``spec_k - accepted`` positions
    back: a masked ``pos`` decrement (stale dense KV is invisible
    behind the position mask) plus, paged, releasing the rejected
    suffix's pages (``serving/batcher.py::PageAllocator.truncate_slot``).
    Rollback makes speculation a pure latency optimization — greedy
    output is bit-exact vs non-speculative decode by construction.

    The mixin adds the contract on top of any :class:`ServingSchedule`
    timing: :meth:`accept_pos_delta` (the accept/rollback arithmetic),
    :meth:`rollback_table` (the second exit table — the tick each
    slot's rejected suffix resolves), a :meth:`validate` extension that
    proves both, and a :meth:`memory_model` term for the widened
    verify workspace and the draft state.
    """

    is_speculative = True

    @property
    def verify_qlen(self) -> int:
        """Positions scored per slot per round: spec_k drafts + 1."""
        return self.spec_k + 1

    def accept_pos_delta(self, accepted: int) -> Tuple[int, int]:
        """(advance, rolled_back) for a slot that accepted ``accepted``.

        advance = accepted + 1 (matched drafts + the verifier's bonus
        token), rolled_back = spec_k - accepted; together they account
        for every scored position.  ``accepted`` outside [0, spec_k]
        is a caller bug and raises.
        """
        a = int(accepted)
        if not 0 <= a <= self.spec_k:
            raise ValueError(
                f"accepted={accepted} outside [0, spec_k={self.spec_k}]")
        return a + 1, self.spec_k - a

    def rollback_table(self) -> np.ndarray:
        """Second exit table: tick → slot whose rejected suffix resolves.

        Acceptance for a slot is known the tick its last chunk exits
        (``tables().exit_mb``), and the rollback — masked ``pos``
        decrement + KV truncation — applies in that same tick's
        epilogue, before the next round's drafts are drawn.  The table
        therefore mirrors ``exit_mb`` over live slots: every live slot
        resolves exactly once per round, dead slots never.
        """
        return np.asarray(self.tables().exit_mb).copy()

    def validate(self) -> None:
        """Forward-only contract plus the accept/rollback contract."""
        super().validate()
        k = self.spec_k
        assert k >= 1, f"spec_k={k} must be >= 1 for a speculative schedule"
        rb = self.rollback_table()
        tabs = self.tables()
        assert rb.shape == tabs.exit_mb.shape and (rb == tabs.exit_mb).all(), (
            "rollback table must resolve each slot at its exit tick")
        live = self.live_mask()
        counts = np.bincount(rb[rb >= 0], minlength=self.n_microbatches)
        for m in range(self.n_microbatches):
            assert counts[m] == (1 if live[m] else 0), (
                f"slot {m} resolves {counts[m]} times per round")
        # accept/rollback arithmetic: every acceptance a ∈ [0, k]
        # advances a+1 and rolls back k-a — all k+1 scored positions
        # accounted for, and advance ≥ 1 (the bonus token always lands)
        for a in range(k + 1):
            adv, rolled = self.accept_pos_delta(a)
            assert adv == a + 1 and rolled == k - a, (a, adv, rolled)
            assert adv + rolled == self.verify_qlen and adv >= 1
        try:
            self.accept_pos_delta(k + 1)
            raise AssertionError("accept_pos_delta(k+1) must raise")
        except ValueError:
            pass

    def memory_model(self, spec, plan, hw, *, microbatch_tokens: int,
                     data_replicas: int = 1, cache_len: int = None,
                     global_batch: int = None, sp: bool = False,
                     prefill: bool = False, page_size: int = 0,
                     kv_occupancy: float = 1.0) -> MemoryModel:
        """Serving footprint with the verify-width and draft-state terms.

        The in-flight rings hold ``verify_qlen`` positions per slot
        instead of 1, so the workspace scales by spec_k + 1; the draft
        state (per-slot draft tokens + one embeds row in flight through
        the head-only drafter) rides on top.
        """
        from repro.core.profiler import ACT_BYTES
        mm = super().memory_model(
            spec, plan, hw, microbatch_tokens=microbatch_tokens,
            data_replicas=data_replicas, cache_len=cache_len,
            global_batch=global_batch, sp=sp, prefill=prefill,
            page_size=page_size, kv_occupancy=kv_occupancy)
        act = microbatch_tokens * spec.d_model * ACT_BYTES
        draft_bytes = (self.n_microbatches * self.spec_k * 4.0  # tokens
                       + act)                  # one drafter row in flight
        return dataclasses.replace(
            mm,
            workspace_bytes=mm.workspace_bytes * self.verify_qlen
            + draft_bytes)


@dataclasses.dataclass(frozen=True)
class ScheduleServeSpec1F(_SpeculativeServe, ScheduleServe1F):
    """Speculative draft–verify decode on the 1F serving pipe.

    Identical tick program to :class:`ScheduleServe1F` — each slot's
    row is just ``spec_k + 1`` positions wide instead of 1, so one
    R + S − 1 tick round verifies up to spec_k + 1 tokens per slot.
    """

    spec_k: int = 4

    name = "serve_spec_1f"

    def __post_init__(self):
        super().__post_init__()
        assert self.spec_k >= 1, (
            f"spec_k={self.spec_k} must be >= 1 (0 drafts is plain "
            "serve_1f)")

    @classmethod
    def from_plan(cls, plan) -> "ScheduleServeSpec1F":
        return cls(plan.pp, plan.decode_microbatches)


@dataclasses.dataclass(frozen=True)
class ScheduleServeSpecInterleaved(_SpeculativeServe,
                                   ScheduleServeInterleaved):
    """Speculative draft–verify decode on the interleaved serving pipe.

    :class:`ScheduleServeInterleaved` timing (v chunks per stage,
    ramp/v), verify rows ``spec_k + 1`` wide.  Shares the training
    storage order, so train → serve checkpoints round-trip unchanged.
    """

    spec_k: int = 4

    name = "serve_spec_interleaved"

    def __post_init__(self):
        super().__post_init__()
        assert self.spec_k >= 1, (
            f"spec_k={self.spec_k} must be >= 1 (0 drafts is plain "
            "serve_interleaved)")

    @classmethod
    def from_plan(cls, plan) -> "ScheduleServeSpecInterleaved":
        return cls(plan.pp, plan.decode_microbatches,
                   virtual_stages=getattr(plan, "virtual_stages", 1) or 1)


def serve_ttft(sched: PipelineSchedule, t_fwd=1.0) -> float:
    """Weighted time-to-first-token of a prefill round.

    The F-phase walk (ramp ticks charged like
    :func:`weighted_round_time`: each tick costs its slowest active
    stage's forward, a chunk slot costs 1/v of a stage pass) through the
    tick where the LAST microbatch's first token exits — i.e. the
    worst request's TTFT when the whole batch prefills together.  For a
    forward-only schedule this is the entire round; the closed forms
    (full microbatch groups, S | R) are (R + S − 1)·t for ``serve_1f``
    and (v·R + S − 1)·t/v for ``serve_interleaved`` — strictly smaller
    for v ≥ 2 whenever S ≥ 2.  Partial last groups (R % S ≠ 0) pad the
    interleaved ramp but never past the 1F time.
    """
    tabs = sched.tables()
    S, v = sched.n_stages, sched.virtual_stages
    tf = np.broadcast_to(np.asarray(t_fwd, float), (S,))
    fbusy = tabs.fwd[:, :, F_MB] >= 0
    f_phase = np.where(fbusy, tf[None, :], 0.0).max(axis=1) / v
    exits = np.flatnonzero(tabs.exit_mb >= 0)
    assert exits.size, "schedule has no exit ticks"
    return float(f_phase[: int(exits[-1]) + 1].sum())


def bucket_lattice(R: int) -> Tuple[int, ...]:
    """The compacted-variant sizes the liveness-aware engine compiles.

    Powers of two up to R, plus R itself: {1, 2, 4, …, R}.  Log₂(R)+1
    programs cover every occupancy within 2x of the ideal slot count —
    the lattice-of-static-variants trick (compile few, select per
    step), bounded so lazy per-bucket jit stays cheap.  R = 6 →
    (1, 2, 4, 6).
    """
    if R < 1:
        raise ValueError(f"R={R} must be >= 1")
    lat = []
    b = 1
    while b < R:
        lat.append(b)
        b *= 2
    lat.append(R)
    return tuple(lat)


def pick_bucket(n_live: int, lattice: Iterable[int]) -> int:
    """Smallest lattice entry that fits ``n_live`` live slots.

    An empty batch (n_live = 0) still runs the smallest bucket — the
    engine's decode is never a no-op program.  ``lattice`` must contain
    a bucket ≥ n_live (it always does when built by
    :func:`bucket_lattice` with R ≥ n_live).
    """
    fits = sorted(b for b in lattice if b >= max(1, int(n_live)))
    if not fits:
        raise ValueError(
            f"no bucket in {sorted(lattice)} fits {n_live} live slots")
    return fits[0]


def fit_serving_microbatches(decode_microbatches: int, global_batch: int,
                             dp: int, *, sp: bool = False) -> int:
    """The decode microbatch count the engine will actually run.

    Largest R ≤ ``decode_microbatches`` with dp·R | global_batch
    (sequence-parallel decode forces R = 1: rows replicate).  Shared by
    the engine (serving/engine.py::fit_decode_microbatches) and
    ``plan_search``'s serving workloads, so the planner prices the same
    tables the engine executes — not the config's nominal R.
    """
    if sp:
        return 1
    if decode_microbatches < 1:
        raise ValueError(
            f"decode_microbatches={decode_microbatches} must be >= 1")
    if dp < 1 or global_batch % dp:
        raise ValueError(
            f"global_batch={global_batch} is not divisible by the "
            f"data-parallel degree dp={dp}; no microbatch count can tile "
            "it — pick a batch divisible by dp or reshape the mesh")
    R = min(decode_microbatches, max(global_batch // dp, 1))
    while global_batch % (dp * R):
        R -= 1
    return R


def make_serving_schedule(plan, n_microbatches: int = None,
                          spec_k: int = None) -> "ServingSchedule":
    """The forward-only schedule a plan asks for, from the registry.

    A plan whose ``schedule`` names a serving schedule gets exactly
    that; a training-schedule (or ``'auto'``) plan maps onto the
    serving analogue of its chunking — ``serve_interleaved`` when
    ``virtual_stages > 1``, else ``serve_1f``.  ``n_microbatches``
    overrides ``plan.decode_microbatches`` (the engine passes its
    batch-fitted R).  ``spec_k`` overrides the draft depth of a
    speculative (``serve_spec_*``) schedule; passing it for a
    non-speculative resolution is a typed error (never silently
    ignored).  Unknown or non-serving resolutions raise a
    registry-lookup error naming the registered serving schedules.
    """
    name = getattr(plan, "schedule", "auto")
    cls = SCHEDULES.get(name)
    # only 'auto' and *registered training* schedules map onto their
    # serving analogue — an unknown name is an error, never a silent
    # serve_1f fallback
    if name == "auto" or (cls is not None and not cls.is_serving):
        name = ("serve_interleaved" if plan.virtual_stages > 1
                else "serve_1f")
        cls = SCHEDULES.get(name)
    if cls is None or not cls.is_serving:
        raise KeyError(
            f"no serving schedule {name!r} in the registry; registered "
            f"serving schedules: "
            f"{sorted(n for n, c in SCHEDULES.items() if c.is_serving)}")
    if spec_k is not None and not cls.is_speculative:
        raise ValueError(
            f"spec_k={spec_k} passed but schedule {name!r} is not "
            "speculative; speculative serving schedules: "
            f"{sorted(n for n, c in SCHEDULES.items() if c.is_speculative)}")
    R = (n_microbatches if n_microbatches is not None
         else plan.decode_microbatches)
    kw = {}
    if cls.takes_virtual_stages:
        kw["virtual_stages"] = plan.virtual_stages
    if spec_k is not None:
        kw["spec_k"] = int(spec_k)
    return cls(plan.pp, R, **kw)


# ---------------------------------------------------------------------------
# Time-weighted round walk (shared by benchmarks/simulator and plan_search)
# ---------------------------------------------------------------------------

def weighted_round_time(sched: PipelineSchedule, t_fwd=1.0, t_bwd=2.0
                        ) -> Tuple[float, float]:
    """Wall-clock of one round with per-direction (and per-stage) costs.

    The SPMD engine runs each tick as a synchronized F phase then B
    phase across all stages, so a tick's F phase costs the *slowest
    active* stage's forward (0 when no stage forwards — ramp-up/drain
    ticks are charged only for the direction that actually runs), and a
    chunk slot costs 1/v of its stage's full pass.  ``t_fwd``/``t_bwd``
    are scalars or per-physical-stage arrays of full-stage (all-chunk)
    seconds.

    Returns ``(round_time, weighted_bubble_fraction)`` where the bubble
    is idle *time* over ``n_stages × round_time`` — unlike the
    slot-count :attr:`PipelineSchedule.bubble_fraction`, which weights F
    and B slots equally and charges half-empty ticks in full.
    """
    tabs = sched.tables()
    S, v = sched.n_stages, sched.virtual_stages
    tf = np.broadcast_to(np.asarray(t_fwd, float), (S,))
    tb = np.broadcast_to(np.asarray(t_bwd, float), (S,))
    fbusy = tabs.fwd[:, :, F_MB] >= 0
    bbusy = tabs.bwd[:, :, B_MB] >= 0
    f_phase = np.where(fbusy, tf[None, :], 0.0).max(axis=1) / v
    b_phase = np.where(bbusy, tb[None, :], 0.0).max(axis=1) / v
    round_time = float(f_phase.sum() + b_phase.sum())
    if round_time <= 0.0:
        return 0.0, 0.0
    busy_time = float((fbusy * (tf[None, :] / v)).sum()
                      + (bbusy * (tb[None, :] / v)).sum())
    return round_time, 1.0 - busy_time / (S * round_time)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCHEDULES: Dict[str, Type[PipelineSchedule]] = {
    "1f1b": Schedule1F1B,
    "gpipe": ScheduleGPipe,
    "interleaved": ScheduleInterleaved1F1B,
    "interleaved_async": ScheduleInterleavedAsync1F1B,
    "serve_1f": ScheduleServe1F,
    "serve_interleaved": ScheduleServeInterleaved,
    "serve_spec_1f": ScheduleServeSpec1F,
    "serve_spec_interleaved": ScheduleServeSpecInterleaved,
}


def register_schedule(name: str, cls: Type[PipelineSchedule]) -> None:
    """Add a schedule implementation to the registry."""
    assert name not in SCHEDULES, f"schedule {name!r} already registered"
    SCHEDULES[name] = cls


def plan_kwargs_for_schedule(name: str, *, virtual_stages=None,
                             stash_mode=None) -> Dict[str, object]:
    """``ParallelismPlan.with_()`` kwargs that put a plan onto ``name``.

    The single source of the schedule -> plan policy (consumed by
    ``plan_search`` candidates and the launch CLIs, so registering a
    schedule needs no edits there): keeps ``stash_mode`` when the class
    accepts it (``plan_stash_modes``), normalizes to the class default
    otherwise, and resolves ``virtual_stages`` — default 2 for the
    interleaved family (``takes_virtual_stages``), forced to 1 for
    single-chunk schedules.
    """
    cls = SCHEDULES.get(name)
    assert cls is not None, (
        f"unknown schedule {name!r}; registered: {sorted(SCHEDULES)}")
    kw: Dict[str, object] = {"schedule": name}
    if stash_mode not in cls.plan_stash_modes:
        kw["stash_mode"] = cls.plan_stash_modes[0]
    kw["virtual_stages"] = ((virtual_stages or 2)
                            if cls.takes_virtual_stages else 1)
    return kw


def virtual_stages_error(schedule_name, virtual_stages) -> str | None:
    """None when the combination is valid, else the CLI error message.

    Shared by the launch entry points (launch/train.py,
    launch/dryrun.py) so the --virtual-stages/--schedule compatibility
    rule and its diagnostic cannot drift between them.
    """
    if not virtual_stages or virtual_stages <= 1:
        return None
    cls = SCHEDULES.get(schedule_name) if schedule_name else None
    if cls is not None and cls.takes_virtual_stages:
        return None
    return ("--virtual-stages > 1 requires --schedule in "
            f"{sorted(n for n, c in SCHEDULES.items() if c.takes_virtual_stages)}")


def make_schedule(plan) -> PipelineSchedule:
    """Build the schedule a ParallelismPlan asks for.

    ``plan.schedule='auto'`` (the default) derives the schedule name
    from the legacy ``stash_mode`` field: stash/vertical -> 1f1b,
    flush/2bw -> gpipe.  The resolved class constructs itself from the
    plan via its ``from_plan`` classmethod, so registered third-party
    schedules receive the full plan (virtual_stages, stash_mode, ...)
    without edits here.
    """
    name = getattr(plan, "schedule", "auto")
    if name == "auto":
        name = "gpipe" if plan.stash_mode in ("flush", "2bw") else "1f1b"
    cls = SCHEDULES.get(name)
    assert cls is not None, (
        f"unknown schedule {name!r}; registered: {sorted(SCHEDULES)}")
    return cls.from_plan(plan)


def paper_noam(total_machines: int, input_stage_machines: int) -> int:
    """NUM_OPT_ACTIVE_MINIBATCHES = ceil(#machines / #machines input stage)."""
    return math.ceil(total_machines / input_stage_machines)
