"""1F1B schedule (paper §3.3) as static tables.

We use the "double-tick" formulation: one tick = one F-slot followed by one
B-slot on every stage.  In steady state each stage alternates F and B — the
paper's one-forward-one-backward policy — and the startup/drain phases fall
out as ticks whose F- or B-slot is invalid (the pipeline bubble).

Indices (S stages, R microbatches, stage s ∈ [0, S), tick τ):
    F slot:  microbatch f = τ − s                  valid iff 0 ≤ f < R
    B slot:  microbatch b = τ − 2(S−1) + s         valid iff 0 ≤ b < R
The output stage (s = S−1) runs F(m) and B(m) in the same tick — exactly
Figure 8.  Weight versions in flight at stage s: 2(S−1−s)+1, so the
SPMD-uniform stash ring needs V = 2(S−1)+1 slots (paper: NOAM versions at
the input stage; the factor-2 reflects equal F/B slot granularity).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule1F1B:
    n_stages: int
    n_microbatches: int

    def __post_init__(self):
        assert self.n_stages >= 1 and self.n_microbatches >= 1

    @property
    def n_ticks(self) -> int:
        return self.n_microbatches + 2 * (self.n_stages - 1)

    @property
    def stash_slots(self) -> int:
        return 2 * (self.n_stages - 1) + 1

    def fwd_mb(self, tick: int, stage: int) -> int:
        """Microbatch this stage forwards at this tick (-1 if bubble)."""
        f = tick - stage
        return f if 0 <= f < self.n_microbatches else -1

    def bwd_mb(self, tick: int, stage: int) -> int:
        b = tick - 2 * (self.n_stages - 1) + stage
        return b if 0 <= b < self.n_microbatches else -1

    def max_in_flight(self, stage: int) -> int:
        """Microbatches between F(m) and B(m) at this stage (incl. current)."""
        return 2 * (self.n_stages - 1 - stage) + 1

    def tables(self):
        """(fwd[T, S], bwd[T, S]) int arrays, -1 marks bubble slots."""
        t, s = self.n_ticks, self.n_stages
        fwd = np.full((t, s), -1, np.int32)
        bwd = np.full((t, s), -1, np.int32)
        for tick in range(t):
            for stage in range(s):
                fwd[tick, stage] = self.fwd_mb(tick, stage)
                bwd[tick, stage] = self.bwd_mb(tick, stage)
        return fwd, bwd

    @property
    def bubble_fraction(self) -> float:
        """Fraction of (tick, stage, slot) triples idle over a round."""
        total = 2 * self.n_ticks * self.n_stages
        busy = 2 * self.n_microbatches * self.n_stages
        return 1.0 - busy / total

    def steady_state_ticks(self):
        """Tick range in which every stage has both slots busy."""
        lo = 2 * (self.n_stages - 1)
        hi = self.n_microbatches - 1
        return (lo, hi) if hi >= lo else None


def paper_noam(total_machines: int, input_stage_machines: int) -> int:
    """NUM_OPT_ACTIVE_MINIBATCHES = ceil(#machines / #machines input stage)."""
    return math.ceil(total_machines / input_stage_machines)
