"""Weight-version stash ring + ZeRO-1 update policies (paper §3.3/§3.5).

Split out of core/pipeline.py so the executor holds only orchestration:
this module owns

  * pytree ring-buffer primitives (the weight stash and residual rings
    are rings of stacked pytrees, indexed by schedule-table slots) —
    both the stage-global [V, ...] layout (1F1B / 2BW) and the
    chunk-major two-level [V, chunks, ...] layout keyed by
    (version slot, local chunk) that the async interleaved schedule's
    per-chunk rings use;
  * ZeRO-1 optimizer-state sharding over the data axes — axis choice,
    partition-spec derivation, and the manual reduce-scatter / update /
    all-gather step used on the per-microbatch update path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------
# Pytree ring-buffer helpers
# --------------------------------------------------------------------------


def tree_ring_read(tree, idx):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False),
        tree)


def tree_ring_write(tree, idx, val, valid):
    def w(a, v):
        cur = jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False)
        new = jnp.where(valid, v.astype(a.dtype), cur)
        return jax.lax.dynamic_update_index_in_dim(a, new, idx, 0)
    return jax.tree.map(w, tree, val)


def tree_select(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda a: a * s.astype(a.dtype), tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_chunk(tree, idx):
    """Select one local chunk row, keeping the leading [1] stage dim."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=True),
        tree)


def tree_chunk_write(tree, idx, val):
    """Write one local chunk row (val keeps its leading [1] chunk dim)."""
    return jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_index_in_dim(
            a, p[0].astype(a.dtype), idx, 0),
        tree, val)


def tree_chunk_ring_read(ring, slot, chunk):
    """Chunk-major version ring [V, v, ...] -> chunk view [1, ...].

    The async-interleaved schedule keys its weight stash by
    (version slot, local chunk); this is the B-side read of the version
    F recorded for that (microbatch, chunk).
    """
    def r(a):
        row = jax.lax.dynamic_index_in_dim(a, slot, 0, keepdims=False)
        return jax.lax.dynamic_index_in_dim(row, chunk, 0, keepdims=True)
    return jax.tree.map(r, ring)


def tree_chunk_ring_write(ring, slot, chunk, val, valid):
    """Record a chunk's current weights into its ring slot (F side)."""
    def w(a, p):
        row = jax.lax.dynamic_index_in_dim(a, slot, 0, keepdims=False)
        cur = jax.lax.dynamic_index_in_dim(row, chunk, 0, keepdims=False)
        new = jnp.where(valid, p[0].astype(a.dtype), cur)
        row = jax.lax.dynamic_update_index_in_dim(row, new, chunk, 0)
        return jax.lax.dynamic_update_index_in_dim(a, row, slot, 0)
    return jax.tree.map(w, ring, val)


def tree_chunk_add(acc, grad, idx, batch_dims: int = 1):
    """acc[..., idx, :] += grad, with ``batch_dims`` leading dims on acc.

    Accumulates a per-chunk gradient (leading [1] stage dim) into the
    chunk-stacked accumulator at dynamic chunk index ``idx``.
    """
    def upd(a, g):
        lead = a[tuple(0 for _ in range(batch_dims))]
        cur = jax.lax.dynamic_index_in_dim(lead, idx, 0, keepdims=False)
        new = jax.lax.dynamic_update_index_in_dim(
            lead, cur + g[0].astype(a.dtype), idx, 0)
        return new[tuple(None for _ in range(batch_dims))]
    return jax.tree.map(upd, acc, grad)


def _is_pspec(x):
    return isinstance(x, P)


# --------------------------------------------------------------------------
# ZeRO-1 (beyond-paper): shard optimizer state over the data axes.
#
# Per stage-parameter leaf we pick one dimension whose *local* (post-tensor-
# sharding) size divides the data-parallel degree; gradients are
# reduce-scattered along it, the optimizer update runs on the 1/dp shard,
# and the updated weights are all-gathered back.  Elementwise optimizers
# (SGDM / Adam / RMSProp) commute with the sharding, so results match the
# replicated update exactly (up to fp reduction order).  Leaves with no
# divisible dim fall back to the replicated psum path (axis = -1).
# --------------------------------------------------------------------------


def zero1_axes(stage_shapes, stage_pspecs, mesh, dp: int):
    """Tree of ints: per-leaf shard dim for optimizer state (-1 = none)."""

    def pick(sds, pspec):
        if dp <= 1:
            return -1
        shape = sds.shape
        for ax in range(1, len(shape)):  # dim 0 is the stacked stage dim
            ent = pspec[ax] if ax < len(pspec) else None
            names = () if ent is None else (
                ent if isinstance(ent, tuple) else (ent,))
            tp_div = 1
            for nm in names:
                tp_div *= mesh.devices.shape[mesh.axis_names.index(nm)]
            if shape[ax] % tp_div:
                continue
            local = shape[ax] // tp_div
            if local % dp == 0 and local >= dp:
                return ax
        return -1

    return jax.tree.map(pick, stage_shapes, stage_pspecs, is_leaf=None)


def zero1_opt_pspec(stage_pspecs, axes_tree, daxes):
    """Stage pspecs with the data axes added on the chosen dim."""

    def combine(pspec, ax):
        if ax < 0:
            return pspec
        ents = list(pspec) + [None] * (ax + 1 - len(pspec))
        ent = ents[ax]
        names = () if ent is None else (
            ent if isinstance(ent, tuple) else (ent,))
        ents[ax] = tuple(names) + tuple(daxes)
        return P(*ents)

    return jax.tree.map(combine, stage_pspecs, axes_tree, is_leaf=_is_pspec)


def zero1_microbatch_update(optimizer, dW, opt_state, weights, step, valid,
                            *, z1_axes, daxes, dnames, dp: int):
    """One ZeRO-1 per-microbatch update inside the B shard_map body.

    Reduce-scatter grads over the data axes, update the local 1/dp
    optimizer-state + weight shard, all-gather the fresh weights.  Same
    bytes on the wire as the psum path (an all-reduce IS RS+AG) but 1/dp
    optimizer memory and FLOPs per device.
    """
    rank = jax.lax.axis_index(daxes)

    def rs(g, ax):
        if ax < 0:
            return jax.lax.psum(g, dnames)
        return jax.lax.psum_scatter(g, daxes, scatter_dimension=ax,
                                    tiled=True)

    def shard(w, ax):
        if ax < 0:
            return w
        sz = w.shape[ax] // dp
        return jax.lax.dynamic_slice_in_dim(w, rank * sz, sz, ax)

    def gather(w, ax):
        if ax < 0:
            return w
        return jax.lax.all_gather(w, daxes, axis=ax, tiled=True)

    dW_sh = jax.tree.map(rs, dW, z1_axes)
    w_sh = jax.tree.map(shard, weights, z1_axes)
    upd_w, upd_opt = optimizer.update(dW_sh, opt_state, w_sh, step)
    upd_w = tree_select(valid, upd_w, w_sh)
    new_opt = tree_select(valid, upd_opt, opt_state)
    new_w = jax.tree.map(gather, upd_w, z1_axes)
    return new_w, new_opt


def replicated_microbatch_update(optimizer, dW, opt_state, weights, step,
                                 valid, *, dnames):
    """Replicated-stage sync (paper §3.2): per-microbatch psum over the
    data axis — on TPU, XLA schedules this async against the next tick's
    compute (wait-free backprop)."""
    dW = jax.tree.map(lambda g: jax.lax.psum(g, dnames), dW)
    upd_w, upd_opt = optimizer.update(dW, opt_state, weights, step)
    new_w = tree_select(valid, upd_w, weights)
    new_opt = tree_select(valid, upd_opt, opt_state)
    return new_w, new_opt
