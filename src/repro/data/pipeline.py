"""Deterministic synthetic LM data pipeline.

Produces microbatched rounds shaped for the pipeline train step:
tokens/labels (R, Bmb, S).  Fully deterministic in (seed, step) so a
restarted run consumes identical data — required for checkpoint/restart
tests and for PipeDream's deterministic round-robin replica routing.

On a real multi-host pod each host materializes only its shard via
``jax.make_array_from_callback``; on the single-process CPU host the same
code path produces the global array.  A background prefetch thread keeps
``prefetch`` rounds in flight (the input stage's "reads from disk" in
paper Figure 9).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    """Zipf-ish token stream with EOS-delimited documents."""

    def __init__(self, vocab: int, seq_len: int, *, seed: int = 0,
                 eos_id: int = 0, mean_doc_len: int = 512):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        self.eos_id = eos_id
        self.mean_doc_len = mean_doc_len

    def round_batch(self, step: int, r_microbatches: int, bmb: int
                    ) -> Dict[str, np.ndarray]:
        """(R, Bmb, S) tokens + next-token labels for one round."""
        rng = np.random.default_rng((self.seed, step))
        shape = (r_microbatches, bmb, self.seq_len + 1)
        # zipf-like marginal over the vocab, cheap to sample
        u = rng.random(shape)
        toks = np.minimum((u ** 2.5 * self.vocab).astype(np.int64),
                          self.vocab - 1)
        # sprinkle document boundaries
        doc = rng.random(shape) < (1.0 / self.mean_doc_len)
        toks = np.where(doc, self.eos_id, toks).astype(np.int32)
        return {"tokens": toks[..., :-1],
                "labels": toks[..., 1:].astype(np.int32)}


class ShardedLoader:
    """Places per-round host arrays onto the mesh with the bundle's specs."""

    def __init__(self, source: SyntheticLM, batch_specs: Dict,
                 *, extra_fn=None):
        self.source = source
        self.batch_specs = batch_specs
        self.extra_fn = extra_fn or (lambda step, shapes: {})

    def get(self, step: int):
        t = self.batch_specs["tokens"]
        r, bmb, s = t.shape
        host = self.source.round_batch(step, r, bmb)
        out = {}
        for k, spec in self.batch_specs.items():
            if k in host:
                data = host[k]
            else:
                data = self.extra_fn(step, {k: spec})[k]

            def cb(index, _data=data):
                return _data[index]

            out[k] = jax.make_array_from_callback(spec.shape, spec.sharding,
                                                  cb)
        return out


class Prefetcher:
    """Background-thread prefetch of upcoming rounds."""

    def __init__(self, loader: ShardedLoader, start_step: int = 0,
                 prefetch: int = 2):
        self.loader = loader
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put(self.loader.get(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5)


def vlm_patch_stub(d_model: int, seed: int = 0):
    """Frontend stub: deterministic fake patch embeddings for VLM configs."""

    def fn(step: int, shapes: Dict):
        out = {}
        for k, spec in shapes.items():
            rng = np.random.default_rng((seed, step, hash(k) % (2 ** 31)))
            out[k] = rng.standard_normal(spec.shape).astype(np.float32) * 0.02
        return out

    return fn
