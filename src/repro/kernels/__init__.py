"""Pallas TPU kernels for the compute hot-spots.

  flash_attention.py   causal/sliding-window/GQA flash attention
  wkv6.py              RWKV6 chunked WKV scan (matrix-valued state)
  ops.py               jit'd wrappers + use_pallas() dispatch gate
  ref.py               naive pure-jnp oracles (tests assert against these)
"""
from repro.kernels import ops, ref  # noqa: F401
