"""Flash attention Pallas TPU kernel (causal + sliding-window + GQA).

TPU adaptation of the memory hierarchy insight: stream KV through VMEM in
``block_k`` tiles while the (block_q, d_head) query tile and the running
(m, l, acc) softmax state stay resident in VMEM; the (block_q, block_k)
score tile hits the MXU as one matmul.  Block defaults are 128-aligned to
the MXU systolic array; the k-block grid axis is the innermost (sequential
on TPU) so VMEM scratch carries the running state across k steps.

Grid: (batch, q_heads, Sq/block_q, Sk/block_k).
BlockSpecs (VMEM tiles):
  q   (1, block_q, 1, d_head)   index (b, iq)    — reused across all ik
  k,v (1, block_k, 1, d_head)   index (b, ik, h // group_q)   — GQA: query
                                 heads map onto their shared KV head
  out (1, block_q, 1, d_head)   written once at ik == nk-1

Scratch: m, l (block_q,) f32; acc (block_q, d_head) f32.

Fully-masked (q, k) block pairs are skipped with pl.when — on hardware
this prunes ~half the causal grid and all-but-window/block_k of the SWA
grid (the compute-roofline win the paper's profile-then-partition flow
would observe as a shorter stage time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(w_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool,
                  block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    window = w_ref[0]            # SMEM scalar; <= 0 means global

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = iq * block_q
    k0 = ik * block_k
    # Block-level visibility: skip fully-masked tiles.
    visible = jnp.bool_(True)
    if causal:
        visible &= k0 <= q0 + block_q - 1           # below-diagonal overlap
    visible &= (window <= 0) | (k0 + block_k - 1 > q0 - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        mask &= (window <= 0) | ((qpos - kpos) < jnp.maximum(window, 1))
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=-1,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh), H % KV == 0.

    ``window`` may be a Python int or a traced scalar (<= 0 means global)
    — it rides in SMEM, matching the stage design where per-layer window
    size is data, not program structure.  Returns (B, Sq, H, Dh) in
    q.dtype.  Sq % block_q == Sk % block_k == 0 (pad outside if needed);
    softmax statistics in f32.
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0 and sq % block_q == 0 and sk % block_k == 0, (
        q.shape, k.shape, block_q, block_k)
    group = h // kv
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(dh)
    warr = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b_, h_, iq, ik: (b_, ik, h_ // group, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b_, h_, iq, ik: (b_, ik, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(warr, q, k, v)
