"""Mamba selective-scan Pallas TPU kernel.

The diagonal SSM recurrence

    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t u_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ u_t

expands to a (Ci × N) state per token; the XLA twin (models/nn.py::
selective_scan) must materialize (chunk, Ci, N) decay tensors at fusion
boundaries — the dominant HBM-byte signature of the jamba dry-run.  The
kernel keeps the (ci_block × N) state AND the expansion in VMEM: HBM
traffic collapses to streaming u/dt (Ci-major) and B/C (N-major) in, y
out — the roofline-ideal O(S·Ci) bytes.

Grid: (B, Ci/ci_block, S/chunk) — chunk axis innermost/sequential, state
scratch (ci_block, N) f32 carried across chunks; within a chunk a
fori_loop steps token by token entirely in VMEM/VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _mamba_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref,
                  hout_ref, h_scr, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)                  # (ci_b, N)
    dvec = d_ref[0].astype(jnp.float32)               # (ci_b,)
    u = u_ref[0].astype(jnp.float32)                  # (chunk, ci_b)
    dt = dt_ref[0].astype(jnp.float32)
    bm = b_ref[0].astype(jnp.float32)                 # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, y = carry                                  # h (ci_b, N)
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]      # (ci_b,)
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)[0]       # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)[0]
        da = jnp.exp(dt_t[:, None] * a)                          # (ci_b, N)
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=-1) + dvec * u_t    # (ci_b,)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None], t, 0)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((chunk, u.shape[1]), jnp.float32)
    h_last, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[...] = h_last
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit():
        hout_ref[0] = h_last


@functools.partial(jax.jit, static_argnames=("chunk", "ci_block",
                                             "interpret"))
def mamba_scan(u, dt, A, B, C, D, *, chunk: int = 128,
               ci_block: int = 512, interpret: bool = False):
    """u, dt: (B, S, Ci); A: (Ci, N); B, C: (B, S, N); D: (Ci,).

    Returns (y (B,S,Ci) in u.dtype — D⊙u included, h_last (B,Ci,N) f32).
    S % chunk == 0 and Ci % ci_block == 0 (pad outside).
    """
    b, s, ci = u.shape
    n = A.shape[-1]
    ci_block = min(ci_block, ci)
    assert s % chunk == 0 and ci % ci_block == 0, (s, chunk, ci, ci_block)
    nc = s // chunk
    nci = ci // ci_block

    kernel = functools.partial(_mamba_kernel, chunk=chunk, nc=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, nci, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, ci_block),
                         lambda b_, ici, ic: (b_, ic, ici)),   # u
            pl.BlockSpec((1, chunk, ci_block),
                         lambda b_, ici, ic: (b_, ic, ici)),   # dt
            pl.BlockSpec((1, chunk, n),
                         lambda b_, ici, ic: (b_, ic, 0)),     # B
            pl.BlockSpec((1, chunk, n),
                         lambda b_, ici, ic: (b_, ic, 0)),     # C
            pl.BlockSpec((1, ci_block, n),
                         lambda b_, ici, ic: (ici, 0, 0)),     # A (lead 1)
            pl.BlockSpec((1, ci_block),
                         lambda b_, ici, ic: (ici, 0)),        # D
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, ci_block),
                         lambda b_, ici, ic: (b_, ic, ici)),   # y
            pl.BlockSpec((1, ci_block, n),
                         lambda b_, ici, ic: (b_ * nci + ici, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, ci), u.dtype),
            jax.ShapeDtypeStruct((b * nci, ci_block, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ci_block, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt,
      B, C,
      A.reshape(nci, ci_block, n), D.reshape(nci, ci_block))
    h_last = h_last.reshape(b, ci, n)
    return y, h_last
