"""jit'd wrappers + dispatch for the Pallas kernels.

On TPU the kernels run compiled; this CPU container validates them in
``interpret=True`` mode (the kernel body executes in Python — exact
semantics, no Mosaic).  ``use_pallas()`` gates the dispatch from
models/nn.py: by default the XLA-lowerable jnp twins run (fast on CPU and
inside big jit graphs); set REPRO_USE_PALLAS=1 (or call ``enable(True)``)
to route attention / WKV through the kernels.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.wkv6 import wkv6 as _wkv6

_FORCE: Optional[bool] = None


def enable(on: bool = True):
    global _FORCE
    _FORCE = on


def use_pallas() -> bool:
    if _FORCE is not None:
        return _FORCE
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def interpret_mode() -> bool:
    """Pallas interpret mode whenever we are not actually on TPU."""
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    block_q: int = 128, block_k: int = 128):
    """Shape-padding wrapper: pads Sq/Sk up to block multiples and crops.

    Padding keys sit *after* the real ones, so causal masking plus the
    in-kernel kpos bound keeps them unattended for any real query.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = _flash(qp, kp, vp, causal=causal, window=window,
                 block_q=block_q, block_k=block_k,
                 interpret=interpret_mode())
    return out[:, :sq]


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    window=-1, k_scale=None, v_scale=None):
    """Decode (q (B, H, Dh)) or speculative verify (q (B, Q, H, Dh))
    attention over a paged KV pool (no padding needed: page and table
    extents are already block-exact by construction).  ``k_scale`` /
    ``v_scale`` (P, KV) activate the int8-pool dequantizing page walk."""
    return _paged(q, k_pages, v_pages, block_tables, lengths,
                  window=window, k_scale=k_scale, v_scale=v_scale,
                  interpret=interpret_mode())


def mamba_scan(u, dt, A, B, C, D, *, chunk: int = 128,
               ci_block: int = 512):
    """Pads S to the chunk multiple (dt=0 padding is state-neutral)."""
    b, s, ci = u.shape
    chunk = min(chunk, max(s, 8))
    ci_block = min(ci_block, ci)
    while ci % ci_block:
        ci_block //= 2
    pad = (-s) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0))
        u, dt, B, C = (jnp.pad(a, zp) for a in (u, dt, B, C))
    y, h_last = _mamba(u, dt, A, B, C, D, chunk=chunk, ci_block=ci_block,
                       interpret=interpret_mode())
    return y[:, :s], h_last


def wkv6(r, k, v, w, u, *, chunk: int = 128
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pads S up to the chunk multiple (w=1 padding is decay-neutral)."""
    b, s, h, dh = r.shape
    chunk = min(chunk, max(s, 8))
    pad = (-s) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zp)
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        w = jnp.pad(w, zp, constant_values=1.0)
    y, s_last = _wkv6(r, k, v, w, u, chunk=chunk,
                      interpret=interpret_mode())
    return y[:, :s], s_last
