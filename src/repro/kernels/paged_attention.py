"""Paged-attention decode Pallas TPU kernel (page-table gather + GQA).

Decode-side attention over a block-paged KV cache: instead of one dense
``(B, cache_len, KV, Dh)`` slab per sequence, keys/values live in a
global page pool ``(P, page, KV, Dh)`` and each sequence owns an ordered
list of page ids (its *page table* row).  The kernel walks the table one
page per sequential grid step: the scalar-prefetched table entry feeds
the k/v BlockSpec index maps, so the gather IS the DMA schedule — each
(page, Dh) tile streams through VMEM exactly like a ``block_k`` tile of
the flash kernel (kernels/flash_attention.py), with the same running
(m, l, acc) softmax scratch discipline.

Grid: (batch, kv_heads, n_pages); the page axis is innermost
("arbitrary" = sequential on TPU) so the VMEM scratch carries the
running state across pages.  GQA is handled by processing one KV head's
whole query-head group (G = H // KV) per grid step — the (G, page)
score tile hits the MXU as one matmul.

Speculative verify generalizes the query tile from one position to
``Q = spec_k + 1``: the tile becomes the row-flattened (Q·G, page)
score matrix — row r is query position ``lengths - Q + r // G`` — and
causal masking happens *inside* the tile (``kpos <= qpos`` per row), so
drafts never attend to the suffix they precede.  Q = 1 is plain decode
and reproduces the original kernel bit-for-bit.

Scalar-prefetch operands (SMEM, available before the body runs):
  block_tables (B, n_pages) int32   page ids, -1 = not allocated
  lengths      (B,)         int32   valid keys per sequence
  window       (1,)         int32   sliding window (<= 0: global)

``pl.when`` skips pages past the sequence's valid length (and pages
wholly outside the window for every query row), so a short sequence in a
long-capacity batch costs only its own pages — the roofline win paging
buys at the kernel level on top of the HBM-capacity win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(tab_ref, len_ref, w_ref, q_ref, k_ref, v_ref, *rest,
                  page: int, n_pages: int, q_len: int, group: int,
                  scale: float, quantized: bool):
    if quantized:
        # int8 pools ride with per-(page, kv-head) f32 scales; the scale
        # tile is gathered by the same table entry as its page.
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)
    length = len_ref[b]              # valid keys for this sequence
    window = w_ref[0]                # <= 0 means global
    # the q_len queries sit at positions length - q_len .. length - 1;
    # score-tile row r belongs to query position length - q_len + r//group
    min_qpos = length - q_len

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Page-level visibility: skip unallocated pages, pages past the valid
    # length, and pages wholly older than the window for even the OLDEST
    # query (younger queries see strictly less of the past).
    live = (tab_ref[b, i] >= 0) & (i * page < length)
    live &= (window <= 0) | (min_qpos - (i * page + page - 1)
                             < jnp.maximum(window, 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                                   # (Q·G, Dh)
        k = k_ref[0, :, 0, :]                             # (page, Dh)
        v = v_ref[0, :, 0, :]
        if quantized:
            # dequantize the page tile in VMEM: int8 payload times the
            # page's per-kv-head scale, compute in f32 end to end
            q = q.astype(jnp.float32)
            k = k.astype(jnp.float32) * ks_ref[0, 0]
            v = v.astype(jnp.float32) * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Q·G, page)
        r = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        qpos = min_qpos + r // group                      # per-row query pos
        kpos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        mask &= (window <= 0) | ((qpos - kpos) < jnp.maximum(window, 1))
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    window=-1, k_scale=None, v_scale=None,
                    interpret: bool = False):
    """q: (B, H, Dh) decode or (B, Q, H, Dh) verify; pools (P, page, KV, Dh).

    ``block_tables``: (B, n_pages) int32 page ids into the pool, -1 for
    unallocated entries; ``lengths``: (B,) int32 valid keys per sequence
    — the Q queries sit at positions ``lengths - Q .. lengths - 1``
    (Q = 1 for plain decode, spec_k + 1 for speculative verify; causal
    masking between the queries happens inside the tile).  ``window``
    may be a Python int or traced scalar (<= 0: global).  Returns the
    query shape back ((B, H, Dh) or (B, Q, H, Dh)) in q.dtype; softmax
    statistics in f32.  H % KV == 0.

    int8 pools: pass ``k_scale`` / ``v_scale`` (P, KV) f32 per-page
    per-kv-head scales; the kernel dequantizes each page tile in VMEM
    and computes scores/weighted values in f32.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, q_len, h, dh = q.shape
    n_pool, page, kv, dh_k = k_pages.shape
    assert dh == dh_k and h % kv == 0, (q.shape, k_pages.shape)
    quantized = k_scale is not None
    assert (v_scale is not None) == quantized
    n_pages = block_tables.shape[1]
    group = h // kv
    scale = 1.0 / np.sqrt(dh)
    # row-flatten (Q, G) so one (Q·G, page) tile scores all queries of a
    # KV head per grid step
    qg = (q.reshape(b, q_len, kv, group, dh)
          .transpose(0, 2, 1, 3, 4)
          .reshape(b, kv, q_len * group, dh))

    kernel = functools.partial(_paged_kernel, page=page, n_pages=n_pages,
                               q_len=q_len, group=group, scale=scale,
                               quantized=quantized)
    page_spec = pl.BlockSpec((1, page, 1, dh),
                             lambda b_, h_, i, tab, lens, w:
                             (jnp.maximum(tab[b_, i], 0), 0, h_, 0))
    in_specs = [
        pl.BlockSpec((1, 1, q_len * group, dh),
                     lambda b_, h_, i, tab, lens, w: (b_, h_, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        # scale tiles gather with the same table entry as their page
        scale_spec = pl.BlockSpec((1, 1),
                                  lambda b_, h_, i, tab, lens, w:
                                  (jnp.maximum(tab[b_, i], 0), h_))
        in_specs += [scale_spec, scale_spec]
        operands += [jnp.asarray(k_scale, jnp.float32),
                     jnp.asarray(v_scale, jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, q_len * group, dh),
            lambda b_, h_, i, tab, lens, w: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_len * group,), jnp.float32),
            pltpu.VMEM((q_len * group,), jnp.float32),
            pltpu.VMEM((q_len * group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, q_len * group, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(lengths, jnp.int32),
      jnp.asarray(window, jnp.int32).reshape(1),
      *operands)
    out = (out.reshape(b, kv, q_len, group, dh)
           .transpose(0, 2, 1, 3, 4)
           .reshape(b, q_len, h, dh))
    return out[:, 0] if squeeze else out
