"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the kernel tests assert against
(tests/test_kernels.py sweeps shapes/dtypes in interpret mode).  They are
deliberately the *naive* O(S²)/O(S·D²) formulations — independent of both
the kernels and the blockwise jnp twins used inside the training graph
(models/nn.py), so a bug in the shared chunking logic cannot hide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = -1):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh) with H % KV == 0.

    Softmax in f32; returns (B, Sq, H, Dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    scale = 1.0 / np.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_scan_ref(u, dt, A, B, C, D, h0=None):
    """Stepwise diagonal SSM recurrence (Mamba-1 definition).

    u, dt: (B, S, Ci); A: (Ci, N); B, C: (B, S, N); D: (Ci,).
        h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t u_t) ⊗ B_t
        y_t = h_t · C_t + D ⊙ u_t
    Returns (y (B,S,Ci), h_last (B,Ci,N) f32).
    """
    b, s, ci = u.shape
    n = A.shape[-1]
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * Af)                  # (B,Ci,N)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t) + Df * u_t
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, ci, n), jnp.float32)
    xs = (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype), h_last


def wkv6_ref(r, k, v, w, u, s0=None):
    """RWKV6 WKV recurrence, step by step (the paper's definition).

    r, k, v, w: (B, S, H, Dh); w is the per-channel decay in (0, 1];
    u: (H, Dh) bonus.  Returns (y (B,S,H,Dh) f32->r.dtype, s_last f32).

        y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    b, s, h, dh = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                         # (B,H,Dh)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,Dh,Dh)
        y = jnp.einsum("bhd,bhde->bhe", rt,
                       state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, y

    if s0 is None:
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))  # (S,B,H,D)
    s_last, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)                                   # (B,S,H,D)
    return y.astype(r.dtype), s_last


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = -1, k_scale=None, v_scale=None):
    """Decode-step oracle over a paged KV pool.

    q: (B, H, Dh); k_pages, v_pages: (P, page, KV, Dh);
    block_tables: (B, n_pages) int32 page ids (-1 = unallocated);
    lengths: (B,) int32 valid keys (query sits at lengths - 1).
    Gathers every table entry into a dense (B, n_pages*page, KV, Dh)
    slab, masks invalid keys, and runs the naive f32 softmax.

    int8 pools: ``k_scale`` / ``v_scale`` (P, KV) f32 per-page scales
    dequantize the whole pool up front — the obvious formulation the
    kernel's in-VMEM tile dequantization is checked against.
    """
    b, h, dh = q.shape
    n_pool, page, kv, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    if k_scale is not None:
        k_pages = k_pages.astype(jnp.float32) * k_scale[:, None, :, None]
    if v_scale is not None:
        v_pages = v_pages.astype(jnp.float32) * v_scale[:, None, :, None]
    tab = jnp.asarray(block_tables, jnp.int32)
    safe = jnp.clip(tab, 0, n_pool - 1)
    k = k_pages[safe].reshape(b, n_pages * page, kv, dh)
    v = v_pages[safe].reshape(b, n_pages * page, kv, dh)
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    scale = 1.0 / np.sqrt(dh)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = jnp.arange(n_pages * page)[None, :]               # (1, K)
    qpos = (jnp.asarray(lengths, jnp.int32) - 1)[:, None]    # (B, 1)
    mask = (kpos <= qpos) & (kpos < jnp.asarray(lengths)[:, None])
    mask &= jnp.repeat(tab >= 0, page, axis=1)
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # zero masked values too: a dead page may hold garbage (even NaN),
    # and 0 * NaN would otherwise poison the weighted sum
    v = jnp.where(mask[:, :, None, None], v, 0.0)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
