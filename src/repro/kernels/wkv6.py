"""RWKV6 (Finch) chunked WKV Pallas TPU kernel.

The WKV recurrence with data-dependent per-channel decay

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)

is sequential in t, which wastes the MXU if evaluated stepwise.  TPU
adaptation (same insight as the CUDA chunked kernels, re-blocked for
VMEM/MXU): split the sequence into C-length chunks; inside a chunk the
contribution of earlier in-chunk tokens is an attention-like (C × C)
matmul with decay weights, and the carry-in state contributes through a
(C × Dh) @ (Dh × Dh) matmul — both MXU-shaped.  The (Dh × Dh) f32 state
lives in VMEM scratch across the (sequential) chunk grid axis.

Grid: (B·H, S/C) — chunk axis innermost/sequential.
BlockSpecs: r/k/v/w tiles (1, C, Dh) in VMEM; y tile (1, C, Dh); the
final state (1, Dh, Dh) is written at the last chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref,
                 state_scr, *, chunk: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)                 # (C, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # (Dh,)

    logw = jnp.log(jnp.clip(w, 1e-8, 1.0))
    cum = jnp.cumsum(logw, axis=0)                   # (C, Dh)
    decay_to_t = jnp.exp(cum - logw)                 # prod over [0, t-1]

    state = state_scr[...]                           # (Dh, Dh)
    # inter-chunk: y_t += (r_t ⊙ decay_to_t) @ S_in
    rd = r * decay_to_t
    y = jax.lax.dot(rd, state, preferred_element_type=jnp.float32)
    # intra-chunk: strictly-lower-triangular attention-like term
    att = jax.lax.dot_general(rd, k * jnp.exp(-cum),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, att.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, att.shape, 1)
    att = jnp.where(ti > si, att, 0.0)
    y += jax.lax.dot(att, v, preferred_element_type=jnp.float32)
    # bonus diagonal term: y_t += (r_t · (u ⊙ k_t)) v_t
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S_out = diag(prod w) S_in + Σ_s (prod_{τ>s} w_τ ⊙ k_s) v_s^T
    total = jnp.exp(cum[-1])                         # (Dh,)
    kdec = k * jnp.exp(cum[-1][None, :] - cum)       # (C, Dh)
    state_scr[...] = total[:, None] * state + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ic == nc - 1)
    def _emit_state():
        sout_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """r, k, v, w: (B, S, H, Dh); u: (H, Dh).  S % chunk == 0.

    Returns (y (B,S,H,Dh) in r.dtype, s_last (B,H,Dh,Dh) f32).
    """
    b, s, h, dh = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def flat(x):  # (B,S,H,Dh) -> (B*H, S, Dh)
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.broadcast_to(u[None], (b, h, dh)).reshape(b * h, dh)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, nc=nc)
    y, s_last = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, dh), lambda bh, ic: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, dh, dh), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, dh), r.dtype),
            jax.ShapeDtypeStruct((b * h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)

    y = y.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    s_last = s_last.reshape(b, h, dh, dh)
    return y, s_last
