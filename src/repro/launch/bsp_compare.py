import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Measured (compiled-HLO) BSP vs pipeline communication comparison —
the paper's §5.2 claim with the production mesh's own collective
schedule as evidence.

Compiles BSP data-parallel training (model replicated on all 256 chips,
gradient all-reduce — only feasible for archs whose replicated
weights+optimizer fit 16 GB) and compares per-device collective bytes
against the PipeDream cell's dry-run artifact.

  python -m repro.launch.bsp_compare --arch whisper-medium
"""
import argparse        # noqa: E402
import glob            # noqa: E402
import json            # noqa: E402

import jax             # noqa: E402

from repro import configs                          # noqa: E402
from repro.core.baselines import build_bsp         # noqa: E402
from repro.launch import hlo_analysis as H         # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim.optimizers import by_name         # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="whisper-medium")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    spec = cfg.full_spec()
    shape = configs.SHAPES["train_4k"]
    mesh = make_production_mesh()
    train_step, init_state, state_sh, batch_specs = build_bsp(
        spec, mesh, seq_len=shape.seq_len, global_batch=shape.global_batch,
        optimizer=by_name(*cfg.OPTIMIZER))
    state_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        jax.eval_shape(init_state, jax.random.key(0)), state_sh)
    with mesh:
        compiled = jax.jit(train_step, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, None),
                           donate_argnums=0).lower(
            state_sds, batch_specs).compile()
    cost = H.analyze(compiled.as_text())
    bsp_bytes = cost.coll_operand_bytes

    # PipeDream cell artifact (any note variant, prefer the plain one)
    cands = sorted(glob.glob(
        f"{args.out}/{configs.resolve(args.arch)}__train_4k__16x16*.json"))
    pp_bytes = None
    if cands:
        with open(cands[0]) as f:
            pp_bytes = json.load(f)["coll_operand_bytes"]

    result = {
        "arch": args.arch,
        "bsp_coll_bytes_per_device": bsp_bytes,
        "bsp_per_kind": cost.per_collective,
        "pp_coll_bytes_per_device": pp_bytes,
        "reduction_pct": (100.0 * (1 - pp_bytes / bsp_bytes)
                          if pp_bytes else None),
        "bsp_memory": {k: getattr(compiled.memory_analysis(), k)
                       for k in ("argument_size_in_bytes",
                                 "temp_size_in_bytes")},
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"bsp_compare__{args.arch}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"BSP  collective bytes/device/step: {bsp_bytes:.3e}")
    if pp_bytes:
        print(f"PP   collective bytes/device/step: {pp_bytes:.3e}")
        print(f"measured comm reduction: {result['reduction_pct']:.1f}%")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
