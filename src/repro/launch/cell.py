"""(architecture × input-shape × mesh) cell builder.

One cell = one dry-run / benchmark unit: the jit-able step function, its
ShapeDtypeStruct input stand-ins (``input_specs`` — no device allocation),
and the in/out shardings.  train_* shapes lower the pipelined train_step;
prefill_* the pipelined prefill; decode_*/long_* the pipelined decode step
(long_* with the sequence-parallel KV cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.pipeline import build_pipeline
from repro.optim.optimizers import by_name
from repro.parallel.mesh import ParallelismPlan, data_axes, split_model_axis
from repro.serving.engine import build_serving


@dataclasses.dataclass
class Cell:
    arch: str
    shape: configs.Shape
    plan: ParallelismPlan
    mesh: Mesh                     # production mesh (data, model[, pod])
    dmesh: Mesh                    # derived mesh (data, stage, tensor[, pod])
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs with shardings
    in_shardings: Any
    out_shardings: Any
    spec: Any
    bundle: Any

    def lower(self, donate: bool = True):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=(0,) if donate else ())
        with self.dmesh:
            return jitted.lower(*self.args)


def _sds(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def _fit_microbatches(plan: ParallelismPlan, global_batch: int,
                      dp: int) -> ParallelismPlan:
    """Clamp R so global_batch divides dp·R (multi-pod halves per-replica
    batch; the 1F1B schedule is valid for any R >= 1, the training
    interleaved family additionally needs R divisible by the stage
    count — registry-driven, so new schedules state their own rule)."""
    from repro.core.schedule import SCHEDULES
    cls = SCHEDULES.get(plan.schedule)
    needs_groups = (cls is not None and cls.takes_virtual_stages
                    and cls.needs_group_microbatches)

    def ok(r):
        if global_batch % (dp * r):
            return False
        return not needs_groups or r % plan.pp == 0
    r = min(plan.microbatches, max(global_batch // dp, 1))
    while r > 1 and not ok(r):
        r -= 1
    assert ok(r), (plan, global_batch, dp)
    return plan.with_(microbatches=r) if r != plan.microbatches else plan


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               plan: Optional[ParallelismPlan] = None,
               optimizer=None, serve_op: str = "auto",
               page_size: int = 0,
               bucket: Optional[int] = None,
               spec_k: Optional[int] = None) -> Cell:
    """Build one (arch × shape × mesh) cell.

    ``serve_op`` selects the serving step lowered for prefill shapes:
    ``"auto"`` (the one-shot ``prefill_step``, unchanged behaviour) or
    ``"admit"`` — the continuous-batching masked per-slot prefill
    (``EngineSession.admit_step``: (state, batch, slot_mask)), so the
    admission path gets the same dry-run lowering/SPMD-sharding proof
    the one-shot steps get.

    ``page_size`` (serving shapes only) builds the session with the
    paged KV cache, so the dry-run lowers and sharding-checks the page
    pool + page-table step signatures the paged engine runs.

    ``bucket`` (decode shapes only) builds the session with the
    liveness-aware bucket lattice and lowers the compacted
    ``bucket``-slot decode variant (``EngineSession.decode_step_for``)
    instead of the full-R step — same state/token signature, shorter
    table scan — so bucketed programs get the same dry-run proof.

    ``spec_k`` (decode shapes only) builds the session on the
    speculative ``serve_spec_*`` schedule and lowers the draft–verify
    step (``EngineSession.verify_step``: (state, tokens[B, k+1]) ->
    (state, (scores, accepted))) instead of the one-token decode step,
    so the verify pass gets the same lowering/SPMD-sharding proof.
    """
    assert serve_op in ("auto", "admit"), serve_op
    assert bucket is None or configs.SHAPES[shape_name].kind in (
        "decode", "long_decode"), "bucket= lowers a decode variant"
    assert spec_k is None or configs.SHAPES[shape_name].kind == "decode", (
        "spec_k lowers the speculative verify step, a decode variant")
    shape_kind = configs.SHAPES[shape_name].kind
    assert page_size == 0 or shape_kind != "train", (
        "page_size pages the serving KV cache; training shapes have none")
    cfg = configs.get(arch)
    spec = cfg.full_spec()
    shape = configs.SHAPES[shape_name]
    plan = plan or cfg.PLAN
    ok, why = configs.supports(arch, shape_name)
    if not ok:
        raise ValueError(f"{arch} × {shape_name} skipped: {why}")
    dmesh = split_model_axis(mesh, plan.pp, plan.tp)
    daxes = data_axes(dmesh)
    dp = 1
    for a in daxes:
        dp *= dmesh.devices.shape[dmesh.axis_names.index(a)]

    if shape.kind == "train":
        plan = _fit_microbatches(plan, shape.global_batch, dp)
        opt = optimizer or by_name(*cfg.OPTIMIZER)
        bundle = build_pipeline(spec, plan, dmesh, seq_len=shape.seq_len,
                                global_batch=shape.global_batch,
                                optimizer=opt)
        state_shape = jax.eval_shape(bundle.init_state, jax.random.key(0))
        state_sds = _sds(state_shape, bundle.state_shardings())
        batch_sds = bundle.batch_specs()
        in_sh = (bundle.state_shardings(), bundle.batch_shardings())
        out_sh = (bundle.state_shardings(), None)
        return Cell(arch, shape, plan, mesh, dmesh, bundle.train_step,
                    (state_sds, batch_sds), in_sh, out_sh, spec, bundle)

    # serving cells ride the schedule-table engine: build_serving returns
    # an EngineSession whose pure step fns lower exactly like train_step
    # (virtual-stage plans run the serve_interleaved schedule)
    sp = shape.kind == "long_decode"
    prefill_len = shape.seq_len if shape.kind == "prefill" else 0
    if spec_k is not None:
        plan = plan.with_(schedule=("serve_spec_interleaved"
                                    if plan.virtual_stages > 1
                                    else "serve_spec_1f"))
    session = build_serving(spec, plan, dmesh, cache_len=shape.seq_len,
                            global_batch=shape.global_batch,
                            prefill_len=prefill_len, sp=sp,
                            page_size=page_size,
                            buckets=bucket is not None,
                            spec_k=spec_k)
    state_shape = jax.eval_shape(session.init_state, jax.random.key(0))
    state_sds = _sds(state_shape, session.state_shardings())
    state_sh = session.state_shardings()

    if shape.kind == "prefill":
        dnames = daxes if len(daxes) > 1 else daxes[0]
        batch_sh = {
            k: NamedSharding(dmesh, P(*((None, dnames) +
                                        (None,) * (len(v.shape) - 2))))
            for k, v in session.prefill_specs.items()}
        batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=batch_sh[k])
                     for k, v in session.prefill_specs.items()}
        if serve_op == "admit":
            # masked per-slot admission: one replicated [R] slot mask
            mask_sh = NamedSharding(dmesh, P())
            mask_sds = jax.ShapeDtypeStruct(
                (session.sched.n_microbatches,), jax.numpy.int32,
                sharding=mask_sh)
            return Cell(arch, shape, plan, mesh, dmesh, session.admit_step,
                        (state_sds, batch_sds, mask_sds),
                        (state_sh, batch_sh, mask_sh), (state_sh, None),
                        spec, session)
        in_sh = (state_sh, batch_sh)
        out_sh = (state_sh, None)
        return Cell(arch, shape, plan, mesh, dmesh, session.prefill_step,
                    (state_sds, batch_sds), in_sh, out_sh, spec, session)

    # decode / long_decode: one new token per sequence (spec_k + 1
    # proposed tokens per row under the speculative verify step)
    tok_sh = NamedSharding(dmesh, P())
    tok_shape = session.token_spec.shape
    if spec_k is not None:
        tok_shape = tok_shape + (spec_k + 1,)
    tok_sds = jax.ShapeDtypeStruct(tok_shape, session.token_spec.dtype,
                                   sharding=tok_sh)
    in_sh = (state_sh, tok_sh)
    out_sh = (state_sh, None)
    step = session.verify_step if spec_k is not None \
        else session.decode_step
    if bucket is not None:
        if bucket not in session.buckets:
            raise ValueError(f"bucket {bucket} not in the lattice "
                             f"{session.buckets} for R="
                             f"{session.sched.n_microbatches}")
        step = (session.verify_step_for(bucket) if spec_k is not None
                else session.decode_step_for(bucket))
    return Cell(arch, shape, plan, mesh, dmesh, step,
                (state_sds, tok_sds), in_sh, out_sh, spec, session)


def input_specs(arch: str, shape_name: str, mesh: Mesh, **kw):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cell = build_cell(arch, shape_name, mesh, **kw)
    return cell.args
