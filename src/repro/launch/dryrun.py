import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count on first init, and the production meshes need 512 host devices
(16×16 single pod; 2×16×16 multi-pod).

Per cell this prints compiled.memory_analysis() (proves it fits) and
compiled.cost_analysis() (FLOPs/bytes), derives the trip-count-aware
roofline terms (launch/roofline.py), and dumps a JSON artifact under
experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # full 40-cell sweep
"""
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro import configs                         # noqa: E402
from repro.core import profiler as prof           # noqa: E402
from repro.launch import roofline as RL           # noqa: E402
from repro.launch.cell import build_cell          # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.obs import Observability               # noqa: E402


def _data_replicas(mesh, plan) -> int:
    return mesh.devices.size // (plan.pp * plan.tp)


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             plan=None, note: str = "", verbose: bool = True,
             do_plan_search: bool = False, hw=prof.TPU_V5E,
             page_size: int = 0, spec_k=None,
             weight_dtype=None, kv_dtype=None, obs=None):
    if obs is None:
        obs = Observability()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t_low = obs.timer("launch_phase_seconds", phase="lower")
    t_low.__enter__()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    if do_plan_search:
        from repro.runtime.driver import plan_search_report
        cfg = configs.get(arch)
        spec, base = cfg.full_spec(), plan or cfg.PLAN
        sh = configs.SHAPES[shape]
        # serving shapes search the serve registry (workload axis): the
        # decode objective is per-token round time under the KV-inclusive
        # memory model, prefill is weighted TTFT
        workload = {"train": "train", "prefill": "prefill",
                    "decode": "decode", "long_decode": "decode"}[sh.kind]
        choice = plan_search_report(
            spec, base, hw, seq_len=sh.seq_len,
            global_batch=sh.global_batch,
            data_replicas=_data_replicas(mesh, base),
            prefix=f"[{arch} × {shape} @ {mesh_name}] ",
            workload=workload, sp=sh.kind == "long_decode",
            weight_dtype=None if sh.kind == "train" else weight_dtype,
            kv_dtype=None if sh.kind == "train" else kv_dtype)
        plan = choice.plan      # serve choices carry schedule="serve_*";
        #                         build_serving resolves them via the
        #                         registry (make_serving_schedule)
    # train has no KV cache; long_decode runs sp, which excludes paging
    # (and speculative verify — the lowered step is a decode variant)
    sh_kind = configs.SHAPES[shape].kind
    if sh_kind not in ("prefill", "decode"):
        page_size = 0
    if sh_kind != "decode":
        spec_k = None
    # quantized storage dtypes only price serving cells; training keeps
    # full-precision weights (plan_search asserts the same invariant)
    if sh_kind == "train":
        weight_dtype = kv_dtype = None
    cell = build_cell(arch, shape, mesh, plan=plan, page_size=page_size,
                      spec_k=spec_k)
    lowered = cell.lower()
    t_low.__exit__(None, None, None)
    t_lower = t_low.elapsed
    with obs.timer("launch_phase_seconds", phase="compile") as t_comp:
        compiled = lowered.compile()
    t_compile = t_comp.elapsed

    mem = compiled.memory_analysis()
    print(f"[{arch} × {shape} @ {mesh_name}] memory_analysis:")
    print(f"  {mem}")
    # analytic cross-check of the schedule's footprint vs XLA's: the
    # training memory model for train cells, the KV-cache-inclusive
    # serving one for prefill/decode cells
    dp = _data_replicas(mesh, cell.plan)
    from repro.core.schedule import weighted_round_time
    sched = cell.bundle.sched
    if cell.shape.kind == "train":
        label = "schedule"
        mm = sched.memory_model(
            cell.spec, cell.plan, hw,
            microbatch_tokens=cell.bundle.microbatch_size
            * cell.bundle.seq_len,
            data_replicas=dp)
    else:
        label = "serve"
        sp = cell.shape.kind == "long_decode"
        rows = (cell.shape.global_batch if sp else
                max(cell.shape.global_batch
                    // (dp * sched.n_microbatches), 1))
        qlen = cell.shape.seq_len if cell.shape.kind == "prefill" else 1
        mm = sched.memory_model(
            cell.spec, cell.plan, hw, microbatch_tokens=rows * qlen,
            data_replicas=dp, cache_len=cell.shape.seq_len,
            global_batch=cell.shape.global_batch, sp=sp,
            prefill=cell.shape.kind == "prefill",
            page_size=0 if sp else page_size,
            weight_dtype=weight_dtype, kv_dtype=kv_dtype)
    _, bubble = weighted_round_time(sched)
    print(f"  {label} memory_model (analytic): {mm}")
    print(f"  predicted weighted bubble: {bubble:.3f} "
          f"(budget {hw.hbm_bytes / 1e9:.1f} GB -> "
          f"{'fits' if mm.fits(hw.hbm_bytes) else 'OVER'})")
    from repro.parallel.compat import cost_analysis
    cost = cost_analysis(compiled)
    print(f"[{arch} × {shape} @ {mesh_name}] cost_analysis (stock, "
          f"while-bodies-once): flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")

    mfpd = RL.model_flops_per_device(cell.spec, cell.shape, n_chips)
    r = RL.from_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        plan=f"pp{cell.plan.pp}xtp{cell.plan.tp}x{cell.plan.stash_mode}"
             f"xR{cell.plan.microbatches}"
             + (f"+iv{cell.plan.virtual_stages}"
                if cell.plan.virtual_stages > 1 else "")
             + ("+zero1" if cell.plan.zero1 else ""),
        model_flops_per_device=mfpd, note=note)
    if verbose:
        print("  " + RL.fmt_row(r))
        print(f"  per-collective operand bytes: "
              f"{ {k: f'{v:.3e}' for k, v in r.per_collective.items()} }")
        print(f"  while trips: {r.while_trips} "
              f"(unknown: {r.unknown_trip_whiles}); "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_name}" + (f"__{note}" if note else "")
    RL.dump(r, os.path.join(out_dir, f"{tag}.json"))
    return r


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(configs.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every runnable (arch × shape) cell")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--note", type=str, default="")
    ap.add_argument("--grad-sync", type=str, default=None,
                    choices=[None, "per_microbatch", "per_round"])
    ap.add_argument("--stash-mode", type=str, default=None,
                    choices=[None, "stash", "flush", "vertical", "2bw"])
    from repro.core.schedule import (SCHEDULES, plan_kwargs_for_schedule,
                                     virtual_stages_error)
    ap.add_argument("--schedule", type=str, default=None,
                    choices=[None, *sorted(SCHEDULES)])
    ap.add_argument("--virtual-stages", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--plan-search", action="store_true",
                    help="let plan_search pick (pp, tp, schedule, "
                         "virtual_stages) under the HBM budget instead of "
                         "the config's hand-written plan")
    ap.add_argument("--page-size", type=int, default=0,
                    help="serving shapes: lower the paged-KV engine "
                         "(page pool + page tables) instead of the dense "
                         "cache; ignored for train shapes")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="decode shapes: lower the speculative verify "
                         "step (serve_spec_* schedule, k drafts + 1 "
                         "bonus position per round) instead of the "
                         "one-token decode step; ignored elsewhere")
    ap.add_argument("--weight-dtype", type=str, default=None,
                    choices=[None, "fp32", "bf16", "int8", "fp8"],
                    help="serving shapes: price quantized weight storage "
                         "in the analytic memory cross-check (and "
                         "plan_search, with --plan-search); ignored for "
                         "train shapes")
    ap.add_argument("--kv-dtype", type=str, default=None,
                    choices=[None, "fp32", "bf16", "int8"],
                    help="serving shapes: KV-cache storage dtype for the "
                         "analytic memory model (int8 prices the paged "
                         "pools + scale planes); ignored for train shapes")
    args = ap.parse_args(argv)
    err = virtual_stages_error(args.schedule, args.virtual_stages)
    if err:
        ap.error(err)
    if args.schedule and args.stash_mode and \
            args.stash_mode not in SCHEDULES[args.schedule].plan_stash_modes:
        ap.error(f"--stash-mode {args.stash_mode} is incompatible with "
                 f"--schedule {args.schedule} (accepts "
                 f"{list(SCHEDULES[args.schedule].plan_stash_modes)})")

    def plan_for(arch):
        from repro import configs as _c
        plan = _c.get(arch).PLAN
        if args.grad_sync:
            plan = plan.with_(grad_sync=args.grad_sync)
        if args.stash_mode:
            plan = plan.with_(stash_mode=args.stash_mode)
        if args.schedule:
            plan = plan.with_(**plan_kwargs_for_schedule(
                args.schedule, virtual_stages=args.virtual_stages,
                stash_mode=plan.stash_mode))
        if args.microbatches:
            plan = plan.with_(microbatches=args.microbatches)
        return plan if (args.grad_sync or args.stash_mode or args.schedule
                        or args.microbatches) else None

    if args.all:
        failures = []
        for arch, shape, ok, why in configs.cells():
            if not ok:
                print(f"[{arch} × {shape}] SKIP: {why}")
                continue
            try:
                run_cell(arch, shape, multi_pod=args.multi_pod,
                         out_dir=args.out, note=args.note,
                         plan=plan_for(arch),
                         do_plan_search=args.plan_search,
                         page_size=args.page_size, spec_k=args.spec_k,
                         weight_dtype=args.weight_dtype,
                         kv_dtype=args.kv_dtype)
            except Exception:
                failures.append((arch, shape))
                traceback.print_exc()
        if failures:
            print(f"FAILED cells: {failures}")
            sys.exit(1)
        print("all cells compiled OK")
        return

    assert args.arch and args.shape, "--arch/--shape or --all"
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             out_dir=args.out, note=args.note, plan=plan_for(args.arch),
             do_plan_search=args.plan_search, page_size=args.page_size,
             spec_k=args.spec_k, weight_dtype=args.weight_dtype,
             kv_dtype=args.kv_dtype)


if __name__ == "__main__":
    main()
