"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` visits every while-loop body exactly ONCE
(verified empirically: a scan of 10 matmuls reports the FLOPs of one), so
for a scan-over-ticks pipeline it undercounts by the trip count.  This
module parses ``compiled.as_text()`` (the post-SPMD, per-device module),
recovers each while's trip count from its condition computation, and
accumulates

  * dot FLOPs                         (matmuls dominate every arch here)
  * HBM bytes                         (operands + outputs at fusion/call
                                       sites — post-fusion boundaries
                                       approximate actual HBM traffic)
  * collective bytes, per op kind     (operand bytes per the roofline
                                       spec, plus ring-model wire bytes)

multiplied through the call graph (ENTRY ×1, while body ×trips, fusion
bodies counted at their call site).  Cross-checked against
``cost_analysis()`` in tests on while-free modules.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes whose operand/output bytes are NOT HBM traffic (metadata / control)
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "custom-call", "bitcast-convert", "iota",
}


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    out_type: str
    body: str                     # full text after the opcode's '('


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    types: Dict[str, str] = dataclasses.field(default_factory=dict)

    def operand_names(self, ins: Instruction) -> List[str]:
        """Operand instruction names (within the operand parens only)."""
        depth = 1
        end = len(ins.body)
        for i, ch in enumerate(ins.body):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return [m.group(1)
                for m in re.finditer(r"%([\w\.\-_]+)", ins.body[:end])
                if m.group(1) in self.types]

    def operand_bytes(self, ins: Instruction) -> int:
        return sum(shape_bytes(self.types[n])
                   for n in self.operand_names(ins))


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*)$")


def _split_type_opcode(rest: str) -> Optional[Tuple[str, str, str]]:
    """'bf16[2,4]{1,0} dot(f32[...' -> (out_type, opcode, body)."""
    rest = rest.strip()
    if rest.startswith("("):
        # tuple type: find matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out_type = rest[: i + 1]
                    tail = rest[i + 1:].strip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_type, tail = rest[:sp], rest[sp + 1:].strip()
    par = tail.find("(")
    if par < 0:
        return None
    return out_type, tail[:par].strip(), tail[par + 1:]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = Computation(m.group(2), [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        parsed = _split_type_opcode(m.group(2))
        if parsed is None:
            continue
        out_type, opcode, body = parsed
        cur.instructions.append(Instruction(m.group(1), opcode, out_type,
                                            body))
        cur.types[m.group(1)] = out_type
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


_ATTR_COMP = re.compile(r"(\w+)=%?([\w\.\-_]+)")
_CONST_INT = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_GROUP_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _called(instr: Instruction, keys=("body", "condition", "to_apply",
                                      "calls", "branch_computations")):
    out = {}
    for m in _ATTR_COMP.finditer(instr.body):
        if m.group(1) in keys:
            out[m.group(1)] = m.group(2)
    return out


def while_trip_count(cond: Computation) -> Optional[int]:
    """Scan bounds lower to `lt(counter, constant(N))`; recover N."""
    consts = {}
    for ins in cond.instructions:
        m = _CONST_INT.search(f"= {ins.out_type} {ins.opcode}({ins.body}")
        if ins.opcode == "constant":
            mm = re.match(r"(\d+)\)?", ins.body)
            if mm and "[]" in ins.out_type and ins.out_type[0] in "su":
                consts[ins.name] = int(mm.group(1))
    for ins in cond.instructions:
        if ins.opcode == "compare" and "direction=LT" in ins.body:
            for name, val in consts.items():
                if re.search(rf"%?{re.escape(name)}\b", ins.body):
                    return val
    if len(consts) == 1:
        return next(iter(consts.values()))
    return None


def _group_size(body: str, default: int) -> int:
    m = _GROUP_LIST.search(body)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_IOTA.search(body)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(comp: Computation, instr: Instruction) -> float:
    out_elems = 1
    for d in _shape_dims(instr.out_type):
        out_elems *= d
    # contracting dims from the lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.body)
    operands = comp.operand_names(instr)
    if not m or not operands:
        return 2.0 * out_elems  # degenerate; should not happen
    lhs_dims = _shape_dims(comp.types[operands[0]])
    contract = 1
    for ax in (m.group(1).split(",") if m.group(1) else []):
        contract *= lhs_dims[int(ax)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0
    while_trips: List[int] = dataclasses.field(default_factory=list)
    promoted_collectives: int = 0
    # attribution: (opcode, out_type) -> accumulated bytes / wire bytes
    bytes_by_sig: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_by_sig: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_collective(self, kind: str, operand_b: float, wire_b: float,
                       mult: float, sig: str = ""):
        self.coll_operand_bytes += operand_b * mult
        self.coll_wire_bytes += wire_b * mult
        self.per_collective[kind] = (self.per_collective.get(kind, 0.0)
                                     + operand_b * mult)
        if sig:
            self.coll_by_sig[sig] = (self.coll_by_sig.get(sig, 0.0)
                                     + operand_b * mult)

    def add_bytes(self, b: float, sig: str):
        self.hbm_bytes += b
        self.bytes_by_sig[sig] = self.bytes_by_sig.get(sig, 0.0) + b

    def top(self, table: Dict[str, float], k: int = 15):
        return sorted(table.items(), key=lambda kv: -kv[1])[:k]


def analyze(text: str, *, default_group: int = 1) -> HloCost:
    comps, entry = parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost

    def visit(comp_name: str, mult: float, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instructions:
            op = ins.opcode
            if op == "while":
                called = _called(ins)
                trips = None
                if "condition" in called and called["condition"] in comps:
                    trips = while_trip_count(comps[called["condition"]])
                if trips is None:
                    trips = 1
                    cost.unknown_trip_whiles += 1
                else:
                    cost.while_trips.append(trips)
                if "body" in called:
                    visit(called["body"], mult * trips, count_bytes)
                continue
            if op == "conditional":
                for m in re.finditer(r"%?([\w\.\-_]+)", ins.body):
                    if m.group(1) in comps and m.group(1) != comp_name:
                        visit(m.group(1), mult, count_bytes)
                continue
            if op in ("call", "async-start"):
                called = _called(ins, keys=("to_apply", "calls"))
                for c in called.values():
                    visit(c, mult, count_bytes)
                continue
            if op == "fusion":
                called = _called(ins, keys=("calls",))
                for c in called.values():
                    visit(c, mult, count_bytes=False)   # FLOPs only inside
                if count_bytes:
                    cost.add_bytes(
                        (comp.operand_bytes(ins)
                         + shape_bytes(ins.out_type)) * mult,
                        f"fusion {ins.out_type[:90]}")
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                out_b = shape_bytes(ins.out_type)
                n = _group_size(ins.body, default_group)
                # async -start ops return (operand, result[, ctx]) tuples;
                # treat the logical payload as out/2 in that case.
                if op.endswith("-start") and ins.out_type.startswith("("):
                    out_b = out_b // 2
                # CPU float-normalization promotes bf16 reductions to f32
                # (to_apply=%region_N_promoted wrapping convert ops); TPU
                # ICI runs them native bf16 — count the real payload.
                if "_promoted" in ins.body:
                    out_b = out_b // 2
                    cost.promoted_collectives += 1
                operand_b = {
                    "all-reduce": out_b,
                    "all-gather": out_b // max(n, 1),
                    "reduce-scatter": out_b * n,
                    "all-to-all": out_b,
                    "collective-permute": out_b,
                }[base]
                frac = (n - 1) / n if n > 1 else 0.0
                wire = {
                    "all-reduce": 2.0 * out_b * frac,
                    "all-gather": out_b * frac,
                    "reduce-scatter": operand_b * frac,
                    "all-to-all": out_b * frac,
                    "collective-permute": float(out_b),
                }[base]
                cost.add_collective(base, operand_b, wire, mult,
                                    sig=f"{base} {ins.out_type[:90]}")
                if count_bytes:
                    cost.add_bytes((operand_b + out_b) * mult,
                                   f"{base} {ins.out_type[:90]}")
                continue
            if op == "dot":
                cost.flops += _dot_flops(comp, ins) * mult
            if count_bytes and op not in _NO_BYTES:
                cost.add_bytes(
                    (comp.operand_bytes(ins)
                     + shape_bytes(ins.out_type)) * mult,
                    f"{op} {ins.out_type[:90]}")

    visit(entry, 1.0, True)
    return cost
