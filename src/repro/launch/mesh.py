"""Production mesh factories.

Importing this module never touches jax device state; meshes are built
lazily inside the functions so that ``XLA_FLAGS=--xla_force_host_platform_
device_count=...`` set by the launcher (dryrun.py) is respected.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    Single pod: 16x16 = 256 chips, axes ("data", "model").
    Multi pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for CPU-host testing (device count set via XLA_FLAGS)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
