import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Per-op attribution profile of a dry-run cell: top HBM-byte and
collective-byte contributors (the §Perf 'profile' — no wall clock on CPU,
so the lowered-IR attribution IS the profile).

  python -m repro.launch.profile_cell --arch jamba-v0.1-52b --shape train_4k
"""
import argparse       # noqa: E402

from repro.launch import hlo_analysis as H           # noqa: E402
from repro.launch.cell import build_cell             # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    ap.add_argument("--dump", type=str, default=None,
                    help="write the compiled HLO text here for grepping")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = build_cell(args.arch, args.shape, mesh)
    compiled = cell.lower().compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    cost = H.analyze(text)
    print(f"total: flops={cost.flops:.3e} hbm={cost.hbm_bytes:.3e}B "
          f"coll={cost.coll_operand_bytes:.3e}B "
          f"trips={cost.while_trips}")
    print(f"\n== top HBM-byte signatures (of {cost.hbm_bytes:.3e}) ==")
    for sig, b in cost.top(cost.bytes_by_sig, args.top):
        print(f"  {b:12.3e}  {100 * b / cost.hbm_bytes:5.1f}%  {sig}")
    print(f"\n== top collective signatures "
          f"(of {cost.coll_operand_bytes:.3e}) ==")
    for sig, b in cost.top(cost.coll_by_sig, args.top):
        print(f"  {b:12.3e}  {100 * b / max(cost.coll_operand_bytes, 1):5.1f}%"
              f"  {sig}")


if __name__ == "__main__":
    main()
