"""Roofline terms from the compiled dry-run artifact (task §Roofline).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Sources: the per-device partitioned HLO (compiled.as_text()) analyzed with
trip-count-aware hlo_analysis (the stock cost_analysis counts while bodies
once — see hlo_analysis docstring), so the numbers below are already
per-chip; dividing global totals by chips is the same thing.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.core.profiler import TPU_V5E, Hardware
from repro.launch import hlo_analysis


_TILE_SIG = None  # set below


def kernel_adjusted_bytes(cost, spec=None) -> float:
    """Memory bytes with MXU-tile intermediates removed.

    The pure-jnp twins of the Pallas kernels (flash attention, WKV6,
    selective scan) materialize their (q_tile × k_tile) score /
    state-expansion intermediates at HLO fusion boundaries; the kernels
    hold them in VMEM and stream only Q/K/V/O (+ per-chunk carries).
    Adjusted term = measured − Σ(score/state-tile signatures).  The
    streamed operand traffic stays counted because the q/k/v reads and
    output writes appear as separate (kept) signatures.

    Tile signatures (f32 only — the twins accumulate in f32):
      attention score tiles  [b, h≤128, q≥512, k≥512]
      scan-state expansions  [b, s≥512, c≥512, n≤64]   (selective scan's
                             (B,S,Ci,N) dA/dBu — VMEM-resident per chunk
                             in the kernel)
    Plain [b, s, d_model] activations never match (3-dim).
    """
    import re as _re
    drop = 0.0
    for sig, b in cost.bytes_by_sig.items():
        m = _re.search(r"f32\[([\d,]+)\]", sig)
        if not m:
            continue
        dims = [int(d) for d in m.group(1).split(",")]
        if len(dims) < 4:
            continue
        score_tile = (dims[1] <= 128 and dims[-1] >= 512
                      and dims[-2] >= 512)
        # (b, t≤chunk, Ci≥512, N≤64): the selective-scan expansion and
        # every level of XLA's associative-scan halving cascade — the
        # kernel's in-VMEM sequential recurrence has no cascade at all
        state_tile = dims[-1] <= 64 and dims[-2] >= 512
        if score_tile or state_tile:
            drop += b
    return max(cost.hbm_bytes - drop, 0.0)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    plan: str
    # per-device totals for ONE step
    hlo_flops: float
    hlo_bytes: float
    coll_operand_bytes: float
    coll_wire_bytes: float
    per_collective: Dict[str, float]
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # memory term with Pallas-kernel VMEM-resident tiles removed (the
    # expected on-TPU number when kernels/ replace the jnp twins)
    memory_adj_s: float
    # usefulness
    model_flops: float            # 6·N_active·D per device-step
    useful_ratio: float           # model_flops / hlo_flops
    # bookkeeping
    cost_analysis: Dict[str, Any]
    memory_analysis: Dict[str, Any]
    while_trips: list
    unknown_trip_whiles: int
    note: str = ""

    @property
    def step_seconds(self) -> float:
        """Bound = max of the three terms (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_seconds_adj(self) -> float:
        return max(self.compute_s, self.memory_adj_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / bound — the score being hillclimbed."""
        if self.step_seconds <= 0:
            return 0.0
        ideal = self.model_flops / TPU_V5E.flops_peak
        return ideal / self.step_seconds

    @property
    def roofline_fraction_adj(self) -> float:
        """Fraction with the kernel-adjusted memory term."""
        if self.step_seconds_adj <= 0:
            return 0.0
        ideal = self.model_flops / TPU_V5E.flops_peak
        return ideal / self.step_seconds_adj

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["step_seconds"] = self.step_seconds
        d["roofline_fraction"] = self.roofline_fraction
        d["roofline_fraction_adj"] = self.roofline_fraction_adj
        return d


def mem_stats(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
        return {k: getattr(m, k) for k in dir(m)
                if k.endswith("_in_bytes") and not k.startswith("_")}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                  plan: str, model_flops_per_device: float,
                  hw: Hardware = TPU_V5E, hlo_text: Optional[str] = None,
                  note: str = "") -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_analysis.analyze(text)
    try:
        from repro.parallel.compat import cost_analysis as _ca
        ca = _ca(compiled)
        ca = {k: float(v) for k, v in ca.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "transcendentals",
               "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        ca = {"error": str(e)}

    compute_s = cost.flops / hw.flops_peak
    memory_s = cost.hbm_bytes / hw.hbm_bw
    memory_adj_s = kernel_adjusted_bytes(cost) / hw.hbm_bw
    collective_s = cost.coll_operand_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops_per_device / cost.flops) if cost.flops else 0.0

    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, plan=plan,
        hlo_flops=cost.flops, hlo_bytes=cost.hbm_bytes,
        coll_operand_bytes=cost.coll_operand_bytes,
        coll_wire_bytes=cost.coll_wire_bytes,
        per_collective=cost.per_collective,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, memory_adj_s=memory_adj_s,
        model_flops=model_flops_per_device, useful_ratio=useful,
        cost_analysis=ca, memory_analysis=mem_stats(compiled),
        while_trips=cost.while_trips,
        unknown_trip_whiles=cost.unknown_trip_whiles, note=note)


def model_flops_per_device(spec, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference),
    per chip per step."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        factor = 2.0
    else:  # decode: one new token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * spec.active_param_count() * tokens / n_chips


def dump(r: Roofline, path: str):
    with open(path, "w") as f:
        json.dump(r.to_json(), f, indent=2)


def fmt_row(r: Roofline) -> str:
    return (f"{r.arch:18s} {r.shape:12s} {r.mesh:9s} "
            f"C={r.compute_s*1e3:9.2f}ms M={r.memory_s*1e3:9.2f}ms "
            f"(adj {r.memory_adj_s*1e3:9.2f}ms) "
            f"X={r.collective_s*1e3:9.2f}ms dom={r.dominant:10s} "
            f"useful={r.useful_ratio:5.2f} frac={r.roofline_fraction:5.3f} "
            f"(adj {r.roofline_fraction_adj:5.3f})")
