import os
import sys

if __name__ == "__main__" and "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))
"""Pipelined serving driver: prefill a batch of requests, then decode.

Drives the schedule-table EngineSession (serving/engine.py): pick a
serve schedule from the registry with --schedule serve_1f /
serve_interleaved (--virtual-stages v interleaves each stage's chunks,
cutting the prefill ramp — and the worst request's TTFT — by ~v).

CPU example:
  python -m repro.launch.serve --arch rwkv6-1.6b --smoke --tokens 16 \\
      --host-devices 2 --batch 4
  python -m repro.launch.serve --arch qwen3-14b --smoke --tokens 8 \\
      --host-devices 2 --batch 4 --schedule serve_interleaved \\
      --virtual-stages 2
"""
import argparse        # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402

from repro import configs                          # noqa: E402
from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: E402
from repro.parallel.mesh import split_model_axis   # noqa: E402
from repro.serving.engine import build_serving     # noqa: E402


def main(argv=None):
    from repro.core.schedule import SCHEDULES, plan_kwargs_for_schedule
    serve_names = sorted(n for n, c in SCHEDULES.items() if c.is_serving)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--schedule", type=str, default=None,
                    choices=[None, *serve_names])
    ap.add_argument("--virtual-stages", type=int, default=None)
    args = ap.parse_args(argv)
    if args.virtual_stages and args.virtual_stages > 1 \
            and args.schedule not in (None, "serve_interleaved"):
        ap.error("--virtual-stages > 1 requires --schedule "
                 "serve_interleaved")

    cfg = configs.get(args.arch)
    if args.smoke:
        spec, plan = cfg.smoke_spec(), cfg.SMOKE_PLAN
        mesh = make_host_mesh(data=args.data, model=plan.pp * plan.tp)
        batch, prefill, cache_len = args.batch, args.prefill, args.cache_len
    else:
        spec, plan = cfg.full_spec(), cfg.PLAN
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = configs.SHAPES["decode_32k"]
        batch, prefill, cache_len = (shape.global_batch, args.prefill,
                                     shape.seq_len)
    if args.schedule or args.virtual_stages:
        name = args.schedule or ("serve_interleaved"
                                 if (args.virtual_stages or 1) > 1
                                 else "serve_1f")
        plan = plan.with_(**plan_kwargs_for_schedule(
            name, virtual_stages=args.virtual_stages,
            stash_mode=plan.stash_mode))
    if spec.frontend == "vision":
        prefill = max(prefill, spec.n_patches + 8)
    dmesh = split_model_axis(mesh, plan.pp, plan.tp)
    session = build_serving(spec, plan, dmesh, cache_len=cache_len,
                            global_batch=batch, prefill_len=prefill,
                            compute_dtype=(jnp.float32 if args.smoke
                                           else jnp.bfloat16))
    print(f"serve schedule: {session.sched.name} "
          f"(S={session.sched.n_stages} R={session.sched.n_microbatches}"
          f"{f' v={session.sched.virtual_stages}' if session.sched.virtual_stages > 1 else ''}"
          f", {session.sched.n_ticks} ticks/pass)")

    session.start(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch_in = {k: jnp.asarray(
        rng.integers(0, spec.vocab, v.shape).astype(np.int32)
        if v.dtype == jnp.int32 else
        rng.standard_normal(v.shape).astype(np.float32) * 0.02)
        for k, v in session.prefill_specs.items()}
    t0 = time.time()
    nxt = session.prefill(batch_in)
    jax.block_until_ready(nxt)
    t_pre = time.time() - t0
    print(f"prefill[{prefill}] batch={batch}: {t_pre:.2f}s "
          f"first tokens {np.asarray(nxt)[:8]}")

    t0 = time.time()
    outs = []
    for _ in range(args.tokens):
        nxt = session.decode(nxt)
        outs.append(np.asarray(nxt))
    dt = time.time() - t0
    print(f"decoded {args.tokens} steps × {batch} seqs in {dt:.2f}s "
          f"({args.tokens * batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", np.stack(outs)[:, 0])


if __name__ == "__main__":
    main()
