import os
import sys

if __name__ == "__main__" and "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))
"""Pipelined serving driver: prefill a batch of requests, then decode.

Drives the schedule-table EngineSession (serving/engine.py): pick a
serve schedule from the registry with --schedule serve_1f /
serve_interleaved (--virtual-stages v interleaves each stage's chunks,
cutting the prefill ramp — and the worst request's TTFT — by ~v).

--arrivals switches to continuous batching (serving/batcher.py): the
batch becomes R microbatch *slots* served from a request stream —
admission writes a new request's prefill into a freed slot mid-stream,
eviction on max_new_tokens frees it the next tick.  The trace is
either explicit arrival steps ("0,0,3,7" — one request per entry) or
"poisson:RATE:N" (N requests, exponential inter-arrival at RATE
requests/step, seeded); --policy synchronized runs the drain-then-
refill baseline for comparison.

CPU example:
  python -m repro.launch.serve --arch rwkv6-1.6b --smoke --tokens 16 \\
      --host-devices 2 --batch 4
  python -m repro.launch.serve --arch qwen3-14b --smoke --tokens 8 \\
      --host-devices 2 --batch 4 --schedule serve_interleaved \\
      --virtual-stages 2
  python -m repro.launch.serve --arch qwen3-14b --smoke --tokens 12 \\
      --host-devices 2 --batch 4 --arrivals 0,0,2,5,9
"""
import argparse        # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402

from repro import configs                          # noqa: E402
from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: E402
from repro.obs import Observability, reconcile     # noqa: E402
from repro.parallel.mesh import split_model_axis   # noqa: E402
from repro.serving.engine import build_serving     # noqa: E402


_ARRIVALS_HELP = ("accepted --arrivals formats: 't0,t1,...' "
                  "(comma-separated non-negative integer arrival steps, "
                  "one request each) or 'poisson:RATE:N' (N requests, "
                  "exponential inter-arrival at RATE requests/step, "
                  "e.g. 'poisson:0.5:32')")


def parse_arrivals(spec_str: str, seed: int = 0):
    """'t0,t1,...' explicit steps, or 'poisson:RATE:N' (RATE req/step).

    A malformed spec raises :class:`ValueError` naming the accepted
    formats — never a bare unpack/parse traceback.
    """
    if spec_str.startswith("poisson:"):
        parts = spec_str.split(":")
        if len(parts) != 3:
            raise ValueError(
                f"malformed arrivals spec {spec_str!r}: poisson traces "
                f"need both a rate and a count; {_ARRIVALS_HELP}")
        try:
            rate, n = float(parts[1]), int(parts[2])
        except ValueError:
            raise ValueError(
                f"malformed arrivals spec {spec_str!r}: RATE must be a "
                f"number and N an integer; {_ARRIVALS_HELP}") from None
        if rate <= 0 or n <= 0:
            raise ValueError(
                f"malformed arrivals spec {spec_str!r}: RATE and N must "
                f"be positive; {_ARRIVALS_HELP}")
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(scale=1.0 / rate, size=n)
        return np.floor(np.cumsum(gaps)).astype(int).tolist()
    try:
        steps = [int(t) for t in spec_str.split(",")]
    except ValueError:
        raise ValueError(
            f"malformed arrivals spec {spec_str!r}: non-numeric arrival "
            f"step; {_ARRIVALS_HELP}") from None
    if any(t < 0 for t in steps):
        raise ValueError(
            f"malformed arrivals spec {spec_str!r}: arrival steps must "
            f"be non-negative; {_ARRIVALS_HELP}")
    return steps


def load_checkpoint(session, spec, args):
    """Install a converted checkpoint (checkpoint/convert.py) into the
    freshly started session, validating the conversion plan matches
    this schedule's storage chunk order."""
    from repro.checkpoint.convert import ConvertError, load_converted
    params, manifest = load_converted(args.ckpt, spec)
    sched = session.sched
    want = (list(int(c) for c in sched.storage_chunk_order())
            if sched.virtual_stages > 1 else list(range(sched.n_chunks)))
    if (manifest["n_chunks"] != sched.n_chunks
            or list(manifest["storage_order"]) != want):
        raise ConvertError(
            f"checkpoint at '{args.ckpt}' was converted for "
            f"pp={manifest['pp']} v={manifest['virtual_stages']} "
            f"(storage order {manifest['storage_order']}); this session "
            f"runs {sched.n_chunks} chunks in order {want} — reconvert "
            f"with --pp {sched.n_stages} --virtual-stages "
            f"{sched.virtual_stages}")
    session.load_params(params)
    print(f"loaded checkpoint {args.ckpt} (family={manifest['family']}, "
          f"{manifest['n_chunks']} chunks"
          f"{f', weights quantized to {session.weight_dtype}' if session.weight_dtype in ('int8', 'fp8') else ''})")


def serve_arrivals(session, spec, args):
    """Continuous batching over a request trace (--arrivals)."""
    from repro.serving.batcher import ContinuousBatchingSession, Request
    if spec.frontend == "vision" or spec.encoder is not None:
        raise SystemExit("--arrivals serves text-only models")
    arrivals = parse_arrivals(args.arrivals, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    text_len = session.prefill_specs["tokens"].shape[2]
    trace = [Request(rid=i,
                     prompt=rng.integers(1, spec.vocab, text_len)
                     .astype(np.int32),
                     max_new_tokens=args.tokens, arrival=int(t))
             for i, t in enumerate(sorted(arrivals))]
    session.start(jax.random.key(0))
    if args.ckpt:
        load_checkpoint(session, spec, args)
    server = ContinuousBatchingSession(session, policy=args.policy)
    obs = session.obs
    with obs.timer("launch_phase_seconds", phase="run") as t:
        report = server.run(trace)
    dt = t.elapsed
    s = report.summary()
    print(f"{args.policy} batching: {s['requests']} requests over "
          f"{session.sched.n_microbatches} slots, {s['steps']} steps "
          f"({s['decode_rounds']} decode + {s['admit_rounds']} admit "
          f"rounds) in {dt:.2f}s")
    fmt_ms = lambda v: "n/a" if v is None else f"{v * 1e3:.1f} ms"  # noqa: E731
    print(f"  goodput {s['goodput_tokens_per_s']:.1f} tok/s; per-token "
          f"latency p50 {fmt_ms(s['p50_per_token_latency_s'])} / "
          f"p99 {fmt_ms(s['p99_per_token_latency_s'])}; mean TTFT "
          f"{fmt_ms(s['mean_ttft_s'])}")
    if s.get("spec_rounds"):
        print(f"  speculative: {s['spec_rounds']} verify rounds, "
              f"acceptance {s['acceptance_rate']:.2f}, "
              f"{s['accepted_per_round']:.2f} accepted tok/lane-round "
              "(goodput counts accepted tokens only)")
    if getattr(session, "buckets", None) and session._bucket_log:
        from collections import Counter
        hist = Counter(session._bucket_log)
        print("  bucket rounds: " + ", ".join(
            f"R_b={b} x{hist[b]}" for b in sorted(hist)))
    for r in report.requests[:8]:
        print(f"  request {r.rid}: arrival step {r.arrival}, admitted "
              f"{r.step_admitted}, done {r.step_done}, "
              f"tokens {r.tokens[:6]}{'...' if len(r.tokens) > 6 else ''}")


def main(argv=None):
    from repro.core.schedule import SCHEDULES, plan_kwargs_for_schedule
    serve_names = sorted(n for n, c in SCHEDULES.items() if c.is_serving)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache page size in tokens (0 = dense; "
                         "must divide --cache-len)")
    ap.add_argument("--buckets", action="store_true",
                    help="liveness-aware bucketed execution: compile a "
                         "lattice of compacted decode variants and run "
                         "the smallest bucket covering the live slots "
                         "(bit-exact vs the full-R path)")
    ap.add_argument("--ckpt", type=str, default=None,
                    help="converted checkpoint directory (see "
                         "repro.checkpoint.convert: HF safetensors -> "
                         "per-chunk files in this plan's storage order)")
    ap.add_argument("--weight-dtype", type=str, default=None,
                    choices=[None, "fp32", "bf16", "int8", "fp8"],
                    help="weight storage dtype: int8/fp8 store matmul "
                         "weights quantized with per-output-channel "
                         "scales, dequantized on the fly")
    ap.add_argument("--kv-dtype", type=str, default=None,
                    choices=[None, "fp32", "bf16", "int8"],
                    help="KV-cache storage dtype; int8 needs --page-size "
                         "> 0 (per-page scales live in the page pools)")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--schedule", type=str, default=None,
                    choices=[None, *serve_names])
    ap.add_argument("--virtual-stages", type=int, default=None)
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative decode: draft depth (routes onto "
                         "the serve_spec_* schedules; each decode round "
                         "drafts k tokens and verifies k+1 positions in "
                         "one pipelined pass)")
    ap.add_argument("--arrivals", type=str, default=None,
                    help="continuous batching: 't0,t1,...' arrival steps "
                         "(one request each) or 'poisson:RATE:N'")
    ap.add_argument("--policy", type=str, default="continuous",
                    choices=["continuous", "synchronized"],
                    help="slot scheduler policy under --arrivals")
    ap.add_argument("--seed", type=int, default=0,
                    help="prompt + poisson-trace seed under --arrivals")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Chrome trace-event JSON of every "
                         "executed pipeline round (one track per stage; "
                         "open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the metrics-registry snapshot JSON "
                         "(counters/gauges/histograms; schema-checked by "
                         "scripts/bench_check.py)")
    args = ap.parse_args(argv)
    if args.virtual_stages and args.virtual_stages > 1 \
            and args.schedule not in (None, "serve_interleaved",
                                      "serve_spec_interleaved"):
        ap.error("--virtual-stages > 1 requires --schedule "
                 "serve_interleaved or serve_spec_interleaved")
    if args.spec_k is not None and args.schedule is not None \
            and not getattr(SCHEDULES[args.schedule], "is_speculative",
                            False):
        ap.error(f"--spec-k needs a speculative schedule "
                 f"(--schedule serve_spec_1f / serve_spec_interleaved), "
                 f"got {args.schedule}")
    if args.spec_k is None and args.schedule is not None \
            and getattr(SCHEDULES[args.schedule], "is_speculative", False):
        args.spec_k = 4         # the schedules' default draft depth

    cfg = configs.get(args.arch)
    if args.smoke:
        spec, plan = cfg.smoke_spec(), cfg.SMOKE_PLAN
        mesh = make_host_mesh(data=args.data, model=plan.pp * plan.tp)
        batch, prefill, cache_len = args.batch, args.prefill, args.cache_len
    else:
        spec, plan = cfg.full_spec(), cfg.PLAN
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = configs.SHAPES["decode_32k"]
        batch, prefill, cache_len = (shape.global_batch, args.prefill,
                                     shape.seq_len)
    if args.schedule or args.virtual_stages or args.spec_k:
        v2 = (args.virtual_stages or 1) > 1
        name = args.schedule or (
            ("serve_spec_interleaved" if v2 else "serve_spec_1f")
            if args.spec_k else
            ("serve_interleaved" if v2 else "serve_1f"))
        plan = plan.with_(**plan_kwargs_for_schedule(
            name, virtual_stages=args.virtual_stages,
            stash_mode=plan.stash_mode))
    if spec.frontend == "vision":
        prefill = max(prefill, spec.n_patches + 8)
    dmesh = split_model_axis(mesh, plan.pp, plan.tp)
    obs = Observability(trace=bool(args.trace_out))
    session = build_serving(spec, plan, dmesh, cache_len=cache_len,
                            global_batch=batch, prefill_len=prefill,
                            compute_dtype=(jnp.float32 if args.smoke
                                           else jnp.bfloat16),
                            page_size=args.page_size,
                            buckets=args.buckets,
                            spec_k=args.spec_k,
                            weight_dtype=args.weight_dtype,
                            kv_dtype=args.kv_dtype,
                            obs=obs)
    print(f"serve schedule: {session.sched.name} "
          f"(S={session.sched.n_stages} R={session.sched.n_microbatches}"
          f"{f' v={session.sched.virtual_stages}' if session.sched.virtual_stages > 1 else ''}"
          f"{f' spec_k={session.sched.spec_k}' if getattr(session.sched, 'is_speculative', False) else ''}"
          f", {session.sched.n_ticks} ticks/pass)")
    if session.paged:
        pg = session.paged
        print(f"paged KV: page_size={pg['page_size']} "
              f"max_pages/slot={pg['max_pages']} "
              f"pool_pages={pg['pool_pages']}")
    if session.buckets:
        print(f"bucket lattice: {session.buckets} (liveness-aware "
              "compacted decode variants, jitted lazily per bucket)")
    if args.weight_dtype or args.kv_dtype:
        print(f"storage dtypes: weights={args.weight_dtype or 'compute'} "
              f"kv={args.kv_dtype or 'compute'}")

    if args.arrivals:
        serve_arrivals(session, spec, args)
        return _finish_obs(obs, session, args)

    session.start(jax.random.key(0))
    if args.ckpt:
        load_checkpoint(session, spec, args)
    rng = np.random.default_rng(0)
    batch_in = {k: jnp.asarray(
        rng.integers(0, spec.vocab, v.shape).astype(np.int32)
        if v.dtype == jnp.int32 else
        rng.standard_normal(v.shape).astype(np.float32) * 0.02)
        for k, v in session.prefill_specs.items()}
    with obs.timer("launch_phase_seconds", phase="prefill") as tp:
        nxt = session.prefill(batch_in)
        jax.block_until_ready(nxt)
    print(f"prefill[{prefill}] batch={batch}: {tp.elapsed:.2f}s "
          f"first tokens {np.asarray(nxt)[:8]}")

    if getattr(session.sched, "is_speculative", False):
        # draft-verify rounds: each commits 1..spec_k+1 tokens per slot
        last = np.asarray(nxt, np.int32)
        rows_g = last.shape[0] // session.sched.n_microbatches
        emitted, rounds, acc_total = 0, 0, 0
        sample = []
        with obs.timer("launch_phase_seconds", phase="decode") as td:
            while emitted < args.tokens * batch:
                drafts = session.draft(last)
                toks = np.concatenate([last[:, None], drafts], axis=1)
                scores, acc = session.verify(toks.astype(np.int32))
                rounds += 1
                acc_total += int(np.sum(acc))
                emitted += int(np.sum(acc + 1)) * rows_g
                sample.append(int(scores[0, 0]))
                acc_rows = np.asarray(acc).repeat(rows_g)
                last = scores[np.arange(scores.shape[0]),
                              acc_rows].astype(np.int32)
        dt = td.elapsed
        print(f"spec-decoded {emitted} tokens in {rounds} verify rounds "
              f"(k={session.sched.spec_k}, mean accepted/round "
              f"{acc_total / max(rounds * session.sched.n_microbatches, 1):.2f}) "
              f"in {dt:.2f}s ({emitted / max(dt, 1e-9):.1f} tok/s)")
        print("sample (first emitted/round):", sample[:args.tokens])
    else:
        outs = []
        with obs.timer("launch_phase_seconds", phase="decode") as td:
            for _ in range(args.tokens):
                nxt = session.decode(nxt)
                outs.append(np.asarray(nxt))
        dt = td.elapsed
        print(f"decoded {args.tokens} steps × {batch} seqs in {dt:.2f}s "
              f"({args.tokens * batch / max(dt, 1e-9):.1f} tok/s)")
        print("sample:", np.stack(outs)[:, 0])
    _finish_obs(obs, session, args)


def _finish_obs(obs, session, args):
    """Print the measured-vs-predicted report and write --trace-out /
    --metrics-out artifacts (repro.obs)."""
    for kind in ("decode", "verify"):
        if obs.registry.counter("rounds_total").value(kind=kind):
            print(" ", reconcile(session.sched, trace=obs.trace,
                                 registry=obs.registry, kind=kind))
    obs.save(trace_out=args.trace_out, metrics_out=args.metrics_out)
    if args.trace_out:
        print(f"wrote pipeline trace to {args.trace_out} "
              "(open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        print(f"wrote metrics snapshot to {args.metrics_out}")


if __name__ == "__main__":
    main()
