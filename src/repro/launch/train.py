import os
import sys

if __name__ == "__main__" and "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))
"""End-to-end pipelined training driver.

Builds the (arch × plan) pipeline on the available device mesh, feeds the
deterministic synthetic LM stream through the fault-tolerant TrainDriver
(periodic per-stage checkpoints, restart-from-last-complete-round), and
logs loss per round.

CPU example (the --smoke config fits a laptop):
  python -m repro.launch.train --arch qwen3-14b --smoke --steps 20 \\
      --host-devices 4 --data 2 --ckpt /tmp/ckpt
"""
import argparse        # noqa: E402
import json            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                          # noqa: E402
from repro.core.pipeline import build_pipeline     # noqa: E402
from repro.data.pipeline import ShardedLoader, SyntheticLM, vlm_patch_stub  # noqa: E402
from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: E402
from repro.obs import Observability, reconcile     # noqa: E402
from repro.optim.optimizers import by_name         # noqa: E402
from repro.parallel.mesh import split_model_axis   # noqa: E402
from repro.runtime.driver import DriverConfig, TrainDriver  # noqa: E402


def build(args, obs=None):
    cfg = configs.get(args.arch)
    if args.smoke:
        spec = cfg.smoke_spec()
        plan = cfg.SMOKE_PLAN.with_(microbatches=args.microbatches)
        seq_len, global_batch = args.seq_len, args.global_batch
    else:
        spec = cfg.full_spec()
        plan = cfg.PLAN
        shape = configs.SHAPES["train_4k"]
        seq_len, global_batch = shape.seq_len, shape.global_batch
    from repro.core.schedule import (plan_kwargs_for_schedule,
                                     virtual_stages_error)
    err = virtual_stages_error(args.schedule, args.virtual_stages)
    if err:
        raise SystemExit(err)
    if args.schedule:
        plan = plan.with_(**plan_kwargs_for_schedule(
            args.schedule, virtual_stages=args.virtual_stages,
            stash_mode=plan.stash_mode))
    if spec.frontend == "vision":
        seq_len = max(seq_len, spec.n_patches + 16)
    if args.plan_search:
        from repro.runtime.driver import plan_search_report
        if args.smoke:
            dp = args.data
        else:
            dp = make_production_mesh(multi_pod=args.multi_pod) \
                .devices.size // (plan.pp * plan.tp)
        plan = plan_search_report(spec, plan, seq_len=seq_len,
                                  global_batch=global_batch,
                                  data_replicas=dp).plan
    if args.smoke:
        mesh = make_host_mesh(data=args.data, model=plan.pp * plan.tp)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    dmesh = split_model_axis(mesh, plan.pp, plan.tp)
    name, lr = cfg.OPTIMIZER
    opt = by_name(args.optimizer or name, args.lr or lr)
    bundle = build_pipeline(spec, plan, dmesh, seq_len=seq_len,
                            global_batch=global_batch, optimizer=opt,
                            compute_dtype=(jnp.float32 if args.smoke
                                           else jnp.bfloat16), obs=obs)
    return spec, bundle


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    from repro.core.schedule import SCHEDULES
    ap.add_argument("--schedule", type=str, default=None,
                    choices=[None, *sorted(SCHEDULES)],
                    help="override the plan's pipeline schedule")
    ap.add_argument("--virtual-stages", type=int, default=None,
                    help="model chunks per stage (interleaved schedule)")
    ap.add_argument("--plan-search", action="store_true",
                    help="let plan_search pick (pp, tp, schedule, "
                         "virtual_stages) under the HBM budget")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--optimizer", type=str, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--log", type=str, default=None)
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Chrome trace-event JSON of every "
                         "training round (one track per stage; open in "
                         "Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the metrics-registry snapshot JSON "
                         "(schema-checked by scripts/bench_check.py)")
    args = ap.parse_args(argv)

    obs = Observability(trace=bool(args.trace_out))
    spec, bundle = build(args, obs=obs)
    from repro.core.schedule import weighted_round_time
    plan = bundle.plan
    _, bubble = weighted_round_time(bundle.sched)
    print(f"plan: pp={plan.pp} tp={plan.tp} schedule={bundle.sched.name}"
          + (f" v={plan.virtual_stages}" if plan.virtual_stages > 1 else "")
          + f" R={plan.microbatches} predicted_bubble={bubble:.3f}")
    src = SyntheticLM(spec.vocab, bundle.seq_len
                      - (spec.n_patches if spec.frontend == "vision" else 0))
    extra = vlm_patch_stub(spec.d_model) if spec.frontend == "vision" else None
    loader = ShardedLoader(src, bundle.batch_specs(), extra_fn=extra)
    driver = TrainDriver(bundle, loader, args.ckpt,
                         DriverConfig(checkpoint_every=args.ckpt_every))

    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(0))
    with obs.timer("launch_phase_seconds", phase="run") as t:
        state, step = driver.run(state, args.steps)
    dt = t.elapsed
    losses = [m["loss"] for m in driver.metrics_log]
    print(f"arch={spec.name} steps={step} time={dt:.1f}s "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(" ", reconcile(bundle.sched, trace=obs.trace,
                         registry=obs.registry, kind="train"))
    obs.save(trace_out=args.trace_out, metrics_out=args.metrics_out)
    if args.trace_out:
        print(f"wrote pipeline trace to {args.trace_out}")
    if args.metrics_out:
        print(f"wrote metrics snapshot to {args.metrics_out}")
    if args.log:
        with open(args.log, "w") as f:
            json.dump({"arch": spec.name, "losses": losses,
                       "seconds": dt}, f)
    return losses


if __name__ == "__main__":
    main()
