from repro.models.spec import (  # noqa: F401
    GLOBAL_WINDOW,
    BlockSpec,
    EncoderSpec,
    MambaSpec,
    ModelSpec,
    MoESpec,
    RWKVSpec,
    validate_stageability,
)
