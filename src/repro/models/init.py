"""Parameter initialization + sharding specs.

Layout: every per-layer leaf is stacked with a leading [pp] stage dim and
sharded P("stage", ...); tensor-parallel dims are sharded over "tensor".
Embedding/head/encoder live outside the pipeline:
  embed  [vocab_padded, d]   sharded on d over ("stage","tensor")  (gather stays local)
  head   [d, vocab_padded]   sharded on vocab over ("stage","tensor")
Runs under ``jax.eval_shape`` for the allocation-free dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import spec as spec_lib
from repro.models.nn import AttnStatic, MambaStatic, MoEStatic, RWKVStatic
from repro.parallel.mesh import ParallelismPlan

MODEL_SHARDS = 16  # stage * tensor on the production mesh


def padded_vocab(vocab: int, multiple: int = 128) -> int:
    return -(-vocab // multiple) * multiple


# --------------------------------------------------------------------------
# Static per-device layer configs derived from (spec, plan)
# --------------------------------------------------------------------------

def attn_static(spec: spec_lib.ModelSpec, tp: int, causal: bool = True) -> AttnStatic:
    assert spec.n_heads % tp == 0, (spec.name, spec.n_heads, tp)
    if spec.n_kv % tp == 0:
        kv_local, kv_sharded, groups_per_dev = spec.n_kv // tp, True, 0
    else:
        assert tp % spec.n_kv == 0, (
            f"{spec.name}: kv={spec.n_kv} and tp={tp} must divide one another")
        kv_local, kv_sharded, groups_per_dev = 1, False, tp // spec.n_kv
    return AttnStatic(
        n_heads_local=spec.n_heads // tp,
        n_kv_local=kv_local,
        d_head=spec.d_head,
        kv_sharded=kv_sharded,
        kv_groups_per_device=groups_per_dev,
        qk_norm=spec.qk_norm,
        rope_2d=spec.rope_2d,
        causal=causal,
    )


def moe_static(spec: spec_lib.ModelSpec, tp: int, tokens_per_mb: int,
               capacity_factor: float = 1.25) -> MoEStatic:
    m = spec.moe
    assert m.n_experts % tp == 0, (spec.name, m.n_experts, tp)
    cap = int(np.ceil(tokens_per_mb * m.top_k / m.n_experts * capacity_factor))
    cap = max(cap, 4)
    return MoEStatic(n_experts=m.n_experts, n_local=m.n_experts // tp,
                     top_k=m.top_k, capacity=cap, n_shared=m.n_shared)


def mamba_static(spec: spec_lib.ModelSpec, tp: int) -> MambaStatic:
    ms = spec.mamba
    d_inner = ms.expand * spec.d_model
    assert d_inner % tp == 0
    dt_rank = ms.dt_rank or -(-spec.d_model // 16)
    return MambaStatic(d_inner_local=d_inner // tp, d_state=ms.d_state,
                       d_conv=ms.d_conv, dt_rank=dt_rank)


def rwkv_static(spec: spec_lib.ModelSpec, tp: int) -> RWKVStatic:
    rs = spec.rwkv
    n_heads = spec.d_model // rs.head_dim
    assert n_heads % tp == 0
    return RWKVStatic(n_heads_local=n_heads // tp, d_head=rs.head_dim)


# --------------------------------------------------------------------------
# Initializers (return (arrays, pspecs) leaf-by-leaf)
# --------------------------------------------------------------------------

def _norm_init(pp, d, kind, key, dtype):
    p = {"scale": jnp.ones((pp, d), dtype)}
    s = {"scale": P("stage", None)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((pp, d), dtype)
        s["bias"] = P("stage", None)
    return p, s


def _dense(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _attn_init(spec, pp, tp, key, dtype, *, cross=False, out_scale=0.02):
    d, h, kv, dh = spec.d_model, spec.n_heads, spec.n_kv, spec.d_head
    keys = jax.random.split(key, 8)
    kv_spec = P("stage", None, "tensor", None) if kv % tp == 0 else P("stage", None, None, None)
    p = {
        "wq": _dense(keys[0], (pp, d, h, dh), dtype),
        "wk": _dense(keys[1], (pp, d, kv, dh), dtype),
        "wv": _dense(keys[2], (pp, d, kv, dh), dtype),
        "wo": _dense(keys[3], (pp, h * dh, d), dtype, out_scale),
    }
    s = {
        "wq": P("stage", None, "tensor", None),
        "wk": kv_spec,
        "wv": kv_spec,
        "wo": P("stage", "tensor", None),
    }
    if spec.qk_norm and not cross:
        p["q_norm"] = jnp.ones((pp, dh), dtype)
        p["k_norm"] = jnp.ones((pp, dh), dtype)
        s["q_norm"] = s["k_norm"] = P("stage", None)
    return p, s


def _mlp_init(spec, pp, tp, key, dtype, d_ff=None, out_scale=0.02):
    d = spec.d_model
    ff = d_ff or spec.d_ff
    keys = jax.random.split(key, 3)
    p = {"w1": _dense(keys[0], (pp, d, ff), dtype),
         "w2": _dense(keys[1], (pp, ff, d), dtype, out_scale)}
    s = {"w1": P("stage", None, "tensor"), "w2": P("stage", "tensor", None)}
    if spec.act == "silu":
        p["w3"] = _dense(keys[2], (pp, d, ff), dtype)
        s["w3"] = P("stage", None, "tensor")
    return p, s


def _moe_init(spec, pp, tp, key, dtype, out_scale=0.02):
    d, m = spec.d_model, spec.moe
    keys = jax.random.split(key, 5)
    p = {
        "router": _dense(keys[0], (pp, d, m.n_experts), dtype),
        "w1": _dense(keys[1], (pp, m.n_experts, d, m.d_expert), dtype),
        "w2": _dense(keys[2], (pp, m.n_experts, m.d_expert, d), dtype, out_scale),
        "w3": _dense(keys[3], (pp, m.n_experts, d, m.d_expert), dtype),
    }
    s = {
        "router": P("stage", None, None),
        "w1": P("stage", "tensor", None, None),
        "w2": P("stage", "tensor", None, None),
        "w3": P("stage", "tensor", None, None),
    }
    if m.n_shared:
        sp, ss = _mlp_init(spec, pp, tp, keys[4], dtype,
                           d_ff=m.n_shared * m.d_shared, out_scale=out_scale)
        p["shared"], s["shared"] = sp, ss
    return p, s


def _mamba_init(spec, pp, tp, key, dtype, out_scale=0.02):
    d = spec.d_model
    ms = spec.mamba
    ci = ms.expand * d
    dt_rank = ms.dt_rank or -(-d // 16)
    keys = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, ms.d_state + 1, dtype=jnp.float32), (pp, ci, 1))
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(keys[6], (pp, ci), jnp.float32,
                                   np.log(1e-3), np.log(1e-1)))))
    p = {
        "in_x": _dense(keys[0], (pp, d, ci), dtype),
        "in_z": _dense(keys[1], (pp, d, ci), dtype),
        "conv_w": _dense(keys[2], (pp, ci, ms.d_conv), dtype, 0.1),
        "x_proj": _dense(keys[3], (pp, ci, dt_rank + 2 * ms.d_state), dtype),
        "dt_proj": _dense(keys[4], (pp, dt_rank, ci), dtype, dt_rank ** -0.5),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((pp, ci), jnp.float32),
        "out_proj": _dense(keys[5], (pp, ci, d), dtype, out_scale),
    }
    s = {
        "in_x": P("stage", None, "tensor"),
        "in_z": P("stage", None, "tensor"),
        "conv_w": P("stage", "tensor", None),
        "x_proj": P("stage", "tensor", None),
        "dt_proj": P("stage", None, "tensor"),
        "dt_bias": P("stage", "tensor"),
        "A_log": P("stage", "tensor", None),
        "D": P("stage", "tensor"),
        "out_proj": P("stage", "tensor", None),
    }
    return p, s


def _rwkv_tmix_init(spec, pp, tp, key, dtype, out_scale=0.02):
    d = spec.d_model
    rs = spec.rwkv
    keys = jax.random.split(key, 12)
    maa = lambda k: 0.5 * jnp.ones((pp, d), dtype)
    p = {
        "maa_x": maa(0), "maa_w": maa(0), "maa_k": maa(0),
        "maa_v": maa(0), "maa_r": maa(0), "maa_g": maa(0),
        "tmix_w1": _dense(keys[0], (pp, d, 5 * rs.tmix_lora), dtype, 0.01),
        "tmix_w2": _dense(keys[1], (pp, 5, rs.tmix_lora, d), dtype, 0.01),
        "wr": _dense(keys[2], (pp, d, d), dtype),
        "wk": _dense(keys[3], (pp, d, d), dtype),
        "wv": _dense(keys[4], (pp, d, d), dtype),
        "wg": _dense(keys[5], (pp, d, d), dtype),
        "wo": _dense(keys[6], (pp, d, d), dtype, out_scale),
        "w0": (-3.9 + 0.2 * jax.random.normal(keys[7], (pp, d), jnp.float32)
               ).astype(jnp.float32),
        "decay_w1": _dense(keys[8], (pp, d, rs.decay_lora), dtype, 0.01),
        "decay_w2": _dense(keys[9], (pp, rs.decay_lora, d), dtype, 0.01),
        "u": _dense(keys[10], (pp, d), dtype),
        "gn_scale": jnp.ones((pp, d), dtype),
        "gn_bias": jnp.zeros((pp, d), dtype),
    }
    rep = P("stage", None)
    ten = P("stage", "tensor")
    s = {
        "maa_x": rep, "maa_w": rep, "maa_k": rep, "maa_v": rep,
        "maa_r": rep, "maa_g": rep,
        "tmix_w1": P("stage", None, None),
        "tmix_w2": P("stage", None, None, None),
        "wr": P("stage", None, "tensor"),
        "wk": P("stage", None, "tensor"),
        "wv": P("stage", None, "tensor"),
        "wg": P("stage", None, "tensor"),
        "wo": P("stage", "tensor", None),
        "w0": ten,
        "decay_w1": P("stage", None, None),
        "decay_w2": P("stage", None, "tensor"),
        "u": ten,
        "gn_scale": ten,
        "gn_bias": ten,
    }
    return p, s


def _rwkv_cmix_init(spec, pp, tp, key, dtype, out_scale=0.02):
    d = spec.d_model
    ffc = spec.d_ff
    keys = jax.random.split(key, 3)
    p = {
        "maa_k": 0.5 * jnp.ones((pp, d), dtype),
        "maa_r": 0.5 * jnp.ones((pp, d), dtype),
        "wk": _dense(keys[0], (pp, d, ffc), dtype),
        "wv": _dense(keys[1], (pp, ffc, d), dtype, out_scale),
        "wr_gate": _dense(keys[2], (pp, d, d), dtype),
    }
    s = {
        "maa_k": P("stage", None), "maa_r": P("stage", None),
        "wk": P("stage", None, "tensor"),
        "wv": P("stage", "tensor", None),
        "wr_gate": P("stage", None, None),
    }
    return p, s


# --------------------------------------------------------------------------
# Whole-model init
# --------------------------------------------------------------------------

def init_params(spec: spec_lib.ModelSpec, plan: ParallelismPlan, key,
                dtype=jnp.bfloat16):
    """Returns (params, pspecs). Usable under jax.eval_shape."""
    pp, tp = plan.pp, plan.tp
    lps = spec.layers_per_stage(pp)
    program = spec.stage_program(pp)
    out_scale = 0.02 / np.sqrt(2 * spec.n_layers)

    params: Dict = {}
    pspecs: Dict = {}
    vpad = padded_vocab(spec.vocab)

    key_e, key_h, key_s, key_enc = jax.random.split(key, 4)
    params["embed"] = _dense(key_e, (vpad, spec.d_model), dtype, 1.0)
    pspecs["embed"] = P(None, ("stage", "tensor"))
    params["head"] = _dense(key_h, (spec.d_model, vpad), dtype)
    pspecs["head"] = P(None, ("stage", "tensor"))
    params["final_norm"] = {"scale": jnp.ones((spec.d_model,), dtype)}
    pspecs["final_norm"] = {"scale": P(None)}
    if spec.norm == "layernorm":
        params["final_norm"]["bias"] = jnp.zeros((spec.d_model,), dtype)
        pspecs["final_norm"]["bias"] = P(None)

    stages_p: Dict = {}
    stages_s: Dict = {}
    for i, blk in enumerate(program):
        kp = jax.random.fold_in(key_s, i)
        lp: Dict = {}
        ls: Dict = {}
        if blk.mixer != "none":
            lp["norm1"], ls["norm1"] = _norm_init(pp, spec.d_model, spec.norm, kp, dtype)
        if blk.mixer == "attn":
            lp["attn"], ls["attn"] = _attn_init(
                spec, pp, tp, jax.random.fold_in(kp, 1), dtype, out_scale=out_scale)
            if blk.cross_attn:
                lp["xattn"], ls["xattn"] = _attn_init(
                    spec, pp, tp, jax.random.fold_in(kp, 2), dtype,
                    cross=True, out_scale=out_scale)
                lp["norm_x"], ls["norm_x"] = _norm_init(
                    pp, spec.d_model, spec.norm, kp, dtype)
        elif blk.mixer == "mamba":
            lp["mamba"], ls["mamba"] = _mamba_init(
                spec, pp, tp, jax.random.fold_in(kp, 3), dtype, out_scale)
        elif blk.mixer == "rwkv":
            lp["tmix"], ls["tmix"] = _rwkv_tmix_init(
                spec, pp, tp, jax.random.fold_in(kp, 4), dtype, out_scale)
        if blk.ffn != "none":
            lp["norm2"], ls["norm2"] = _norm_init(pp, spec.d_model, spec.norm, kp, dtype)
        if blk.ffn == "dense":
            lp["mlp"], ls["mlp"] = _mlp_init(
                spec, pp, tp, jax.random.fold_in(kp, 5), dtype, out_scale=out_scale)
        elif blk.ffn == "moe":
            lp["moe"], ls["moe"] = _moe_init(
                spec, pp, tp, jax.random.fold_in(kp, 6), dtype, out_scale)
        elif blk.ffn == "rwkv_cmix":
            lp["cmix"], ls["cmix"] = _rwkv_cmix_init(
                spec, pp, tp, jax.random.fold_in(kp, 7), dtype, out_scale)
        stages_p[f"layer_{i}"] = lp
        stages_s[f"layer_{i}"] = ls
    params["stages"] = stages_p
    pspecs["stages"] = stages_s

    # Per-(stage, position) traced scalars
    windows, thetas = spec_lib.stage_varying_scalars(spec, pp)
    params["layer_windows"] = jnp.asarray(windows, jnp.int32)       # [pp, lps]
    params["layer_thetas"] = jnp.asarray(thetas, jnp.float32)
    pspecs["layer_windows"] = P("stage", None)
    pspecs["layer_thetas"] = P("stage", None)

    if spec.encoder is not None:
        params["encoder"], pspecs["encoder"] = _encoder_init(
            spec, tp, key_enc, dtype)
    return params, pspecs


def _encoder_init(spec, tp, key, dtype):
    e = spec.encoder
    n = e.n_layers
    keys = jax.random.split(key, 8)
    dh = e.d_model // e.n_heads
    p = {
        "wq": _dense(keys[0], (n, e.d_model, e.n_heads, dh), dtype),
        "wk": _dense(keys[1], (n, e.d_model, e.n_heads, dh), dtype),
        "wv": _dense(keys[2], (n, e.d_model, e.n_heads, dh), dtype),
        "wo": _dense(keys[3], (n, e.n_heads * dh, e.d_model), dtype),
        "w1": _dense(keys[4], (n, e.d_model, e.d_ff), dtype),
        "w2": _dense(keys[5], (n, e.d_ff, e.d_model), dtype),
        "norm1": jnp.ones((n, e.d_model), dtype),
        "norm2": jnp.ones((n, e.d_model), dtype),
        "final_norm": jnp.ones((e.d_model,), dtype),
        "pos": _dense(keys[6], (e.source_len, e.d_model), dtype),
    }
    s = {
        "wq": P(None, None, "tensor", None),
        "wk": P(None, None, "tensor", None),
        "wv": P(None, None, "tensor", None),
        "wo": P(None, "tensor", None),
        "w1": P(None, None, "tensor"),
        "w2": P(None, "tensor", None),
        "norm1": P(None, None),
        "norm2": P(None, None),
        "final_norm": P(None),
        "pos": P(None, None),
    }
    return p, s
