"""Embedding lookup and vocab-sharded head/loss — the pipeline-external ops.

Embedding is sharded on d_model (gathers stay local); the head is sharded
on vocab (logits never materialize unsharded).  These run at pjit level
*outside* the stage shard_map: the head+loss runs once per pipeline tick on
the microbatch exiting the output stage (see core/pipeline.py), which keeps
every collective SPMD-uniform and avoids replicating head FLOPs per stage.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import is_quantized, maybe_dequant


def embed_tokens(embed, tokens, dtype=None):
    """embed: (Vpad, d) sharded on d; tokens: (..., S) int32 -> (..., S, d).

    Quantized embeds gather the int8/fp8 rows AND their per-row scales,
    multiplying only the gathered slice — the full-precision table never
    materializes.  ``dtype`` pins the output (serving passes its compute
    dtype so an f32 dequant cannot promote the bf16 activation rings).
    """
    if is_quantized(embed):
        rows = jnp.take(embed["q"], tokens, axis=0).astype(jnp.float32)
        scales = jnp.take(embed["scale"], tokens, axis=0)
        out = rows * scales
    else:
        out = jnp.take(embed, tokens, axis=0)
    return out if dtype is None else out.astype(dtype)


def head_loss(head, final_norm_scale, h, labels, *, norm_kind: str = "rmsnorm",
              norm_bias=None, valid_mask=None, vocab: Optional[int] = None):
    """Cross-entropy over the vocab-sharded head.

    h: (B, S, d) hidden exiting the pipeline; labels: (B, S) int32.
    Returns (mean_loss, n_tokens).  Padded vocab ids are masked out.
    """
    from repro.models import nn  # local import to avoid cycles

    if norm_kind == "rmsnorm":
        h = nn.rmsnorm(h, final_norm_scale)
    else:
        h = nn.layernorm(h, final_norm_scale, norm_bias)
    logits = (h @ maybe_dequant(head, h.dtype)).astype(jnp.float32)
    # (B, S, Vpad) sharded on vocab
    if vocab is not None and vocab < logits.shape[-1]:
        pad = logits.shape[-1] - vocab
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate([jnp.zeros((vocab,)), neg])
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if valid_mask is None:
        valid_mask = jnp.ones(labels.shape, jnp.float32)
    n = jnp.maximum(valid_mask.sum(), 1.0)
    return (nll * valid_mask).sum() / n, n


def head_loss_and_grad(head, final_norm_scale, h, labels, **kw):
    """Returns (loss, dh, dhead, dnorm_scale) — feeds the output stage's B."""
    def f(head_, scale_, h_):
        loss, _ = head_loss(head_, scale_, h_, labels, **kw)
        return loss

    loss, grads = jax.value_and_grad(f, argnums=(0, 1, 2))(
        head, final_norm_scale, h)
    dhead, dscale, dh = grads
    return loss, dh, dhead, dscale


def embed_bwd(embed_shape_like, tokens, d_embeds):
    """Accumulate d(embedding) from d(embeds) via scatter-add on vocab.

    tokens: (..., S); d_embeds: (..., S, d).  Output matches embed sharding
    (scatter on vocab dim is local when embed is sharded on d).
    """
    flat_tok = tokens.reshape(-1)
    flat_d = d_embeds.reshape(-1, d_embeds.shape[-1])
    return jnp.zeros(embed_shape_like.shape, flat_d.dtype).at[flat_tok].add(flat_d)


def sample_greedy(head, final_norm_scale, h, *, norm_kind: str = "rmsnorm",
                  norm_bias=None, vocab: Optional[int] = None):
    """Greedy next-token ids from the last position. h: (B, 1, d)."""
    from repro.models import nn

    if norm_kind == "rmsnorm":
        h = nn.rmsnorm(h, final_norm_scale)
    else:
        h = nn.layernorm(h, final_norm_scale, norm_bias)
    logits = (h[:, -1] @ maybe_dequant(head, h.dtype)).astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        pad = logits.shape[-1] - vocab
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate([jnp.zeros((vocab,)), neg])
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def greedy_tokens(head, final_norm_scale, h, *, norm_kind: str = "rmsnorm",
                  norm_bias=None, vocab: Optional[int] = None):
    """Greedy token ids at EVERY position. h: (B, S, d) -> (B, S) int32.

    The verify half of speculative decode: one pipelined pass scores a
    slot's spec_k + 1 positions at once, and position j's argmax is the
    model's next token after the prefix ending at j — identical to what
    :func:`sample_greedy` would emit one position at a time, which is
    what makes draft rejection bit-exact.  Padded vocab ids are masked
    with the same -1e30 additive mask as the loss path.
    """
    from repro.models import nn

    if norm_kind == "rmsnorm":
        h = nn.rmsnorm(h, final_norm_scale)
    else:
        h = nn.layernorm(h, final_norm_scale, norm_bias)
    logits = (h @ maybe_dequant(head, h.dtype)).astype(jnp.float32)
    # (B, S, Vpad)
    if vocab is not None and vocab < logits.shape[-1]:
        pad = logits.shape[-1] - vocab
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate([jnp.zeros((vocab,)), neg])
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
