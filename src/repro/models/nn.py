"""Layer zoo, written shard_map-native.

Every function takes the *local* parameter shard (what one device sees
inside the pipeline shard_map) plus an optional ``tp_axis`` naming the
tensor-parallel mesh axis; collectives no-op when ``tp_axis is None`` so the
same code runs single-device in smoke tests and in the reference pipeline.

Per-layer scalars that vary across stages (attention window, rope theta)
arrive as traced scalars so all stages execute one SPMD program.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.parallel.mesh import maybe_axis_index, maybe_psum
from repro.quant import maybe_dequant, quantize_kv_page_batched

# Sequence-length product above which attention switches to the blockwise
# (flash-style) jnp implementation to keep activation memory O(S * block).
# 4M ⇒ every ≥2k×2k attention goes blockwise (train_4k's 4k×4k included —
# the naive path would materialize (mb, h, 4k, 4k) f32 score tensors).
_FLASH_THRESHOLD = 4 * 1024 * 1024
_FLASH_BLOCK = 1024


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    # NOTE (§Perf iteration Q5, refuted): a bf16 normalize-multiply
    # (x * rsqrt(var).astype(x.dtype)) measured WORSE (qwen3 M
    # 18.9 → 32.2 s) — the f32 chain below fuses into its consumer,
    # the split form does not.  Keep the fused f32 form.
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x, scale, bias, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    out = (h - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * scale + bias


def apply_norm(p, x, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def groupnorm_heads(x, scale, bias, eps: float = 1e-5):
    """GroupNorm over the head dim of (B, S, H, Dh) -> normalized per head."""
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    out = (h - mu) * jax.lax.rsqrt(var + eps)
    b, s, nh, dh = x.shape
    out = out.reshape(b, s, nh * dh).astype(x.dtype)
    return out * scale + bias


# --------------------------------------------------------------------------
# Rotary embeddings (standard neox rotate-half; chatglm "2d" = half-rotary)
# --------------------------------------------------------------------------

def rope_frequencies(d_rot: int, theta):
    exponent = jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot
    return 1.0 / (theta ** exponent)  # (d_rot/2,)


def apply_rope(q, k, positions, theta, *, rope_2d: bool = False):
    """q: (B,S,H,Dh), k: (B,S,KV,Dh), positions: (B,S) int32, theta traced."""
    dh = q.shape[-1]
    d_rot = dh // 2 if rope_2d else dh
    inv = rope_frequencies(d_rot, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B,S,d_rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]

    def rot(x):
        rx, keep = x[..., :d_rot], x[..., d_rot:]
        x1, x2 = rx[..., : d_rot // 2], rx[..., d_rot // 2:]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
        return jnp.concatenate([out, keep], axis=-1) if rope_2d else out

    return rot(q), rot(k)


# --------------------------------------------------------------------------
# Attention (GQA + qk-norm + sliding window + KV cache + cross-attention)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnStatic:
    """Static (compile-time) attention configuration for one device."""

    n_heads_local: int
    n_kv_local: int            # local kv heads after sharding (>=1)
    d_head: int
    kv_sharded: bool           # False -> kv weights replicated; slice by rank
    kv_groups_per_device: int  # only used when not kv_sharded
    qk_norm: bool
    rope_2d: bool
    causal: bool = True


def _project_kv(p, x, st: AttnStatic, tp_axis):
    """Project K/V, handling replicated-kv slicing when kv < tp."""
    wk = maybe_dequant(p["wk"], x.dtype)
    wv = maybe_dequant(p["wv"], x.dtype)
    if not st.kv_sharded:
        rank = maybe_axis_index(tp_axis)
        grp = rank // st.kv_groups_per_device if st.kv_groups_per_device else 0
        wk = jax.lax.dynamic_slice_in_dim(wk, grp * st.n_kv_local, st.n_kv_local, 1)
        wv = jax.lax.dynamic_slice_in_dim(wv, grp * st.n_kv_local, st.n_kv_local, 1)
    k = jnp.einsum("bsd,dkh->bskh", x, wk)
    v = jnp.einsum("bsd,dkh->bskh", x, wv)
    return k, v


_INVALID_POS = -(10 ** 9)  # sentinel for padded / not-yet-written KV slots


def _attn_mask(q_pos, k_pos, window, causal: bool):
    """(Q, K) bool mask from traced positions + traced window (<=0: global)."""
    dq = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones(dq.shape, bool) if not causal else (dq >= 0)
    m = m & ((window <= 0) | (dq < jnp.maximum(window, 1)))
    m = m & (k_pos > _INVALID_POS // 2)[None, :]
    return m


def _sdpa_naive(q, k, v, mask):
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[:, None] if mask.ndim == 3 else mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def _sdpa_flash_jnp(q, k, v, q_pos, k_pos, window, causal, block: int = _FLASH_BLOCK):
    """Blockwise (flash) attention in pure jnp: O(S*block) memory.

    Scans over KV blocks carrying running (max, sum, acc) — the TPU Pallas
    kernel in repro.kernels.flash_attention is the hardware version of this
    loop; this is the XLA-lowerable twin used inside jit'd training graphs.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nblk = -(-sk // block)
    pad = nblk * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=_INVALID_POS)
    scale = 1.0 / np.sqrt(dh)
    kb = k.reshape(b, nblk, block, -1, dh)
    vb = v.reshape(b, nblk, block, -1, dh)
    kpb = k_pos.reshape(nblk, block)

    def step(carry, inp):
        m_run, l_run, acc = carry
        kblk, vblk, kp = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk).astype(jnp.float32) * scale
        mask = _attn_mask(q_pos, kp, window, causal)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, h, sq), -jnp.inf, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, dh), jnp.float32),
    )
    # checkpoint the block step: backward recomputes the (sq, block)
    # score/probability tile from (q, k-block) instead of storing an
    # O(S²) f32 residual — the jnp twin of what the Pallas kernel's
    # VMEM-resident tile achieves structurally.
    (m_run, l_run, acc), _ = jax.lax.scan(
        jax.checkpoint(step), init,
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpb))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)  # (B, S, H, Dh)


def _sdpa_decode_seq_sharded(q, k, v, q_pos, k_pos, window, seq_axis):
    """Decode attention over a sequence-sharded KV cache (SP decode).

    Each device holds a KV shard; partial softmax statistics combine with
    pmax/psum over ``seq_axis``.  q: (B, 1, H, Dh); k/v: local shards.
    """
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = _attn_mask(q_pos, k_pos, window, causal=True)
    s = jnp.where(mask[None, None], s, -1e30)
    m_loc = jnp.max(s, axis=-1)
    m_glob = jax.lax.pmax(m_loc, seq_axis)
    p = jnp.exp(s - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v
                     ).astype(jnp.float32)
    l_glob = jax.lax.psum(l_loc, seq_axis)
    acc = jax.lax.psum(acc, seq_axis)
    out = acc / jnp.maximum(l_glob, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)


def attention(
    p,
    x,
    st: AttnStatic,
    *,
    positions,                 # (B, S) int32 query positions
    window,                    # traced scalar; <=0 means global
    theta,                     # traced rope theta
    tp_axis: Optional[str],
    kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_pos=None,            # scalar write offset into the cache
    cross_x=None,              # encoder output for cross attention
    seq_axis: Optional[str] = None,  # cache sharded over this axis (SP)
    paged_kv=None,        # (pools, table_row, write_gate, tokenwise);
                          # pools = (k, v) or int8 (k, v, k_scale, v_scale)
):
    """Returns (out, new_kv_cache). x: (B, S, d_local-replicated)."""
    b, s, _ = x.shape
    wo = maybe_dequant(p["wo"], x.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, maybe_dequant(p["wq"], x.dtype))
    kv_src = cross_x if cross_x is not None else x
    k, v = _project_kv(p, kv_src, st, tp_axis)

    if st.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])

    if cross_x is None:
        k_positions_new = positions[0] if positions.ndim == 2 else positions
        q, k = apply_rope(q, k, positions, theta, rope_2d=st.rope_2d)
    else:
        k_positions_new = jnp.arange(kv_src.shape[1])

    new_cache = None
    if kv_cache is not None and seq_axis is not None:
        # SP decode: cache sharded over seq_axis; writes land on the owner
        # shard via scatter-drop, reads combine partial softmax stats.
        assert s == 1, "sequence-sharded cache supports decode (S=1) only"
        ck, cv = kv_cache                       # (B, L_local, KV, Dh)
        l_local = ck.shape[1]
        off = jax.lax.axis_index(seq_axis) * l_local
        idx = cache_pos - off                   # out-of-range writes drop
        ck = ck.at[:, idx].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[:, idx].set(v[:, 0].astype(cv.dtype), mode="drop")
        new_cache = (ck, cv)
        k_pos = off + jnp.arange(l_local)
        k_pos = jnp.where(k_pos < cache_pos + 1, k_pos, _INVALID_POS)
        groups = st.n_heads_local // ck.shape[2]
        kk = jnp.repeat(ck, groups, axis=2)
        vv = jnp.repeat(cv, groups, axis=2)
        q_pos = positions[0] if positions.ndim == 2 else positions
        out = _sdpa_decode_seq_sharded(q, kk, vv, q_pos, k_pos, window,
                                       seq_axis)
        out = out.reshape(b, s, st.n_heads_local * st.d_head)
        out = jnp.einsum("bsk,kd->bsd", out, wo)
        return maybe_psum(out, tp_axis), new_cache

    if paged_kv is not None:
        # Block-paged KV cache (serving decode/prefill). The pools are
        # global across slots — (n_pool, B, page, KV, Dh) — and ``row``
        # is this slot's page table (-1 = unallocated). Writes are gated
        # by ``gate`` (slot validity) AND page liveness; reads gather the
        # table into a dense (B, n_pages*page, KV, Dh) view whose extent
        # and k_pos mask match the dense ring path exactly, so fp32
        # outputs are bit-identical to the dense cache (masked entries
        # contribute exact zeros to the softmax).
        assert kv_cache is None and cross_x is None and seq_axis is None
        pools, row, gate = paged_kv[0], paged_kv[1], paged_kv[2]
        # token-wise writes: decode always; s > 1 only when the caller
        # says so (speculative verify) — prefill keeps the aligned slab.
        tokenwise = (s == 1) or (len(paged_kv) > 3 and bool(paged_kv[3]))
        kq = len(pools) == 4      # int8 pools carry per-page scale planes
        if kq:
            k_pool, v_pool, ks_pool, vs_pool = pools
        else:
            (k_pool, v_pool), ks_pool, vs_pool = pools, None, None
        n_pool, _, ps, n_kv, dh = k_pool.shape
        npg = row.shape[0]
        L = npg * ps
        q_pos = positions[0] if positions.ndim == 2 else positions

        def _write_page(pool, new, pi, width):
            # new: (B, width, KV, Dh) slab chunk for table entry ``pi``.
            pid = jax.lax.dynamic_index_in_dim(row, pi, keepdims=False)
            ok = gate & (pid >= 0)
            pid_safe = jnp.clip(pid, 0, n_pool - 1)
            cur = jax.lax.dynamic_slice(
                pool, (pid_safe, 0, 0, 0, 0), (1, b, ps, n_kv, dh))
            upd = cur.at[0, :, :width].set(new.astype(pool.dtype))
            upd = jnp.where(ok, upd, cur)
            return jax.lax.dynamic_update_slice(
                pool, upd, (pid_safe, 0, 0, 0, 0))

        def _write_page_q(pool, spool, new, pi, width):
            # int8 prefill write: quantize a freshly built zero-padded
            # page (one scale per kv head per page).  Zeroing the tail
            # past ``width`` is safe — decode appends token-wise later,
            # requantizing the whole page.
            pid = jax.lax.dynamic_index_in_dim(row, pi, keepdims=False)
            ok = gate & (pid >= 0)
            pid_safe = jnp.clip(pid, 0, n_pool - 1)
            page = jnp.zeros((b, ps, n_kv, dh), jnp.float32)
            page = page.at[:, :width].set(new.astype(jnp.float32))
            qpage, scale = quantize_kv_page_batched(page)
            cur = jax.lax.dynamic_slice(
                pool, (pid_safe, 0, 0, 0, 0), (1, b, ps, n_kv, dh))
            cur_s = jax.lax.dynamic_slice(
                spool, (pid_safe, 0, 0), (1, b, n_kv))
            pool = jax.lax.dynamic_update_slice(
                pool, jnp.where(ok, qpage[None], cur),
                (pid_safe, 0, 0, 0, 0))
            spool = jax.lax.dynamic_update_slice(
                spool, jnp.where(ok, scale[None], cur_s),
                (pid_safe, 0, 0))
            return pool, spool

        if tokenwise:
            # decode / verify: key t lands at offset (cache_pos + t) % ps
            # inside the slot's page (cache_pos + t) // ps.  Token-wise
            # (static unroll over s, a compile-time constant: 1 for
            # decode, spec_k + 1 for verify) because a verify round
            # starts at an arbitrary mid-page position — the aligned
            # slab write below would clobber the page's earlier tokens.

            def _write_tok(pool, new, t):
                posn = cache_pos + t
                pi = posn // ps
                off = posn % ps
                pid = jax.lax.dynamic_index_in_dim(row, pi, keepdims=False)
                ok = gate & (pid >= 0)
                pid_safe = jnp.clip(pid, 0, n_pool - 1)
                cur = jax.lax.dynamic_slice(
                    pool, (pid_safe, 0, off, 0, 0), (1, b, 1, n_kv, dh))
                upd = jnp.where(ok, new[None, :, None].astype(pool.dtype),
                                cur)
                return jax.lax.dynamic_update_slice(
                    pool, upd, (pid_safe, 0, off, 0, 0))

            def _write_tok_q(pool, spool, new, t):
                posn = cache_pos + t
                pi = posn // ps
                off = posn % ps
                pid = jax.lax.dynamic_index_in_dim(row, pi, keepdims=False)
                ok = gate & (pid >= 0)
                pid_safe = jnp.clip(pid, 0, n_pool - 1)
                cur = jax.lax.dynamic_slice(
                    pool, (pid_safe, 0, 0, 0, 0), (1, b, ps, n_kv, dh))
                cur_s = jax.lax.dynamic_slice(
                    spool, (pid_safe, 0, 0), (1, b, n_kv))
                # dequantize the whole page, insert the token, requantize:
                # one scale per page stays valid under arbitrary new-token
                # magnitudes (requantization drift is bounded by the page
                # length and gated by the serving tolerance tests).
                page = (cur[0].astype(jnp.float32)
                        * cur_s[0][:, None, :, None])
                page = jax.lax.dynamic_update_slice(
                    page, new[:, None].astype(jnp.float32), (0, off, 0, 0))
                qpage, scale = quantize_kv_page_batched(page)
                pool = jax.lax.dynamic_update_slice(
                    pool, jnp.where(ok, qpage[None], cur),
                    (pid_safe, 0, 0, 0, 0))
                spool = jax.lax.dynamic_update_slice(
                    spool, jnp.where(ok, scale[None], cur_s),
                    (pid_safe, 0, 0))
                return pool, spool

            for t in range(s):
                if kq:
                    k_pool, ks_pool = _write_tok_q(k_pool, ks_pool,
                                                   k[:, t], t)
                    v_pool, vs_pool = _write_tok_q(v_pool, vs_pool,
                                                   v[:, t], t)
                else:
                    k_pool = _write_tok(k_pool, k[:, t], t)
                    v_pool = _write_tok(v_pool, v[:, t], t)
            if st.causal and kernel_ops.use_pallas():
                # Pallas paged kernel: flatten (page, lane) so every lane
                # gets its own table row (all lanes of a slot share page
                # ids and the slot's length); the s queries sit at
                # positions cache_pos .. cache_pos + s - 1.
                lane = jnp.arange(b, dtype=jnp.int32)
                tabs = jnp.where(row[None, :] >= 0,
                                 row[None, :] * b + lane[:, None], -1)
                lens_v = jnp.full((b,), cache_pos + s, jnp.int32)
                kp = k_pool.swapaxes(0, 1).reshape(n_pool * b, ps, n_kv, dh)
                vp = v_pool.swapaxes(0, 1).reshape(n_pool * b, ps, n_kv, dh)
                if kq:
                    ks = ks_pool.swapaxes(0, 1).reshape(n_pool * b, n_kv)
                    vs = vs_pool.swapaxes(0, 1).reshape(n_pool * b, n_kv)
                else:
                    ks = vs = None
                out = kernel_ops.paged_attention(q, kp, vp, tabs, lens_v,
                                                 window=window,
                                                 k_scale=ks, v_scale=vs)
                out = out.reshape(b, s, st.n_heads_local * st.d_head)
                out = jnp.einsum("bsk,kd->bsd", out, wo)
                new_cache = ((k_pool, v_pool, ks_pool, vs_pool) if kq
                             else (k_pool, v_pool))
                return maybe_psum(out, tp_axis), new_cache
        else:
            # prefill: write the fresh slab page-by-page (static unroll —
            # n_pages_slab is a compile-time constant). Unallocated pages
            # of ragged slots skip via the per-page gate.
            for ii in range(-(-s // ps)):
                lo = ii * ps
                width = min(ps, s - lo)
                pi = cache_pos // ps + ii
                if kq:
                    k_pool, ks_pool = _write_page_q(
                        k_pool, ks_pool, k[:, lo:lo + width], pi, width)
                    v_pool, vs_pool = _write_page_q(
                        v_pool, vs_pool, v[:, lo:lo + width], pi, width)
                else:
                    k_pool = _write_page(k_pool, k[:, lo:lo + width],
                                         pi, width)
                    v_pool = _write_page(v_pool, v[:, lo:lo + width],
                                         pi, width)

        # XLA twin read: gather the table into a dense slab and fall
        # through to the shared masked-softmax tail.
        safe = jnp.clip(row, 0, n_pool - 1)
        kk = jnp.take(k_pool, safe, axis=0)      # (npg, B, ps, KV, Dh)
        vv = jnp.take(v_pool, safe, axis=0)
        if kq:
            sk = jnp.take(ks_pool, safe, axis=0)   # (npg, B, KV)
            sv = jnp.take(vs_pool, safe, axis=0)
            kk = (kk.astype(jnp.float32)
                  * sk[:, :, None, :, None]).astype(q.dtype)
            vv = (vv.astype(jnp.float32)
                  * sv[:, :, None, :, None]).astype(q.dtype)
        k = kk.transpose(1, 0, 2, 3, 4).reshape(b, L, n_kv, dh)
        v = vv.transpose(1, 0, 2, 3, 4).reshape(b, L, n_kv, dh)
        j_idx = jnp.arange(L)
        alive = jnp.repeat(row >= 0, ps)
        k_pos = jnp.where((j_idx < cache_pos + s) & alive, j_idx,
                          _INVALID_POS)
        new_cache = ((k_pool, v_pool, ks_pool, vs_pool) if kq
                     else (k_pool, v_pool))
    elif kv_cache is not None:
        ck, cv = kv_cache  # (B, L, KV, Dh)
        L = ck.shape[1]
        if s == 1:
            # decode: ring-buffer write. For full caches (L > pos always)
            # this reduces to an append; for windowed caches (L == window)
            # old positions are overwritten — sliding-window semantics.
            idx = cache_pos % L
            ck = jax.lax.dynamic_update_index_in_dim(
                ck, k[:, 0].astype(ck.dtype), idx, 1)
            cv = jax.lax.dynamic_update_index_in_dim(
                cv, v[:, 0].astype(cv.dtype), idx, 1)
            j = jnp.arange(L)
            # most recent position congruent to slot j that is <= cache_pos
            k_pos = cache_pos - ((cache_pos - j) % L)
            k_pos = jnp.where(k_pos >= 0, k_pos, _INVALID_POS)
        else:
            # prefill: contiguous slab write (cache must be full-length)
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), cache_pos, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), cache_pos, 1)
            k_pos = jnp.arange(L)
            k_pos = jnp.where(k_pos < cache_pos + s, k_pos, _INVALID_POS)
        new_cache = (ck, cv)
        k, v = ck, cv
    else:
        k_pos = k_positions_new

    causal = st.causal and cross_x is None
    if (kv_cache is None and paged_kv is None and cross_x is None
            and causal and kernel_ops.use_pallas()):
        # Pallas TPU flash kernel (kernels/flash_attention.py): GQA mapped
        # in the BlockSpec index map, window rides in SMEM.
        out = kernel_ops.flash_attention(q, k, v, causal=True,
                                         window=window)
        out = out.reshape(b, s, st.n_heads_local * st.d_head)
        out = jnp.einsum("bsk,kd->bsd", out, wo)
        return maybe_psum(out, tp_axis), None

    # GQA: broadcast kv heads to query heads
    groups = st.n_heads_local // k.shape[2]
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)

    q_pos = positions[0] if positions.ndim == 2 else positions
    if s * k.shape[1] <= _FLASH_THRESHOLD:
        mask = _attn_mask(q_pos, k_pos, window, causal)
        out = _sdpa_naive(q, k, v, mask[None, None])
    else:
        out = _sdpa_flash_jnp(q, k, v, q_pos, k_pos, window, causal)

    out = out.reshape(b, s, st.n_heads_local * st.d_head)
    out = jnp.einsum("bsk,kd->bsd", out, wo)
    return maybe_psum(out, tp_axis), new_cache


# --------------------------------------------------------------------------
# Dense FFN (SwiGLU / GELU), tensor-parallel column->row split
# --------------------------------------------------------------------------

def mlp(p, x, act: str, tp_axis: Optional[str]):
    w1 = maybe_dequant(p["w1"], x.dtype)
    w2 = maybe_dequant(p["w2"], x.dtype)
    if act == "silu":
        h = jax.nn.silu(x @ w1) * (x @ maybe_dequant(p["w3"], x.dtype))
    else:
        h = jax.nn.gelu(x @ w1)
    return maybe_psum(h @ w2, tp_axis)


# --------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch, experts over tensor)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEStatic:
    n_experts: int
    n_local: int               # experts on this device
    top_k: int
    capacity: int              # per-expert token slots
    n_shared: int


def moe_dispatch_indices(gate_idx, n_experts: int, capacity: int):
    """Sort-based dispatch: (N*K,) expert ids -> slot assignment.

    Returns (slot_id, keep) where slot_id = expert*capacity + position and
    keep masks tokens dropped past capacity.  Pure jnp; XLA lowers the sort.
    """
    nk = gate_idx.shape[0]
    order = jnp.argsort(gate_idx, stable=True)
    sorted_e = gate_idx[order]
    counts = jnp.bincount(gate_idx, length=n_experts)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(nk) - starts[sorted_e]
    keep_sorted = pos_in_e < capacity
    slot_sorted = sorted_e * capacity + jnp.minimum(pos_in_e, capacity - 1)
    inv = jnp.argsort(order, stable=True)
    return slot_sorted[inv], keep_sorted[inv]


def moe(p, x, ms: MoEStatic, act: str, tp_axis: Optional[str]):
    """x: (B, S, d) replicated over tensor; experts sharded over tensor.

    Compute per device = n_local * capacity * expert FLOPs (true top-k cost,
    not dense-dispatch).  Returns (out, aux_loss).
    """
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, ms.top_k)            # (N, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], ms.n_experts, dtype=jnp.float32), axis=0)
    aux = ms.n_experts * jnp.sum(me * ce)

    flat_e = top_i.reshape(-1)
    slot, keep = moe_dispatch_indices(flat_e, ms.n_experts, ms.capacity)
    token_of = jnp.repeat(jnp.arange(n), ms.top_k)

    buf = jnp.zeros((ms.n_experts * ms.capacity, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[token_of], 0))
    buf = buf.reshape(ms.n_experts, ms.capacity, d)

    # Each device computes only its expert shard.
    rank = maybe_axis_index(tp_axis)
    local = jax.lax.dynamic_slice_in_dim(buf, rank * ms.n_local, ms.n_local, 0)
    mw1 = maybe_dequant(p["w1"], x.dtype)
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", local, mw1)) * \
            jnp.einsum("ecd,edf->ecf", local,
                       maybe_dequant(p["w3"], x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", local, mw1))
    y_local = jnp.einsum("ecf,efd->ecd", h,
                         maybe_dequant(p["w2"], x.dtype))

    # EP combine: all-gather the per-device expert outputs over the
    # tensor axis (rank order == expert order).  Half the wire bytes of
    # the zero-padded full-buffer all-reduce this replaces, and no
    # wasted adds of zero slots (§Perf iteration D1).
    if tp_axis is None:
        y = y_local.reshape(ms.n_experts * ms.capacity, d)
    else:
        y = jax.lax.all_gather(y_local, tp_axis, axis=0, tiled=True)
        y = y.reshape(ms.n_experts * ms.capacity, d)

    gathered = y[slot] * jnp.where(keep, top_p.reshape(-1), 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[token_of].add(gathered)
    out = out.reshape(b, s, d)

    if ms.n_shared:
        out = out + mlp(p["shared"], x, act, tp_axis)
    return out, aux


# --------------------------------------------------------------------------
# Mamba (selective state space; jamba's mixer), channel-sharded TP
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MambaStatic:
    d_inner_local: int
    d_state: int
    d_conv: int
    dt_rank: int
    chunk: int = 256


def _causal_conv1d(x, w):
    """Depthwise causal conv via shifts; x: (B,S,C), w: (C,K)."""
    k = w.shape[-1]
    out = x * w[:, -1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[:, -1 - i]
    return out


def selective_scan(u, dt, A, B, C, D, *, chunk: int, h0=None):
    """Chunked selective scan. u,dt: (B,S,Ci); A: (Ci,N); B,C: (B,S,N).

    Diagonal linear recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t,
    y_t = (h_t . C_t) + D u_t.  Within-chunk via associative scan, chunks
    sequential (carrying h) — O(S/chunk) sequential steps, O(chunk) memory.
    Returns (y, h_last) so decode can carry state.
    """
    b, s, ci = u.shape
    n = A.shape[-1]
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    # (B, nchunk, chunk, ·) views — the (B,S,Ci,N) decay/input expansions
    # are built PER CHUNK inside the (rematerialized) scan body, never at
    # full sequence length (§Perf iteration J2); the Pallas kernel
    # (kernels/mamba_scan.py) keeps even the per-chunk expansion in VMEM.
    uc = u.reshape(b, nchunk, chunk, ci).swapaxes(0, 1)
    dtc = dt.reshape(b, nchunk, chunk, ci).swapaxes(0, 1)
    Bc = B.reshape(b, nchunk, chunk, n).swapaxes(0, 1)
    Cc = C.reshape(b, nchunk, chunk, n).swapaxes(0, 1)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, inp):
        u_, dt_, B_, cc = inp                                # (B,chunk,·)
        da = jnp.exp(dt_[..., None] * A)                     # (B,chunk,Ci,N)
        dbu = (dt_ * u_)[..., None] * B_[:, :, None, :]
        acc_a, acc_b = jax.lax.associative_scan(assoc, (da, dbu), axis=1)
        h_t = acc_a * h[:, None] + acc_b                     # (B,chunk,Ci,N)
        y = jnp.einsum("btcn,btn->btc", h_t, cc)
        return h_t[:, -1], y

    if h0 is None:
        h0 = jnp.zeros((b, ci, n), jnp.float32)
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                              (uc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(b, nchunk * chunk, ci)[:, :s]
    return y + u[:, :s] * D, h_last


def mamba_block(p, x, ms: MambaStatic, tp_axis: Optional[str], state=None):
    """x: (B,S,d). state: (conv_tail (B,K-1,Ci), h (B,Ci,N)) for decode."""
    xi = x @ p["in_x"]                                       # (B,S,Ci)
    z = x @ p["in_z"]
    if state is not None:
        conv_tail, h0 = state
        xi_cat = jnp.concatenate([conv_tail, xi], axis=1)
        new_tail = xi_cat[:, -(ms.d_conv - 1):]
        conv_in = xi_cat
        xc = _causal_conv1d(conv_in, p["conv_w"])[:, -(xi.shape[1]):]
    else:
        h0 = None
        new_tail = None
        xc = _causal_conv1d(xi, p["conv_w"])
    xc = jax.nn.silu(xc)
    # x_proj rows are channel-sharded: partial products reduce over tp so
    # dt/B/C match the unsharded reference exactly.
    proj = maybe_psum(xc @ p["x_proj"], tp_axis)             # (B,S,dt_rank+2N)
    dt_in, Bm, Cm = jnp.split(
        proj, [ms.dt_rank, ms.dt_rank + ms.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if h0 is None and state is None and kernel_ops.use_pallas():
        # Pallas TPU selective-scan kernel (kernels/mamba_scan.py).
        y, h_last = kernel_ops.mamba_scan(
            xc.astype(jnp.float32), dt.astype(jnp.float32), A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), p["D"],
            chunk=ms.chunk)
    else:
        y, h_last = selective_scan(
            xc.astype(jnp.float32), dt.astype(jnp.float32), A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), p["D"],
            chunk=ms.chunk, h0=h0)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    out = maybe_psum(y, tp_axis)
    new_state = (new_tail, h_last) if state is not None else None
    return out, new_state


# --------------------------------------------------------------------------
# RWKV6 (Finch): time-mix with data-dependent decay + channel-mix
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RWKVStatic:
    n_heads_local: int
    d_head: int
    chunk: int = 128


def _token_shift(x, prev=None):
    """x_{t-1} per position; ``prev`` carries the last token for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, : x.shape[1]]
    return jnp.concatenate([prev[:, None], x], axis=1)[:, : x.shape[1]]


def wkv6_chunked(r, k, v, w, u, *, chunk: int, s0=None):
    """RWKV6 WKV with matrix-valued state and per-channel decay.

    r,k,v: (B,S,H,Dh); w: (B,S,H,Dh) decay in (0,1); u: (H,Dh) bonus.
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
      y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    Chunked: intra-chunk O(chunk^2) attention-like term + inter-chunk state.
    This is the jnp oracle twin of kernels/wkv6.py.  Returns (y, s_last).
    """
    b, s, h, dh = r.shape
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zpad), jnp.pad(k, zpad), jnp.pad(v, zpad)
        w = jnp.pad(w, zpad, constant_values=1.0)

    def rs(x):
        return x.reshape(b, nchunk, chunk, h, dh).swapaxes(0, 1)

    rc, kc, vc, wc = rs(r), rs(k), rs(v), rs(w)
    logw = jnp.log(jnp.clip(wc.astype(jnp.float32), 1e-8, 1.0))
    cum = jnp.cumsum(logw, axis=2)                            # (n,B,C,H,Dh)

    def chunk_step(state, inp):
        rb, kb, vb, cumb, logwb = inp                         # (B,C,H,Dh)
        # inter-chunk: y += (r_t * prod_{<=t-1} w) @ S
        decay_to_t = jnp.exp(cumb - logwb)                    # prod over [0, t-1]
        y_inter = jnp.einsum("bchd,bhde->bche",
                             (rb.astype(jnp.float32) * decay_to_t), state)
        # intra-chunk: s<t term with decay prod_{s<tau<t} ... = exp(cum_{t-1}-cum_s)
        att = jnp.einsum("bchd,bghd->bhcg",
                         rb.astype(jnp.float32) * decay_to_t,
                         kb.astype(jnp.float32) * jnp.exp(-cumb))
        tri = jnp.tril(jnp.ones((rb.shape[1], rb.shape[1]), bool), -1)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcg,bghd->bchd", att, vb.astype(jnp.float32))
        # bonus diagonal term
        y_diag = jnp.einsum("bchd,bchd,bche->bche",
                            rb.astype(jnp.float32), u[None, None] *
                            kb.astype(jnp.float32), vb.astype(jnp.float32))
        y = y_inter + y_intra + y_diag
        # state update: S' = diag(prod w) S + sum_s (prod_{tau>s} w) k_s v_s
        total = jnp.exp(cumb[:, -1])                          # (B,H,Dh)
        kdec = kb.astype(jnp.float32) * jnp.exp(cumb[:, -1][:, None] - cumb)
        state = total[..., None] * state + jnp.einsum(
            "bchd,bche->bhde", kdec, vb.astype(jnp.float32))
        return state, y

    if s0 is None:
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    s_last, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, cum, logw))
    y = ys.swapaxes(0, 1).reshape(b, nchunk * chunk, h, dh)[:, :s]
    return y.astype(r.dtype), s_last


def rwkv_time_mix(p, x, rst: RWKVStatic, tp_axis: Optional[str], state=None):
    """RWKV6 time-mix. state = (x_prev (B,d), wkv_state) for decode."""
    prev_tok = state[0] if state is not None else None
    s0 = state[1] if state is not None else None
    xs = _token_shift(x, prev_tok)
    dx = xs - x

    xxx = x + dx * p["maa_x"]
    low = jnp.tanh(xxx @ p["tmix_w1"])                        # (B,S,5*r)
    low = low.reshape(*low.shape[:-1], 5, -1)
    mids = jnp.einsum("bsfr,frd->bsfd", low, p["tmix_w2"])    # (B,S,5,d)
    mw, mk, mv, mr, mg = [mids[:, :, i] for i in range(5)]
    xw = x + dx * (p["maa_w"] + mw)
    xk = x + dx * (p["maa_k"] + mk)
    xv = x + dx * (p["maa_v"] + mv)
    xr = x + dx * (p["maa_r"] + mr)
    xg = x + dx * (p["maa_g"] + mg)

    b, s, _ = x.shape
    h, dh = rst.n_heads_local, rst.d_head
    r = (xr @ p["wr"]).reshape(b, s, h, dh)
    k = (xk @ p["wk"]).reshape(b, s, h, dh)
    v = (xv @ p["wv"]).reshape(b, s, h, dh)
    g = jax.nn.silu(xg @ p["wg"])
    dec = p["w0"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(b, s, h, dh)

    if s0 is None and state is None and kernel_ops.use_pallas():
        # Pallas TPU chunked WKV kernel (kernels/wkv6.py), train mode.
        y, s_last = kernel_ops.wkv6(r, k, v, w.astype(r.dtype),
                                    p["u"].reshape(h, dh), chunk=rst.chunk)
    else:
        y, s_last = wkv6_chunked(r, k, v, w.astype(r.dtype),
                                 p["u"].reshape(h, dh), chunk=rst.chunk,
                                 s0=s0)
    y = groupnorm_heads(y, p["gn_scale"], p["gn_bias"])
    out = (y * g) @ p["wo"]
    out = maybe_psum(out, tp_axis)
    new_state = (x[:, -1], s_last) if state is not None else None
    return out, new_state


def rwkv_channel_mix(p, x, tp_axis: Optional[str], state=None):
    prev_tok = state if state is not None else None
    xs = _token_shift(x, prev_tok)
    dx = xs - x
    xk = x + dx * p["maa_k"]
    xr = x + dx * p["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr_gate"]) * maybe_psum(k @ p["wv"], tp_axis)
    new_state = x[:, -1] if state is not None else None
    return out, new_state
