"""Architecture specification.

A model is a sequence of *blocks* (mixer + ffn), plus embedding/head and an
optional non-pipelined frontend (audio frames / vision patches / encoder).

PipeDream requirement: blocks are grouped into ``pp`` contiguous stages.
Because the pipeline is SPMD (every stage executes the same program), the
*kind pattern* of blocks inside each stage must be identical across stages;
per-layer scalars that differ (attention window, rope theta) travel as data
arrays of shape [pp, layers_per_stage] instead of static attributes.
Configs choose pp so this holds (validated by ``validate_stageability``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

GLOBAL_WINDOW = -1  # window sentinel: full causal attention


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int              # ffn width per expert
    n_shared: int = 0          # shared (always-on) experts
    d_shared: int = 0          # ffn width of the shared expert(s)
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    head_dim: int = 64
    decay_lora: int = 64       # rank of the data-dependent decay LoRA
    tmix_lora: int = 32        # rank of the token-shift mix LoRA


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Non-pipelined encoder (whisper). Runs tensor-sharded before the pipe."""

    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    source_len: int            # frames after the (stubbed) conv frontend


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str = "attn"        # attn | mamba | rwkv | none
    ffn: str = "dense"         # dense | moe | rwkv_cmix | none
    window: int = GLOBAL_WINDOW
    rope_theta: float = 1e4
    cross_attn: bool = False   # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    blocks: Tuple[BlockSpec, ...]
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "silu"          # silu | gelu
    qk_norm: bool = False
    rope_2d: bool = False      # chatglm-style half-rotary
    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    rwkv: Optional[RWKVSpec] = None
    encoder: Optional[EncoderSpec] = None
    frontend: str = "none"     # none | audio | vision
    n_patches: int = 0         # vision frontend: patch tokens per sample
    tie_embeddings: bool = False
    family: str = "dense"      # dense | moe | ssm | hybrid | vlm | audio
    subquadratic: bool = False # eligible for long_500k

    def __post_init__(self):
        assert len(self.blocks) == self.n_layers, (len(self.blocks), self.n_layers)
        assert self.norm in ("rmsnorm", "layernorm")
        assert self.act in ("silu", "gelu")

    # ---- stage decomposition -------------------------------------------------

    def layers_per_stage(self, pp: int) -> int:
        assert self.n_layers % pp == 0, (
            f"{self.name}: pp={pp} must divide n_layers={self.n_layers}")
        return self.n_layers // pp

    def stage_program(self, pp: int) -> Tuple[BlockSpec, ...]:
        """The (validated) per-stage block pattern."""
        validate_stageability(self, pp)
        return self.blocks[: self.layers_per_stage(pp)]

    # ---- bookkeeping ---------------------------------------------------------

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks + head + norms)."""
        n = self.vocab * self.d_model                       # embed
        if not self.tie_embeddings:
            n += self.vocab * self.d_model                  # head
        n += self.d_model                                   # final norm
        for b in self.blocks:
            n += _block_params(self, b)
        if self.encoder is not None:
            e = self.encoder
            per = (4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff
                   + 4 * e.d_model)
            n += e.n_layers * per + e.d_model
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_total = self.param_count()
        per_expert = 3 * self.d_model * m.d_expert
        n_moe_blocks = sum(1 for b in self.blocks if b.ffn == "moe")
        inactive = n_moe_blocks * per_expert * (m.n_experts - m.top_k)
        return dense_total - inactive


def _block_params(spec: ModelSpec, b: BlockSpec) -> int:
    n = 0
    d = spec.d_model
    if b.mixer == "attn":
        n += d * spec.d_attn + 2 * d * spec.n_kv * spec.d_head + spec.d_attn * d
        n += d  # mixer norm
        if spec.qk_norm:
            n += 2 * spec.d_head
        if b.cross_attn:
            n += d * spec.d_attn + 2 * d * spec.n_kv * spec.d_head + spec.d_attn * d + d
    elif b.mixer == "mamba":
        ms = spec.mamba
        d_in = ms.expand * d
        dt_rank = ms.dt_rank or -(-d // 16)
        n += d * 2 * d_in                      # in_proj (x, z)
        n += d_in * ms.d_conv                  # conv
        n += d_in * (dt_rank + 2 * ms.d_state)  # x -> dt, B, C
        n += dt_rank * d_in + d_in             # dt proj + bias
        n += d_in * ms.d_state + d_in          # A_log, D
        n += d_in * d                          # out proj
        n += d                                 # norm
    elif b.mixer == "rwkv":
        rs = spec.rwkv
        n += 4 * d * d                         # r, k, v, g
        n += d * d                             # output
        n += 5 * d + d * rs.tmix_lora * 2 * 5  # token-shift maa + lora
        n += d * rs.decay_lora + rs.decay_lora * d + d  # decay lora + u
        n += 2 * d                             # group norm
        n += d                                 # block norm
    if b.ffn == "dense":
        n += 3 * d * spec.d_ff if spec.act == "silu" else 2 * d * spec.d_ff
        n += d
    elif b.ffn == "moe":
        m = spec.moe
        n += m.n_experts * 3 * d * m.d_expert
        n += d * m.n_experts                   # router
        n += m.n_shared * 3 * d * m.d_shared
        n += d
    elif b.ffn == "rwkv_cmix":
        n += d * int(3.5 * d) + int(3.5 * d) * d + 2 * d  # wide k + v proj + maa
        n += d
    return n


def validate_stageability(spec: ModelSpec, pp: int) -> None:
    """Every stage must run the identical block-kind program."""
    lps = spec.layers_per_stage(pp)
    pattern = [(b.mixer, b.ffn, b.cross_attn) for b in spec.blocks[:lps]]
    for s in range(1, pp):
        got = [(b.mixer, b.ffn, b.cross_attn)
               for b in spec.blocks[s * lps:(s + 1) * lps]]
        assert got == pattern, (
            f"{spec.name}: stage {s} block pattern {got} != stage 0 {pattern}; "
            f"choose a pp that aligns with the layer-type period")


def stage_varying_scalars(spec: ModelSpec, pp: int):
    """Per-layer scalars that differ across stages, as [pp, lps] lists."""
    lps = spec.layers_per_stage(pp)
    windows = [[spec.blocks[s * lps + i].window for i in range(lps)]
               for s in range(pp)]
    thetas = [[spec.blocks[s * lps + i].rope_theta for i in range(lps)]
              for s in range(pp)]
    return windows, thetas
