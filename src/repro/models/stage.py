"""Stage assembly: the function one pipeline stage executes.

A stage runs ``layers_per_stage`` blocks (the validated stage program).
``stage_fwd`` consumes the *local* (per-device) parameter shard — leading
[1] stage dim already sliced by shard_map — and an optional recurrent/KV
state pytree for serving.  The same code runs single-device (tp_axis=None)
for smoke tests and the reference pipeline.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models import spec as spec_lib
from repro.models.init import (attn_static, mamba_static, moe_static,
                               rwkv_static)
from repro.parallel.mesh import ParallelismPlan


@dataclasses.dataclass(frozen=True)
class StageStatics:
    """Compile-time info shared by every stage (SPMD-uniform)."""

    spec: spec_lib.ModelSpec
    plan: ParallelismPlan
    program: Tuple[spec_lib.BlockSpec, ...]
    attn: Optional[nn.AttnStatic]
    xattn: Optional[nn.AttnStatic]
    moe: Optional[nn.MoEStatic]
    mamba: Optional[nn.MambaStatic]
    rwkv: Optional[nn.RWKVStatic]


def make_statics(spec: spec_lib.ModelSpec, plan: ParallelismPlan,
                 tokens_per_mb: int) -> StageStatics:
    program = spec.stage_program(plan.pp)
    has_attn = any(b.mixer == "attn" for b in program)
    has_x = any(b.cross_attn for b in program)
    has_moe = any(b.ffn == "moe" for b in program)
    has_mamba = any(b.mixer == "mamba" for b in program)
    has_rwkv = any(b.mixer == "rwkv" for b in program)
    return StageStatics(
        spec=spec,
        plan=plan,
        program=program,
        attn=attn_static(spec, plan.tp) if has_attn else None,
        xattn=attn_static(spec, plan.tp, causal=False) if has_x else None,
        moe=moe_static(spec, plan.tp, tokens_per_mb) if has_moe else None,
        mamba=mamba_static(spec, plan.tp) if has_mamba else None,
        rwkv=rwkv_static(spec, plan.tp) if has_rwkv else None,
    )


def _squeeze_stage(tree):
    """Drop the leading local stage dim ([1, ...] -> [...])."""
    return jax.tree.map(lambda a: a[0], tree)


def _block_apply(st: StageStatics, blk: spec_lib.BlockSpec, lp, x, *,
                 positions, window, theta, tp_axis, state, cache_pos,
                 cross_x, seq_axis=None, paged=None):
    """One block: mixer + ffn with pre-norm residuals.

    Returns (x, new_state, aux_loss).  ``paged`` (serving only) is a
    (pools, table_row, write_gate[, tokenwise]) tuple routing this
    layer's attention through the block-paged KV pool instead of the
    dense per-slot cache; ``pools`` is (k, v) or, for int8 storage,
    (k, v, k_scale, v_scale) (``tokenwise`` forces token-wise writes for
    s > 1 — speculative verify); the updated pools come back under the
    ``"paged_kv"`` key of new_state (popped off by stage_fwd).
    """
    aux = jnp.zeros((), jnp.float32)
    new_state: Dict[str, Any] = {}
    if blk.mixer == "attn":
        h = nn.apply_norm(lp["norm1"], x, st.spec.norm)
        if paged is not None:
            pools, row, gate = paged[0], paged[1], paged[2]
            tokenwise = paged[3] if len(paged) > 3 else False
            out, new_pools = nn.attention(
                lp["attn"], h, st.attn, positions=positions, window=window,
                theta=theta, tp_axis=tp_axis, cache_pos=cache_pos,
                paged_kv=(pools, row, gate, tokenwise))
            x = x + out
            new_state["paged_kv"] = new_pools
        else:
            kv = state.get("kv") if state else None
            out, new_kv = nn.attention(
                lp["attn"], h, st.attn, positions=positions, window=window,
                theta=theta, tp_axis=tp_axis, kv_cache=kv,
                cache_pos=cache_pos, seq_axis=seq_axis)
            x = x + out
            if new_kv is not None:
                new_state["kv"] = new_kv
        if blk.cross_attn:
            h = nn.apply_norm(lp["norm_x"], x, st.spec.norm)
            out, _ = nn.attention(
                lp["xattn"], h, st.xattn, positions=positions,
                window=jnp.int32(-1), theta=theta, tp_axis=tp_axis,
                cross_x=cross_x)
            x = x + out
    elif blk.mixer == "mamba":
        h = nn.apply_norm(lp["norm1"], x, st.spec.norm)
        sstate = state.get("ssm") if state else None
        out, new_ssm = nn.mamba_block(lp["mamba"], h, st.mamba, tp_axis, sstate)
        x = x + out
        if new_ssm is not None:
            new_state["ssm"] = new_ssm
    elif blk.mixer == "rwkv":
        h = nn.apply_norm(lp["norm1"], x, st.spec.norm)
        tstate = state.get("tmix") if state else None
        out, new_t = nn.rwkv_time_mix(lp["tmix"], h, st.rwkv, tp_axis, tstate)
        x = x + out
        if new_t is not None:
            new_state["tmix"] = new_t

    if blk.ffn == "dense":
        h = nn.apply_norm(lp["norm2"], x, st.spec.norm)
        x = x + nn.mlp(lp["mlp"], h, st.spec.act, tp_axis)
    elif blk.ffn == "moe":
        h = nn.apply_norm(lp["norm2"], x, st.spec.norm)
        out, a = nn.moe(lp["moe"], h, st.moe, st.spec.act, tp_axis)
        x = x + out
        aux = aux + a
    elif blk.ffn == "rwkv_cmix":
        h = nn.apply_norm(lp["norm2"], x, st.spec.norm)
        cstate = state.get("cmix") if state else None
        out, new_c = nn.rwkv_channel_mix(lp["cmix"], h, tp_axis, cstate)
        x = x + out
        if new_c is not None:
            new_state["cmix"] = new_c
    return x, new_state, aux


def stage_fwd(stage_params, x, st: StageStatics, *, positions, windows,
              thetas, tp_axis: Optional[str], state=None, cache_pos=None,
              cross_x=None, seq_axis=None, paged=None):
    """Run one stage over its blocks.

    stage_params: {'layer_i': ...} with leading [1] stage dim on leaves.
    windows/thetas: traced [lps] vectors for this stage.
    state: optional {'layer_i': {...}} recurrent state (serving).
    seq_axis: None, an axis name/tuple applied to every block, or a
    *list* with one entry per stage position (SP shards only full-length
    caches — serving/engine.py).
    paged: optional {"pools": {'layer_i': (k_pool, v_pool)}, "row",
    "gate"[, "tokenwise"]} routing the listed attention layers through
    the block-paged KV pool (serving/engine.py; "tokenwise" selects
    token-wise writes for s > 1 — speculative verify).  When given,
    returns
    (x, (new_state, new_pools), aux_loss_sum) — the pools are global
    across slots, so they cannot ride in the per-slot state tree.
    Returns (x, new_state, aux_loss_sum) otherwise.
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_states: Dict[str, Any] = {}
    new_pools: Dict[str, Any] = {}

    def run_block(i, blk, x):
        lp = _squeeze_stage(stage_params[f"layer_{i}"])
        lstate = state[f"layer_{i}"] if state is not None else None
        sa = seq_axis[i] if isinstance(seq_axis, list) else seq_axis
        pg = None
        if paged is not None and f"layer_{i}" in paged["pools"]:
            pg = (paged["pools"][f"layer_{i}"], paged["row"], paged["gate"],
                  paged.get("tokenwise", False))
        return _block_apply(
            st, blk, lp, x, positions=positions, window=windows[i],
            theta=thetas[i], tp_axis=tp_axis, state=lstate,
            cache_pos=cache_pos, cross_x=cross_x, seq_axis=sa, paged=pg)

    for i, blk in enumerate(st.program):
        fn = partial(run_block, i, blk)
        if st.plan.remat and state is None:
            fn = jax.checkpoint(fn)
        x, ns, aux = fn(x)
        aux_total = aux_total + aux
        if state is not None:
            if ns and "paged_kv" in ns:
                new_pools[f"layer_{i}"] = ns.pop("paged_kv")
            new_states[f"layer_{i}"] = ns
    if paged is not None:
        return x, (new_states, new_pools), aux_total
    return x, (new_states if state is not None else None), aux_total


# --------------------------------------------------------------------------
# Recurrent / KV state construction (serving)
# --------------------------------------------------------------------------

def init_stage_state(st: StageStatics, batch_local: int, cache_lens,
                     dtype=jnp.bfloat16, paged_layers=()):
    """Per-stage serving state with a leading [pp]-stackable layout.

    cache_lens: [lps] static KV capacities (per position; uniform across
    stages — union-max, see DESIGN.md).  Entries for non-attn blocks ignored.
    paged_layers: positions whose attention KV lives in the global page
    pool instead (serving/engine.py) — no dense "kv" entry for those.
    Returned WITHOUT the leading stage dim (caller stacks / shards).
    """
    out: Dict[str, Any] = {}
    for i, blk in enumerate(st.program):
        s: Dict[str, Any] = {}
        if blk.mixer == "attn" and i not in paged_layers:
            kvshape = (batch_local, cache_lens[i], st.attn.n_kv_local, st.attn.d_head)
            s["kv"] = (jnp.zeros(kvshape, dtype), jnp.zeros(kvshape, dtype))
        elif blk.mixer == "mamba":
            ms = st.mamba
            s["ssm"] = (
                jnp.zeros((batch_local, ms.d_conv - 1, ms.d_inner_local), dtype),
                jnp.zeros((batch_local, ms.d_inner_local, ms.d_state), jnp.float32),
            )
        elif blk.mixer == "rwkv":
            rs = st.rwkv
            s["tmix"] = (
                jnp.zeros((batch_local, st.spec.d_model), dtype),
                jnp.zeros((batch_local, rs.n_heads_local, rs.d_head, rs.d_head),
                          jnp.float32),
            )
        if blk.ffn == "rwkv_cmix":
            s["cmix"] = jnp.zeros((batch_local, st.spec.d_model), dtype)
        out[f"layer_{i}"] = s
    return out


# --------------------------------------------------------------------------
# Full (non-pipelined) forward — baselines, smoke tests, reference
# --------------------------------------------------------------------------

def full_transformer(params, x, st: StageStatics, *, positions,
                     tp_axis=None, cross_x=None):
    """Run all pp stages sequentially on one device group."""
    aux_total = jnp.zeros((), jnp.float32)
    for s in range(st.plan.pp):
        stage_p = jax.tree.map(lambda a: a[s:s + 1], params["stages"])
        x, _, aux = stage_fwd(
            stage_p, x, st, positions=positions,
            windows=params["layer_windows"][s],
            thetas=params["layer_thetas"][s],
            tp_axis=tp_axis, cross_x=cross_x)
        aux_total = aux_total + aux
    return x, aux_total


def encoder_fwd(enc_params, frames, spec: spec_lib.ModelSpec, tp_axis=None):
    """Whisper-style encoder over stubbed conv-frontend frames.

    frames: (B, T_src, d_enc).  Scan over stacked encoder layers.
    """
    e = spec.encoder
    x = frames + enc_params["pos"][None, : frames.shape[1]]
    est = nn.AttnStatic(
        n_heads_local=e.n_heads, n_kv_local=e.n_heads, d_head=e.d_model // e.n_heads,
        kv_sharded=True, kv_groups_per_device=0, qk_norm=False, rope_2d=False,
        causal=False)
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])

    def layer(x, lp):
        h = nn.layernorm(x, lp["norm1"], jnp.zeros_like(lp["norm1"]))
        out, _ = nn.attention(
            {"wq": lp["wq"], "wk": lp["wk"], "wv": lp["wv"], "wo": lp["wo"]},
            h, est, positions=positions, window=jnp.int32(-1),
            theta=jnp.float32(1e4), tp_axis=None)
        x = x + out
        h = nn.layernorm(x, lp["norm2"], jnp.zeros_like(lp["norm2"]))
        x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
        return x, None

    scanned = {k: v for k, v in enc_params.items() if k not in ("pos", "final_norm")}
    x, _ = jax.lax.scan(layer, x, scanned)  # pytree leaves [n_layers,...]
    return nn.layernorm(x, enc_params["final_norm"],
                        jnp.zeros_like(enc_params["final_norm"]))
