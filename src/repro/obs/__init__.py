"""Unified observability: metrics registry + pipeline trace + reconcile.

One :class:`Observability` object rides through a run — handed to
``build_pipeline(obs=)`` / ``build_serving(obs=)`` /
``ContinuousBatchingSession(obs=)`` / ``TrainDriver(obs=)`` — and every
execution layer reports into it through two narrow verbs:

  * :meth:`Observability.on_round` — the engine/driver calls this once
    per executed schedule round (decode / verify / admit / prefill /
    train) with the host wall interval; it feeds the ``round_seconds``
    / ``rounds_total`` / ``bucket_rounds_total`` registry series and,
    when tracing, synthesizes the per-tick Perfetto spans from the
    round's schedule table (:mod:`repro.obs.trace`);
  * plain registry access (:meth:`counter` / :meth:`gauge` /
    :meth:`histogram` / :meth:`timer`) for everything that is not a
    table walk — allocator occupancy, batcher goodput, launcher phase
    timing.

``obs=None`` everywhere means "off" with zero overhead: call sites
guard with ``if obs is not None``.  :mod:`repro.obs.reconcile` closes
the loop, turning the collected series back into planner inputs.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.reconcile import ReconcileReport, reconcile, stage_seconds
from repro.obs.trace import RoundRecord, TraceRecorder

__all__ = ["Counter", "Gauge", "Histogram", "Observability",
           "ReconcileReport", "Registry", "RoundRecord", "TraceRecorder",
           "reconcile", "stage_seconds"]


class Observability:
    """Registry + optional trace recorder + the clock that stamps both.

    ``clock`` defaults to ``time.perf_counter``; analytic benchmarks
    pass their modeled clock so spans and histograms carry modeled
    seconds through the identical code path (``scripts/obs_smoke.py``
    leans on this for its exact-ratio assertion).
    """

    def __init__(self, registry: Optional[Registry] = None,
                 trace=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.registry = registry if registry is not None else Registry()
        if trace is True:
            trace = TraceRecorder()
        self.trace: Optional[TraceRecorder] = trace or None
        self.clock = clock

    # ---- registry passthrough --------------------------------------------

    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str) -> Histogram:
        return self.registry.histogram(name)

    def timer(self, name: str, **labels):
        """Phase timer on this object's clock (see ``Registry.timer``)."""
        return self.registry.timer(name, clock=self.clock, **labels)

    # ---- execution-layer verbs -------------------------------------------

    def on_round(self, kind: str, sched, t0: float, t1: float, *,
                 bucket: Optional[int] = None,
                 t_fwd=1.0, t_bwd=1.0) -> None:
        """One executed schedule round: ``[t0, t1)`` seconds over
        ``sched``'s table.  ``bucket`` tags bucketed serving rounds with
        the lattice size actually run."""
        dt = max(float(t1 - t0), 0.0)
        self.registry.histogram("round_seconds").observe(dt, kind=kind)
        self.registry.counter("rounds_total").inc(kind=kind)
        if bucket is not None:
            self.registry.counter("bucket_rounds_total").inc(
                kind=kind, bucket=bucket)
        if self.trace is not None:
            self.trace.record_round(kind, sched, t0, t1, bucket=bucket,
                                    t_fwd=t_fwd, t_bwd=t_bwd)

    def page_gauges(self, alloc, *,
                    queue_depth: Optional[int] = None) -> None:
        """Snapshot a ``PageAllocator``'s occupancy (and, when given,
        the admission queue depth behind it)."""
        self.registry.gauge("pages_in_use").set(alloc.live_pages)
        self.registry.gauge("pages_free").set(alloc.free_pages)
        if queue_depth is not None:
            self.registry.gauge("admit_queue_depth").set(queue_depth)

    # ---- output -----------------------------------------------------------

    def save(self, *, trace_out: Optional[str] = None,
             metrics_out: Optional[str] = None) -> None:
        if trace_out and self.trace is not None:
            self.trace.save(trace_out)
        if metrics_out:
            self.registry.save(metrics_out)
