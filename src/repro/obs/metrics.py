"""Metrics registry: labeled counters / gauges / histograms.

The measurement half of the paper's profile→plan→measure→replan loop
needs somewhere uniform to put numbers: the engine's decode rounds, the
batcher's goodput and TTFT, the page allocator's occupancy, the
driver's per-stage wall times.  This module is that sink — a
dependency-free registry of named metric families, each fanning out
into labeled series (``rounds_total{kind=decode}`` /
``stage_round_seconds{stage=2}``), snapshot-able to JSON-safe dicts
(``scripts/bench_check.py::check_metrics_snapshot`` gates the schema).

Three families, Prometheus-shaped because every reader already knows
that vocabulary:

  * :class:`Counter` — monotone accumulator (``inc``);
  * :class:`Gauge`   — last-write-wins level (``set``);
  * :class:`Histogram` — sample collector with percentile summaries
    (``observe``); empty series summarize to ``None``, never ``NaN``
    (NaN survives ``json.dump`` and poisons every downstream
    comparison — the same rule bench_check enforces on artifacts).

``Registry.timer`` is the shared phase timer the launchers use instead
of ad-hoc ``time.time()`` pairs: a context manager observing its
elapsed seconds into a histogram series, with a pluggable clock so
analytic benchmarks time modeled seconds through the very same path.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]

LabelKey = Tuple[Tuple[str, str], ...]


def _key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_dict(key: LabelKey) -> Dict[str, str]:
    return {k: v for k, v in key}


class _Metric:
    """One named family of labeled series."""

    kind = "metric"

    def __init__(self, name: str):
        self.name = name
        self._series: Dict[LabelKey, object] = {}

    def labelsets(self) -> List[Dict[str, str]]:
        return [_label_dict(k) for k in sorted(self._series)]


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> float:
        if amount < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc({amount}))")
        k = _key(labels)
        self._series[k] = self._series.get(k, 0.0) + float(amount)
        return self._series[k]

    def value(self, **labels) -> float:
        return float(self._series.get(_key(labels), 0.0))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_key(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        v = self._series.get(_key(labels))
        return None if v is None else float(v)


class Histogram(_Metric):
    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        self._series.setdefault(_key(labels), []).append(float(value))

    def values(self, **labels) -> List[float]:
        return list(self._series.get(_key(labels), ()))

    def stats(self, **labels) -> Dict[str, Optional[float]]:
        """count/sum/mean/min/max/p50/p99 — ``None`` stats when empty."""
        v = np.asarray(self._series.get(_key(labels), ()), float)
        if not v.size:
            return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                    "max": None, "p50": None, "p99": None}
        return {"count": int(v.size), "sum": float(v.sum()),
                "mean": float(v.mean()), "min": float(v.min()),
                "max": float(v.max()),
                "p50": float(np.percentile(v, 50)),
                "p99": float(np.percentile(v, 99))}


class _Timer:
    """Context manager observing elapsed clock time into a histogram."""

    def __init__(self, hist: Histogram, clock: Callable[[], float],
                 labels: Dict[str, object]):
        self._hist, self._clock, self._labels = hist, clock, labels
        self.elapsed: Optional[float] = None

    def __enter__(self) -> "_Timer":
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = max(self._clock() - self._t0, 0.0)
        self._hist.observe(self.elapsed, **self._labels)


class Registry:
    """Named metric families; one instance per run / session."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {m.kind}, not a {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(Counter, name)

    def gauge(self, name: str) -> Gauge:
        return self._get(Gauge, name)

    def histogram(self, name: str) -> Histogram:
        return self._get(Histogram, name)

    def timer(self, name: str, *,
              clock: Callable[[], float] = time.perf_counter,
              **labels) -> _Timer:
        """``with reg.timer("launch_phase_seconds", phase="run") as t:``
        — observes elapsed seconds into the histogram series and leaves
        them on ``t.elapsed`` for printing."""
        return _Timer(self.histogram(name), clock, labels)

    # ---- snapshot ---------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe dump of every series.

        Schema (gated by scripts/bench_check.py::check_metrics_snapshot):
        ``{"kind": "metrics", "counters": [...], "gauges": [...],
        "histograms": [...]}`` where counter/gauge rows carry
        ``{name, labels, value}`` and histogram rows ``{name, labels,
        count, sum, mean, min, max, p50, p99}`` — empty-series stats are
        ``None``, and every number present is finite.
        """
        counters, gauges, hists = [], [], []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            for labels in m.labelsets():
                if isinstance(m, Histogram):
                    hists.append({"name": name, "labels": labels,
                                  **m.stats(**labels)})
                elif isinstance(m, Counter):
                    counters.append({"name": name, "labels": labels,
                                     "value": m.value(**labels)})
                else:
                    gauges.append({"name": name, "labels": labels,
                                   "value": m.value(**labels)})
        return {"kind": "metrics", "counters": counters, "gauges": gauges,
                "histograms": hists}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
