"""Measured-vs-predicted reconciliation: close the replan loop.

The planner's predictions (``weighted_round_time``,
``benchmarks/simulator.py::simulate_schedule``) and the executor's
measurements (:class:`~repro.obs.trace.TraceRecorder` rounds, the
``round_seconds`` / ``stage_round_seconds`` registry series) describe
the same quantity — wall seconds per schedule round — so comparing
them is the repro's first-class health check: a ratio far from 1.0
means the cost model the planner searched over does not describe the
machine it planned for.

Two consumers:

  * :func:`reconcile` → :class:`ReconcileReport` — measured round time
    and span-measured bubble fraction next to the table predictions,
    printed by ``launch/serve.py``/``launch/train.py`` and asserted
    (ratio ≈ 1 on an analytic clock) by ``scripts/obs_smoke.py``;
  * :func:`stage_seconds` — per-stage mean wall seconds read back out
    of a :class:`~repro.obs.metrics.Registry`, in the exact shape
    ``core/profiler.py::scale_profiles_to_measurements`` consumes, so
    ``runtime/driver.py::replan_from_registry`` can re-search plans off
    telemetry the run actually produced.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.schedule import weighted_round_time

__all__ = ["ReconcileReport", "reconcile", "stage_seconds"]


@dataclasses.dataclass(frozen=True)
class ReconcileReport:
    """Measured vs predicted for one round kind on one schedule."""

    kind: Optional[str]
    rounds: int                            # measured rounds folded in
    measured_round_s: Optional[float]      # mean wall seconds / round
    predicted_round_s: Optional[float]     # None without absolute costs
    round_ratio: Optional[float]           # measured / predicted
    measured_bubble: Optional[float]       # from emitted spans
    predicted_bubble: float                # weighted_round_time's bubble

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        ratio = ("n/a" if self.round_ratio is None
                 else f"{self.round_ratio:.3f}")
        meas = ("n/a" if self.measured_round_s is None
                else f"{self.measured_round_s * 1e3:.3f} ms")
        bub = ("n/a" if self.measured_bubble is None
               else f"{self.measured_bubble:.3f}")
        return (f"reconcile[{self.kind or 'all'}]: "
                f"round {meas} measured vs ratio {ratio}; "
                f"bubble {bub} measured vs "
                f"{self.predicted_bubble:.3f} predicted "
                f"({self.rounds} rounds)")


def reconcile(sched, *, trace=None, registry=None,
              kind: Optional[str] = None,
              t_fwd=None, t_bwd=None) -> ReconcileReport:
    """Compare measured rounds against ``sched``'s table prediction.

    Measurements come from ``trace`` (span-derived bubble + round
    durations) and/or ``registry`` (the ``round_seconds{kind=}``
    histogram — used when no trace was recorded).  ``t_fwd``/``t_bwd``
    are per-stage (or scalar) *absolute seconds* as taken by
    ``weighted_round_time``; when given, the report carries a predicted
    round time and a measured/predicted ratio — without them only the
    unit-free bubble fractions are compared (predicted with uniform
    costs).
    """
    measured_round = None
    measured_bubble = None
    n_rounds = 0
    if trace is not None:
        recs = [r for r in trace.rounds if kind is None or r.kind == kind]
        n_rounds = len(recs)
        if recs:
            measured_round = trace.measured_round_seconds(kind)
            measured_bubble = trace.measured_bubble_fraction(kind)
    if measured_round is None and registry is not None:
        labels = {} if kind is None else {"kind": kind}
        stats = registry.histogram("round_seconds").stats(**labels)
        n_rounds = stats["count"]
        measured_round = stats["mean"]

    # without absolute costs the bubble prediction is unit-free
    # (uniform costs); with t_fwd but no t_bwd we are on a forward-only
    # serving table, where backward cost is definitionally zero
    have_costs = t_fwd is not None
    pf = t_fwd if have_costs else 1.0
    if t_bwd is None:
        t_bwd = 0.0 if have_costs else 1.0
    predicted_round, predicted_bubble = weighted_round_time(sched, pf, t_bwd)

    predicted_round_s = float(predicted_round) if have_costs else None
    ratio = None
    if predicted_round_s and measured_round is not None:
        ratio = measured_round / predicted_round_s
    return ReconcileReport(
        kind=kind, rounds=int(n_rounds),
        measured_round_s=measured_round,
        predicted_round_s=predicted_round_s,
        round_ratio=ratio,
        measured_bubble=measured_bubble,
        predicted_bubble=float(predicted_bubble))


def stage_seconds(registry, n_stages: int, *,
                  name: str = "stage_round_seconds") -> List[float]:
    """Per-stage mean wall seconds out of the registry.

    Reads the ``name{stage=s}`` histogram for ``s`` in
    ``range(n_stages)`` — the series ``runtime/driver.py``'s training
    loop populates — and returns the per-stage means in the exact shape
    ``scale_profiles_to_measurements`` expects.  Raises ``ValueError``
    when a stage has no samples: replanning off partial telemetry would
    silently mis-balance.
    """
    hist = registry.histogram(name)
    out = []
    for s in range(n_stages):
        mean = hist.stats(stage=s)["mean"]
        if mean is None:
            raise ValueError(
                f"registry has no {name}{{stage={s}}} samples; "
                f"cannot replan from partial telemetry")
        out.append(float(mean))
    return out
