"""Per-tick pipeline trace recorder → Chrome trace-event JSON.

Both executors run their tick loops inside ``jax.lax.scan`` on device,
so there is nothing host-side to hook *per tick* — the host observes
one wall-clock interval per executed round (one ``decode()`` /
``verify()`` / train step call).  What the host *does* know statically
is the schedule table: exactly which (tick, stage, microbatch, chunk)
cells are busy and which are bubbles.  :meth:`TraceRecorder.record_round`
therefore synthesizes the per-tick spans from the table, apportioning
the measured round duration across tick phases with the same
max-active-stage weighting as
``src/repro/core/schedule.py::weighted_round_time`` — which buys two
invariants the smoke gate (``scripts/obs_smoke.py``) asserts:

  * per-stage F/B span counts equal the table's non-bubble cells by
    construction, for every round, bucketed or not;
  * the bubble fraction measured off the emitted spans equals the
    table's *weighted* bubble fraction exactly (under the same
    per-stage costs), so measured-vs-predicted reconciliation has a
    fixed point at ratio 1.0 on an analytic clock.

Output is the Chrome trace-event format (``{"traceEvents": [...]}``,
``ph="X"`` complete events, ts/dur in µs): one ``tid`` track per
physical stage under a single ``pid``, named via ``ph="M"`` metadata,
loadable directly in Perfetto / ``chrome://tracing``.  Span ``args``
carry ``(round, tick, stage, microbatch, chunk, phase, bucket, kind)``;
bubble cells are emitted as spans too (``phase="bubble"``) so idle
time is visible on the track, but never counted by
:meth:`TraceRecorder.span_counts`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.schedule import B_CHUNK, B_MB, F_CHUNK, F_MB

__all__ = ["RoundRecord", "TraceRecorder"]

_PID = 1          # single process track: the pipeline itself


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """Host-side summary of one executed round (one table walk)."""

    kind: str                     # decode / verify / admit / prefill / train
    bucket: Optional[int]         # bucketed table size, None when full-R
    t0: float                     # host clock, seconds
    t1: float
    n_spans: int                  # non-bubble cells emitted
    bubble_fraction: float        # idle span time / (S * duration)


class TraceRecorder:
    """Accumulates rounds; saves one Perfetto-loadable trace file."""

    def __init__(self):
        self.events: List[dict] = []
        self.rounds: List[RoundRecord] = []
        self._epoch: Optional[float] = None
        self._named_tracks: set = set()

    # ---- internals --------------------------------------------------------

    def _us(self, t: float) -> float:
        if self._epoch is None:
            self._epoch = t
        return (t - self._epoch) * 1e6

    def _name_track(self, stage: int) -> None:
        if stage in self._named_tracks:
            return
        self._named_tracks.add(stage)
        if not self.events:
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": _PID, "tid": 0,
                                "args": {"name": "pipeline"}})
        self.events.append({"ph": "M", "name": "thread_name", "pid": _PID,
                            "tid": stage,
                            "args": {"name": f"stage {stage}"}})

    # ---- recording --------------------------------------------------------

    def record_round(self, kind: str, sched, t0: float, t1: float, *,
                     bucket: Optional[int] = None,
                     t_fwd=1.0, t_bwd=1.0) -> RoundRecord:
        """Expand one measured round ``[t0, t1)`` over ``sched``'s table.

        ``t_fwd``/``t_bwd`` are the same scalar-or-per-stage relative
        costs ``weighted_round_time`` takes; they shape how the measured
        duration is split across ticks (uniform by default) — the span
        *set* depends only on the table.
        """
        tabs = sched.tables()
        S, v = sched.n_stages, sched.virtual_stages
        tf = np.broadcast_to(np.asarray(t_fwd, float), (S,))
        tb = np.broadcast_to(np.asarray(t_bwd, float), (S,))
        fbusy = tabs.fwd[:, :, F_MB] >= 0           # [T, S]
        bbusy = tabs.bwd[:, :, B_MB] >= 0
        f_phase = np.where(fbusy, tf[None, :], 0.0).max(axis=1) / v
        b_phase = np.where(bbusy, tb[None, :], 0.0).max(axis=1) / v
        total_w = float(f_phase.sum() + b_phase.sum())
        duration = max(float(t1 - t0), 0.0)
        # scale model-weight → measured seconds; a degenerate all-bubble
        # table still records the round, just with no spans
        scale = duration / total_w if total_w > 0 else 0.0

        for s in range(S):
            self._name_track(s)
        round_idx = len(self.rounds)
        n_spans = 0
        busy_time = 0.0
        cursor = t0
        for t in range(tabs.fwd.shape[0]):
            for phase, tab, busy, cost, plen in (
                    ("F", tabs.fwd, fbusy, tf, f_phase[t]),
                    ("B", tabs.bwd, bbusy, tb, b_phase[t])):
                if plen <= 0.0:
                    continue
                phase_len = plen * scale
                mb_col = F_MB if phase == "F" else B_MB
                ck_col = F_CHUNK if phase == "F" else B_CHUNK
                for s in range(S):
                    args = {"kind": kind, "round": round_idx, "tick": t,
                            "stage": s, "phase": phase}
                    if bucket is not None:
                        args["bucket"] = int(bucket)
                    if busy[t, s]:
                        dur = (cost[s] / v) * scale
                        args["microbatch"] = int(tab[t, s, mb_col])
                        args["chunk"] = int(tab[t, s, ck_col])
                        name = (f"{phase} mb{args['microbatch']}"
                                f".c{args['chunk']}")
                        cat = phase
                        n_spans += 1
                        busy_time += dur
                    else:
                        dur = phase_len
                        args["phase"] = "bubble"
                        name, cat = "bubble", "bubble"
                    self.events.append({
                        "ph": "X", "pid": _PID, "tid": s, "name": name,
                        "cat": cat, "ts": self._us(cursor),
                        "dur": dur * 1e6, "args": args})
                cursor += phase_len
        bubble = (1.0 - busy_time / (S * duration)) if duration > 0 else 0.0
        rec = RoundRecord(kind=kind, bucket=bucket, t0=t0, t1=t1,
                          n_spans=n_spans, bubble_fraction=bubble)
        self.rounds.append(rec)
        return rec

    # ---- summaries --------------------------------------------------------

    def span_counts(self, kind: Optional[str] = None) -> Dict[int, int]:
        """Non-bubble span count per stage track (optionally one kind)."""
        counts: Dict[int, int] = {}
        for e in self.events:
            if e["ph"] != "X" or e["cat"] == "bubble":
                continue
            if kind is not None and e["args"]["kind"] != kind:
                continue
            counts[e["tid"]] = counts.get(e["tid"], 0) + 1
        return counts

    def measured_bubble_fraction(self, kind: Optional[str] = None) -> float:
        """Duration-weighted mean bubble fraction across recorded rounds."""
        recs = [r for r in self.rounds
                if (kind is None or r.kind == kind) and r.t1 > r.t0]
        if not recs:
            return 0.0
        dur = np.array([r.t1 - r.t0 for r in recs])
        bub = np.array([r.bubble_fraction for r in recs])
        return float((dur * bub).sum() / dur.sum())

    def measured_round_seconds(self, kind: Optional[str] = None) -> float:
        """Mean measured wall seconds per recorded round."""
        recs = [r for r in self.rounds if kind is None or r.kind == kind]
        if not recs:
            return 0.0
        return float(np.mean([r.t1 - r.t0 for r in recs]))

    # ---- output -----------------------------------------------------------

    def to_json(self) -> dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
