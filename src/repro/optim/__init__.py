from repro.optim.optimizers import SGDM, Adam, RMSProp, Optimizer  # noqa: F401
from repro.optim.compression import onebit_compress_psum  # noqa: F401
