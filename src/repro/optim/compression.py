"""1-bit gradient compression with error feedback (CNTK-style, paper §2).

Optional transform on the replica-axis gradient sync.  Each worker sends
sign(g + e) scaled by the mean magnitude; the quantization error e feeds
back into the next step.  On TPU we model the bandwidth saving by reducing
the all-reduced payload to the bf16 scale + int8 signs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.mesh import maybe_psum


def onebit_compress_psum(grads, errors, axis: Optional[str],
                         n_replicas: int) -> Tuple:
    """Returns (synced_grads, new_errors). grads/errors: matching pytrees."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.mean(jnp.abs(x))
        sign = jnp.where(x >= 0, jnp.int8(1), jnp.int8(-1))
        q = sign.astype(jnp.float32) * scale
        new_e = x - q
        # aggregate compressed payloads across replicas
        agg = maybe_psum(q, axis) / n_replicas
        return agg.astype(g.dtype), new_e

    flat = jax.tree.map(one, grads, errors)
    synced = jax.tree.map(lambda t: t[0], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return synced, new_err


def init_errors(params, dtype=jnp.float32):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
