"""Optimizers used by the paper: SGD+momentum (VGG16/S2VT), RMSProp
(Inception-v3); Adam included for the LM archs.

Functional API: ``init(params) -> state``; ``update(grads, state, params,
step) -> (new_params, new_state)``.  All states are pytrees that mirror the
params (so they stack/shard exactly like the stage weights in the pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

LR = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: LR, step):
    return lr(step) if callable(lr) else lr


def _cast_like(x, ref):
    return x.astype(ref.dtype) if hasattr(ref, "dtype") else x


@dataclasses.dataclass(frozen=True)
class SGDM:
    """SGD with momentum (paper: momentum 0.9, lr 0.01 for VGG16/S2VT)."""

    lr: LR = 0.01
    momentum: float = 0.9
    state_dtype: Any = jnp.float32

    def init(self, params):
        return {"v": jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.state_dtype), params)}

    def update(self, grads, state, params, step=0):
        lr = _lr_at(self.lr, step)

        def upd(g, v, p):
            v_new = self.momentum * v + g.astype(v.dtype)
            return (p - lr * _cast_like(v_new, p)).astype(p.dtype), v_new

        flat = jax.tree.map(upd, grads, state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"v": new_v}


@dataclasses.dataclass(frozen=True)
class RMSProp:
    """RMSProp (paper: Inception-v3, lr 0.045, decay 0.9, eps 1.0)."""

    lr: LR = 0.045
    decay: float = 0.9
    eps: float = 1.0
    state_dtype: Any = jnp.float32

    def init(self, params):
        return {"s": jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.state_dtype), params)}

    def update(self, grads, state, params, step=0):
        lr = _lr_at(self.lr, step)

        def upd(g, s, p):
            g32 = g.astype(s.dtype)
            s_new = self.decay * s + (1 - self.decay) * g32 * g32
            step_v = lr * g32 / (jnp.sqrt(s_new) + self.eps)
            return (p - _cast_like(step_v, p)).astype(p.dtype), s_new

        flat = jax.tree.map(upd, grads, state["s"], params)
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_s = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"s": new_s}


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: LR = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    state_dtype: Any = jnp.float32

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(self, grads, state, params, step=0):
        lr = _lr_at(self.lr, step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(m.dtype)
            m_new = self.b1 * m + (1 - self.b1) * g32
            v_new = self.b2 * v + (1 - self.b2) * g32 * g32
            step_v = lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + self.eps)
            return (p - _cast_like(step_v, p)).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], flat,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}


Optimizer = Union[SGDM, RMSProp, Adam]


def by_name(name: str, lr: LR, **kw) -> Optimizer:
    return {"sgdm": SGDM, "rmsprop": RMSProp, "adam": Adam}[name](lr=lr, **kw)
