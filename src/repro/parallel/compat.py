"""Version-compat shims for jax APIs that moved between releases.

Two shims the whole codebase routes through:

  shard_map   jax >= 0.6 exports it at the top level and renamed the
              replication-check kwarg ``check_rep`` -> ``check_vma``;
              older releases have it under jax.experimental.shard_map
              with ``check_rep``.  Callers always pass ``check_vma`` and
              this wrapper translates when needed.
  tpu_compiler_params
              pallas renamed ``pltpu.TPUCompilerParams`` ->
              ``pltpu.CompilerParams``.  Kernels build the params through
              this helper instead of naming the class.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6 moved shard_map to the top level
    from jax import shard_map as _shard_map  # type: ignore
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kw):
    """shard_map with ``check_vma``/``check_rep`` translated per version."""
    if "check_vma" in kw and "check_vma" not in _SHARD_MAP_PARAMS:
        kw.setdefault("check_rep", kw.pop("check_vma"))
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a dict on every jax version.

    Older releases return a one-element list of per-device dicts; newer
    ones return the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def tpu_compiler_params(**kw):
    """pltpu.CompilerParams / TPUCompilerParams, whichever this jax has."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # pragma: no cover - depends on installed jax
        cls = pltpu.TPUCompilerParams
    return cls(**kw)
