"""Logical/physical mesh utilities and the per-arch parallelism plan.

The production mesh (launch/mesh.py) exposes axes ("pod",) "data", "model".
PipeDream's pipeline runs over *stages*; tensor parallelism runs *within* a
stage.  We therefore derive a mesh from the same device array with the
"model" axis split into ("stage", "tensor"), pp * tp == model.

Logical axis conventions used throughout the framework:
  batch   -> ("pod", "data")     PipeDream stage replication (uniform)
  stage   -> "stage"             pipeline stages (the paper's contribution)
  heads / ffn / vocab / experts -> "tensor"
  seq (long-context KV)         -> "tensor"  (sequence-parallel KV sharding)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_STAGE = "stage"
AXIS_TENSOR = "tensor"
AXIS_MODEL = "model"


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    """Per-architecture distribution plan (declared in configs/<arch>.py)."""

    pp: int                    # pipeline stages (PipeDream stages)
    tp: int                    # tensor parallel degree within a stage
    microbatches: int = 8      # R: PipeDream "minibatches" in flight per round
    stash_mode: str = "stash"  # stash | flush | vertical | 2bw
    schedule: str = "auto"     # auto | registry name (1f1b, gpipe,
                               # interleaved, interleaved_async, ...);
                               # auto derives from stash_mode (see
                               # core.schedule.make_schedule)
    virtual_stages: int = 1    # model chunks per physical stage
                               # (interleaved schedule family only)
    zero1: bool = True         # shard optimizer state over the data axis
    remat: bool = True         # per-layer activation checkpointing
    grad_sync: str = "per_microbatch"  # per_microbatch (faithful) | per_round
    # Serving-only knobs
    decode_microbatches: int = 8

    def __post_init__(self):
        assert self.stash_mode in ("stash", "flush", "vertical", "2bw"), self.stash_mode
        assert self.grad_sync in ("per_microbatch", "per_round"), self.grad_sync
        assert self.pp >= 1 and self.tp >= 1 and self.microbatches >= 1
        assert self.virtual_stages >= 1, self.virtual_stages
        if self.virtual_stages > 1:
            # registry-driven, so third-party interleaved-family
            # schedules (takes_virtual_stages=True) need no edits here
            from repro.core.schedule import SCHEDULES
            cls = SCHEDULES.get(self.schedule)
            assert cls is not None and cls.takes_virtual_stages, (
                "virtual_stages > 1 requires an interleaved-family "
                f"schedule (got schedule={self.schedule!r}); registered: "
                f"{sorted(n for n, c in SCHEDULES.items() if c.takes_virtual_stages)}")

    def with_(self, **kw) -> "ParallelismPlan":
        return dataclasses.replace(self, **kw)

    def make_schedule(self):
        """The PipelineSchedule instance this plan describes."""
        from repro.core.schedule import make_schedule
        return make_schedule(self)

    @property
    def stash_slots(self) -> int:
        """Weight versions kept per stage (SPMD-uniform ring size).

        Delegates to the schedule subsystem: 1F1B keeps 2(S-1)+1
        in-flight versions at the input stage; flush keeps none
        beyond the live weights; 2bw keeps a double buffer.
        """
        return self.make_schedule().stash_slots


def split_model_axis(mesh: Mesh, pp: int, tp: int) -> Mesh:
    """Derive a ("pod",) "data", "stage", "tensor" mesh from the production mesh."""
    axes = mesh.axis_names
    assert axes[-1] == AXIS_MODEL, f"expected trailing 'model' axis, got {axes}"
    model = mesh.devices.shape[-1]
    assert pp * tp == model, f"pp*tp={pp * tp} must equal model axis size {model}"
    devices = mesh.devices.reshape(mesh.devices.shape[:-1] + (pp, tp))
    new_axes = tuple(axes[:-1]) + (AXIS_STAGE, AXIS_TENSOR)
    return Mesh(devices, new_axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All axes that carry batch replication (pod included when present)."""
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def model_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in (AXIS_STAGE, AXIS_TENSOR) if a in mesh.axis_names)


def axis_size(mesh: Mesh, *names: str) -> int:
    n = 1
    for name in names:
        if name in mesh.axis_names:
            n *= mesh.devices.shape[mesh.axis_names.index(name)]
    return n


def sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def local_batch(mesh: Mesh, global_batch: int) -> int:
    dp = axis_size(mesh, *data_axes(mesh))
    assert global_batch % dp == 0, (global_batch, dp)
    return global_batch // dp


def maybe_psum(x, axis: Optional[str]):
    """psum that no-ops outside shard_map / when the axis is absent (tp=1)."""
    if axis is None:
        return x
    return jax.lax.psum(x, axis)


def maybe_axis_index(axis: Optional[str]):
    if axis is None:
        return 0
    return jax.lax.axis_index(axis)


def shard_divides(n: int, parts: int) -> bool:
    return parts >= 1 and n % parts == 0


def pick_tp_shard(n: int, tp: int) -> Tuple[int, bool]:
    """Return (local_n, sharded?) — replicate when tp does not divide n.

    Used for GQA KV heads when kv < tp: weights are replicated over the
    tensor axis and each device slices the head group it owns.
    """
    if shard_divides(n, tp):
        return n // tp, True
    return n, False
