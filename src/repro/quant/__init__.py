"""Quantized weight / KV-cache storage dtypes.

Serving memory is dominated by two pools the planner must price: the
resident weights and the KV cache.  This package provides the storage
formats for both:

  * **int8 weights** — per-output-channel scale.  Each matmul weight
    ``w`` is stored as ``{"q": int8, "scale": f32}`` where the scale has
    ``w``'s shape with the *contraction* axis reduced to 1 (keepdims), so
    dequantization is a broadcasted ``q * scale``.  Per-output-channel
    scaling keeps the rounding error of each output feature independent
    of every other channel's magnitude.
  * **fp8-e4m3 weights** — same layout, payload ``float8_e4m3fn``
    scaled so each channel's absmax maps to the format max (448).
  * **int8 KV cache** — per-page, per-kv-head scales for the paged
    pool (``kernels/paged_attention.py`` dequantizes inside the page
    walk; ``kernels/ref.py`` carries the oracle).

A quantized leaf is a plain ``{"q", "scale"}`` dict, NOT a custom pytree
node: the params tree stays a nested dict, so jit/shard_map/checkpoint
flattening all work unchanged — only the matmul call sites in
``models/nn.py`` / ``models/lm_head.py`` need the ``maybe_dequant``
shim.  The parallel pspec tree is transformed the same way
(``quantize_params`` returns both), with the scale's entry for the
reduced axis forced to ``None`` (a size-1 axis cannot be sharded).

Pricing (``weight_byte_cost`` / ``kv_byte_cost``) is what
``core/schedule.py`` / ``core/partitioner.py`` use: payload bytes plus
the f32 scale overhead amortized per parameter / per cache element.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

WEIGHT_DTYPES = ("fp32", "bf16", "fp8", "int8")
KV_DTYPES = ("fp32", "bf16", "int8")

_STORAGE_BYTES = {"fp32": 4.0, "bf16": 2.0, "fp16": 2.0,
                  "fp8": 1.0, "int8": 1.0}
_INT8_MAX = 127.0
_FP8_MAX = 448.0          # float8_e4m3fn finite max


def storage_bytes(name: str) -> float:
    """Payload bytes per element for a storage dtype name."""
    return _STORAGE_BYTES[name]


def is_quantized(leaf) -> bool:
    """True for the ``{"q", "scale"}`` dict encoding of a quantized leaf."""
    return isinstance(leaf, dict) and set(leaf) == {"q", "scale"}


# --------------------------------------------------------------------------
# Leaf-level quantize / dequantize
# --------------------------------------------------------------------------

def _channel_absmax(w, axis: int):
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    return jnp.where(amax > 0, amax, 1.0)


def quantize(w, dtype_name: str, axis: int) -> Dict[str, jax.Array]:
    """Quantize one weight along its contraction ``axis``.

    Returns ``{"q": payload, "scale": f32}`` with a keepdims scale so
    ``dequantize`` is a single broadcasted multiply.
    """
    if dtype_name == "int8":
        scale = _channel_absmax(w, axis) / _INT8_MAX
        q = jnp.round(w.astype(jnp.float32) / scale)
        q = jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    elif dtype_name == "fp8":
        scale = _channel_absmax(w, axis) / _FP8_MAX
        q = (w.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    else:
        raise ValueError(f"unknown quantized weight dtype {dtype_name!r}; "
                         f"expected one of ('int8', 'fp8')")
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize(w: Dict[str, jax.Array], dtype=None):
    out = w["q"].astype(jnp.float32) * w["scale"]
    return out.astype(dtype) if dtype is not None else out


def maybe_dequant(w, dtype=None):
    """Dequantize a ``{"q", "scale"}`` leaf; pass plain arrays through.

    The single shim ``models/nn.py`` / ``models/lm_head.py`` wrap around
    every weight use — on-the-fly dequantization at the matmul site, so
    only one layer's weights ever exist at full precision at a time.
    """
    if is_quantized(w):
        return dequantize(w, dtype)
    return w if dtype is None else w.astype(dtype)


# --------------------------------------------------------------------------
# Whole-tree quantization (params + pspecs in lockstep)
# --------------------------------------------------------------------------

# (parent key, leaf key) -> contraction axis of the stage-stacked array.
# Only the attn / dense-mlp / moe matmul families quantize — norms,
# routers, rope scalars and the mamba/rwkv mixers stay in compute dtype
# (they are a rounding error of the footprint and some are numerically
# load-bearing).
_STAGE_RULES = {
    ("attn", "wq"): 1, ("attn", "wk"): 1, ("attn", "wv"): 1,
    ("attn", "wo"): 1,
    ("xattn", "wq"): 1, ("xattn", "wk"): 1, ("xattn", "wv"): 1,
    ("xattn", "wo"): 1,
    ("mlp", "w1"): 1, ("mlp", "w2"): 1, ("mlp", "w3"): 1,
    ("shared", "w1"): 1, ("shared", "w2"): 1, ("shared", "w3"): 1,
    ("moe", "w1"): 2, ("moe", "w2"): 2, ("moe", "w3"): 2,
}


def quantized_axis(path: Tuple[str, ...]) -> Optional[int]:
    """Contraction axis for a stages-tree leaf path, or None (skip)."""
    if len(path) >= 2:
        return _STAGE_RULES.get((path[-2], path[-1]))
    return None


def _scale_pspec(pspec, axis: int):
    from jax.sharding import PartitionSpec as P
    entries = list(pspec)
    while len(entries) <= axis:
        entries.append(None)
    entries[axis] = None
    return P(*entries)


def quantize_params(params: Dict, pspecs: Optional[Dict], dtype_name: str
                    ) -> Tuple[Dict, Optional[Dict]]:
    """Quantize a full serving params tree (and its pspec twin).

    Stage matmuls follow ``_STAGE_RULES``; ``embed`` quantizes per
    vocab row (axis 1), ``head`` per vocab column (axis 0).  Everything
    else passes through untouched.  Works under ``jax.eval_shape``.
    ``pspecs=None`` quantizes the params tree alone (host-side loads
    where the sharding twin is derived separately).
    """
    if dtype_name in ("fp32", "bf16", None):
        return params, pspecs
    if dtype_name not in WEIGHT_DTYPES:
        raise ValueError(f"unknown weight dtype {dtype_name!r}; expected "
                         f"one of {WEIGHT_DTYPES}")

    def walk(p, s, path):
        if isinstance(p, dict):
            out_p, out_s = {}, {}
            for k in p:
                out_p[k], out_s[k] = walk(p[k], None if s is None else s[k],
                                          path + (k,))
            return out_p, out_s
        axis = quantized_axis(path)
        if axis is None:
            return p, s
        qp = quantize(p, dtype_name, axis)
        if s is None:
            return qp, None
        return qp, {"q": s, "scale": _scale_pspec(s, axis)}

    out_params = dict(params)
    out_pspecs = None if pspecs is None else dict(pspecs)
    out_params["stages"], qs = walk(
        params["stages"], None if pspecs is None else pspecs["stages"], ())
    if out_pspecs is not None:
        out_pspecs["stages"] = qs
    for name, axis in (("embed", 1), ("head", 0)):
        if name in params:
            out_params[name] = quantize(params[name], dtype_name, axis)
            if out_pspecs is not None:
                out_pspecs[name] = {
                    "q": pspecs[name],
                    "scale": _scale_pspec(pspecs[name], axis)}
    return out_params, out_pspecs


# --------------------------------------------------------------------------
# int8 KV-cache page helpers (write-side; the read side lives in the
# Pallas page walk and the ref.py oracle)
# --------------------------------------------------------------------------

def quantize_kv_page(page_f32):
    """Quantize one (page, n_kv, dh) page; scale is per kv head.

    Returns ``(q int8 (page, kv, dh), scale f32 (kv,))``.
    """
    amax = jnp.max(jnp.abs(page_f32.astype(jnp.float32)), axis=(0, 2))
    scale = jnp.where(amax > 0, amax, 1.0) / _INT8_MAX
    q = jnp.round(page_f32.astype(jnp.float32) / scale[None, :, None])
    return jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8), scale


def quantize_kv_page_batched(pages_f32):
    """Quantize a batch of pages: (B, page, kv, dh) -> (q, (B, kv) f32).

    The per-(page, kv-head) scale layout the paged pools store — one f32
    per kv head per physical page, amortized over ``page * dh`` elements.
    """
    amax = jnp.max(jnp.abs(pages_f32.astype(jnp.float32)), axis=(1, 3))
    scale = jnp.where(amax > 0, amax, 1.0) / _INT8_MAX
    q = jnp.round(pages_f32.astype(jnp.float32)
                  / scale[:, None, :, None])
    return jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8), scale


def dequantize_kv_pages(q_pages, scales, dtype=jnp.float32):
    """(P, page, kv, dh) int8 + (P, kv) f32 -> dequantized pages."""
    return (q_pages.astype(jnp.float32)
            * scales[:, None, :, None]).astype(dtype)


# --------------------------------------------------------------------------
# Analytic pricing (consumed by core/schedule.py, core/partitioner.py)
# --------------------------------------------------------------------------

def weight_byte_cost(dtype_name: Optional[str], spec, hw) -> float:
    """Bytes per weight parameter under a storage dtype.

    ``None``/"auto" defaults to the hardware's ``param_bytes`` (the
    pre-quantization behaviour).  Quantized dtypes pay the payload byte
    plus the per-output-channel f32 scale amortized over the fan-in —
    priced at ``4 / d_model`` per parameter (the dominant matmuls
    contract over d_model; w2's d_ff fan-in only makes this an upper
    bound).
    """
    if dtype_name in (None, "auto"):
        return hw.param_bytes
    b = storage_bytes(dtype_name)
    if dtype_name in ("int8", "fp8"):
        b += 4.0 / spec.d_model
    return b


def kv_byte_cost(dtype_name: Optional[str], spec, page_size: int = 0) -> float:
    """Bytes per KV-cache element (one scalar of one K or V vector).

    ``None`` keeps the schedule's ACT_BYTES default.  int8 adds the
    per-page, per-kv-head f32 scale amortized over the
    ``page_size * d_head`` elements it covers (dense caches price the
    same way with an effective page of ``d_head`` — per-token scales).
    """
    if dtype_name in (None, "auto"):
        from repro.core.profiler import ACT_BYTES
        return ACT_BYTES
    b = storage_bytes(dtype_name)
    if dtype_name == "int8":
        span = (page_size if page_size else 1) * spec.d_head
        b += 4.0 / span
    return b
