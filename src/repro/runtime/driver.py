"""Fault-tolerant training driver.

Responsibilities at fleet scale (DESIGN.md §10):
  * periodic per-stage checkpointing (paper §4) + restart from the last
    round checkpointed by *all* stages;
  * failure handling — any exception in a round triggers restore + replay
    (data is deterministic in step, so replayed rounds are identical);
  * elastic scaling — on a world-size change, re-run the partitioner for
    the new machine count, re-group the stage-stacked parameters
    (checkpoint.reshard_stages), and continue;
  * straggler mitigation — measured per-stage tick times feed the
    rectangular partitioner, which proposes a rebalanced (pp, tp) plan
    (the paper's answer to skew: better partitioning, not work stealing).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, reshard_stages
from repro.core import profiler as prof
from repro.core.partitioner import partition_rectangular


@dataclasses.dataclass
class DriverConfig:
    checkpoint_every: int = 10
    max_restarts: int = 3
    keep_last: int = 3


class TrainDriver:
    def __init__(self, bundle, loader, ckpt_dir: str,
                 cfg: DriverConfig = DriverConfig(),
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.bundle = bundle
        self.loader = loader
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir)
        self.failure_hook = failure_hook or (lambda step: None)
        self._jit_step = jax.jit(
            bundle.train_step,
            in_shardings=(bundle.state_shardings(), bundle.batch_shardings()),
            out_shardings=(bundle.state_shardings(), None),
            donate_argnums=0)
        self.metrics_log: List[Dict[str, float]] = []
        self.stage_times: List[float] = []

    # ---------------- main loop -------------------------------------------

    def run(self, state, n_rounds: int, start_step: int = 0):
        step = start_step
        restarts = 0
        while step < n_rounds:
            try:
                self.failure_hook(step)          # may raise (simulated fault)
                batch = self.loader.get(step)
                t0 = time.perf_counter()
                state, metrics = self._jit_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                self.stage_times.append(time.perf_counter() - t0)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state, self.bundle.plan.pp)
            except Exception:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                state, step = self.restore_latest(state)
        return state, step

    def restore_latest(self, state_template):
        rnd = self.ckpt.latest_complete_round()
        if rnd is None:
            # no complete checkpoint: restart from scratch (round 0)
            st = jax.jit(self.bundle.init_state,
                         out_shardings=self.bundle.state_shardings())(
                jax.random.key(0))
            return st, 0
        host_template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype),
            jax.eval_shape(self.bundle.init_state, jax.random.key(0)))
        restored = self.ckpt.restore(rnd, host_template)
        sh = self.bundle.state_shardings()
        restored = jax.tree.map(jax.device_put, restored, sh)
        return restored, rnd


# --------------------------------------------------------------------------
# Elastic re-planning
# --------------------------------------------------------------------------

def elastic_replan(spec, old_plan, new_model_axis: int, hw=prof.TPU_V5E, *,
                   minibatch_tokens: int, data_replicas: int):
    """Choose (pp, tp) for a new model-axis size via the partitioner.

    Tries every pp dividing both the axis and the layer count with a valid
    stage program; scores each with the rectangular DP bottleneck time and
    returns the best plan.
    """
    profiles = prof.profile_analytic(spec, hw,
                                     minibatch_tokens=minibatch_tokens)
    best = None
    vstages = getattr(old_plan, "virtual_stages", 1)
    for pp in range(1, new_model_axis + 1):
        if new_model_axis % pp or spec.n_layers % (pp * vstages):
            continue
        if vstages > 1 and old_plan.microbatches % pp:
            continue  # interleaved schedule needs R divisible by stages
        try:
            spec.stage_program(pp * vstages)
        except AssertionError:
            continue
        tp = new_model_axis // pp
        if spec.n_heads and spec.n_heads % tp:
            continue
        part = partition_rectangular(profiles, max(pp, 1), data_replicas, hw)
        score = part.bottleneck_time
        if best is None or score < best[0]:
            best = (score, pp, tp)
    assert best is not None, "no feasible plan"
    _, pp, tp = best
    return old_plan.with_(pp=pp, tp=tp)


def reshard_state_for_plan(state_host, spec, old_plan, new_plan):
    """Move a host-side checkpointed state to a new pipeline depth.

    Ring sizes and whether a stash ring exists at all come from the
    target plan's schedule (core/schedule.py) — a flush/interleaved
    target drops the ring, a 1F1B target rebuilds it at the new
    2(S−1)+1 size from the current weights (the restart is a sync
    point, so seeding every version with the live weights is exact).
    """
    if old_plan.virtual_stages == new_plan.virtual_stages \
            and old_plan.pp == new_plan.pp:
        return state_host
    assert old_plan.virtual_stages == 1 and new_plan.virtual_stages == 1, (
        "elastic reshard from/to an interleaved plan is an open item "
        "(storage-order chunk regrouping); see ROADMAP")
    new_stages = reshard_stages(state_host["params"]["stages"],
                                old_plan.pp, new_plan.pp)
    import jax.numpy as jnp

    from repro.models.spec import stage_varying_scalars

    out = dict(state_host)
    params = dict(state_host["params"])
    params["stages"] = new_stages
    # windows/thetas re-derive from the spec
    w, t = stage_varying_scalars(spec, new_plan.pp)
    params["layer_windows"] = jnp.asarray(w, jnp.int32)
    params["layer_thetas"] = jnp.asarray(t, jnp.float32)
    out["params"] = params
    # optimizer/stash state: re-group the same way
    out["opt_stages"] = {
        slot: reshard_stages(sub, old_plan.pp, new_plan.pp)
        for slot, sub in state_host["opt_stages"].items()}
    out["stash"] = {"current": new_stages}
    new_sched = new_plan.make_schedule()
    if new_sched.uses_stash_ring:
        out["stash"]["ring"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (new_sched.stash_slots,) + a.shape) + 0, new_stages)
    return out


# --------------------------------------------------------------------------
# Straggler mitigation: profile-guided rebalancing
# --------------------------------------------------------------------------

def rebalance_from_measurements(spec, plan, measured_stage_seconds,
                                hw=prof.TPU_V5E, *, minibatch_tokens: int,
                                data_replicas: int, slack: float = 1.25):
    """If one stage is >slack× the median (straggler), propose a new plan.

    Returns (new_plan, rebalanced: bool).  With homogeneous stacked stages
    the lever is the (pp, tp) split — deeper tp shrinks the straggling
    stage's work; the partitioner arbitrates using measured times scaled
    into the analytic profile.
    """
    times = np.asarray(measured_stage_seconds, float)
    med = float(np.median(times))
    if med <= 0 or float(times.max()) <= slack * med:
        return plan, False
    new_plan = elastic_replan(spec, plan, plan.pp * plan.tp, hw,
                              minibatch_tokens=minibatch_tokens,
                              data_replicas=data_replicas)
    if (new_plan.pp, new_plan.tp) == (plan.pp, plan.tp) and plan.pp > 1:
        # fall back: halve pipeline depth, double tensor parallelism
        new_plan = plan.with_(pp=plan.pp // 2, tp=plan.tp * 2)
    return new_plan, True
