"""Fault-tolerant training driver.

Responsibilities at fleet scale (DESIGN.md §10):
  * periodic per-stage checkpointing (paper §4) + restart from the last
    round checkpointed by *all* stages;
  * failure handling — any exception in a round triggers restore + replay
    (data is deterministic in step, so replayed rounds are identical);
  * elastic scaling — on a world-size change, re-run the partitioner for
    the new machine count, re-group the stage-stacked parameters
    (checkpoint.reshard_stages), and continue;
  * straggler mitigation — measured per-stage tick times feed the
    rectangular partitioner, which proposes a rebalanced (pp, tp) plan
    (the paper's answer to skew: better partitioning, not work stealing).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, reshard_stages
from repro.core import profiler as prof
from repro.core.partitioner import PlanChoice, plan_search


@dataclasses.dataclass
class DriverConfig:
    checkpoint_every: int = 10
    max_restarts: int = 3
    keep_last: int = 3


class TrainDriver:
    def __init__(self, bundle, loader, ckpt_dir: str,
                 cfg: DriverConfig = DriverConfig(),
                 failure_hook: Optional[Callable[[int], None]] = None,
                 obs=None,
                 stage_seconds_fn: Optional[Callable[[int], Any]] = None):
        self.bundle = bundle
        self.loader = loader
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir)
        self.failure_hook = failure_hook or (lambda step: None)
        # observability (repro.obs.Observability): one on_round("train")
        # per executed round.  The SPMD step is one fused device program,
        # so the host cannot time stages individually; stage_seconds_fn
        # (step -> per-stage seconds, e.g. from a profiler hook or a
        # straggler harness) feeds the stage_round_seconds{stage=}
        # histograms that replan_from_registry re-plans from.
        self.obs = obs if obs is not None else getattr(bundle, "obs", None)
        self.stage_seconds_fn = stage_seconds_fn
        self._jit_step = jax.jit(
            bundle.train_step,
            in_shardings=(bundle.state_shardings(), bundle.batch_shardings()),
            out_shardings=(bundle.state_shardings(), None),
            donate_argnums=0)
        self.metrics_log: List[Dict[str, float]] = []
        self.stage_times: List[float] = []

    # ---------------- main loop -------------------------------------------

    def run(self, state, n_rounds: int, start_step: int = 0):
        step = start_step
        restarts = 0
        while step < n_rounds:
            try:
                self.failure_hook(step)          # may raise (simulated fault)
                batch = self.loader.get(step)
                clk = (self.obs.clock if self.obs is not None
                       else time.perf_counter)
                t0 = clk()
                state, metrics = self._jit_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                t1 = clk()
                self.stage_times.append(t1 - t0)
                if self.obs is not None:
                    self.obs.on_round("train", self.bundle.sched, t0, t1)
                    if self.stage_seconds_fn is not None:
                        hist = self.obs.histogram("stage_round_seconds")
                        for s, sec in enumerate(self.stage_seconds_fn(step)):
                            hist.observe(float(sec), stage=s)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state, self.bundle.plan.pp)
                    # durable progress: a complete checkpoint resets the
                    # failure budget, so max_restarts bounds *consecutive*
                    # failures, not sporadic ones over a long run
                    restarts = 0
            except Exception:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                state, step = self.restore_latest(state)
        return state, step

    def restore_latest(self, state_template):
        rnd = self.ckpt.latest_complete_round()
        if rnd is None:
            # no complete checkpoint: restart from scratch (round 0)
            st = jax.jit(self.bundle.init_state,
                         out_shardings=self.bundle.state_shardings())(
                jax.random.key(0))
            return st, 0
        host_template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype),
            jax.eval_shape(self.bundle.init_state, jax.random.key(0)))
        restored = self.ckpt.restore(rnd, host_template)
        sh = self.bundle.state_shardings()
        restored = jax.tree.map(jax.device_put, restored, sh)
        return restored, rnd


# --------------------------------------------------------------------------
# Elastic re-planning
# --------------------------------------------------------------------------

def elastic_replan(spec, old_plan, new_model_axis: int, hw=prof.TPU_V5E, *,
                   minibatch_tokens: int, data_replicas: int,
                   measured_stage_seconds=None, schedules=None,
                   hbm_bytes=None) -> Any:
    """Choose (pp, tp, schedule, virtual_stages) for a new model axis.

    Backed by :func:`~repro.core.partitioner.plan_search`: every
    candidate is scored by the simulated time-weighted round_time of its
    schedule tables and rejected when its MemoryModel exceeds the HBM
    budget — so a shrink event can re-pick the schedule too (e.g.
    stash → interleaved to trade the now-unaffordable version ring for
    bubble; the restart is a sync point, so the switch is semantically
    clean and ``reshard_state_for_plan`` regroups the chunks).

    ``measured_stage_seconds`` (per physical stage of ``old_plan``)
    calibrates the analytic profile before the search — see
    :func:`rebalance_from_measurements`.
    """
    choice = plan_choice(spec, old_plan, new_model_axis, hw,
                         minibatch_tokens=minibatch_tokens,
                         data_replicas=data_replicas,
                         measured_stage_seconds=measured_stage_seconds,
                         schedules=schedules, hbm_bytes=hbm_bytes)
    return choice.plan


def plan_choice(spec, old_plan, new_model_axis: int, hw=prof.TPU_V5E, *,
                minibatch_tokens: int, data_replicas: int,
                measured_stage_seconds=None, schedules=None,
                hbm_bytes=None) -> PlanChoice:
    """elastic_replan returning the full scored PlanChoice (round_time,
    bubble, MemoryModel) — what launch/train and launch/dryrun surface."""
    profiles = prof.profile_analytic(spec, hw,
                                     minibatch_tokens=minibatch_tokens)
    if measured_stage_seconds is not None:
        profiles = prof.scale_profiles_to_measurements(
            profiles, measured_stage_seconds, n_stages=old_plan.pp,
            virtual_stages=old_plan.virtual_stages)
    return plan_search(spec, old_plan, new_model_axis, hw,
                       minibatch_tokens=minibatch_tokens,
                       data_replicas=data_replicas, profiles=profiles,
                       schedules=schedules, hbm_bytes=hbm_bytes)


def plan_search_report(spec, base_plan, hw=prof.TPU_V5E, *, seq_len: int,
                       global_batch: int, data_replicas: int,
                       prefix: str = "", workload: str = "train",
                       sp: bool = False, weight_dtype=None,
                       kv_dtype=None) -> PlanChoice:
    """Shared launch-entry-point surface: search, print, return.

    Used by launch/train.py and launch/dryrun.py so the microbatch-token
    derivation and the printed summary stay in sync between them.
    ``workload`` follows :func:`~repro.core.partitioner.plan_search`:
    serving workloads derive per-microbatch tokens from the decode
    microbatch count (one query token per row when decoding) and budget
    the KV/SSM cache against the HBM alongside the weights.
    """
    dp = max(data_replicas, 1)
    if workload == "train":
        mb_tokens = seq_len * max(global_batch // dp
                                  // base_plan.microbatches, 1)
        choice = plan_choice(spec, base_plan, base_plan.pp * base_plan.tp,
                             hw, minibatch_tokens=mb_tokens,
                             data_replicas=data_replicas)
    else:
        from repro.core.schedule import fit_serving_microbatches
        R = fit_serving_microbatches(base_plan.decode_microbatches,
                                     global_batch, dp, sp=sp)
        rows = global_batch if sp else max(global_batch // dp // R, 1)
        mb_tokens = rows * (seq_len if workload == "prefill" else 1)
        choice = plan_search(spec, base_plan, base_plan.pp * base_plan.tp,
                             hw, minibatch_tokens=mb_tokens,
                             data_replicas=data_replicas,
                             workload=workload, cache_len=seq_len,
                             global_batch=global_batch, sp=sp,
                             weight_dtype=weight_dtype, kv_dtype=kv_dtype)
    print(f"{prefix}plan_search[{workload}]: {choice.describe()}")
    print(f"{prefix}  predicted {choice.memory}")
    return choice


def _storage_perms(plan):
    """(to_layer_major, from_layer_major) row-gather indices, or None.

    Interleaved storage row p = s·v + j holds model chunk j·S + s
    (schedule.storage_chunk_order); layer-major order is what
    ``reshard_stages`` regroups over.
    """
    if plan.virtual_stages == 1:
        return None
    order = np.asarray(plan.make_schedule().storage_chunk_order())
    return np.argsort(order), order


def _regroup_chunks(tree, old_plan, new_plan):
    """Stage-stacked leaves [old_chunks, ...] -> [new_chunks, ...].

    Goes through canonical layer-major chunk order: un-permute the
    interleaved storage order if the source is interleaved, regroup the
    stage boundaries, re-permute for an interleaved target.
    """
    old_chunks = old_plan.pp * old_plan.virtual_stages
    new_chunks = new_plan.pp * new_plan.virtual_stages
    src = _storage_perms(old_plan)
    if src is not None:
        tree = jax.tree.map(lambda a: a[src[0]], tree)
    tree = reshard_stages(tree, old_chunks, new_chunks)
    dst = _storage_perms(new_plan)
    if dst is not None:
        tree = jax.tree.map(lambda a: a[dst[1]], tree)
    return tree


def reshard_state_for_plan(state_host, spec, old_plan, new_plan):
    """Move a host-side checkpointed state to a new pipeline layout.

    Handles any (pp, virtual_stages) -> (pp', virtual_stages') move —
    parameters are keyed by global layer, so an interleaved source or
    target is a storage-order permutation around the same layer-major
    regroup.  Ring sizes and whether a stash ring exists at all come
    from the target plan's schedule (core/schedule.py) — a
    flush/interleaved target drops the ring, a 1F1B target rebuilds it
    at the new 2(S−1)+1 size from the current weights, and an
    async-interleaved target rebuilds the chunk-major per-chunk ring
    ([stash_slots, pp'·v', ...] over the regrouped storage rows) the
    same way (the restart is a sync point, so seeding every version
    with the live weights is exact).

    Serving plans ride the same path: the serving engine stores its
    weights (and caches) in the SAME chunk-major storage order as the
    training interleaved family, so a train checkpoint at (pp, v) is
    bit-identical under a serve plan at (pp, v) — the round-trip is the
    identity on parameters — and a serving state (no ``opt_stages`` /
    ``stash`` keys) regroups its parameters without growing them.  The
    per-slot ``pos``/``live`` vectors of a continuous-batching state
    are slot-major, not chunk-major: they pass through untouched while
    the cache rows permute, staying aligned with the (unchanged) slot
    axis — partially-filled states reshard exactly like full ones.
    """
    old_sched = old_plan.make_schedule()
    new_sched = new_plan.make_schedule()
    same_layout = (old_plan.virtual_stages == new_plan.virtual_stages
                   and old_plan.pp == new_plan.pp)
    has_rings = "stash" in state_host
    old_ring = old_sched.uses_stash_ring and has_rings
    new_ring = new_sched.uses_stash_ring and has_rings
    if same_layout and old_ring == new_ring \
            and (not new_ring
                 or old_sched.stash_slots == new_sched.stash_slots):
        return state_host
    # a schedule-only change at the same (pp, v) still falls through: the
    # state tree's stash ring must be dropped/rebuilt to the new schedule
    new_chunks = new_plan.pp * new_plan.virtual_stages
    new_stages = (state_host["params"]["stages"] if same_layout
                  else _regroup_chunks(state_host["params"]["stages"],
                                       old_plan, new_plan))
    import jax.numpy as jnp

    from repro.models.spec import stage_varying_scalars

    out = dict(state_host)
    params = dict(state_host["params"])
    params["stages"] = new_stages
    # windows/thetas re-derive from the spec (rows follow storage order)
    w, t = stage_varying_scalars(spec, new_chunks)
    w = jnp.asarray(w, jnp.int32)
    t = jnp.asarray(t, jnp.float32)
    dst = _storage_perms(new_plan)
    if dst is not None:
        w, t = w[dst[1]], t[dst[1]]
    params["layer_windows"] = w
    params["layer_thetas"] = t
    out["params"] = params
    # optimizer/stash state: re-group the same way (training states only —
    # a serving state carries neither)
    if "opt_stages" in state_host:
        out["opt_stages"] = {
            slot: (sub if same_layout
                   else _regroup_chunks(sub, old_plan, new_plan))
            for slot, sub in state_host["opt_stages"].items()}
    # a serving KV/SSM cache is chunk-stacked like the weights: permute
    # its rows through the same storage orders.  Across chunk *counts*
    # the per-row layer groups change and live recurrent state cannot be
    # re-cut — refuse loudly; the caller re-prefills after replanning.
    if "cache" in state_host:
        old_chunks = old_plan.pp * old_plan.virtual_stages
        if old_chunks != new_chunks:
            raise ValueError(
                "cannot reshard a serving KV/SSM cache across chunk "
                f"counts ({old_chunks} -> {new_chunks} storage rows): "
                "per-row layer groups change; re-prefill after "
                "replanning (params regroup fine — drop 'cache' from "
                "the state to move weights only)")
        src = _storage_perms(old_plan)
        dst = _storage_perms(new_plan)

        def _rows(a):
            if src is not None:
                a = a[src[0]]
            if dst is not None:
                a = a[dst[1]]
            return a

        out["cache"] = jax.tree.map(_rows, state_host["cache"])
        # the paged KV page pool is chunk-stacked exactly like the dense
        # cache: permute its leading rows the same way.  Page tables are
        # slot-major and shared across all paged layers — they pass
        # through untouched, like pos/live.
        if "pages" in state_host:
            out["pages"] = jax.tree.map(_rows, state_host["pages"])
    if has_rings:
        out["stash"] = {"current": new_stages}
        if new_sched.uses_stash_ring:
            out["stash"]["ring"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (new_sched.stash_slots,) + a.shape) + 0,
                new_stages)
    return out


# --------------------------------------------------------------------------
# Straggler mitigation: profile-guided rebalancing
# --------------------------------------------------------------------------

def rebalance_from_measurements(spec, plan, measured_stage_seconds,
                                hw=prof.TPU_V5E, *, minibatch_tokens: int,
                                data_replicas: int, slack: float = 1.25,
                                schedules=None, hbm_bytes=None):
    """If one stage is >slack× the median (straggler), propose a new plan.

    Returns (new_plan, rebalanced: bool).  The measured per-stage times
    are scaled into the analytic profile
    (profiler.scale_profiles_to_measurements) *before* the search — the
    replanner used to call the purely analytic profile and therefore
    proposed the same plan regardless of what was measured; now the DP
    sees the straggler's layers as genuinely slower, so deeper tp (or a
    different schedule) can shrink the straggling stage's work.
    """
    times = np.asarray(measured_stage_seconds, float)
    med = float(np.median(times))
    if med <= 0 or float(times.max()) <= slack * med:
        return plan, False
    new_plan = elastic_replan(spec, plan, plan.pp * plan.tp, hw,
                              minibatch_tokens=minibatch_tokens,
                              data_replicas=data_replicas,
                              measured_stage_seconds=measured_stage_seconds,
                              schedules=schedules, hbm_bytes=hbm_bytes)
    same = ((new_plan.pp, new_plan.tp, new_plan.virtual_stages)
            == (plan.pp, plan.tp, plan.virtual_stages)
            and new_plan.make_schedule().name == plan.make_schedule().name)
    if same and plan.pp > 1:
        # fall back: halve pipeline depth, double tensor parallelism —
        # but only if that plan would survive plan_search's own checks
        fb = plan.with_(pp=plan.pp // 2, tp=plan.tp * 2)
        if _plan_is_buildable(spec, fb, hw,
                              minibatch_tokens=minibatch_tokens,
                              data_replicas=data_replicas,
                              hbm_bytes=hbm_bytes):
            new_plan = fb
    return new_plan, True


def replan_from_registry(spec, plan, registry, hw=prof.TPU_V5E, *,
                         minibatch_tokens: int, data_replicas: int,
                         slack: float = 1.25, schedules=None,
                         hbm_bytes=None):
    """Rebalance off telemetry the run actually collected.

    Reads the per-stage mean wall seconds out of the metrics registry's
    ``stage_round_seconds{stage=}`` histograms (populated by
    :class:`TrainDriver` via its ``stage_seconds_fn`` hook, or by any
    executor timing its stages through ``Registry.timer``) and hands
    them to :func:`rebalance_from_measurements` — the end of the
    paper's profile→plan→measure→replan loop, with no hand-injected
    numbers between the measurement and the search.  Returns
    ``(new_plan, rebalanced)``; raises ``ValueError`` when any of
    ``plan.pp`` stages has no samples.
    """
    from repro.obs.reconcile import stage_seconds
    measured = stage_seconds(registry, plan.pp)
    return rebalance_from_measurements(
        spec, plan, measured, hw, minibatch_tokens=minibatch_tokens,
        data_replicas=data_replicas, slack=slack, schedules=schedules,
        hbm_bytes=hbm_bytes)


def _plan_is_buildable(spec, plan, hw, *, minibatch_tokens: int,
                       data_replicas: int, hbm_bytes=None) -> bool:
    """Structural + HBM feasibility, mirroring plan_search's filters."""
    n_chunks = plan.pp * plan.virtual_stages
    if spec.n_layers % n_chunks:
        return False
    if spec.n_heads and spec.n_heads % plan.tp:
        return False
    if plan.virtual_stages > 1 and plan.microbatches % plan.pp:
        return False
    try:
        spec.stage_program(n_chunks)
    except AssertionError:
        return False
    mm = plan.make_schedule().memory_model(
        spec, plan, hw, microbatch_tokens=minibatch_tokens,
        data_replicas=data_replicas)
    budget = hw.hbm_bytes if hbm_bytes is None else hbm_bytes
    return mm.fits(budget)
