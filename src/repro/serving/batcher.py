"""Continuous batching: an Orca-style slot scheduler over the serve tables.

The one-shot :class:`~repro.serving.engine.EngineSession` runs a single
synchronized batch: every schedule microbatch slot prefills together and
decodes until the caller stops — a finished sequence's slot keeps
burning its table rows (bubbles), and new requests wait for a full
restart.  This module turns that session into a request-stream server
by scheduling *slots* instead of batches, Orca-style (iteration-level
scheduling):

  * a **slot** is one of the serve schedule's R microbatch slots — the
    unit the tables already name per tick (``F_MB``) — carrying
    ``lanes`` sequence rows and, on the device, its own cache rows,
    cache position and liveness (``state["pos"][r]`` /
    ``state["live"][r]``, serving/engine.py);
  * **requests** move waiting → prefilling → decoding → finished:
    admission writes a waiting request's prefill into a free slot
    mid-stream (``EngineSession.write_prefill_into_slots`` — a masked
    per-slot prefill pass, no global flush: live slots' recurrent state
    is untouched and their decode resumes from the same pipeline
    state), and eviction on EOS / ``max_new_tokens`` frees the slot and
    its cache rows on the *next* scheduler tick
    (``EngineSession.reset_slots``);
  * the per-slot cache-lifetime discipline is the serving analogue of
    PipeDream-2BW's bounded weight/activation versions: at most R slot
    caches are ever live, and a slot's cache lifetime is exactly its
    request's admission→eviction interval.

Exactness: because admission only gates *writes* per slot (rows are
independent in every mixer), a request admitted mid-stream decodes
bit-exactly (fp32) what the same request produces in a solo one-shot
run — scripts/batch_smoke.py and tests/test_batcher.py prove it
against ``serve_1f`` for S ∈ {2, 4} including interleaved (v = 2)
configs.

Scheduling policies:

  * ``policy="continuous"`` — admit into any free slot the moment both
    the slot and a request are available (the point of this module);
  * ``policy="synchronized"`` — admit only when EVERY slot is free
    (drain-then-refill), the PR-4 baseline the benchmark
    (benchmarks/batching_bench.py) compares against.

Time is counted in scheduler **steps** (one step = at most one masked
admission pass + one decode round), which keeps arrival traces
deterministic under a real engine; wall-clock seconds come from a
pluggable ``clock`` so the analytic benchmark can drive the same
scheduler with modeled time.

Prompts up to the engine's ``prefill_len`` admit directly: the masked
prefill is a fixed-shape pipelined pass, so shorter (ragged) prompts are
right-padded into the batch and a per-slot ``lens`` vector tells the
engine where each slot's real prompt ends (the first token is read at
``lens - 1`` and decode resumes from ``pos = lens``) — no global flush,
no per-length session builds.  Models with recurrent (mamba/rwkv) state
still need exact-length prompts (their prefill would absorb the
padding); prompts *longer* than ``prefill_len`` always raise.

Paged KV (``build_serving(page_size=...)``): this module also owns the
:class:`PageAllocator` — the host-side free list behind the engine's
global page pool.  Admission allocates ``ceil(len / page_size)`` pages
per slot, decode allocates one page at each page-boundary crossing, and
eviction releases the slot's pages.  When the pool cannot cover a
prompt, admission *queues* the request (no crash) and retries after the
next eviction.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np

__all__ = ["PageAllocator", "Request", "RequestQueue", "Slot",
           "BatchingReport", "ContinuousBatchingSession"]


class PageAllocator:
    """Host-side free-list allocator for the global KV page pool.

    The pool is ``pool_pages`` pages of ``page_size`` tokens each; every
    slot owns an ordered page-table row (``tables[slot]``, int32, -1 =
    unallocated) shared by all paged attention layers (a slot's layers
    hold identical lengths, so one table indexes every layer's pool).
    Freed pages go back on the free list LIFO — reuse needs no zeroing,
    because admission overwrites every allocated prompt page and decode
    writes each position before it becomes visible (the k_pos mask hides
    stale tails).

    Invariants (checked by :meth:`check`, gated by scripts/page_smoke.py):
    live + free page counts partition the pool, no page appears twice,
    and a slot's page count is exactly ``ceil(tokens / page_size)``.
    """

    def __init__(self, pool_pages: int, n_slots: int, max_pages: int,
                 page_size: int):
        if pool_pages <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry: {pool_pages=} {page_size=}")
        self.pool_pages = int(pool_pages)
        self.page_size = int(page_size)
        self.max_pages = int(max_pages)
        self.n_slots = int(n_slots)
        self.free: List[int] = list(range(self.pool_pages - 1, -1, -1))
        self.tables = np.full((n_slots, max_pages), -1, np.int32)
        self.counts = np.zeros(n_slots, np.int64)   # pages per slot
        self.tokens = np.zeros(n_slots, np.int64)   # tokens per slot

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def live_pages(self) -> int:
        return int(self.counts.sum())

    def pages_needed(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    def alloc_slot(self, slot: int, n_tokens: int) -> None:
        """(Re)allocate ``slot`` to hold an ``n_tokens`` prompt."""
        if n_tokens > self.max_pages * self.page_size:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed the paged KV "
                f"capacity of {self.max_pages * self.page_size} tokens "
                f"({self.max_pages} pages x {self.page_size})")
        need = self.pages_needed(n_tokens)
        self.release_slot(slot)
        if need > len(self.free):
            raise RuntimeError(
                f"page pool exhausted: slot {slot} needs {need} pages, "
                f"{len(self.free)}/{self.pool_pages} free — the batcher "
                "should queue admissions when the pool runs dry")
        for i in range(need):
            self.tables[slot, i] = self.free.pop()
        self.counts[slot] = need
        self.tokens[slot] = n_tokens

    def extend_slot(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot`` to cover ``n_tokens`` (decode boundary crossing)."""
        if n_tokens > self.max_pages * self.page_size:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed the paged KV "
                f"capacity of {self.max_pages * self.page_size} tokens")
        need = self.pages_needed(n_tokens)
        while self.counts[slot] < need:
            if not self.free:
                raise RuntimeError(
                    f"page pool exhausted growing slot {slot} to "
                    f"{n_tokens} tokens ({need} pages); evict a slot or "
                    "size pool_pages for the worst-case decode length")
            self.tables[slot, self.counts[slot]] = self.free.pop()
            self.counts[slot] += 1
        self.tokens[slot] = max(int(self.tokens[slot]), int(n_tokens))

    def truncate_slot(self, slot: int, n_tokens: int) -> int:
        """Shrink ``slot`` to ``n_tokens``, freeing now-unused tail pages.

        The speculative-decode epilogue: verify writes KV for all
        ``spec_k + 1`` proposed positions, then the accepted prefix
        keeps only ``pos'`` of them — pages wholly past the accepted
        length go straight back on the free list (the k_pos mask hides
        the stale partial tail of the last kept page).  Returns the
        number of pages freed.  Growing is :meth:`extend_slot`'s job:
        asking for more tokens than the slot holds raises.
        """
        n_tokens = int(n_tokens)
        if n_tokens < 0:
            raise ValueError(f"slot {slot}: cannot truncate to "
                             f"{n_tokens} tokens")
        if n_tokens > int(self.tokens[slot]):
            raise ValueError(
                f"slot {slot}: truncate_slot({n_tokens}) exceeds the "
                f"slot's {int(self.tokens[slot])} tokens — truncate "
                "only shrinks (extend_slot grows)")
        need = self.pages_needed(n_tokens)
        freed = 0
        while self.counts[slot] > need:
            self.counts[slot] -= 1
            pid = int(self.tables[slot, self.counts[slot]])
            if pid < 0:
                raise AssertionError(
                    f"slot {slot} table corrupt: entry "
                    f"{int(self.counts[slot])} unallocated inside the "
                    "counted range")
            self.tables[slot, self.counts[slot]] = -1
            self.free.append(pid)
            freed += 1
        self.tokens[slot] = n_tokens
        return freed

    def permute_slots(self, perm) -> None:
        """Reorder the slot rows: new slot i takes old slot perm[i].

        The host half of ``EngineSession.compact_slots``: page *ids*
        (and therefore the pool and free list) are untouched — a slot's
        pages travel with its table row, so compaction never moves or
        re-owns a page, it only renames which slot index points at it.
        """
        perm = np.asarray(perm, np.int64).reshape(-1)
        if sorted(perm.tolist()) != list(range(self.n_slots)):
            raise ValueError(
                f"perm must be a permutation of range({self.n_slots}), "
                f"got {perm.tolist()}")
        self.tables = self.tables[perm].copy()
        self.counts = self.counts[perm].copy()
        self.tokens = self.tokens[perm].copy()

    def release_slot(self, slot: int) -> None:
        """Return the slot's pages to the pool (no-op on an empty slot)."""
        n = int(self.counts[slot])
        for i in range(n):
            pid = int(self.tables[slot, i])
            if pid < 0:
                raise AssertionError(
                    f"slot {slot} table corrupt: entry {i} unallocated "
                    f"inside counted range {n}")
            self.free.append(pid)
        self.tables[slot, :] = -1
        self.counts[slot] = 0
        self.tokens[slot] = 0

    def check(self) -> None:
        """Assert the allocator invariants (scripts/page_smoke.py gate)."""
        live = [int(p) for row, c in zip(self.tables, self.counts)
                for p in row[:int(c)]]
        if any(p < 0 for p in live):
            raise AssertionError("unallocated entry inside a counted range")
        seen = live + [int(p) for p in self.free]
        if len(seen) != self.pool_pages or len(set(seen)) != len(seen):
            raise AssertionError(
                f"pages lost or double-booked: {len(set(seen))} unique of "
                f"{len(seen)} tracked, pool is {self.pool_pages}")
        for s in range(self.n_slots):
            if int(self.counts[s]) != self.pages_needed(self.tokens[s]):
                raise AssertionError(
                    f"slot {s}: {int(self.counts[s])} pages != "
                    f"ceil({int(self.tokens[s])} / {self.page_size})")
            tail = self.tables[s, int(self.counts[s]):]
            if (tail >= 0).any():
                raise AssertionError(f"slot {s}: pages beyond count")


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record.

    ``arrival`` is the scheduler step at which the request becomes
    visible to the server.  The scheduler fills the lifecycle fields:
    generated ``tokens`` (the prefill's first token included), the
    admission/first-token/completion steps, and the wall-clock stamps
    (from the session's ``clock``).
    """

    rid: int
    prompt: np.ndarray             # (prefill_len,) int32
    max_new_tokens: int
    arrival: int = 0               # scheduler step of arrival
    eos_id: Optional[int] = None   # per-request override of the session's

    # -- lifecycle (scheduler-owned) --------------------------------------
    state: str = "waiting"         # waiting|prefilling|decoding|finished
    # finished early because its slot ran out of KV room mid-decode
    # (CacheExhausted backpressure) — tokens holds what was generated
    truncated: bool = False
    tokens: List[int] = dataclasses.field(default_factory=list)
    step_admitted: Optional[int] = None
    step_first: Optional[int] = None
    step_done: Optional[int] = None
    t_arrival: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def finished(self) -> bool:
        return self.state == "finished"

    def _record(self, token: int, step: int, now: float,
                eos_id: Optional[int]) -> None:
        """Append one generated token; flip to finished on EOS/max_len."""
        self.tokens.append(int(token))
        if self.t_first is None:
            self.t_first, self.step_first = now, step
        self.state = "decoding"
        eos = self.eos_id if self.eos_id is not None else eos_id
        if (eos is not None and int(token) == eos) \
                or len(self.tokens) >= self.max_new_tokens:
            self.state = "finished"
            self.t_done, self.step_done = now, step


class RequestQueue:
    """Arrival-gated FIFO of waiting requests."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._pending = deque(sorted(requests,
                                     key=lambda r: (r.arrival, r.rid)))
        self._ready: deque = deque()

    def push(self, request: Request) -> None:
        """Add a request (arrival must be >= every queued arrival)."""
        if self._pending and request.arrival < self._pending[-1].arrival:
            raise ValueError(
                f"request {request.rid} arrives at step {request.arrival}, "
                f"before the queue tail "
                f"({self._pending[-1].arrival}); push in arrival order")
        self._pending.append(request)

    def absorb_arrivals(self, step: int, now: float) -> None:
        """Move every request with ``arrival <= step`` into the ready FIFO."""
        while self._pending and self._pending[0].arrival <= step:
            r = self._pending.popleft()
            r.t_arrival = now
            self._ready.append(r)

    def pop_ready(self) -> Optional[Request]:
        return self._ready.popleft() if self._ready else None

    def peek_ready(self) -> Optional[Request]:
        return self._ready[0] if self._ready else None

    def push_front(self, request: Request) -> None:
        """Return a popped request to the head (admission stall/retry)."""
        self._ready.appendleft(request)

    @property
    def n_ready(self) -> int:
        return len(self._ready)

    def __len__(self) -> int:
        return len(self._pending) + len(self._ready)


@dataclasses.dataclass
class Slot:
    """One schedule microbatch slot: ``lanes`` request lanes that share
    the slot's device-side cache rows, position and liveness."""

    index: int
    lanes: int
    requests: List[Optional[Request]] = dataclasses.field(
        default_factory=list)

    def __post_init__(self):
        if not self.requests:
            self.requests = [None] * self.lanes

    @property
    def free(self) -> bool:
        return all(r is None for r in self.requests)

    @property
    def drained(self) -> bool:
        """Occupied, and every request in it has finished (evict next tick)."""
        return (not self.free
                and all(r is None or r.finished for r in self.requests))

    def live_lanes(self):
        """(lane, request) pairs still decoding."""
        return [(i, r) for i, r in enumerate(self.requests)
                if r is not None and not r.finished]

    def clear(self) -> None:
        self.requests = [None] * self.lanes


@dataclasses.dataclass
class BatchingReport:
    """Outcome of one :meth:`ContinuousBatchingSession.run`."""

    requests: List[Request]
    policy: str
    steps: int
    decode_rounds: int
    admit_rounds: int
    wall_seconds: float
    # -- speculative decode accounting (zero on a plain session) ----------
    spec_rounds: int = 0        # verify rounds run
    spec_lane_rounds: int = 0   # live (lane, round) pairs across the run
    drafted_tokens: int = 0     # spec_k drafts proposed per live lane-round
    accepted_drafts: int = 0    # drafts the verifier accepted
    accepted_tokens: int = 0    # tokens actually committed to requests

    @property
    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.finished]

    @property
    def completed_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.completed)

    @property
    def goodput_tokens_per_s(self) -> float:
        """Completed tokens per second — tokens of unfinished requests
        do not count (that is what makes it goodput, not throughput).
        Under speculative decode only *accepted* tokens ever reach
        ``Request.tokens``, so rejected drafts never inflate this number:
        spec goodput is accepted-token goodput by construction."""
        return self.completed_tokens / max(self.wall_seconds, 1e-12)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed drafts the verifier accepted."""
        return self.accepted_drafts / max(self.drafted_tokens, 1)

    @property
    def accepted_per_round(self) -> float:
        """Mean tokens committed per lane per verify round (the
        speculative speedup over one-token-per-round decode)."""
        return self.accepted_tokens / max(self.spec_lane_rounds, 1)

    def per_token_latency_s(self) -> np.ndarray:
        """Per-request (completion − arrival) / tokens, seconds."""
        return np.asarray([(r.t_done - r.t_arrival) / len(r.tokens)
                           for r in self.completed])

    def summary(self) -> dict:
        lat = self.per_token_latency_s()
        ttft = np.asarray([r.t_first - r.t_arrival for r in self.completed])
        # latency stats over zero completed requests are None, not NaN:
        # NaN survives json.dump and trips bench_check's non-finite gate
        return {
            "policy": self.policy,
            "requests": len(self.requests),
            "completed": len(self.completed),
            "completed_tokens": self.completed_tokens,
            "steps": self.steps,
            "decode_rounds": self.decode_rounds,
            "admit_rounds": self.admit_rounds,
            "wall_seconds": self.wall_seconds,
            "goodput_tokens_per_s": self.goodput_tokens_per_s,
            "p50_per_token_latency_s":
                float(np.percentile(lat, 50)) if lat.size else None,
            "p99_per_token_latency_s":
                float(np.percentile(lat, 99)) if lat.size else None,
            "mean_ttft_s":
                float(ttft.mean()) if ttft.size else None,
        } | ({
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "accepted_drafts": self.accepted_drafts,
            "accepted_tokens": self.accepted_tokens,
            "acceptance_rate": self.acceptance_rate,
            "accepted_per_round": self.accepted_per_round,
        } if self.spec_rounds else {})


class ContinuousBatchingSession:
    """Drive an EngineSession as a request-stream server.

    ``session`` needs the admission surface (built with
    ``prefill_len > 0``); anything engine-shaped works — the analytic
    benchmark drives the same scheduler with a modeled engine.  One
    ``step()``:

      1. evict slots drained on the previous step
         (``session.reset_slots``) — EOS/max_len frees the slot and its
         cache rows the next tick;
      2. admit ready requests into free slots
         (``session.write_prefill_into_slots`` — continuous policy; the
         synchronized policy waits until every slot is free);
      3. one decode round for all live slots (``session.decode``).
    """

    def __init__(self, session, *, eos_id: Optional[int] = None,
                 policy: str = "continuous",
                 clock: Callable[[], float] = time.perf_counter,
                 draft_fn: Optional[Callable] = None, obs=None):
        if policy not in ("continuous", "synchronized"):
            raise ValueError(f"unknown policy {policy!r}")
        if getattr(session, "admit_step", None) is None:
            raise ValueError(
                "continuous batching needs the per-slot admission step; "
                "build the session with prefill_len= (> 0)")
        self.session = session
        self.eos_id = eos_id
        self.policy = policy
        self.clock = clock
        # scheduler-level metrics ride the engine's Observability unless
        # a separate one is passed; the engine itself reports its rounds
        self.obs = obs if obs is not None else getattr(session, "obs", None)
        sched = getattr(session, "sched", None)
        self.spec_k = (int(getattr(sched, "spec_k", 0))
                       if getattr(sched, "is_speculative", False) else 0)
        if draft_fn is not None and not self.spec_k:
            raise ValueError(
                "draft_fn= passed but the session's schedule is not "
                "speculative; build with spec_k= (serve_spec_* schedule) "
                "or drop draft_fn")
        # default draft source: the engine's head-only self-draft;
        # injectable so tests/benchmarks can force acceptance extremes
        self.draft_fn = (draft_fn if draft_fn is not None
                         else getattr(session, "draft", None))
        self.R = int(session.sched.n_microbatches)
        gb = int(session.token_spec.shape[0])
        tok = session.prefill_specs["tokens"].shape   # (R, rows, text_len)
        assert tok[0] == self.R, (tok, self.R)
        self.rows = int(tok[1])
        self.text_len = int(tok[2])
        if gb != self.R * self.rows:
            raise ValueError(
                f"global_batch {gb} != R·rows = {self.R}·{self.rows}")
        self.slots = [Slot(i, self.rows) for i in range(self.R)]
        self.queue = RequestQueue()
        self.steps = 0
        self.decode_rounds = 0
        self.admit_rounds = 0
        self._all: List[Request] = []
        self._reset_spec_counters()

    def _reset_spec_counters(self) -> None:
        self.spec_rounds = 0
        self.spec_lane_rounds = 0
        self.drafted_tokens = 0
        self.accepted_drafts = 0
        self.accepted_tokens = 0
        # per-slot committed-token counts (speculative accounting: how
        # many tokens each schedule slot actually emitted)
        self.accepted_per_slot = np.zeros(self.R, np.int64)

    # ---- admission -------------------------------------------------------

    def _admissible_slots(self) -> List[Slot]:
        free = [s for s in self.slots if s.free]
        if self.policy == "synchronized" and len(free) != len(self.slots):
            return []               # drain-then-refill: wait for all
        return free

    def _admit(self) -> None:
        alloc = getattr(self.session, "_alloc", None)
        ragged_ok = getattr(self.session, "ragged_ok", True)
        slots: List[Slot] = []
        slot_lens = {}
        reserved = 0        # pool pages claimed by this admission round
        stalled = False
        for slot in self._admissible_slots():
            if stalled or not self.queue.n_ready:
                break
            for lane in range(slot.lanes):
                req = self.queue.peek_ready()
                if req is None:
                    break
                plen = len(req.prompt)
                if plen > self.text_len:
                    raise ValueError(
                        f"request {req.rid}: prompt length {plen} exceeds "
                        f"the session's prefill_len {self.text_len}; "
                        "truncate on the client or build the session with "
                        "a larger prefill_len")
                if plen < self.text_len and not ragged_ok:
                    raise ValueError(
                        f"request {req.rid}: prompt length {plen} != "
                        f"prefill_len {self.text_len}, and this model "
                        "carries recurrent (mamba/rwkv) state — ragged "
                        "admission would absorb the padding; pad on the "
                        "client or build per-length sessions")
                if slot.index in slot_lens and slot_lens[slot.index] != plen:
                    # lanes of a slot share one cache position; leave the
                    # mismatched request for the next free slot
                    break
                if alloc is not None and slot.index not in slot_lens:
                    need = alloc.pages_needed(plen)
                    if need > alloc.free_pages - reserved:
                        # page pool dry: queue the request, retry after
                        # the next eviction returns pages
                        stalled = True
                        break
                    reserved += need
                self.queue.pop_ready()
                req.state = "prefilling"
                req.step_admitted = self.steps
                slot.requests[lane] = req
                slot_lens.setdefault(slot.index, plen)
            if not slot.free:
                slots.append(slot)
        if not slots:
            return
        # admission = remapping the embeds ring: the admitted requests'
        # prompts land in their slots' rows of the (R, rows, text) batch,
        # right-padded; ``lens`` carries each slot's real prompt length
        tokens = np.zeros((self.R, self.rows, self.text_len), np.int32)
        mask = np.zeros((self.R,), np.int32)
        lens = np.full((self.R,), self.text_len, np.int32)
        for slot in slots:
            mask[slot.index] = 1
            lens[slot.index] = slot_lens[slot.index]
            for lane, req in enumerate(slot.requests):
                if req is not None:
                    tokens[slot.index, lane, :len(req.prompt)] = req.prompt
        batch = {"tokens": tokens}
        if any(slot_lens[s.index] != self.text_len for s in slots):
            batch["lens"] = lens
        first = self.session.write_prefill_into_slots(batch, mask)
        first = np.asarray(first).reshape(self.R, self.rows)
        self.admit_rounds += 1
        now = self.clock()
        for slot in slots:
            for lane, req in enumerate(slot.requests):
                if req is not None:
                    req._record(first[slot.index, lane], self.steps, now,
                                self.eos_id)

    # ---- slot compaction (liveness-aware bucketed sessions) ---------------

    def _compact(self) -> None:
        """Move occupied slots to the front (stable order) so the live
        set forms a bucket prefix.

        Only runs against a bucketed session (``session.buckets``):
        ``compact_slots`` permutes the device state, host mirrors and
        page-table rows (no KV bytes move in paged mode), and this
        scheduler permutes its request lists to match.  Admission fills
        free slots in index order, so once compacted the occupied
        prefix only ever grows contiguously — the engine's bucket
        picker sees live slots packed in ``[0, n_live)``.
        """
        if getattr(self.session, "buckets", None) is None:
            return
        occ = [s.index for s in self.slots if not s.free]
        perm = occ + [s.index for s in self.slots if s.free]
        if perm == list(range(self.R)):
            return
        self.session.compact_slots(perm)
        old = {s.index: s.requests for s in self.slots}
        for new_i, old_i in enumerate(perm):
            self.slots[new_i].requests = old[old_i]
        self.accepted_per_slot = self.accepted_per_slot[list(perm)].copy()

    def _evict_exhausted(self, slot_idx, now: float) -> None:
        """Backpressure for a :class:`CacheExhausted` decode.

        The named slots have no KV room left (paged capacity or page
        pool dry): their requests finish *truncated* — keeping the
        tokens generated so far — the slots reset (returning their
        pages), and the batch compacts so the retried decode runs a
        smaller bucket.  Queued admissions then reuse the freed room on
        the next step, matching the allocator's pool-dry admission
        behavior.
        """
        mask = np.zeros((self.R,), np.int32)
        n_truncated = 0
        for i in slot_idx:
            slot = self.slots[int(i)]
            for r in slot.requests:
                if r is not None and not r.finished:
                    r.state = "finished"
                    r.truncated = True
                    r.t_done, r.step_done = now, self.steps
                    n_truncated += 1
            slot.clear()
            mask[int(i)] = 1
        self.session.reset_slots(mask)
        self._compact()
        if self.obs is not None:
            self.obs.counter("exhausted_evictions_total").inc(len(slot_idx))
            self.obs.counter("requests_truncated_total").inc(n_truncated)

    def _live_lanes(self):
        return [(s, lane, r) for s in self.slots
                for lane, r in s.live_lanes()]

    def _decode_round(self, live) -> None:
        tokens = np.zeros((self.R, self.rows), np.int32)
        for s, lane, r in live:
            tokens[s.index, lane] = r.tokens[-1]
        nxt = self.session.decode(tokens.reshape(-1))
        nxt = np.asarray(nxt).reshape(self.R, self.rows)
        now = self.clock()
        for s, lane, r in live:
            r._record(nxt[s.index, lane], self.steps, now, self.eos_id)

    def _spec_round(self, live) -> None:
        """One draft–verify round: commit up to spec_k + 1 tokens/lane.

        Drafts come from ``draft_fn`` (default: the engine's head-only
        self-draft); the verifier scores all spec_k + 1 positions in one
        pipelined pass, and each live lane commits its slot's accepted
        prefix plus the bonus token — a request finishing mid-prefix
        (EOS / max_new_tokens) stops committing there, while its slot
        mates keep the full prefix.
        """
        K = self.spec_k
        last = np.zeros((self.R, self.rows), np.int32)
        for s, lane, r in live:
            last[s.index, lane] = r.tokens[-1]
        flat = last.reshape(-1)
        drafts = np.asarray(self.draft_fn(flat), np.int32)
        if drafts.shape != (flat.shape[0], K):
            raise ValueError(
                f"draft_fn returned shape {drafts.shape}, expected "
                f"({flat.shape[0]}, {K}) = (global_batch, spec_k)")
        toks = np.concatenate([flat[:, None], drafts], axis=1)
        scores, acc = self.session.verify(toks)
        scores = np.asarray(scores).reshape(self.R, self.rows, K + 1)
        acc = np.asarray(acc).reshape(-1)
        now = self.clock()
        self.spec_rounds += 1
        for s, lane, r in live:
            a = int(acc[s.index])
            self.spec_lane_rounds += 1
            self.drafted_tokens += K
            self.accepted_drafts += a
            for j in range(a + 1):
                r._record(scores[s.index, lane, j], self.steps, now,
                          self.eos_id)
                self.accepted_tokens += 1
                self.accepted_per_slot[s.index] += 1
                if r.finished:
                    break

    # ---- one scheduler step ----------------------------------------------

    def step(self) -> bool:
        """Run one scheduler step; returns True while work remains."""
        now = self.clock()
        # 1) evict slots drained last step: free cache rows + liveness;
        #    on a bucketed session, compact so live slots stay a prefix
        drained = [s for s in self.slots if s.drained]
        if drained:
            mask = np.zeros((self.R,), np.int32)
            for s in drained:
                mask[s.index] = 1
                s.clear()
            self.session.reset_slots(mask)
            self._compact()
        # 2) admission
        self.queue.absorb_arrivals(self.steps, now)
        if self.queue.n_ready:
            self._admit()
        # 3) one decode (or draft–verify) round for every live lane; a
        #    CacheExhausted round evicts the blocked slots (truncating
        #    their requests) and retries once — backpressure instead of
        #    a crashed serve loop
        live = self._live_lanes()
        if live:
            round_fn = self._spec_round if self.spec_k \
                else self._decode_round
            try:
                round_fn(live)
            except RuntimeError as e:
                from repro.serving.engine import CacheExhausted
                if not isinstance(e, CacheExhausted):
                    raise
                self._evict_exhausted(e.slots, self.clock())
                live = self._live_lanes()
                if live:
                    round_fn(live)
            if live:
                self.decode_rounds += 1
        self.steps += 1
        if self.obs is not None:
            self.obs.gauge("queue_depth").set(self.queue.n_ready)
            self.obs.gauge("slots_live").set(
                sum(1 for s in self.slots if not s.free))
        return bool(len(self.queue) or live
                    or any(not s.free for s in self.slots))

    # ---- main loop ---------------------------------------------------------

    def run(self, requests: Sequence[Request], *,
            max_steps: int = 100_000) -> BatchingReport:
        """Serve a trace of requests to completion (or ``max_steps``)."""
        self._all = list(requests)
        self.queue = RequestQueue(self._all)
        # fresh trace: arrival gating and accounting restart from zero
        # (a reused server would otherwise absorb every arrival at once)
        self.steps = 0
        self.decode_rounds = 0
        self.admit_rounds = 0
        self._reset_spec_counters()
        if self.session.state is None:
            self.session.start()
        # begin empty: every slot free until its first admission
        self.session.reset_slots(np.ones((self.R,), np.int32))
        for s in self.slots:
            s.clear()
        t0 = self.clock()
        while self.steps < max_steps:
            if not self.step():
                break
        report = BatchingReport(
            requests=self._all, policy=self.policy, steps=self.steps,
            decode_rounds=self.decode_rounds,
            admit_rounds=self.admit_rounds,
            wall_seconds=self.clock() - t0,
            spec_rounds=self.spec_rounds,
            spec_lane_rounds=self.spec_lane_rounds,
            drafted_tokens=self.drafted_tokens,
            accepted_drafts=self.accepted_drafts,
            accepted_tokens=self.accepted_tokens)
        if self.obs is not None:
            self._publish(report)
        return report

    def _publish(self, report: BatchingReport) -> None:
        """Fold a finished run into the registry: request/token totals,
        goodput, per-request TTFT and per-token latency histograms (p50/
        p99 fall out of the snapshot), and the speculative acceptance
        counters that used to live only in the summary dict."""
        c, g, h = self.obs.counter, self.obs.gauge, self.obs.histogram
        pol = self.policy
        c("requests_total").inc(len(report.requests), policy=pol)
        c("requests_completed_total").inc(len(report.completed), policy=pol)
        c("tokens_completed_total").inc(report.completed_tokens, policy=pol)
        g("goodput_tokens_per_s").set(report.goodput_tokens_per_s,
                                      policy=pol)
        for r in report.completed:
            h("ttft_seconds").observe(r.t_first - r.t_arrival, policy=pol)
            h("per_token_latency_seconds").observe(
                (r.t_done - r.t_arrival) / len(r.tokens), policy=pol)
        if report.spec_rounds:
            c("spec_rounds_total").inc(report.spec_rounds)
            c("spec_lane_rounds_total").inc(report.spec_lane_rounds)
            c("drafted_tokens_total").inc(report.drafted_tokens)
            c("accepted_drafts_total").inc(report.accepted_drafts)
            c("accepted_tokens_total").inc(report.accepted_tokens)
