"""Table-driven pipelined serving: prefill and decode as schedule clients.

Serving consumes the SAME schedule subsystem training does: a
forward-only :class:`~repro.core.schedule.ServingSchedule` from the
registry (``serve_1f`` one chunk per stage, ``serve_interleaved``
virtual stages) emits the dense (tick, stage) → (microbatch, chunk,
input-source) index tables, and the executor here only gathers table
rows — no tick→stage index arithmetic lives in this module, mirroring
core/pipeline.py.  Each stage holds the KV/SSM state for its own
chunks' layers (cache stacked chunk-major like the weights: storage row
p = s·v + j holds model chunk j·S + s, the
``ScheduleInterleaved1F1B.storage_chunk_order()`` layout — so
``reshard_state_for_plan`` round-trips train → serve checkpoints
unchanged); rows shard over data, heads over tensor.

Long-context mode (``sp=True``, used by long_500k with global_batch=1):
the KV cache is sharded over the *data* axis along sequence length and
attention combines partial softmax stats across shards (SP decode,
models/nn.py::_sdpa_decode_seq_sharded).  The forward-only schedules
have no microbatch-group constraint, so sp (R = 1) interleaves too.

:func:`build_serving` returns an :class:`EngineSession` — the pure
jit-able pieces (``decode_step``/``prefill_step``/``init_state`` +
pspecs, consumed by launch/cell.py for dry-run lowering) plus the
stateful serving API: ``session.prefill(batch)``,
``session.decode(tokens)``, ``session.state_shardings()``.

Continuous batching (serving/batcher.py): the serving state is
per-slot — each schedule microbatch slot carries its own cache
position (``state["pos"]``, [R]) and liveness (``state["live"]``,
[R]) — and two slot ops let a request stream flow through a running
session without a global flush: ``session.reset_slots(mask)`` frees
slots (eviction: zeroed cache rows, pos, live) and
``session.write_prefill_into_slots(batch, mask)`` admits new requests
by running the pipelined prefill with every cache write gated per
slot.  Decode writes are gated by ``live`` the same way, so free
slots compute garbage that is never written while live slots decode
at their own positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core.schedule import (F_CHUNK, F_FROM_EMBEDS, F_MB,
                                 ServingSchedule, bucket_lattice,
                                 default_cache_lens,
                                 fit_serving_microbatches,
                                 make_serving_schedule, pick_bucket)
from repro import quant
from repro.models import lm_head
from repro.models import spec as spec_lib
from repro.models.init import init_params
from repro.models.stage import encoder_fwd, init_stage_state, make_statics, stage_fwd
from repro.parallel.mesh import AXIS_STAGE, AXIS_TENSOR, ParallelismPlan, data_axes

__all__ = ["CacheExhausted", "EngineSession", "build_serving",
           "default_cache_lens", "fit_decode_microbatches"]


class CacheExhausted(RuntimeError):
    """A decode step cannot proceed: the named slots are out of KV room.

    Raised by :meth:`EngineSession.decode` *before* any device step or
    allocator mutation when a live slot hits the paged capacity
    (``pos >= cache_len``) or the page pool cannot cover this step's
    boundary crossings.  ``slots`` names the blocked slot indices so the
    continuous batcher can evict-or-queue exactly those (backpressure,
    matching :class:`~repro.serving.batcher.PageAllocator`'s pool-dry
    admission behavior) instead of crashing the serve loop.
    """

    def __init__(self, message: str, slots=()):
        super().__init__(message)
        self.slots = tuple(int(s) for s in slots)


def fit_decode_microbatches(plan: ParallelismPlan, global_batch: int,
                            dp: int, mesh: Optional[Mesh] = None) -> int:
    """Largest R ≤ ``plan.decode_microbatches`` with dp·R | global_batch.

    Validates up front that the data axes divide the batch: the old
    fitting loop (``while global_batch % (dp * R): R -= 1``) walked R
    down to 0 and died with a bare ``ZeroDivisionError`` when dp did
    not divide ``global_batch``.  The fitting rule itself lives in
    ``core/schedule.py`` (``fit_serving_microbatches``) so plan_search
    prices the same R the engine runs.
    """
    try:
        return fit_serving_microbatches(plan.decode_microbatches,
                                        global_batch, dp)
    except ValueError as e:
        mesh_desc = (
            f" (mesh {dict(zip(mesh.axis_names, mesh.devices.shape))})"
            if mesh is not None else "")
        raise ValueError(f"{e}{mesh_desc}") from None


@dataclasses.dataclass
class EngineSession:
    """One serving session over a registry schedule.

    Pure pieces (``decode_step``/``prefill_step``/``init_state`` and
    the pspecs) are exposed for dry-run lowering (launch/cell.py);
    the stateful API — ``start``, ``prefill``, ``decode`` — is what
    launch/serve.py and the examples drive.  Step functions are jitted
    lazily with the session's shardings; ``state`` lives on the mesh
    between calls.
    """

    spec: spec_lib.ModelSpec
    plan: ParallelismPlan
    mesh: Mesh
    sched: ServingSchedule
    decode_step: Callable          # (state, tokens) -> (state, next_tokens)
    prefill_step: Optional[Callable]
    init_state: Callable           # (key) -> state
    state_pspecs: Any
    token_spec: jax.ShapeDtypeStruct
    prefill_specs: Optional[Dict[str, jax.ShapeDtypeStruct]]
    reset_step: Callable           # (state, slot_mask) -> state
    admit_step: Optional[Callable] = None  # (state, batch, mask) -> (st, tok)
    # slot compaction: (state, perm) -> state with new slot i = old perm[i]
    compact_step: Optional[Callable] = None
    # liveness-aware bucketing (build_serving(buckets=True)): the lattice
    # of compacted variants, plus factories returning the un-jitted
    # decode/admit step for one bucket (jitted lazily per bucket)
    buckets: Optional[tuple] = None
    decode_step_for: Optional[Callable] = None   # (R_b) -> step fn
    admit_step_for: Optional[Callable] = None    # (R_b) -> step fn
    state: Any = None
    # paged-KV config ({"page_size", "max_pages", "pool_pages",
    # "cache_len"}) — None for the dense cache layout
    paged: Optional[Dict[str, Any]] = None
    # ragged (per-slot prompt lengths) admission supported? False when
    # the model carries recurrent (mamba/rwkv) state, whose prefill
    # would absorb the padding tokens.
    ragged_ok: bool = True
    # speculative draft–verify (serve_spec_* schedules): verify_step
    # (state, (B, spec_k+1) tokens) -> (state, (scores, accepted)),
    # its per-bucket factory, the head-only self-drafter, and the pure
    # rollback step (present for EVERY serving session — rollback is
    # just a masked pos decrement)
    verify_step: Optional[Callable] = None
    verify_step_for: Optional[Callable] = None
    draft_step: Optional[Callable] = None
    rollback_step: Optional[Callable] = None
    cache_len: int = 0             # KV capacity (headroom checks)
    # observability hook (repro.obs.Observability or None = off): every
    # host-driven table walk reports one on_round(); CacheExhausted and
    # slot ops feed counters; the allocator feeds page gauges
    obs: Any = None
    # storage dtypes (build_serving(weight_dtype=, kv_dtype=)) and the
    # raw (unquantized) param template load_params casts against
    weight_dtype: Optional[str] = None
    kv_dtype: Optional[str] = None
    compute_dtype: Any = None
    param_template: Any = None
    _jit: Dict[Any, Callable] = dataclasses.field(default_factory=dict)
    _alloc: Any = None             # host-side PageAllocator (paged mode)
    # host mirrors of state["pos"]/state["live"] — maintained in EVERY
    # mode (the bucket picker and the paged allocator both read them;
    # tests/test_paged.py locks them to the device values)
    _pos: Any = None
    _live: Any = None
    # per-slot prompt length mirror: rollback may never cross it
    _prompt_len: Any = None
    _bucket_log: list = dataclasses.field(default_factory=list)
    # bucketed schedule variants built once per bucket for trace spans
    _bucket_scheds: Dict[int, Any] = dataclasses.field(default_factory=dict)

    def state_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def load_params(self, params_host) -> "EngineSession":
        """Install externally loaded weights into the live session state.

        ``params_host`` (e.g. ``checkpoint.convert.load_converted``
        output) must already be in this schedule's storage chunk order —
        the converter writes per-chunk files that way for any
        (pp, tp, v) plan.  Leaves are cast to the engine's param dtypes,
        quantized when the session was built with ``weight_dtype``, and
        placed with the session's param shardings.
        """
        if self.state is None:
            raise RuntimeError("call start() before load_params()")
        cast = jax.tree.map(lambda t, a: jnp.asarray(a).astype(t.dtype),
                            self.param_template, params_host)
        cast, _ = quant.quantize_params(cast, None, self.weight_dtype)
        sh = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                          self.state_pspecs["params"],
                          is_leaf=lambda x: isinstance(x, P))
        self.state = {**self.state,
                      "params": jax.device_put(cast, sh)}
        return self

    def start(self, key=None) -> "EngineSession":
        """Initialize (or reset) the session state on the mesh."""
        if "init" not in self._jit:
            self._jit["init"] = jax.jit(
                self.init_state, out_shardings=self.state_shardings())
        self.state = self._jit["init"](
            key if key is not None else jax.random.key(0))
        R = self.sched.n_microbatches
        self._pos = np.zeros(R, np.int64)
        self._live = np.ones(R, np.int64)
        self._prompt_len = np.zeros(R, np.int64)
        self._bucket_log = []
        if self.paged is not None:
            from repro.serving.batcher import PageAllocator
            self._alloc = PageAllocator(self.paged["pool_pages"], R,
                                        self.paged["max_pages"],
                                        self.paged["page_size"])
        return self

    # ---- liveness-aware bucket selection ---------------------------------

    def _resolve_bucket(self, bucket, n_min=None):
        """The compacted variant to run: explicit, engine-picked, or R."""
        R = self.sched.n_microbatches
        if self.buckets is None:
            if bucket not in (None, R):
                raise ValueError(
                    f"bucket={bucket} on a session built without "
                    "buckets=True — pass buckets=True to build_serving")
            return R
        if bucket is None:
            n = int(self._live.sum()) if n_min is None else int(n_min)
            return pick_bucket(n, self.buckets)
        if bucket not in self.buckets:
            raise ValueError(
                f"bucket {bucket} is not in the lattice {self.buckets}")
        return int(bucket)

    # ---- observability hooks ----------------------------------------------

    def _obs_t0(self):
        """Round start stamp — taken only when obs is on (zero cost off)."""
        return self.obs.clock() if self.obs is not None else None

    def _obs_round(self, kind, b, t0, *sync):
        """Report one executed round [t0, now) over bucket ``b``'s table.

        ``sync``: device outputs to block on so the stamp covers the
        compute, not just the async dispatch.  The bucketed table is
        built once per bucket (``bucketed()`` re-proves its invariants
        on every call) and reused for every round's trace spans.
        """
        if self.obs is None:
            return
        if sync:
            jax.block_until_ready(sync)
        R = self.sched.n_microbatches
        sched = self.sched
        if b != R:
            sched = self._bucket_scheds.get(b)
            if sched is None:
                sched = self._bucket_scheds[b] = self.sched.bucketed(b)
        self.obs.on_round(kind, sched, t0, self.obs.clock(),
                          bucket=b if self.buckets is not None else None)
        if self._alloc is not None:
            self.obs.page_gauges(self._alloc)

    def _obs_exhausted(self, kind, reason):
        """Count a CacheExhausted about to be raised from ``kind``."""
        if self.obs is not None:
            self.obs.counter("cache_exhausted_total").inc(
                kind=kind, reason=reason)

    # ---- paged-KV host-side hooks (allocator lives in serving/batcher) ----

    def _push_tables(self):
        """Mirror the host allocator's page tables into device state."""
        self.state = {**self.state,
                      "tables": jnp.asarray(self._alloc.tables)}

    def _slot_lens(self, batch):
        text_len = self.prefill_specs["tokens"].shape[2]
        R = self.sched.n_microbatches
        if isinstance(batch, dict) and batch.get("lens") is not None:
            lens = np.asarray(batch["lens"]).reshape(-1)
            if lens.shape[0] != R:
                raise ValueError(
                    f"lens has {lens.shape[0]} entries for R={R} slots; "
                    "pass exactly one prompt length per slot")
            if (lens < 1).any() or (lens > text_len).any():
                raise ValueError(
                    f"lens entries must lie in [1, {text_len}] (the "
                    f"session prompt width); got {lens.tolist()}")
            return lens.astype(np.int64), text_len
        return np.full(R, text_len, np.int64), text_len

    def prefill(self, batch):
        """Pipelined prefill of the whole batch; returns first tokens."""
        if self.prefill_step is None:
            raise ValueError(
                "this session was built without a prefill step; pass "
                "prefill_len= (> 0) to build_serving to enable "
                "prefill — decode-only sessions can only decode()")
        if self.state is None:
            self.start()
        lens, _ = self._slot_lens(batch)
        if self.paged is not None:
            for r in range(self.sched.n_microbatches):
                self._alloc.alloc_slot(r, int(lens[r]))
            self._push_tables()
        self._pos[:] = lens
        self._live[:] = 1
        self._prompt_len[:] = lens
        if "prefill" not in self._jit:
            sh = self.state_shardings()
            self._jit["prefill"] = jax.jit(
                self.prefill_step, in_shardings=(sh, None),
                out_shardings=(sh, None))
        t0 = self._obs_t0()
        self.state, tokens = self._jit["prefill"](self.state, batch)
        self._obs_round("prefill", self.sched.n_microbatches, t0, tokens)
        return tokens

    def decode(self, tokens, bucket=None):
        """One pipelined decode step; returns the next token per row.

        On a bucketed session (``build_serving(buckets=True)``) the step
        runs the smallest compacted variant covering the live slots —
        ``bucket`` overrides, ``None`` lets the engine pick from the
        liveness mirror.  Live slots must sit in the bucket prefix
        (the batcher's ``compact_slots`` guarantees it); the returned
        token vector keeps the full ``global_batch`` width, rows of
        slots outside the bucket are garbage (they are dead).
        """
        if self.state is None:
            raise ValueError(
                "decode() before start(): no session state — call "
                "start() (and prefill prompts) before decoding")
        R = self.sched.n_microbatches
        b = self._resolve_bucket(bucket)
        if b < R and int(self._live[b:].sum()):
            raise ValueError(
                f"decode bucket {b} excludes live slots "
                f"{(np.flatnonzero(self._live[b:]) + b).tolist()}; "
                "compact_slots first")
        if self.paged is not None:
            # allocate on page-boundary crossing: this step writes the
            # key at position pos, which must land in an owned page.
            # Every blocker is found BEFORE any allocator mutation, so a
            # CacheExhausted leaves the session retryable after the
            # batcher evicts the named slots.
            cap = self.paged["cache_len"]
            live_r = np.flatnonzero(self._live)
            over = [int(r) for r in live_r if self._pos[r] >= cap]
            if over:
                self._obs_exhausted("decode", "capacity")
                raise CacheExhausted(
                    f"slots {over} are at paged KV capacity "
                    f"(cache_len={cap} tokens); evict or raise cache_len",
                    slots=over)
            free = self._alloc.free_pages
            dry = []
            for r in live_r:
                need = (self._alloc.pages_needed(int(self._pos[r]) + 1)
                        - int(self._alloc.counts[r]))
                if need > free:
                    dry.append(int(r))
                else:
                    free -= need
            if dry:
                self._obs_exhausted("decode", "pool")
                raise CacheExhausted(
                    f"page pool exhausted growing slots {dry} "
                    f"({self._alloc.free_pages} pages free); evict a slot "
                    "or size pool_pages for the worst-case decode length",
                    slots=dry)
            for r in live_r:
                self._alloc.extend_slot(int(r), int(self._pos[r]) + 1)
            self._push_tables()
        key = ("decode", b)
        if key not in self._jit:
            sh = self.state_shardings()
            fn = self.decode_step if b == R else self.decode_step_for(b)
            self._jit[key] = jax.jit(
                fn, in_shardings=(sh, None),
                out_shardings=(sh, None), donate_argnums=0)
        t0 = self._obs_t0()
        self.state, tokens = self._jit[key](self.state, tokens)
        self._obs_round("decode", b, t0, tokens)
        self._pos += self._live
        if self.buckets is not None:
            self._bucket_log.append(b)
        return tokens

    # ---- speculative draft–verify ----------------------------------------

    def draft(self, tokens):
        """k greedy self-drafts per row: (B_global,) -> (B_global, spec_k).

        Head-only (embed → head, no pipeline pass); callers may
        substitute any draft source — verify() accepts arbitrary drafts
        and rollback keeps output exact regardless of their quality.
        """
        if self.draft_step is None:
            raise ValueError(
                "draft() on a non-speculative session: build with "
                "plan.schedule='serve_spec_1f'/'serve_spec_interleaved'")
        if self.state is None:
            raise ValueError(
                "draft() before start(): no session state — call "
                "start() (and prefill/admit prompts) first")
        if "draft" not in self._jit:
            sh = self.state_shardings()
            self._jit["draft"] = jax.jit(self.draft_step,
                                         in_shardings=(sh, None))
        return np.asarray(
            self._jit["draft"](self.state, jnp.asarray(tokens, jnp.int32)))

    def verify(self, tokens, bucket=None):
        """One draft–verify round: score spec_k + 1 positions per slot.

        ``tokens``: (global_batch, spec_k + 1) int32 — column 0 each
        row's current token (what ``decode()`` would be fed), columns
        1..k its draft continuation.  One ramp through the serve tables
        scores every position; each live slot advances by
        ``accepted + 1`` (its accepted draft prefix plus the verifier's
        bonus token — never less than plain decode) and the rejected
        suffix rolls back: dense KV past the new pos is invisible
        behind the position mask, paged suffix pages are released via
        the allocator.  Returns ``(scores, accepted)``: scores
        (global_batch, spec_k + 1) — the tokens to emit per row are
        ``scores[row, :accepted[slot] + 1]`` — and accepted [R] (min
        over each slot's lanes).  Bit-exact vs non-speculative greedy
        decode by construction.
        """
        if self.verify_step is None:
            raise ValueError(
                "verify() on a non-speculative session: build with "
                "plan.schedule='serve_spec_1f'/'serve_spec_interleaved'")
        if self.state is None:
            raise ValueError(
                "verify() before start(): no session state — call "
                "start() (and prefill/admit prompts) first")
        K = int(self.sched.spec_k)
        Q = K + 1
        toks = np.asarray(tokens)
        if toks.ndim != 2 or toks.shape[1] != Q:
            raise ValueError(
                f"tokens must be (global_batch, spec_k+1) = "
                f"(..., {Q}); got {toks.shape}")
        R = self.sched.n_microbatches
        cap = self.cache_len
        if cap and Q > cap:
            raise ValueError(
                f"spec_k={K} exceeds the cache_len headroom: a verify "
                f"round writes spec_k+1={Q} positions but "
                f"cache_len={cap}")
        live_r = np.flatnonzero(self._live)
        if cap:
            # capacity backpressure (evictable), mirroring decode()
            over = [int(r) for r in live_r if self._pos[r] + Q > cap]
            if over:
                self._obs_exhausted("verify", "capacity")
                raise CacheExhausted(
                    f"slots {over} lack verify headroom (pos + spec_k+1 "
                    f"> cache_len={cap}); evict them or lower spec_k",
                    slots=over)
        b = self._resolve_bucket(bucket)
        if b < R and int(self._live[b:].sum()):
            raise ValueError(
                f"verify bucket {b} excludes live slots "
                f"{(np.flatnonzero(self._live[b:]) + b).tolist()}; "
                "compact_slots first")
        if self.paged is not None:
            # pre-extend every live slot to pos + Q (all Q writes land
            # in owned pages); all blockers found BEFORE any mutation
            free = self._alloc.free_pages
            dry = []
            for r in live_r:
                need = (self._alloc.pages_needed(int(self._pos[r]) + Q)
                        - int(self._alloc.counts[r]))
                if need > free:
                    dry.append(int(r))
                else:
                    free -= need
            if dry:
                self._obs_exhausted("verify", "pool")
                raise CacheExhausted(
                    f"page pool exhausted growing slots {dry} for a "
                    f"spec_k={K} verify round "
                    f"({self._alloc.free_pages} pages free); evict a "
                    "slot or size pool_pages for the worst case",
                    slots=dry)
            for r in live_r:
                self._alloc.extend_slot(int(r), int(self._pos[r]) + Q)
            self._push_tables()
        key = ("verify", b)
        if key not in self._jit:
            sh = self.state_shardings()
            fn = (self.verify_step if b == R
                  else self.verify_step_for(b))
            self._jit[key] = jax.jit(
                fn, in_shardings=(sh, None),
                out_shardings=(sh, (None, None)), donate_argnums=0)
        t0 = self._obs_t0()
        self.state, (scores, accepted) = self._jit[key](
            self.state, jnp.asarray(toks, jnp.int32))
        self._obs_round("verify", b, t0, (scores, accepted))
        accepted = np.asarray(accepted, np.int64)
        self._pos += (accepted + 1) * (self._live > 0)
        if self.paged is not None:
            # release the rejected suffixes' pages (truncate never
            # grows; slots whose round fit in already-owned pages are
            # no-ops)
            for r in np.flatnonzero(self._live):
                self._alloc.truncate_slot(int(r), int(self._pos[r]))
            self._push_tables()
        if self.buckets is not None:
            self._bucket_log.append(b)
        return np.asarray(scores), accepted

    def rollback_slots(self, slot_mask, new_pos):
        """Roll masked slots back to ``new_pos`` (pure pos decrement).

        The rejection path exposed directly (verify() applies it
        implicitly): dense KV needs no touch-up — stale entries past
        pos are invisible behind the attention position mask — and
        paged mode releases the truncated suffix's pages.  Typed
        guards: a rollback may never cross a slot's prompt length
        (``new_pos`` below the prompt would orphan prefill KV) nor
        move forward.
        """
        if self.state is None:
            raise ValueError(
                "rollback_slots() before start(): no session state")
        R = self.sched.n_microbatches
        m = np.asarray(slot_mask).reshape(-1) > 0
        if m.shape[0] != R:
            raise ValueError(
                f"slot_mask has {m.shape[0]} entries for R={R} slots")
        npos = np.asarray(new_pos, np.int64).reshape(-1)
        if npos.shape[0] != R:
            raise ValueError(
                f"new_pos has {npos.shape[0]} entries for R={R} slots")
        below = [int(r) for r in np.flatnonzero(m)
                 if npos[r] < self._prompt_len[r]]
        if below:
            raise ValueError(
                f"new_pos rolls slots {below} below their prompt length "
                f"(new_pos={[int(npos[r]) for r in below]}, prompt_len="
                f"{[int(self._prompt_len[r]) for r in below]}): rollback "
                "may only drop generated positions, never the prompt")
        fwd = [int(r) for r in np.flatnonzero(m) if npos[r] > self._pos[r]]
        if fwd:
            raise ValueError(
                f"new_pos advances slots {fwd} (new_pos > pos); "
                "rollback_slots only moves positions backward")
        if "rollback" not in self._jit:
            sh = self.state_shardings()
            self._jit["rollback"] = jax.jit(
                self.rollback_step, in_shardings=(sh, None, None),
                out_shardings=sh, donate_argnums=0)
        self.state = self._jit["rollback"](
            self.state, jnp.asarray(m, jnp.int32),
            jnp.asarray(npos, jnp.int32))
        self._pos[m] = npos[m]
        if self._alloc is not None:
            for r in np.flatnonzero(m):
                self._alloc.truncate_slot(int(r), int(npos[r]))
            self._push_tables()
        return self

    # ---- continuous-batching slot ops (serving/batcher.py drives these) ---

    def reset_slots(self, slot_mask):
        """Free the masked microbatch slots: zero cache rows, pos, live."""
        if self.state is None:
            self.start()
        m = np.asarray(slot_mask) > 0
        if self.paged is not None:
            for r in np.flatnonzero(m):
                self._alloc.release_slot(int(r))
            self._push_tables()
        self._pos[m] = 0
        self._live[m] = 0
        self._prompt_len[m] = 0
        if "reset" not in self._jit:
            sh = self.state_shardings()
            self._jit["reset"] = jax.jit(
                self.reset_step, in_shardings=(sh, None), out_shardings=sh,
                donate_argnums=0)
        self.state = self._jit["reset"](self.state,
                                        jnp.asarray(slot_mask, jnp.int32))
        if self.obs is not None:
            self.obs.counter("slot_resets_total").inc(int(m.sum()))
            if self._alloc is not None:
                self.obs.page_gauges(self._alloc)
        return self

    def write_prefill_into_slots(self, batch, slot_mask, bucket=None):
        """Masked prefill: admit new requests into the masked slots.

        Live slots' recurrent state is untouched (every cache write is
        gated per slot), so admission needs no global flush.  Returns
        the first token of every slot row; only the admitted slots'
        entries are meaningful.  On a bucketed session the pass runs
        the smallest compacted variant covering both the live slots and
        the admitted ones (which must therefore sit in a bucket prefix
        — the batcher admits into the lowest free slots).
        """
        if self.admit_step is None:
            raise ValueError(
                "this session was built without a prefill step; pass "
                "prefill_len= (> 0) to build_serving to enable "
                "per-slot admission")
        if self.state is None:
            self.start()
        if (isinstance(batch, dict) and batch.get("lens") is not None
                and not self.ragged_ok):
            raise ValueError(
                "ragged admission (per-slot prompt lengths) is not "
                "supported for models with recurrent (mamba/rwkv) "
                "state: prefill would absorb the padding tokens; pad "
                "prompts to the session prefill_len instead")
        mask = np.asarray(slot_mask) > 0
        R = self.sched.n_microbatches
        occupied = mask | (self._live > 0)
        n_min = (int(np.flatnonzero(occupied)[-1]) + 1 if occupied.any()
                 else 1)
        b = self._resolve_bucket(bucket, n_min=n_min)
        if b < R and occupied[b:].any():
            raise ValueError(
                f"admit bucket {b} excludes occupied slots "
                f"{(np.flatnonzero(occupied[b:]) + b).tolist()}; "
                "compact_slots or admit into lower slots first")
        lens, _ = self._slot_lens(batch)
        if self.paged is not None:
            for r in np.flatnonzero(mask):
                self._alloc.alloc_slot(int(r), int(lens[r]))
            self._push_tables()
        self._pos[mask] = lens[mask]
        self._live[mask] = 1
        self._prompt_len[mask] = lens[mask]
        key = ("admit", b)
        if key not in self._jit:
            sh = self.state_shardings()
            # donate like decode/reset: admission runs on every freed
            # slot, and a non-donated pass would transiently double the
            # params + full-R cache footprint mid-serving
            fn = self.admit_step if b == R else self.admit_step_for(b)
            self._jit[key] = jax.jit(
                fn, in_shardings=(sh, None, None),
                out_shardings=(sh, None), donate_argnums=0)
        t0 = self._obs_t0()
        self.state, tokens = self._jit[key](
            self.state, batch, jnp.asarray(slot_mask, jnp.int32))
        self._obs_round("admit", b, t0, tokens)
        if self.buckets is not None:
            self._bucket_log.append(b)
        return tokens

    def compact_slots(self, perm):
        """Permute the per-slot state: new slot i takes old slot perm[i].

        Pure row permutation of every per-slot axis — cache slot rows,
        ``pos``, ``live``, the page-table rows and ``enc_out`` — plus
        the host mirrors and the :class:`PageAllocator`'s rows.  In
        paged mode **no KV bytes move**: the page pool is global and
        untouched, only the (R, max_pages) table reorders, which is
        what makes compaction O(R·max_pages) instead of O(cache bytes)
        and lets the batcher compact on every eviction.
        """
        if self.compact_step is None:
            raise ValueError("this session was built without a compact "
                             "step (rebuild with a current build_serving)")
        if self.state is None:
            self.start()
        R = self.sched.n_microbatches
        perm = np.asarray(perm, np.int64).reshape(-1)
        if sorted(perm.tolist()) != list(range(R)):
            raise ValueError(
                f"perm must be a permutation of range({R}), got "
                f"{perm.tolist()}")
        if "compact" not in self._jit:
            sh = self.state_shardings()
            self._jit["compact"] = jax.jit(
                self.compact_step, in_shardings=(sh, None),
                out_shardings=sh, donate_argnums=0)
        self.state = self._jit["compact"](self.state,
                                          jnp.asarray(perm, jnp.int32))
        self._pos = self._pos[perm]
        self._live = self._live[perm]
        self._prompt_len = self._prompt_len[perm]
        if self.obs is not None:
            self.obs.counter("compactions_total").inc()
        if self._alloc is not None:
            # host allocator rows follow the same permutation; the device
            # tables were permuted identically by compact_step, so no
            # _push_tables is needed
            self._alloc.permute_slots(perm)
        return self


def build_serving(spec: spec_lib.ModelSpec, plan: ParallelismPlan,
                  mesh: Mesh, *, cache_len: int, global_batch: int,
                  prefill_len: int = 0, sp: bool = False,
                  compute_dtype=jnp.bfloat16, page_size: int = 0,
                  pool_pages: Optional[int] = None,
                  buckets: bool = False,
                  spec_k: Optional[int] = None,
                  weight_dtype: Optional[str] = None,
                  kv_dtype: Optional[str] = None,
                  obs=None) -> EngineSession:
    """``page_size > 0`` switches full-length attention KV to the
    block-paged layout: a global per-layer page pool
    (n_chunks, pool_pages, rows, page_size, KV, Dh) plus one per-slot
    page table (R, max_pages) shared by every paged layer (all layers of
    a slot hold identical lengths).  ``pool_pages`` defaults to
    R · cache_len / page_size (dense-capacity parity); size it smaller
    to trade worst-case capacity for more slots per HBM byte —
    core/schedule.py::serving_cache_bytes prices the pool, and the
    continuous batcher queues admissions when the pool runs dry.
    Windowed (ring-buffer) layers and recurrent state stay dense.

    ``buckets=True`` turns on liveness-aware bucketed execution: the
    session carries lazy per-bucket decode/admit variants for every
    size in ``bucket_lattice(R)`` — each one the SAME program over the
    same full-R state, scanning only the bucket's (shorter) serve
    tables — plus a ``compact_slots`` permutation op.  A half-empty
    batch then pays ``b + S·v − …`` ticks instead of full-R ticks,
    bit-exact with the full-R path (the bucketed table is provably the
    masked full-R table with dead slots deleted —
    ``ServingSchedule.bucketed``).

    A speculative plan (``schedule='serve_spec_1f'/'serve_spec_interleaved'``,
    draft depth overridable with ``spec_k=``) additionally equips the
    session with the draft–verify API: ``session.draft(tokens)`` (k
    head-only self-draft hops), ``session.verify(tokens)`` (one ramp
    scoring all spec_k + 1 positions per live slot, advancing each slot
    by its accepted prefix + 1 and rolling the rejected suffix back) and
    ``session.rollback_slots(mask, new_pos)``.  Greedy output is
    bit-exact (fp32) vs the non-speculative schedule by construction —
    rollback makes speculation a pure latency optimization.

    ``weight_dtype`` ("int8"/"fp8") stores the matmul weights quantized
    (per-output-channel scales, dequantized on the fly at each matmul —
    repro.quant); ``kv_dtype`` picks the KV-cache storage dtype:
    "fp32"/"bf16" re-types the dense caches, "int8" stores the paged
    pools as int8 payloads with per-(page, kv-head) f32 scale planes
    (requires ``page_size > 0``; the Pallas page walk dequantizes
    in-VMEM).  Both default to the unquantized behaviour.
    """
    S = plan.pp
    if page_size:
        if sp:
            raise ValueError("paged KV (page_size > 0) and sequence-"
                             "sharded caches (sp=True) are exclusive")
        if cache_len % page_size:
            raise ValueError(
                f"cache_len={cache_len} must be a multiple of "
                f"page_size={page_size}")
    if weight_dtype is not None and weight_dtype not in quant.WEIGHT_DTYPES:
        raise ValueError(f"weight_dtype={weight_dtype!r} not in "
                         f"{quant.WEIGHT_DTYPES}")
    if kv_dtype is not None and kv_dtype not in quant.KV_DTYPES:
        raise ValueError(f"kv_dtype={kv_dtype!r} not in {quant.KV_DTYPES}")
    if kv_dtype == "int8" and not page_size:
        raise ValueError(
            "kv_dtype='int8' requires the paged cache (page_size > 0): "
            "the per-page scale planes live alongside the page pools")
    kv_q = kv_dtype == "int8"
    # dense caches re-type wholesale; int8 keeps the dense leftovers
    # (windowed rings, recurrent state) in compute dtype
    cache_dtype = ({"fp32": jnp.float32, "bf16": jnp.bfloat16}
                   .get(kv_dtype, compute_dtype))
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.devices.shape[mesh.axis_names.index(a)]
                      for a in daxes]))
    dnames = daxes if len(daxes) > 1 else daxes[0]
    tp_axis = AXIS_TENSOR if plan.tp > 1 else None

    if sp:
        # SP: batch replicated over data; cache_len sharded over the data
        # axes (both of them on the multi-pod mesh)
        R = 1
        gb = global_batch                       # rows per group (replicated)
        seq_axis = daxes
        sp_shards = dp
        batch_dim_spec = None
    else:
        R = fit_decode_microbatches(plan, global_batch, dp, mesh)
        gb = global_batch // (dp * R)           # local rows per group
        seq_axis = None
        sp_shards = 1
        batch_dim_spec = dnames

    # The serving schedule comes from the registry (make_serving_schedule
    # raises the lookup error for names with no serving analogue); a plan
    # with virtual_stages > 1 interleaves its chunks exactly like the
    # training side.
    sched = make_serving_schedule(plan, R, spec_k=spec_k)
    sched.validate()
    speculative = bool(getattr(sched, "is_speculative", False))
    v = sched.virtual_stages
    n_chunks = sched.n_chunks
    # model-side construction (init, statics, per-chunk scalars) sees the
    # chunk count as "pp", mirroring core/pipeline.py
    mplan = (plan.with_(pp=n_chunks, schedule="auto", virtual_stages=1)
             if v > 1 else plan)
    tabs = sched.tables()
    FT, EXIT_T = np.asarray(tabs.fwd), np.asarray(tabs.exit_mb)

    statics = make_statics(spec, mplan,
                           tokens_per_mb=gb * max(prefill_len, 1))
    lps = spec.layers_per_stage(n_chunks)
    if speculative:
        # rollback is a pos decrement: recurrent (mamba/rwkv/cmix) state
        # cannot rewind, encoder/vision frontends have no draft path, and
        # the SP cache write is decode-only (qlen = 1).
        if sp:
            raise ValueError(
                "speculative decode (serve_spec_*) and sequence-parallel "
                "decode (sp=True) are exclusive: the SP cache write path "
                "is single-token")
        bad = [i for i, blk in enumerate(statics.program)
               if blk.mixer in ("mamba", "rwkv") or blk.ffn == "rwkv_cmix"]
        if bad or spec.encoder is not None or spec.frontend == "vision":
            raise ValueError(
                "speculative decode needs a pure-attention decoder stack: "
                "rejected drafts roll back by a masked pos decrement, and "
                f"recurrent state cannot rewind (layers {bad}, "
                f"encoder={spec.encoder is not None}, "
                f"frontend={spec.frontend!r})")
        if sched.verify_qlen > cache_len:
            raise ValueError(
                f"spec_k={sched.spec_k} exceeds the cache_len headroom: a "
                f"verify round writes spec_k+1={sched.verify_qlen} "
                f"positions but cache_len={cache_len}")
    if prefill_len or speculative:
        # (speculative verify also writes contiguous qlen > 1 slabs
        # mid-stream, so it needs full-length caches like prefill)
        # Prefill writes a contiguous qlen slab: every attention cache must
        # be full-length (windowed layers still *mask* to their window; the
        # ring-buffer memory optimization only applies to decode-only use).
        lens = [cache_len] * lps
    else:
        lens = default_cache_lens(spec, n_chunks, cache_len)
    # SP shards only full-length caches over the data axes; windowed ring
    # buffers (len < cache_len) are small and stay replicated — their
    # modulo write/read does not distribute.  The flag is static and
    # chunk-uniform because default_cache_lens already union-maxes the
    # per-position requirement across chunks.
    sp_flags = [sp and l >= cache_len for l in lens]
    if sp:
        lens = [max(-(-l // sp_shards), 8) if f else l
                for l, f in zip(lens, sp_flags)]
    seq_axes = [seq_axis if f else None for f in sp_flags]

    has_enc = spec.encoder is not None
    enc_len = spec.encoder.source_len if has_enc else 1
    d_enc = spec.encoder.d_model if has_enc else 1

    # ---------------- state construction ---------------------------------
    # rows_g: GLOBAL rows per microbatch group (replicated rows in SP mode).
    rows_g = gb * (1 if sp else dp)
    # Global cache dims: seq-sharded positions hold l_local per device, so
    # the global dim is l_local * dp.
    glens = [l * (dp if f else 1) for l, f in zip(lens, sp_flags)]

    # Paged layers: full-length attention KV moves into the global page
    # pool; windowed ring buffers (len < cache_len) and recurrent state
    # stay dense (constant-size — paging buys them nothing).
    if page_size:
        paged_layers = frozenset(
            i for i, blk in enumerate(statics.program)
            if blk.mixer == "attn" and lens[i] >= cache_len)
        max_pages = cache_len // page_size
        if pool_pages is None:
            pool_pages = R * max_pages
    else:
        paged_layers = frozenset()
        max_pages = pool_pages = 0

    def _layer_of(path) -> int:
        for k in path:
            key = str(getattr(k, "key", ""))
            if key.startswith("layer_"):
                return int(key.split("_")[1])
        raise KeyError(path)

    def _is_kv(path) -> bool:
        return any(getattr(k, "key", None) == "kv" for k in path)

    def _cache_template():
        """Global cache template, stacked chunk-major (S·v, R, rows_g, …).

        Storage row p = s·v + j holds chunk j·S + s's state — the same
        permutation the weights use — so the contiguous stage shard owns
        its chunks' caches.  Every chunk shares the (union-maxed) state
        structure, so the zero template needs no per-row permute.
        """
        base = init_stage_state(statics, rows_g, glens, cache_dtype,
                                paged_layers=paged_layers)

        def stack(leaf):
            return jnp.zeros((n_chunks, R) + leaf.shape, leaf.dtype)

        return jax.tree.map(stack, base)

    def _pages_template():
        """Global page pools, one (k, v) pair per paged layer — or, for
        int8 KV storage, (k, v, k_scale, v_scale) with per-(page,
        kv-head) f32 scale planes (initialized to 1 so dequantizing an
        untouched zero page yields exact zeros).

        Leaves are (n_chunks, pool_pages, rows_g, page, KV, Dh): the
        pool is global across slots (no R dim) — that is the whole
        point — while the lane dim shards over data exactly like the
        dense cache rows.  One shared (R, max_pages) table indexes every
        layer's pool (all layers of a slot hold identical lengths).
        """
        z = jnp.zeros((n_chunks, pool_pages, rows_g, page_size,
                       statics.attn.n_kv_local, statics.attn.d_head),
                      jnp.int8 if kv_q else cache_dtype)
        if kv_q:
            s1 = jnp.ones((n_chunks, pool_pages, rows_g,
                           statics.attn.n_kv_local), jnp.float32)
            return {f"layer_{i}": (z, z, s1, s1)
                    for i in sorted(paged_layers)}
        return {f"layer_{i}": (z, z) for i in sorted(paged_layers)}

    def _pages_pspec():
        pp = P(AXIS_STAGE, None, batch_dim_spec, None, None, None)
        if kv_q:
            sp_ = P(AXIS_STAGE, None, batch_dim_spec, None)
            return {f"layer_{i}": (pp, pp, sp_, sp_)
                    for i in sorted(paged_layers)}
        return {f"layer_{i}": (pp, pp) for i in sorted(paged_layers)}

    def _cache_pspec():
        base = init_stage_state(statics, rows_g, glens, cache_dtype,
                                paged_layers=paged_layers)

        def pspec(path, leaf):
            dims: list = [AXIS_STAGE, None]         # (S·v, R, ...)
            dims.append(batch_dim_spec)             # rows
            dims += [None] * (leaf.ndim - 1)
            if _is_kv(path) and sp_flags[_layer_of(path)]:
                dims[3] = daxes                     # (rows, L, KV, Dh)
            return P(*dims)

        return jax.tree_util.tree_map_with_path(pspec, base)

    # chunk hops wrap from the last stage back to stage 0 at virtual
    # stages (chunk j·S + (S−1) -> chunk (j+1)·S + 0)
    fwd_perm = ([(i, (i + 1) % S) for i in range(S)] if v > 1
                else [(i, i + 1) for i in range(S - 1)])

    def gather_row(table, tick):
        """Row of a [T, S, C] schedule table for (tick, this stage)."""
        s = jax.lax.axis_index(AXIS_STAGE)
        rows = jax.lax.dynamic_index_in_dim(jnp.asarray(table), tick, 0,
                                            keepdims=False)
        return jax.lax.dynamic_index_in_dim(rows, s, 0, keepdims=False)

    # ---------------- one pipelined forward pass --------------------------
    # ``ft_tab``/``exit_tab``/``n_ticks_b`` select the table variant: the
    # full-R serve tables, or a bucketed (compacted) variant whose tables
    # are the full ones with dead slots deleted — the slot-indexed state
    # stays full-R shaped either way, a bucket just scans fewer ticks.
    def _pipe_forward_impl(params, cache, pages, embeds_ring, pos, tables,
                           qlen, enc_ring, slot_mask, ft_tab, exit_tab,
                           n_ticks_b, tokenwise=False):
        """embeds_ring: (R, Bg_rows, qlen, d); returns (h_ring, cache',
        pages').

        Walks the serving schedule's forward table tick by tick: every
        stage gathers its (microbatch, chunk, input-source) row, runs
        that chunk over its recurrent state, and ppermutes the hidden
        state downstream; the exit table names the microbatch whose
        last-chunk output lands in ``h_ring`` each tick.

        ``pos`` is the per-slot cache position vector [R] — each
        microbatch slot decodes at its own offset, which is what lets
        continuous batching hold requests of different ages in one
        batch — and ``slot_mask`` [R] gates every cache write per slot:
        a masked-out slot still computes (the tables are static) but
        its recurrent state is never touched, so a masked prefill can
        admit new requests without perturbing live ones.
        """
        win, th = params["layer_windows"], params["layer_thetas"]

        def f_phase(tick, cache, pages, recv_f, h_ring, weights, win, th,
                    embeds, enc_ring, pos, tables, slot_mask):
            row = gather_row(ft_tab, tick)
            m = row[F_MB]
            rsafe = jnp.clip(m, 0, R - 1)
            valid = (m >= 0) & (jax.lax.dynamic_index_in_dim(
                slot_mask, rsafe, 0, keepdims=False) > 0)
            pos_r = jax.lax.dynamic_index_in_dim(pos, rsafe, 0,
                                                 keepdims=False)
            j = jnp.clip(row[F_CHUNK], 0, v - 1)
            # this tick's chunk view of the stage-local stacked rows
            if v == 1:
                w_loc, win_loc, th_loc = weights, win[0], th[0]
            else:
                w_loc = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, j, 0,
                                                           keepdims=True),
                    weights)
                win_loc = jax.lax.dynamic_index_in_dim(win, j, 0,
                                                       keepdims=False)
                th_loc = jax.lax.dynamic_index_in_dim(th, j, 0,
                                                      keepdims=False)
            x0 = jax.lax.dynamic_index_in_dim(embeds, rsafe, 0,
                                              keepdims=False)
            x_in = jnp.where(row[F_FROM_EMBEDS] > 0, x0, recv_f[0])

            def _read(a):
                aj = jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False)
                return jax.lax.dynamic_index_in_dim(aj, rsafe, 0,
                                                    keepdims=False)

            st_r = jax.tree.map(_read, cache)
            cross = None
            if has_enc:
                cross = jax.lax.dynamic_index_in_dim(enc_ring, rsafe, 0,
                                                     keepdims=False)
            positions = jnp.broadcast_to(
                pos_r + jnp.arange(qlen, dtype=jnp.int32),
                (x_in.shape[0], qlen))
            paged_arg = None
            if pages:
                # this chunk's pool view + the slot's page-table row;
                # writes inside attention are gated by ``valid`` AND
                # per-page liveness (table entry >= 0)
                pools_r = {
                    name: tuple(
                        jax.lax.dynamic_index_in_dim(pl, j, 0,
                                                     keepdims=False)
                        for pl in pair)
                    for name, pair in pages.items()}
                row_r = jax.lax.dynamic_index_in_dim(tables, rsafe, 0,
                                                     keepdims=False)
                paged_arg = {"pools": pools_r, "row": row_r,
                             "gate": valid, "tokenwise": tokenwise}
            h, st_out, _ = stage_fwd(
                w_loc, x_in, statics, positions=positions,
                windows=win_loc, thetas=th_loc, tp_axis=tp_axis,
                state=st_r, cache_pos=pos_r, cross_x=cross,
                seq_axis=seq_axes, paged=paged_arg)
            if paged_arg is not None:
                new_st, new_pools = st_out
            else:
                new_st, new_pools = st_out, None

            def _write(a, n):
                aj = jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False)
                old = jax.lax.dynamic_index_in_dim(aj, rsafe, 0,
                                                   keepdims=False)
                new = jnp.where(valid, n.astype(a.dtype), old)
                aj = jax.lax.dynamic_update_index_in_dim(aj, new, rsafe, 0)
                return jax.lax.dynamic_update_index_in_dim(a, aj, j, 0)

            cache = jax.tree.map(_write, cache, new_st)
            if new_pools is not None:
                # attention already gated the page writes; just put the
                # chunk's pool view back
                pages = {
                    name: tuple(
                        jax.lax.dynamic_update_index_in_dim(
                            pl, np_.astype(pl.dtype), j, 0)
                        for pl, np_ in zip(pages[name], new_pools[name]))
                    for name in pages}
            h_send = jax.lax.ppermute(h, AXIS_STAGE, fwd_perm) if S > 1 else h
            # the exit table names the microbatch leaving the last chunk;
            # every stage updates its own ring shard, and _pipe_forward
            # slices the output stage's shard after the scan (the ring is
            # stage-sharded — stages other than the last hold stale rows,
            # never a "replicated" divergent copy)
            s = jax.lax.axis_index(AXIS_STAGE)
            m_exit = jax.lax.dynamic_index_in_dim(jnp.asarray(exit_tab),
                                                  tick, 0, keepdims=False)
            esafe = jnp.clip(m_exit, 0, R - 1)
            old_h = jax.lax.dynamic_index_in_dim(h_ring[0], esafe, 0,
                                                 keepdims=False)
            h_keep = jnp.where((m_exit >= 0) & (s == S - 1), h, old_h)
            h_ring = jax.lax.dynamic_update_index_in_dim(h_ring[0], h_keep,
                                                         esafe, 0)[None]
            return cache, pages, h_send[None], h_ring

        cache_pspec = _cache_pspec()
        cache_pspec = jax.tree.map(lambda p: P(*p), cache_pspec,
                                   is_leaf=lambda x: isinstance(x, P))
        pages_pspec = _pages_pspec()
        act_pspec = P(AXIS_STAGE, batch_dim_spec, None, None)
        emb_pspec = P(None, batch_dim_spec, None, None)
        hring_pspec = P(AXIS_STAGE, None, batch_dim_spec, None, None)
        enc_pspec = (P(None, batch_dim_spec, None, None) if has_enc
                     else P(None, None, None, None))
        stage_pspec = _box["pspecs"]["stages"]
        win_pspec = P(AXIS_STAGE, None)

        f_sharded = shard_map(
            f_phase, mesh=mesh,
            in_specs=(P(), cache_pspec, pages_pspec, act_pspec, hring_pspec,
                      stage_pspec, win_pspec, win_pspec, emb_pspec,
                      enc_pspec, P(), P(), P()),
            out_specs=(cache_pspec, pages_pspec, act_pspec, hring_pspec),
            check_vma=False)

        recv = jnp.zeros((S, rows_g, qlen, spec.d_model), compute_dtype)
        h_ring = jnp.zeros((S, R, rows_g, qlen, spec.d_model), compute_dtype)

        def body(carry, tick):
            cache, pages, recv, h_ring = carry
            cache, pages, recv, h_ring = f_sharded(
                tick, cache, pages, recv, h_ring, params["stages"], win, th,
                embeds_ring, enc_ring, pos, tables, slot_mask)
            return (cache, pages, recv, h_ring), None

        (cache, pages, _, h_ring), _ = jax.lax.scan(
            body, (cache, pages, recv, h_ring),
            jnp.arange(n_ticks_b, dtype=jnp.int32))
        # only the output stage's ring shard carries the exits
        return h_ring[S - 1], cache, pages

    def _make_pipe_forward(bsched, tokenwise=False):
        # tokenwise=True routes paged cache writes token-by-token (the
        # speculative verify pass starts at arbitrary mid-page positions;
        # prefill keeps the page-aligned slab write)
        bt = bsched.tables()
        ft = np.asarray(bt.fwd)
        ex = np.asarray(bt.exit_mb)
        nt = bsched.n_ticks
        return lambda *a: _pipe_forward_impl(*a, ft, ex, nt, tokenwise)

    _pipe_forward = _make_pipe_forward(sched)

    # ---------------- decode step ----------------------------------------
    def _make_decode_step(pipe_forward, in_bucket):
        """Build one decode step over ``pipe_forward``'s table variant.

        ``in_bucket`` is None for the full-R variant, else the static
        0/1 [R] prefix mask of the bucket: only in-bucket slots compute
        and advance (``pos + live·in_bucket``) — the caller guarantees
        no live slot sits outside the bucket.
        """

        def decode_step(state, tokens):
            """tokens: (B_global,) int32; returns (state, next
            (B_global,)).

            Cache writes are gated by the per-slot ``live`` mask and
            each slot advances its own ``pos``: a free slot (live = 0,
            as left by ``reset_slots``) computes garbage that is never
            written, so the continuous batcher can keep decoding the
            live slots while free slots await admission.  A fully live
            batch (the one-shot sessions: ``init_state`` starts
            all-live) behaves exactly as the scalar-position engine did.
            """
            params, cache = state["params"], state["cache"]
            pos, live = state["pos"], state["live"]
            pages = state.get("pages", {})
            tables = state.get("tables", jnp.zeros((R, 1), jnp.int32))
            emb = lm_head.embed_tokens(params["embed"], tokens,
                                       dtype=compute_dtype)[:, None]
            embeds_ring = emb.reshape(R, rows_g, 1, spec.d_model)
            if has_enc:
                enc_ring = state["enc_out"]
            else:
                enc_ring = jnp.zeros((1, 1, 1, 1), compute_dtype)
            gate = (live if in_bucket is None
                    else live * jnp.asarray(in_bucket, jnp.int32))
            h_ring, cache, pages = pipe_forward(params, cache, pages,
                                                embeds_ring, pos, tables,
                                                1, enc_ring, gate)
            h = h_ring.reshape(R * rows_g, 1, spec.d_model)
            nxt = lm_head.sample_greedy(
                params["head"], params["final_norm"]["scale"], h,
                norm_kind=spec.norm,
                norm_bias=params["final_norm"].get("bias"),
                vocab=spec.vocab)
            new_state = {**state, "cache": cache, "pos": pos + gate}
            if pages:
                new_state["pages"] = pages
            return (new_state, nxt)

        return decode_step

    decode_step = _make_decode_step(_pipe_forward, None)

    # ---------------- speculative verify / draft / rollback ----------------
    def _make_verify_step(pipe_forward, in_bucket):
        """Build one draft–verify step over ``pipe_forward``'s tables.

        The pass is the decode step with qlen = spec_k + 1: each slot's
        row carries its current token plus the k drafts, one ramp
        through the UNCHANGED serve tables scores every position, and
        greedy acceptance keeps the longest draft prefix matching the
        verifier's own argmax chain.  Per-slot acceptance is the MIN
        over the slot's data-parallel lanes (all lanes share one pos —
        a lane that matched further simply regenerates the identical
        greedy token next round, so output stays bit-exact).
        """
        K = int(sched.spec_k)
        Q = K + 1

        def verify_step(state, tokens):
            """tokens: (B_global, spec_k+1) int32 — column 0 the current
            token, columns 1..k the drafts.  Returns (state', (scores,
            accepted)): scores (B_global, spec_k+1) — position j's
            greedy token after prefix ..j — and accepted [R].  pos
            advances by (accepted + 1) · gate; KV written past the new
            pos is stale and invisible behind the position mask (paged
            suffix pages are released host-side by ``verify()``).
            """
            params, cache = state["params"], state["cache"]
            pos, live = state["pos"], state["live"]
            pages = state.get("pages", {})
            tables = state.get("tables", jnp.zeros((R, 1), jnp.int32))
            emb = lm_head.embed_tokens(params["embed"], tokens,
                                       dtype=compute_dtype)  # (B, Q, d)
            embeds_ring = emb.reshape(R, rows_g, Q, spec.d_model)
            enc_ring = jnp.zeros((1, 1, 1, 1), compute_dtype)
            gate = (live if in_bucket is None
                    else live * jnp.asarray(in_bucket, jnp.int32))
            h_ring, cache, pages = pipe_forward(params, cache, pages,
                                                embeds_ring, pos, tables,
                                                Q, enc_ring, gate)
            h = h_ring.reshape(R * rows_g, Q, spec.d_model)
            scores = lm_head.greedy_tokens(
                params["head"], params["final_norm"]["scale"], h,
                norm_kind=spec.norm,
                norm_bias=params["final_norm"].get("bias"),
                vocab=spec.vocab)                         # (B, Q)
            # longest accepted draft prefix per row: draft d_i (column i
            # of tokens[:, 1:]) is accepted iff it equals the verifier's
            # token after prefix ..i-1 (scores[:, :-1]) AND all earlier
            # drafts were
            match = (tokens[:, 1:] == scores[:, :-1]).astype(jnp.int32)
            acc_rows = jnp.cumprod(match, axis=1).sum(axis=1)     # (B,)
            accepted = acc_rows.reshape(R, rows_g).min(axis=1)    # (R,)
            adv = (accepted.astype(jnp.int32) + 1) * gate
            new_state = {**state, "cache": cache,
                         "pos": pos + adv}
            if pages:
                new_state["pages"] = pages
            return new_state, (scores, accepted.astype(jnp.int32))

        return verify_step

    def draft_step(state, tokens):
        """Self-draft: k head-only hops.  tokens (B,) -> drafts (B, k).

        Reuses the target model's embedding and head ONLY — the
        pipeline never runs, so a draft costs k (embed + head) matmuls
        instead of k full rounds.  Draft quality affects the acceptance
        rate, never correctness: verify rolls back every rejected
        suffix.
        """
        params = state["params"]

        def hop(t, _):
            h = lm_head.embed_tokens(params["embed"], t,
                                     dtype=compute_dtype)[:, None]
            nxt = lm_head.sample_greedy(
                params["head"], params["final_norm"]["scale"],
                h.astype(compute_dtype), norm_kind=spec.norm,
                norm_bias=params["final_norm"].get("bias"),
                vocab=spec.vocab)
            return nxt, nxt

        _, drafts = jax.lax.scan(hop, jnp.asarray(tokens, jnp.int32), None,
                                 length=int(getattr(sched, "spec_k", 0)))
        return drafts.T                                   # (B, k)

    def rollback_slots_step(state, slot_mask, new_pos):
        """Masked pos rollback — the whole device-side rejection path.

        ``slot_mask`` [R] selects slots, ``new_pos`` [R] their rolled-
        back positions.  Dense KV needs nothing else: entries past pos
        are invisible behind the attention position mask and the next
        write overwrites them.  Paged suffix pages are released by the
        host allocator (``EngineSession.rollback_slots``).
        """
        m = slot_mask > 0
        return {**state,
                "pos": jnp.where(m, new_pos,
                                 state["pos"]).astype(jnp.int32)}

    verify_step = None
    verify_step_for = None
    session_draft_step = None
    if speculative:
        verify_step = _make_verify_step(
            _make_pipe_forward(sched, tokenwise=True), None)
        session_draft_step = draft_step

    # ---------------- slot reset (eviction) --------------------------------
    def reset_slots_step(state, slot_mask):
        """Zero the cache rows, pos and liveness of masked slots.

        ``slot_mask``: [R] int32, 1 = free this slot.  The freed slot's
        chunk-major cache rows (dim 1 of every [S·v, R, ...] leaf) are
        zeroed so a later admission prefills recurrent layers from a
        clean state; elementwise, so it runs under the session's
        state shardings unchanged.
        """
        m = slot_mask > 0

        def _zero(a):
            mm = m.reshape((1, R) + (1,) * (a.ndim - 2))
            return jnp.where(mm, jnp.zeros((), a.dtype), a)

        out = {**state,
               "cache": jax.tree.map(_zero, state["cache"]),
               "pos": jnp.where(m, 0, state["pos"]).astype(jnp.int32),
               "live": jnp.where(m, 0, state["live"]).astype(jnp.int32)}
        if has_enc:
            out["enc_out"] = jnp.where(
                m.reshape((R, 1, 1, 1)),
                jnp.zeros((), state["enc_out"].dtype), state["enc_out"])
        return out

    # ---------------- slot compaction (permutation) ------------------------
    def compact_slots_step(state, perm):
        """Permute every per-slot axis: new slot i = old slot perm[i].

        ``perm``: [R] int32 full permutation.  A pure gather along the
        slot dim — cache leaves on dim 1 of [S·v, R, ...], pos / live /
        page tables / enc_out on dim 0.  The page *pool* is global and
        untouched: in paged mode compaction moves zero KV bytes, only
        table rows — which is what makes it cheap enough to run on
        every eviction so live slots always form a bucket prefix.
        """
        out = {**state,
               "cache": jax.tree.map(lambda a: jnp.take(a, perm, axis=1),
                                     state["cache"]),
               "pos": jnp.take(state["pos"], perm, axis=0),
               "live": jnp.take(state["live"], perm, axis=0)}
        if "tables" in state:
            out["tables"] = jnp.take(state["tables"], perm, axis=0)
        if has_enc:
            out["enc_out"] = jnp.take(state["enc_out"], perm, axis=0)
        return out

    # ---------------- prefill / admission steps ----------------------------
    prefill_step = None
    admit_step = None
    prefill_specs = None
    if prefill_len:
        def _make_admit_step(pipe_forward, in_bucket):
            def admit_step(state, batch, slot_mask):
                """Masked per-slot prefill: write new requests into
                slots.

                Runs the pipelined prefill pass over this variant's
                (static) tables, but every cache write is gated by
                ``slot_mask``, so only the admitted slots' rows,
                positions and liveness change — live slots' recurrent
                state is untouched and their decode continues from the
                same pipeline state afterwards (no global flush).
                Returns the first token of every slot; the caller keeps
                the admitted ones.
                """
                params, cache = state["params"], state["cache"]
                pages = state.get("pages", {})
                tables = state.get("tables", jnp.zeros((R, 1), jnp.int32))
                tokens = batch["tokens"]                # (R, rows, S_text)
                lens_vec = batch.get("lens")            # (R,) or None
                gate = (slot_mask if in_bucket is None
                        else slot_mask * jnp.asarray(in_bucket, jnp.int32))
                emb = lm_head.embed_tokens(params["embed"], tokens,
                                           dtype=compute_dtype)
                if spec.frontend == "vision" and "patches" in batch:
                    emb = jnp.concatenate(
                        [batch["patches"].astype(emb.dtype), emb], axis=2)
                if has_enc:
                    fr = batch["frames"].reshape(-1, enc_len, d_enc)
                    enc_out = encoder_fwd(params["encoder"],
                                          fr.astype(compute_dtype), spec)
                    enc_ring = enc_out.reshape(tokens.shape[0], -1,
                                               enc_len, d_enc)
                else:
                    enc_ring = jnp.zeros((1, 1, 1, 1), compute_dtype)
                qlen = emb.shape[2]
                h_ring, cache, pages = pipe_forward(
                    params, cache, pages, emb.astype(compute_dtype),
                    jnp.zeros((R,), jnp.int32), tables, qlen, enc_ring,
                    gate)
                if lens_vec is None:
                    h_last = h_ring[:, :, -1:]
                    new_pos = jnp.int32(qlen)
                else:
                    # ragged prompts: each slot's last REAL token sits
                    # at lens - 1 (prompts are right-padded to the batch
                    # width; pad positions never feed real queries —
                    # causal mask)
                    lens_vec = jnp.asarray(lens_vec, jnp.int32)
                    idx = jnp.clip(lens_vec, 1, qlen) - 1
                    h_last = jnp.take_along_axis(
                        h_ring, idx[:, None, None, None], axis=2)
                    new_pos = jnp.clip(lens_vec, 1, qlen)
                h_last = h_last.reshape(R * rows_g, 1, spec.d_model)
                nxt = lm_head.sample_greedy(
                    params["head"], params["final_norm"]["scale"], h_last,
                    norm_kind=spec.norm,
                    norm_bias=params["final_norm"].get("bias"),
                    vocab=spec.vocab)
                m = gate > 0
                new_state = {
                    **state, "cache": cache,
                    "pos": jnp.where(m, new_pos, state["pos"]),
                    "live": jnp.where(m, 1,
                                      state["live"]).astype(jnp.int32)}
                if pages:
                    new_state["pages"] = pages
                if has_enc:
                    new_state["enc_out"] = jnp.where(
                        m.reshape((R, 1, 1, 1)), enc_ring,
                        state["enc_out"])
                return new_state, nxt

            return admit_step

        admit_step = _make_admit_step(_pipe_forward, None)

        def prefill_step(state, batch):
            # one-shot prefill == admitting every slot at once
            return admit_step(state, batch, jnp.ones((R,), jnp.int32))

        text_len = prefill_len - (spec.n_patches
                                  if spec.frontend == "vision" else 0)
        prefill_specs = {"tokens": jax.ShapeDtypeStruct(
            (R, rows_g, text_len), jnp.int32)}
        if spec.frontend == "vision":
            prefill_specs["patches"] = jax.ShapeDtypeStruct(
                (R, rows_g, spec.n_patches, spec.d_model), compute_dtype)
        if has_enc:
            prefill_specs["frames"] = jax.ShapeDtypeStruct(
                (R, rows_g, enc_len, d_enc), compute_dtype)

    # ---------------- init + pspecs ---------------------------------------
    _box: Dict[str, Any] = {}

    def _shapes():
        p, s = init_params(spec, mplan, jax.random.key(0), compute_dtype)
        p, s = quant.quantize_params(p, s, weight_dtype)
        _box["pspecs"] = s
        return p

    params_shape = jax.eval_shape(_shapes)
    pspecs = _box["pspecs"]
    # raw (unquantized) template: load_params casts an incoming host
    # checkpoint to these dtypes before the optional quantization pass
    param_template = jax.eval_shape(
        lambda: init_params(spec, mplan, jax.random.key(0),
                            compute_dtype)[0])

    def init_state(key):
        params, _ = init_params(spec, mplan, key, compute_dtype)
        if v > 1:
            # storage order: row s·v + j holds model chunk j·S + s, so the
            # contiguous stage shard owns its interleaved chunks — the
            # same layout training uses, which is why
            # reshard_state_for_plan loads train checkpoints unchanged
            perm = jnp.asarray(sched.storage_chunk_order())
            params = dict(params)
            params["stages"] = jax.tree.map(lambda a: a[perm],
                                            params["stages"])
            params["layer_windows"] = params["layer_windows"][perm]
            params["layer_thetas"] = params["layer_thetas"][perm]
        params, _ = quant.quantize_params(params, None, weight_dtype)
        # per-slot serving state: each schedule microbatch slot carries
        # its own cache position and liveness.  A fresh session is fully
        # live (the one-shot flows behave as before); the continuous
        # batcher resets all slots first and admits per slot.
        state = {"params": params, "cache": _cache_template(),
                 "pos": jnp.zeros((R,), jnp.int32),
                 "live": jnp.ones((R,), jnp.int32)}
        if page_size:
            state["pages"] = _pages_template()
            state["tables"] = jnp.full((R, max_pages), -1, jnp.int32)
        if has_enc:
            state["enc_out"] = jnp.zeros((R, rows_g, enc_len, d_enc),
                                         compute_dtype)
        return state

    cache_pspec = _cache_pspec()
    state_pspecs = {"params": pspecs, "cache": cache_pspec, "pos": P(),
                    "live": P()}
    if page_size:
        state_pspecs["pages"] = _pages_pspec()
        state_pspecs["tables"] = P()
    if has_enc:
        state_pspecs["enc_out"] = P(None, batch_dim_spec, None, None)

    token_spec = jax.ShapeDtypeStruct((global_batch,), jnp.int32)

    paged_cfg = None
    if page_size:
        paged_cfg = {"page_size": page_size, "max_pages": max_pages,
                     "pool_pages": pool_pages, "cache_len": cache_len}
    ragged_ok = (not has_enc and spec.frontend != "vision" and not any(
        blk.mixer in ("mamba", "rwkv") or blk.ffn == "rwkv_cmix"
        for blk in statics.program))

    # ---------------- liveness-aware bucket variants -----------------------
    lattice = None
    decode_step_for = None
    admit_step_for = None
    if buckets:
        lattice = bucket_lattice(R)

        def decode_step_for(R_b):
            # bucketed() proves the variant's tables are the full-R
            # tables with dead slots deleted — the exactness contract
            in_b = (np.arange(R) < int(R_b)).astype(np.int32)
            return _make_decode_step(_make_pipe_forward(sched.bucketed(R_b)),
                                     in_b)

        if prefill_len:
            def admit_step_for(R_b):
                in_b = (np.arange(R) < int(R_b)).astype(np.int32)
                return _make_admit_step(
                    _make_pipe_forward(sched.bucketed(R_b)), in_b)

        if speculative:
            def verify_step_for(R_b):
                in_b = (np.arange(R) < int(R_b)).astype(np.int32)
                return _make_verify_step(
                    _make_pipe_forward(sched.bucketed(R_b), tokenwise=True),
                    in_b)

    return EngineSession(spec=spec, plan=plan, mesh=mesh, sched=sched,
                         decode_step=decode_step, prefill_step=prefill_step,
                         init_state=init_state, state_pspecs=state_pspecs,
                         token_spec=token_spec, prefill_specs=prefill_specs,
                         reset_step=reset_slots_step, admit_step=admit_step,
                         compact_step=compact_slots_step, buckets=lattice,
                         decode_step_for=decode_step_for,
                         admit_step_for=admit_step_for,
                         paged=paged_cfg, ragged_ok=ragged_ok,
                         verify_step=verify_step,
                         verify_step_for=verify_step_for,
                         draft_step=session_draft_step,
                         rollback_step=rollback_slots_step,
                         cache_len=cache_len, obs=obs,
                         weight_dtype=weight_dtype, kv_dtype=kv_dtype,
                         compute_dtype=compute_dtype,
                         param_template=param_template)
