"""Pipelined serving: prefill and decode steps.

Decode microbatches the request batch into R groups and pipelines them
through the stages (fwd-only 1F schedule, ticks = R + S − 1) — the serving
analogue of PipeDream's minibatch injection; with continuous batching the
pipeline stays full.  Each stage holds the KV/SSM state for its own layers
(cache sharded: batch over data, layers with their stage, heads over
tensor).

Long-context mode (``sp=True``, used by long_500k with global_batch=1):
the KV cache is sharded over the *data* axis along sequence length and
attention combines partial softmax stats across shards (SP decode,
models/nn.py::_sdpa_decode_seq_sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.models import lm_head
from repro.models import spec as spec_lib
from repro.models.init import init_params
from repro.models.stage import encoder_fwd, init_stage_state, make_statics, stage_fwd
from repro.parallel.mesh import AXIS_STAGE, AXIS_TENSOR, ParallelismPlan, data_axes


def default_cache_lens(spec: spec_lib.ModelSpec, pp: int, cache_len: int
                       ) -> List[int]:
    """Per-position static KV capacities (union-max across stages).

    Windowed layers only need ``window`` slots; a position gets the max
    requirement over the stages that share it (DESIGN.md §8).
    """
    lps = spec.layers_per_stage(pp)
    lens = []
    for i in range(lps):
        need = 0
        for s in range(pp):
            blk = spec.blocks[s * lps + i]
            if blk.mixer != "attn":
                continue
            w = blk.window
            need = max(need, cache_len if w <= 0 else min(w, cache_len))
        lens.append(max(need, 8))
    return lens


@dataclasses.dataclass
class ServeBundle:
    spec: spec_lib.ModelSpec
    plan: ParallelismPlan
    mesh: Mesh
    decode_step: Callable          # (state, tokens) -> (state, next_tokens)
    prefill_step: Optional[Callable]
    init_state: Callable           # (key) -> state
    state_pspecs: Any
    token_spec: jax.ShapeDtypeStruct
    prefill_specs: Optional[Dict[str, jax.ShapeDtypeStruct]]

    def state_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_pspecs,
                            is_leaf=lambda x: isinstance(x, P))


def build_serving(spec: spec_lib.ModelSpec, plan: ParallelismPlan,
                  mesh: Mesh, *, cache_len: int, global_batch: int,
                  prefill_len: int = 0, sp: bool = False,
                  compute_dtype=jnp.bfloat16) -> ServeBundle:
    S = plan.pp
    assert plan.virtual_stages == 1, (
        "serving runs one chunk per stage.  Training-side interleaving is "
        "fully supported (schedule='interleaved' for flush semantics, "
        "'interleaved_async' for per-microbatch updates with per-chunk "
        "weight-version rings — see docs/schedules.md); interleaving the "
        "prefill/decode schedules here is a ROADMAP open item")
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.devices.shape[mesh.axis_names.index(a)]
                      for a in daxes]))
    dnames = daxes if len(daxes) > 1 else daxes[0]
    tp_axis = AXIS_TENSOR if plan.tp > 1 else None

    if sp:
        # SP: batch replicated over data; cache_len sharded over the data
        # axes (both of them on the multi-pod mesh)
        R = 1
        gb = global_batch                       # rows per group (replicated)
        seq_axis = daxes
        sp_shards = dp
        batch_dim_spec = None
    else:
        R = min(plan.decode_microbatches, max(global_batch // dp, 1))
        while global_batch % (dp * R):
            R -= 1
        gb = global_batch // (dp * R)           # local rows per group
        seq_axis = None
        sp_shards = 1
        batch_dim_spec = dnames

    statics = make_statics(spec, plan,
                           tokens_per_mb=gb * max(prefill_len, 1))
    if prefill_len:
        # Prefill writes a contiguous qlen slab: every attention cache must
        # be full-length (windowed layers still *mask* to their window; the
        # ring-buffer memory optimization only applies to decode-only use).
        lens = [cache_len] * spec.layers_per_stage(S)
    else:
        lens = default_cache_lens(spec, S, cache_len)
    # SP shards only full-length caches over the data axes; windowed ring
    # buffers (len < cache_len) are small and stay replicated — their
    # modulo write/read does not distribute.  The flag is static and
    # stage-uniform because default_cache_lens already union-maxes the
    # per-position requirement across stages.
    sp_flags = [sp and l >= cache_len for l in lens]
    if sp:
        lens = [max(-(-l // sp_shards), 8) if f else l
                for l, f in zip(lens, sp_flags)]
    seq_axes = [seq_axis if f else None for f in sp_flags]

    has_enc = spec.encoder is not None
    enc_len = spec.encoder.source_len if has_enc else 1
    d_enc = spec.encoder.d_model if has_enc else 1

    # ---------------- state construction ---------------------------------
    # rows_g: GLOBAL rows per microbatch group (replicated rows in SP mode).
    rows_g = gb * (1 if sp else dp)
    # Global cache dims: seq-sharded positions hold l_local per device, so
    # the global dim is l_local * dp.
    glens = [l * (dp if f else 1) for l, f in zip(lens, sp_flags)]

    def _layer_of(path) -> int:
        for k in path:
            key = str(getattr(k, "key", ""))
            if key.startswith("layer_"):
                return int(key.split("_")[1])
        raise KeyError(path)

    def _is_kv(path) -> bool:
        return any(getattr(k, "key", None) == "kv" for k in path)

    def _cache_template():
        """Global cache template, stacked (pp, R, rows_g, ...)."""
        base = init_stage_state(statics, rows_g, glens, compute_dtype)

        def stack(leaf):
            return jnp.zeros((S, R) + leaf.shape, leaf.dtype)

        return jax.tree.map(stack, base)

    def _cache_pspec():
        base = init_stage_state(statics, rows_g, glens, compute_dtype)

        def pspec(path, leaf):
            dims: list = [AXIS_STAGE, None]         # (pp, R, ...)
            dims.append(batch_dim_spec)             # rows
            dims += [None] * (leaf.ndim - 1)
            if _is_kv(path) and sp_flags[_layer_of(path)]:
                dims[3] = daxes                     # (rows, L, KV, Dh)
            return P(*dims)

        return jax.tree_util.tree_map_with_path(pspec, base)

    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    # ---------------- one pipelined forward pass --------------------------
    def _pipe_forward(params, cache, embeds_ring, pos, qlen, enc_ring):
        """embeds_ring: (R, Bg_rows, qlen, d); returns (h_ring, cache')."""
        win, th = params["layer_windows"], params["layer_thetas"]

        def f_phase(tick, cache, recv_f, h_ring, weights, win, th, embeds,
                    enc_ring, pos):
            s = jax.lax.axis_index(AXIS_STAGE)
            r = tick - s
            valid = (r >= 0) & (r < R)
            rsafe = jnp.clip(r, 0, R - 1)
            x0 = jax.lax.dynamic_index_in_dim(embeds, rsafe, 0,
                                              keepdims=False)
            x_in = jnp.where(s == 0, x0, recv_f[0])
            st_r = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a[0], rsafe, 0,
                                                       keepdims=False),
                cache)
            cross = None
            if has_enc:
                cross = jax.lax.dynamic_index_in_dim(enc_ring, rsafe, 0,
                                                     keepdims=False)
            positions = jnp.broadcast_to(
                pos + jnp.arange(qlen, dtype=jnp.int32), (x_in.shape[0], qlen))
            h, new_st, _ = stage_fwd(
                weights, x_in, statics, positions=positions,
                windows=win[0], thetas=th[0], tp_axis=tp_axis,
                state=st_r, cache_pos=pos, cross_x=cross, seq_axis=seq_axes)

            def wr(a, n):
                old = jax.lax.dynamic_index_in_dim(a[0], rsafe, 0,
                                                   keepdims=False)
                new = jnp.where(valid, n.astype(a.dtype), old)
                return jax.lax.dynamic_update_index_in_dim(a[0], new, rsafe,
                                                           0)[None]

            cache = jax.tree.map(wr, cache, new_st)
            h_send = jax.lax.ppermute(h, AXIS_STAGE, fwd_perm) if S > 1 else h
            old_h = jax.lax.dynamic_index_in_dim(h_ring, rsafe, 0,
                                                 keepdims=False)
            h_keep = jnp.where(valid & (s == S - 1), h, old_h)
            h_ring = jax.lax.dynamic_update_index_in_dim(h_ring, h_keep,
                                                         rsafe, 0)
            return cache, h_send[None], h_ring

        cache_pspec = _cache_pspec()
        cache_pspec = jax.tree.map(lambda p: P(*p), cache_pspec,
                                   is_leaf=lambda x: isinstance(x, P))
        act_pspec = P(AXIS_STAGE, batch_dim_spec, None, None)
        emb_pspec = P(None, batch_dim_spec, None, None)
        hring_pspec = P(None, batch_dim_spec, None, None)
        enc_pspec = (P(None, batch_dim_spec, None, None) if has_enc
                     else P(None, None, None, None))
        stage_pspec = _box["pspecs"]["stages"]
        win_pspec = P(AXIS_STAGE, None)

        f_sharded = shard_map(
            f_phase, mesh=mesh,
            in_specs=(P(), cache_pspec, act_pspec, hring_pspec, stage_pspec,
                      win_pspec, win_pspec, emb_pspec, enc_pspec, P()),
            out_specs=(cache_pspec, act_pspec, hring_pspec),
            check_vma=False)

        rows_g = gb * (1 if sp else dp)
        recv = jnp.zeros((S, rows_g, qlen, spec.d_model), compute_dtype)
        h_ring = jnp.zeros((R, rows_g, qlen, spec.d_model), compute_dtype)

        def body(carry, tick):
            cache, recv, h_ring = carry
            cache, recv, h_ring = f_sharded(
                tick, cache, recv, h_ring, params["stages"], win, th,
                embeds_ring, enc_ring, pos)
            return (cache, recv, h_ring), None

        (cache, _, h_ring), _ = jax.lax.scan(
            body, (cache, recv, h_ring),
            jnp.arange(R + S - 1, dtype=jnp.int32))
        return h_ring, cache

    # ---------------- decode step ----------------------------------------
    def decode_step(state, tokens):
        """tokens: (B_global,) int32; returns (state, next (B_global,))."""
        params, cache, pos = state["params"], state["cache"], state["pos"]
        emb = lm_head.embed_tokens(params["embed"], tokens)[:, None]
        rows_g = gb * (1 if sp else dp)
        embeds_ring = emb.reshape(R, rows_g, 1, spec.d_model)
        if has_enc:
            enc_ring = state["enc_out"]
        else:
            enc_ring = jnp.zeros((1, 1, 1, 1), compute_dtype)
        h_ring, cache = _pipe_forward(params, cache, embeds_ring, pos, 1,
                                      enc_ring)
        h = h_ring.reshape(R * rows_g, 1, spec.d_model)
        nxt = lm_head.sample_greedy(
            params["head"], params["final_norm"]["scale"], h,
            norm_kind=spec.norm, norm_bias=params["final_norm"].get("bias"),
            vocab=spec.vocab)
        return ({**state, "cache": cache, "pos": pos + 1}, nxt)

    # ---------------- prefill step ----------------------------------------
    prefill_step = None
    prefill_specs = None
    if prefill_len:
        def prefill_step(state, batch):
            params, cache = state["params"], state["cache"]
            tokens = batch["tokens"]                    # (R, rows, S_text)
            emb = lm_head.embed_tokens(params["embed"], tokens)
            if spec.frontend == "vision" and "patches" in batch:
                emb = jnp.concatenate(
                    [batch["patches"].astype(emb.dtype), emb], axis=2)
            if has_enc:
                fr = batch["frames"].reshape(-1, enc_len, d_enc)
                enc_out = encoder_fwd(params["encoder"],
                                      fr.astype(compute_dtype), spec)
                enc_ring = enc_out.reshape(tokens.shape[0], -1, enc_len,
                                           d_enc)
            else:
                enc_ring = jnp.zeros((1, 1, 1, 1), compute_dtype)
            h_ring, cache = _pipe_forward(params, cache,
                                          emb.astype(compute_dtype),
                                          jnp.int32(0), emb.shape[2],
                                          enc_ring)
            rows_g = h_ring.shape[1]
            h_last = h_ring[:, :, -1:].reshape(R * rows_g, 1, spec.d_model)
            nxt = lm_head.sample_greedy(
                params["head"], params["final_norm"]["scale"], h_last,
                norm_kind=spec.norm,
                norm_bias=params["final_norm"].get("bias"), vocab=spec.vocab)
            new_state = {**state, "cache": cache,
                         "pos": jnp.int32(emb.shape[2])}
            if has_enc:
                new_state["enc_out"] = enc_ring
            return new_state, nxt

        rows_g = gb * (1 if sp else dp)
        text_len = prefill_len - (spec.n_patches
                                  if spec.frontend == "vision" else 0)
        prefill_specs = {"tokens": jax.ShapeDtypeStruct(
            (R, rows_g, text_len), jnp.int32)}
        if spec.frontend == "vision":
            prefill_specs["patches"] = jax.ShapeDtypeStruct(
                (R, rows_g, spec.n_patches, spec.d_model), compute_dtype)
        if has_enc:
            prefill_specs["frames"] = jax.ShapeDtypeStruct(
                (R, rows_g, enc_len, d_enc), compute_dtype)

    # ---------------- init + pspecs ---------------------------------------
    _box: Dict[str, Any] = {}

    def _shapes():
        p, s = init_params(spec, plan, jax.random.key(0), compute_dtype)
        _box["pspecs"] = s
        return p

    params_shape = jax.eval_shape(_shapes)
    pspecs = _box["pspecs"]

    def init_state(key):
        params, _ = init_params(spec, plan, key, compute_dtype)
        state = {"params": params, "cache": _cache_template(),
                 "pos": jnp.zeros((), jnp.int32)}
        if has_enc:
            rows_g = gb * (1 if sp else dp)
            state["enc_out"] = jnp.zeros((R, rows_g, enc_len, d_enc),
                                         compute_dtype)
        return state

    cache_pspec = _cache_pspec()
    state_pspecs = {"params": pspecs, "cache": cache_pspec, "pos": P()}
    if has_enc:
        state_pspecs["enc_out"] = P(None, batch_dim_spec, None, None)

    token_spec = jax.ShapeDtypeStruct(
        (global_batch if sp else global_batch,), jnp.int32)

    return ServeBundle(spec=spec, plan=plan, mesh=mesh,
                       decode_step=decode_step, prefill_step=prefill_step,
                       init_state=init_state, state_pspecs=state_pspecs,
                       token_spec=token_spec, prefill_specs=prefill_specs)
