"""Subprocess worker: continuous batching is bit-exact vs solo runs.

Usage: batch_check.py PP V R STEPS

Builds a tiny dense LM served by a continuous-batching session over a
``pp``-stage pipe (``serve_interleaved`` with v chunks per stage when
V > 1, else ``serve_1f``) with R microbatch slots, runs a staggered
(R + 1)-request trace — the extra request arrives mid-stream and is
admitted into the slot freed by the earliest-finishing request — and
asserts every request's token sequence is bit-identical (fp32) to the
same request run SOLO through a fresh one-shot ``serve_1f`` session
(the ISSUE-5 exactness contract).  Prints MATCH on success.
"""
import sys

pp, v, r_slots, steps = map(int, sys.argv[1:5])

import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={pp}")

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models import spec as spec_lib                     # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.batcher import (ContinuousBatchingSession,  # noqa: E402
                                   Request)
from repro.serving.engine import build_serving                # noqa: E402

PREFILL, CACHE = 8, 64
n_layers = pp * max(v, 1) * 2
blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
               for _ in range(n_layers))
spec = spec_lib.ModelSpec(
    name="batch-check", d_model=64, n_layers=n_layers, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
    norm="rmsnorm", act="silu")
mesh = make_host_mesh(data=1, model=pp)
dmesh = split_model_axis(mesh, pp, 1)


def make_session(schedule, vv):
    plan = ParallelismPlan(pp=pp, tp=1, microbatches=max(r_slots, 1),
                           decode_microbatches=r_slots, schedule=schedule,
                           virtual_stages=vv)
    return build_serving(spec, plan, dmesh, cache_len=CACHE,
                         global_batch=r_slots, prefill_len=PREFILL,
                         compute_dtype=jnp.float32)


def solo_tokens(prompt, n_tokens):
    sess = make_session("auto", 1)           # the serve_1f reference
    sess.start(jax.random.key(0))
    tokens = jnp.asarray(np.broadcast_to(prompt, (r_slots, 1, PREFILL)))
    toks = [np.asarray(sess.prefill({"tokens": tokens}))[0]]
    for _ in range(n_tokens - 1):
        last = jnp.asarray(np.full((r_slots,), toks[-1], np.int32))
        toks.append(np.asarray(sess.decode(last))[0])
    return [int(t) for t in toks]


rng = np.random.default_rng(11)
n_req = r_slots + 1
prompts = [rng.integers(1, 256, PREFILL).astype(np.int32)
           for _ in range(n_req)]
# request 0 finishes early; the last request arrives mid-stream and is
# admitted into its freed slot while the others still decode
lens = [3] + [steps] * (n_req - 2) + [max(steps - 2, 2)]
trace = [Request(rid=i, prompt=prompts[i], max_new_tokens=lens[i],
                 arrival=0 if i < r_slots else 1)
         for i in range(n_req)]

sess = make_session("serve_interleaved" if v > 1 else "auto", v)
assert sess.sched.name == ("serve_interleaved" if v > 1 else "serve_1f")
sess.start(jax.random.key(0))
report = ContinuousBatchingSession(sess).run(trace)
assert len(report.completed) == n_req, report.summary()
late = trace[-1]
assert late.step_admitted > trace[0].step_done, (
    late.step_admitted, trace[0].step_done)

for r in trace:
    want = solo_tokens(r.prompt, r.max_new_tokens)
    np.testing.assert_array_equal(np.asarray(r.tokens), np.asarray(want),
                                  err_msg=f"request {r.rid}")

print("MATCH")
