"""Shared test config.

NOTE: no --xla_force_host_platform_device_count here — smoke tests and
benches must see exactly 1 device (task spec).  Multi-device SPMD tests
spawn subprocesses (tests/test_pipeline_spmd.py) that set the flag
themselves before importing jax.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

try:
    from hypothesis import settings
    settings.register_profile("repro", deadline=None, max_examples=50,
                              derandomize=True)
    settings.load_profile("repro")
except ImportError:  # pragma: no cover
    pass


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
