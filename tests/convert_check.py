"""Subprocess worker: converted checkpoint == direct in-memory load,
through the real engine, at any (pp, tp, v).

Usage: convert_check.py PP TP V STEPS

Writes a synthetic HF safetensors fixture, converts it to storage-chunk
files for the requested plan, and asserts:

  1. ``load_converted`` equals ``hf_to_params`` bit-for-bit (the disk
     round-trip adds nothing).
  2. The engine serves identical greedy tokens from the converted
     checkpoint and from the direct in-memory load (fp32: the decode is
     bit-exact, not tolerance-gated).
  3. For v > 1, a v=1 conversion of the SAME fixture served under
     ``serve_1f`` emits the same tokens — conversion is plan-invariant.
  4. The int8-weight + int8-KV engine loaded from the same checkpoint
     tracks the fp32 greedy continuation (match-rate gate) — the
     quantized sharding (scale pspecs) works across the same mesh.

Prints MATCH on success.
"""
import sys

pp, tp, v, steps = map(int, sys.argv[1:5])

import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={pp * tp}")

import tempfile           # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.checkpoint import convert as cv                    # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models import spec as spec_lib                     # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

n_layers = pp * v * 2
blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
               for _ in range(n_layers))
spec = spec_lib.ModelSpec(
    name="convert-check", d_model=64, n_layers=n_layers, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256,
    blocks=blocks, norm="rmsnorm", act="silu", qk_norm=True)

tmp = tempfile.mkdtemp(prefix="convert_check_")
fixture = os.path.join(tmp, "model.safetensors")
tensors = cv.make_synthetic_checkpoint(fixture, spec, seed=11)

ck = os.path.join(tmp, "ck")
cv.convert(fixture, ck, spec, pp=pp, tp=tp, virtual_stages=v)
params_conv, manifest = cv.load_converted(ck, spec)
assert manifest["storage_order"] == cv.storage_order(pp, v)
params_direct = cv.hf_to_params(tensors, spec, pp=pp, tp=tp,
                                virtual_stages=v)
jax.tree.map(np.testing.assert_array_equal, params_conv, params_direct)

mesh = make_host_mesh(data=1, model=pp * tp)
dmesh = split_model_axis(mesh, pp, tp)
batch, prefill, cache = 4, 8, 64
start_tokens = np.asarray(jax.random.randint(
    jax.random.key(1), (batch, prefill), 1, spec.vocab, jnp.int32))


def run(params, v_run, weight_dtype=None, kv_dtype=None, page_size=0):
    plan = ParallelismPlan(
        pp=pp, tp=tp, microbatches=4, decode_microbatches=4,
        schedule="serve_interleaved" if v_run > 1 else "auto",
        virtual_stages=v_run)
    sess = build_serving(spec, plan, dmesh, cache_len=cache,
                         global_batch=batch, prefill_len=prefill,
                         compute_dtype=jnp.float32, page_size=page_size,
                         weight_dtype=weight_dtype, kv_dtype=kv_dtype)
    sess.start(jax.random.key(0))
    sess.load_params(params)
    tk = jnp.asarray(start_tokens.reshape(
        sess.prefill_specs["tokens"].shape))
    toks = [np.asarray(sess.prefill({"tokens": tk}))]
    for _ in range(steps):
        toks.append(np.asarray(sess.decode(jnp.asarray(toks[-1]))))
    return np.stack(toks)

got_conv = run(params_conv, v)
got_direct = run(params_direct, v)
np.testing.assert_array_equal(got_conv, got_direct)

if v > 1:
    ck1 = os.path.join(tmp, "ck_v1")
    cv.convert(fixture, ck1, spec, pp=pp, tp=tp, virtual_stages=1)
    params_v1, _ = cv.load_converted(ck1, spec)
    np.testing.assert_array_equal(got_conv, run(params_v1, 1))

got_q = run(params_conv, v, weight_dtype="int8", kv_dtype="int8",
            page_size=16)
match = float(np.mean(got_q == got_conv))
assert match >= 0.7, f"int8 greedy match rate {match} < 0.7"
print(f"int8 match rate {match:.3f}")
print("MATCH")
