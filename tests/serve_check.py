"""Subprocess worker: interleaved serving == 1F serving, bit-level.

Usage: serve_check.py DATA PP TP V SP STEPS

Builds the same tiny dense LM under ``serve_1f`` (the one-chunk
reference) and ``serve_interleaved`` (v chunks per stage) on a
(data, pp, tp) host-device mesh and asserts the greedy continuations
are bit-identical — prefill first tokens plus STEPS decode steps
(SP = 1 runs the sequence-parallel decode path instead: replicated
rows, KV positions sharded over data, R = 1).  At dp = tp = 1 the
``serve_1f`` reference itself is additionally pinned to the
non-incremental full-forward teacher.  Prints MATCH on success.
"""
import sys

data, pp, tp, v, sp, steps = map(int, sys.argv[1:7])

import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={data * pp * tp}")

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models import lm_head                              # noqa: E402
from repro.models import spec as spec_lib                     # noqa: E402
from repro.models.stage import full_transformer, make_statics  # noqa: E402
from repro.parallel.mesh import ParallelismPlan, split_model_axis  # noqa: E402
from repro.serving.engine import build_serving                # noqa: E402

n_layers = pp * v * 2
blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
               for _ in range(n_layers))
spec = spec_lib.ModelSpec(
    name="serve-check", d_model=64, n_layers=n_layers, n_heads=4,
    n_kv=2, d_head=16, d_ff=128, vocab=256,
    blocks=blocks, norm="rmsnorm", act="silu")

mesh = make_host_mesh(data=data, model=pp * tp)
dmesh = split_model_axis(mesh, pp, tp)
dp = data
cache = 64
if sp:
    batch, prefill = 2, 0          # decode-only, replicated rows
else:
    batch, prefill = 4 * dp, 8

start_tokens = np.asarray(jax.random.randint(
    jax.random.key(1), (batch, max(prefill, 1)), 1, spec.vocab, jnp.int32))

runs = {}
for name, vv in (("serve_1f", 1), ("serve_interleaved", v)):
    plan = ParallelismPlan(
        pp=pp, tp=tp, microbatches=4, decode_microbatches=4,
        schedule=name if vv > 1 else "auto",
        virtual_stages=vv)
    sess = build_serving(spec, plan, dmesh, cache_len=cache,
                         global_batch=batch, prefill_len=prefill,
                         sp=bool(sp), compute_dtype=jnp.float32)
    assert sess.sched.name == name, (sess.sched.name, name)
    sess.start(jax.random.key(0))
    if prefill:
        tk = jnp.asarray(start_tokens.reshape(
            sess.prefill_specs["tokens"].shape))
        toks = [np.asarray(sess.prefill({"tokens": tk}))]
    else:
        toks = [start_tokens[:, 0]]
    for _ in range(steps):
        toks.append(np.asarray(sess.decode(jnp.asarray(toks[-1]))))
    runs[name] = (np.stack(toks), sess)

got_1f, sess_1f = runs["serve_1f"]
got_iv, _ = runs["serve_interleaved"]
np.testing.assert_array_equal(got_1f, got_iv)

if dp == 1 and tp == 1 and not sp:
    # pin the reference itself to the non-incremental teacher
    params = jax.tree.map(np.asarray, sess_1f.state["params"])
    statics = make_statics(spec, ParallelismPlan(pp=pp, tp=1),
                           tokens_per_mb=prefill + steps + 1)
    seq = jnp.asarray(start_tokens)
    want = []
    for _ in range(steps + 1):
        emb = lm_head.embed_tokens(params["embed"], seq)
        pos = jnp.broadcast_to(jnp.arange(seq.shape[1]), seq.shape)
        h, _ = full_transformer(params, emb.astype(jnp.float32), statics,
                                positions=pos)
        nxt = lm_head.sample_greedy(
            params["head"], params["final_norm"]["scale"], h[:, -1:],
            norm_kind=spec.norm, norm_bias=params["final_norm"].get("bias"),
            vocab=spec.vocab)
        want.append(np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(got_1f, np.stack(want))

print("MATCH")
