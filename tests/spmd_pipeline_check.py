"""SPMD pipeline vs sequential reference — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<N>.

Usage: python tests/spmd_pipeline_check.py <data> <pp> <tp> <mode> [arch]
           [zero1] [schedule] [virtual_stages] [steps]
Exits nonzero (assertion) on mismatch; prints MATCH lines on success.

For ``schedule=interleaved`` the pipeline runs S physical stages with v
chunks each; the reference runs the SAME model as a sequential pp = S*v
flush pipeline (flush semantics are schedule-timing-independent), with
the pipeline's storage-order (s*v + j -> chunk j*S + s) parameters
permuted back to chunk order before comparison.

For ``schedule=interleaved_async`` (per-microbatch updates, per-chunk
weight-version rings) the update order is timing-dependent, so the
sequential oracle walks the SAME async-interleaved schedule tables
natively — state stays in storage order on both sides and is compared
directly.
"""
import os
import sys

if __name__ == "__main__":
    data, pp, tp = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
    mode = sys.argv[4] if len(sys.argv) > 4 else "stash"
    arch = sys.argv[5] if len(sys.argv) > 5 else "dense"
    zero1 = bool(int(sys.argv[6])) if len(sys.argv) > 6 else False
    schedule = sys.argv[7] if len(sys.argv) > 7 else "auto"
    vstages = int(sys.argv[8]) if len(sys.argv) > 8 else 1
    steps = int(sys.argv[9]) if len(sys.argv) > 9 else 1
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={data * pp * tp}")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def build_tiny_spec(arch: str):
    from repro.models import spec as S
    if arch == "dense":
        blocks = tuple(S.BlockSpec(window=(-1 if i % 2 else 8),
                                   rope_theta=1e4 * (1 + i % 2))
                       for i in range(4))
        return S.ModelSpec(name="tiny", d_model=32, n_layers=4, n_heads=4,
                           n_kv=2, d_head=8, d_ff=64, vocab=64,
                           blocks=blocks, qk_norm=True)
    if arch == "dense8":
        blocks = tuple(S.BlockSpec(window=(-1 if i % 2 else 8),
                                   rope_theta=1e4 * (1 + i % 2))
                       for i in range(8))
        return S.ModelSpec(name="tiny8", d_model=32, n_layers=8, n_heads=4,
                           n_kv=2, d_head=8, d_ff=64, vocab=64,
                           blocks=blocks, qk_norm=True)
    if arch == "moe":
        blocks = tuple(S.BlockSpec(ffn="moe") for _ in range(4))
        return S.ModelSpec(name="tmoe", d_model=32, n_layers=4, n_heads=4,
                           n_kv=4, d_head=8, d_ff=64, vocab=64,
                           blocks=blocks,
                           moe=S.MoESpec(n_experts=4, top_k=2, d_expert=16))
    if arch == "rwkv":
        blocks = tuple(S.BlockSpec(mixer="rwkv", ffn="rwkv_cmix")
                       for _ in range(4))
        return S.ModelSpec(name="trwkv", d_model=32, n_layers=4, n_heads=0,
                           n_kv=0, d_head=0, d_ff=96, vocab=64,
                           blocks=blocks,
                           rwkv=S.RWKVSpec(head_dim=8, decay_lora=4,
                                           tmix_lora=4),
                           family="ssm", subquadratic=True)
    if arch == "hybrid":
        def blk(i):
            return S.BlockSpec(mixer=("attn" if i % 4 == 0 else "mamba"),
                               ffn=("moe" if i % 2 == 1 else "dense"))
        return S.ModelSpec(name="tjam", d_model=32, n_layers=8, n_heads=4,
                           n_kv=2, d_head=8, d_ff=64, vocab=64,
                           blocks=tuple(blk(i) for i in range(8)),
                           moe=S.MoESpec(n_experts=4, top_k=2, d_expert=16),
                           mamba=S.MambaSpec(d_state=4, expand=2),
                           family="hybrid", subquadratic=True)
    raise ValueError(arch)


def _unpermute(state, perm):
    """Storage-order pipeline state -> chunk-order (reference) state."""
    inv = np.argsort(perm)
    out = dict(state)
    params = dict(state["params"])
    params["stages"] = jax.tree.map(lambda a: a[inv], params["stages"])
    params["layer_windows"] = params["layer_windows"][inv]
    params["layer_thetas"] = params["layer_thetas"][inv]
    out["params"] = params
    out["opt_stages"] = {k: jax.tree.map(lambda a: a[inv], sub)
                         for k, sub in state["opt_stages"].items()}
    out["stash"] = {"current": params["stages"]}
    return out


def main(data, pp, tp, mode, arch, zero1=False, schedule="auto", vstages=1,
         steps=1):
    from repro.core.pipeline import build_pipeline
    from repro.core.reference import reference_train_step
    from repro.optim import SGDM
    from repro.parallel.mesh import ParallelismPlan, split_model_axis
    from repro.launch.mesh import make_host_mesh

    spec = build_tiny_spec(arch)
    R = 4
    plan = ParallelismPlan(pp=pp, tp=tp, microbatches=R, stash_mode=mode,
                           remat=True, zero1=zero1, schedule=schedule,
                           virtual_stages=vstages)
    mesh = make_host_mesh(data=data, model=pp * tp)
    dmesh = split_model_axis(mesh, pp, tp)

    seq, gbatch = 16, data * R * 2
    opt = SGDM(lr=0.05, momentum=0.9)
    bundle = build_pipeline(spec, plan, dmesh, seq_len=seq,
                            global_batch=gbatch, optimizer=opt,
                            compute_dtype=jnp.float32)

    key = jax.random.key(0)
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(key)
    bmb = gbatch // R
    tokens = jax.random.randint(jax.random.key(1), (R, bmb, seq), 0,
                                spec.vocab, jnp.int32)
    labels = jax.random.randint(jax.random.key(2), (R, bmb, seq), 0,
                                spec.vocab, jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    bsh = bundle.batch_shardings()
    batch_dev = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}

    step = jax.jit(bundle.train_step,
                   in_shardings=(bundle.state_shardings(), bsh),
                   out_shardings=(bundle.state_shardings(), None))

    # reference: flush-interleaved runs against a chunk-level sequential
    # flush pipeline (chunk order); async-interleaved runs the oracle on
    # the same schedule tables natively (storage order, no permutation)
    if vstages > 1 and schedule != "interleaved_async":
        ref_plan = plan.with_(pp=pp * vstages, schedule="auto",
                              virtual_stages=1)
        perm = bundle.sched.storage_chunk_order()
    else:
        ref_plan = plan
        perm = None
    ref_state = jax.device_get(state)
    ref_state = jax.tree.map(jnp.asarray, ref_state)
    if perm is not None:
        ref_state = _unpermute(ref_state, perm)

    for i in range(steps):
        new_state, metrics = step(state, batch_dev)
        ref_state, ref_metrics = reference_train_step(
            spec, ref_plan, ref_state, batch, opt, aux_weight=0.01 / 1.0)
        print(f"step {i}: pipeline loss {float(metrics['loss']):.6f} "
              f"aux {float(metrics['aux']):.6f} | reference loss "
              f"{float(ref_metrics['loss']):.6f} "
              f"aux {float(ref_metrics['aux']):.6f}")

        # tp>1 changes fp32 reduction order (psum of partial products);
        # tp=1 configs match near-bitwise.
        atol = 2e-4 if arch in ("rwkv", "hybrid") else 5e-5
        if tp > 1:
            atol = max(atol, 5e-4)
        np.testing.assert_allclose(float(metrics["loss"]),
                                   float(ref_metrics["loss"]), atol=atol,
                                   rtol=1e-4)
        state = new_state

    got_state = jax.device_get(new_state)
    got_state = jax.tree.map(jnp.asarray, got_state)
    if perm is not None:
        got_state = _unpermute(got_state, perm)
    got = got_state["params"]
    want = jax.device_get(ref_state["params"])
    flat_w, _ = jax.tree.flatten(want)
    paths = jax.tree_util.tree_flatten_with_path(got)[0]
    for (path, g), w in zip(paths, flat_w):
        name = jax.tree_util.keystr(path)
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=atol, rtol=2e-3, err_msg=f"param mismatch at {name}")
    print(f"MATCH data={data} pp={pp} tp={tp} mode={mode} arch={arch} "
          f"zero1={zero1} schedule={schedule} v={vstages} steps={steps}")


if __name__ == "__main__":
    main(data, pp, tp, mode, arch, zero1, schedule, vstages, steps)
