"""Continuous batching: slot scheduler semantics + engine exactness.

Two layers of coverage:

  * host-side scheduler semantics against a deterministic fake engine
    (no jax): admission order, lane routing, next-tick eviction,
    synchronized vs continuous policies, accounting, error paths;
  * end-to-end exactness in subprocesses (tests/batch_check.py, which
    sets the host-device count before jax initializes): every request
    of a staggered trace — including one admitted mid-stream into an
    evicted slot — decodes bit-exactly (fp32) what a solo one-shot
    ``serve_1f`` run produces, for S ∈ {2, 4} and interleaved (v = 2)
    configs (the ISSUE-5 acceptance matrix).
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.serving.batcher import (BatchingReport, ContinuousBatchingSession,
                                   Request, RequestQueue, Slot)

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

# pp, v, slots, steps
FAST_MATRIX = [
    (2, 2, 2, 8),           # S=2 interleaved (v=2): the ISSUE-5 headline
]
SLOW_MATRIX = [
    (2, 1, 2, 8),           # S=2 serve_1f
    (4, 1, 4, 8),           # S=4 deep pipe
    (4, 2, 4, 8),           # S=4 interleaved (v=2)
]


def _run_case(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "batch_check.py"),
         *[str(a) for a in case]],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "MATCH" in out.stdout


@pytest.mark.parametrize("case", FAST_MATRIX,
                         ids=lambda c: "pp{}v{}r{}".format(*c[:3]))
def test_midstream_admission_bit_exact(case):
    _run_case(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", SLOW_MATRIX,
                         ids=lambda c: "pp{}v{}r{}".format(*c[:3]))
def test_midstream_admission_bit_exact_full(case):
    _run_case(case)


# ---------------------------------------------------------------------------
# host-side scheduler semantics (fake engine, no jax)
# ---------------------------------------------------------------------------

class _Spec:
    def __init__(self, shape):
        self.shape = shape


class FakeEngine:
    """Deterministic engine-shaped stand-in.

    First token of a prompt is ``sum(prompt) % 251``; decode maps
    ``t -> (7 t + 13) % 251``.  Tracks the slot ops it saw so the tests
    can assert masked admission / reset behaviour, and advances a
    modeled clock (``dt_admit`` / ``dt_decode`` per op) the way the
    analytic benchmark does.
    """

    def __init__(self, slots, rows=1, text_len=4, dt_admit=3.0,
                 dt_decode=1.0):
        self.R, self.rows, self.text_len = slots, rows, text_len
        self.sched = dataclasses.make_dataclass(
            "S", ["n_microbatches"])(slots)
        self.token_spec = _Spec((slots * rows,))
        self.prefill_specs = {"tokens": _Spec((slots, rows, text_len))}
        self.admit_step = object()       # "has the admission surface"
        self.state = None
        self.now = 0.0
        self.dt_admit, self.dt_decode = dt_admit, dt_decode
        self.reset_masks, self.admit_masks = [], []

    def clock(self):
        return self.now

    def start(self, key=None):
        self.state = np.zeros((self.R,))
        return self

    def reset_slots(self, mask):
        self.reset_masks.append(np.asarray(mask).copy())
        return self

    def write_prefill_into_slots(self, batch, mask):
        self.admit_masks.append(np.asarray(mask).copy())
        self.now += self.dt_admit
        toks = batch["tokens"].astype(np.int64).sum(axis=2) % 251
        return toks.reshape(-1).astype(np.int32)

    def decode(self, tokens):
        self.now += self.dt_decode
        return ((7 * np.asarray(tokens).astype(np.int64) + 13) % 251
                ).astype(np.int32)


def _chain(prompt, n):
    t = int(prompt.astype(np.int64).sum() % 251)
    out = [t]
    for _ in range(n - 1):
        t = (7 * t + 13) % 251
        out.append(t)
    return out


def _mk_requests(lens, arrivals, text_len=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 100, text_len)
                    .astype(np.int32), max_new_tokens=n, arrival=a)
            for i, (n, a) in enumerate(zip(lens, arrivals))]


def test_lifecycle_routing_and_tokens():
    eng = FakeEngine(slots=2)
    server = ContinuousBatchingSession(eng, clock=eng.clock)
    reqs = _mk_requests([3, 6, 4], [0, 0, 1])
    report = server.run(reqs)
    assert all(r.state == "finished" for r in reqs)
    for r in reqs:
        assert r.tokens == _chain(r.prompt, r.max_new_tokens)
    # request 2 rode the slot request 0 freed, mid-stream
    assert reqs[2].step_admitted > reqs[0].step_done
    assert reqs[1].step_done > reqs[2].step_admitted
    # two admissions: {0, 1} at step 0, {2} after the eviction
    assert len(eng.admit_masks) == 2
    np.testing.assert_array_equal(eng.admit_masks[0], [1, 1])
    assert eng.admit_masks[1].sum() == 1
    # the startup reset covers all slots; the mid-stream eviction frees
    # exactly request 0's slot (request 1 keeps decoding in the other)
    np.testing.assert_array_equal(eng.reset_masks[0], [1, 1])
    assert eng.reset_masks[1].sum() == 1
    assert report.completed_tokens == 13


def test_eviction_frees_slot_next_tick():
    eng = FakeEngine(slots=1)
    server = ContinuousBatchingSession(eng, clock=eng.clock)
    reqs = _mk_requests([2, 2], [0, 0])
    server.run(reqs)
    # one slot: request 1 waits for request 0's slot; the reset (free)
    # happens on the tick AFTER request 0 finishes, then admission
    assert reqs[1].step_admitted == reqs[0].step_done + 1
    assert reqs[1].tokens == _chain(reqs[1].prompt, 2)


def test_synchronized_policy_waits_for_drain():
    lens, arrivals = [2, 8, 4], [0, 0, 1]
    ec, es = FakeEngine(slots=2), FakeEngine(slots=2)
    rc = ContinuousBatchingSession(ec, clock=ec.clock).run(
        _mk_requests(lens, arrivals))
    rs = ContinuousBatchingSession(es, policy="synchronized",
                                   clock=es.clock).run(
        _mk_requests(lens, arrivals))
    # synchronized: request 2 cannot enter until BOTH slots drain
    assert rs.requests[2].step_admitted > rs.requests[1].step_done
    assert rc.requests[2].step_admitted < rc.requests[1].step_done
    # same completed tokens, strictly less modeled time -> higher goodput
    assert rc.completed_tokens == rs.completed_tokens
    assert rc.wall_seconds < rs.wall_seconds
    assert rc.goodput_tokens_per_s > rs.goodput_tokens_per_s
    # both produce identical per-request token streams (policy is pure
    # scheduling: it never changes what a request computes)
    for a, b in zip(rc.requests, rs.requests):
        assert a.tokens == b.tokens


def test_eos_finishes_early():
    eng = FakeEngine(slots=1)
    req = _mk_requests([50], [0])[0]
    chain = _chain(req.prompt, 50)
    server = ContinuousBatchingSession(eng, eos_id=chain[4],
                                       clock=eng.clock)
    server.run([req])
    assert req.finished and req.tokens == chain[:5]


def test_report_accounting():
    eng = FakeEngine(slots=2, dt_admit=2.0, dt_decode=1.0)
    server = ContinuousBatchingSession(eng, clock=eng.clock)
    reqs = _mk_requests([4, 4], [0, 0])
    report = server.run(reqs)
    assert isinstance(report, BatchingReport)
    s = report.summary()
    assert s["completed"] == 2 and s["completed_tokens"] == 8
    assert s["admit_rounds"] == 1 and s["decode_rounds"] == 3
    assert s["wall_seconds"] == pytest.approx(2.0 + 3.0)
    assert s["goodput_tokens_per_s"] == pytest.approx(8 / 5.0)
    lat = report.per_token_latency_s()
    assert lat.shape == (2,) and (lat > 0).all()
    assert s["p99_per_token_latency_s"] >= s["p50_per_token_latency_s"]


def test_rerun_resets_arrival_gating_and_counters():
    """A second run() on the same server must replay arrival gating
    from step 0 and report per-run (not cumulative) accounting."""
    eng = FakeEngine(slots=2)
    server = ContinuousBatchingSession(eng, clock=eng.clock)
    r1 = server.run(_mk_requests([4, 4], [0, 0]))
    reqs = _mk_requests([4, 4], [0, 3], seed=1)
    r2 = server.run(reqs)
    # arrival=3 must gate: admitted at its arrival step, not instantly
    assert reqs[1].step_admitted == 3
    assert r2.steps <= r1.steps + 4 and r2.decode_rounds <= 7
    for r in reqs:
        assert r.tokens == _chain(r.prompt, 4)


def test_queue_arrival_gating_and_order():
    q = RequestQueue(_mk_requests([1, 1, 1], [5, 0, 2]))
    q.absorb_arrivals(0, 0.0)
    assert q.n_ready == 1 and len(q) == 3
    q.absorb_arrivals(4, 1.0)
    assert q.n_ready == 2
    first = q.pop_ready()
    assert first.rid == 1 and first.t_arrival == 0.0
    q.absorb_arrivals(5, 2.0)
    assert q.pop_ready().rid == 2 and q.pop_ready().rid == 0
    assert q.pop_ready() is None and len(q) == 0
    with pytest.raises(ValueError, match="arrival order"):
        qq = RequestQueue(_mk_requests([1], [5]))
        qq.push(_mk_requests([1], [1])[0])


def test_slot_states():
    s = Slot(0, lanes=2)
    assert s.free and not s.drained
    reqs = _mk_requests([1, 1], [0, 0])
    s.requests = [reqs[0], None]
    assert not s.free and not s.drained and s.live_lanes() == [(0, reqs[0])]
    reqs[0].state = "finished"
    assert s.drained and s.live_lanes() == []
    s.clear()
    assert s.free


def test_error_paths():
    eng = FakeEngine(slots=2)
    with pytest.raises(ValueError, match="unknown policy"):
        ContinuousBatchingSession(eng, policy="fifo")
    bad = FakeEngine(slots=2)
    bad.admit_step = None
    with pytest.raises(ValueError, match="prefill_len"):
        ContinuousBatchingSession(bad)
    server = ContinuousBatchingSession(eng, clock=eng.clock)
    long = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=1)
    with pytest.raises(ValueError, match="exceeds"):
        server.run([long])
    # short prompts are legal now (ragged admission) — unless the model
    # carries recurrent state, which would absorb the padding
    rec = FakeEngine(slots=2)
    rec.ragged_ok = False
    recs = ContinuousBatchingSession(rec, clock=rec.clock)
    short = Request(rid=0, prompt=np.arange(2, dtype=np.int32),
                    max_new_tokens=1)
    with pytest.raises(ValueError, match="recurrent"):
        recs.run([short])


# ---------------------------------------------------------------------------
# --arrivals trace parsing (launch/serve.py)
# ---------------------------------------------------------------------------

def test_parse_arrivals_accepts_both_forms():
    from repro.launch.serve import parse_arrivals
    assert parse_arrivals("0,0,2,5") == [0, 0, 2, 5]
    pois = parse_arrivals("poisson:0.5:8", seed=1)
    assert len(pois) == 8
    assert all(isinstance(t, int) and t >= 0 for t in pois)
    assert pois == sorted(pois)                  # cumulative gaps
    assert parse_arrivals("poisson:0.5:8", seed=1) == pois   # seeded
    assert parse_arrivals("poisson:0.5:8", seed=2) != pois


def test_parse_arrivals_rejects_malformed_specs():
    """ISSUE-7: every malformed --arrivals spec raises a ValueError
    naming the accepted formats — never a bare unpack/parse traceback."""
    from repro.launch.serve import parse_arrivals
    bad_specs = [
        "poisson:0.5",            # missing N
        "poisson:0.5:8:extra",    # too many parts
        "poisson:fast:8",         # non-numeric rate
        "poisson:0.5:many",       # non-integer count
        "poisson:-1:8",           # non-positive rate
        "poisson:0.5:0",          # non-positive count
        "1,two,3",                # non-numeric step
        "3,-1",                   # negative step
    ]
    for spec in bad_specs:
        with pytest.raises(ValueError, match="accepted --arrivals"):
            parse_arrivals(spec)
