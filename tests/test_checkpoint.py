"""Fault tolerance (paper §4): per-stage local checkpoints, restart from
the last round completed by ALL stages, driver crash/replay determinism,
and elastic stage resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.manager import CheckpointManager, reshard_stages
from repro.core.pipeline import build_pipeline
from repro.core.reference import reference_init_state
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim import SGDM
from repro.parallel.mesh import ParallelismPlan, split_model_axis
from repro.runtime.driver import DriverConfig, TrainDriver


def _tiny_state(pp=2, mode="stash"):
    cfg = configs.get("qwen3_14b")
    spec = cfg.smoke_spec()
    plan = cfg.SMOKE_PLAN.with_(pp=pp, stash_mode=mode)
    opt = SGDM(lr=0.01)
    state = reference_init_state(spec, plan, opt, jax.random.key(0))
    return spec, plan, state


def test_save_restore_roundtrip(tmp_path):
    spec, plan, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, plan.pp)
    assert mgr.latest_complete_round() == 3
    template = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state)
    restored = mgr.restore(3, template)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(state),
            jax.tree_util.tree_leaves_with_path(restored)):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_save_is_ignored(tmp_path):
    """A crash mid-dump leaves an incomplete manifest; restart must fall
    back to the previous complete round — the paper's exact semantics."""
    spec, plan, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, plan.pp)
    mgr.save(2, state, plan.pp, fail_after_stage=0)   # stage 1 never lands
    assert mgr.latest_complete_round() == 1
    mgr.save(4, state, plan.pp)
    assert mgr.latest_complete_round() == 4


def _driver_setup(tmp_path, failure_hook=None, steps_between_ckpt=2):
    """pp=1 pipeline on the single CPU device (still scan + stash +
    per-tick head updates — the full train_step code path)."""
    cfg = configs.get("qwen3_14b")
    spec = cfg.smoke_spec()
    plan = ParallelismPlan(pp=1, tp=1, microbatches=2, stash_mode="stash",
                           zero1=False)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    opt = SGDM(lr=0.01)
    bundle = build_pipeline(spec, plan, dmesh, seq_len=16, global_batch=4,
                            optimizer=opt, compute_dtype=jnp.float32)
    loader = ShardedLoader(SyntheticLM(spec.vocab, 16),
                           bundle.batch_specs())
    driver = TrainDriver(bundle, loader, str(tmp_path),
                         DriverConfig(checkpoint_every=steps_between_ckpt),
                         failure_hook=failure_hook)
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(0))
    return bundle, driver, state


@pytest.mark.slow
def test_driver_restart_replays_identically(tmp_path):
    """Kill the run at step 5, restart from the last checkpoint, and the
    final state must equal an uninterrupted run (deterministic data)."""
    # uninterrupted baseline
    bundle, driver, state = _driver_setup(tmp_path / "a")
    ref_state, _ = driver.run(state, 8)
    ref_losses = [m["loss"] for m in driver.metrics_log]

    crashes = {"armed": True}

    def hook(step):
        if step == 5 and crashes["armed"]:
            crashes["armed"] = False
            raise RuntimeError("simulated node failure")

    bundle2, driver2, state2 = _driver_setup(tmp_path / "b",
                                             failure_hook=hook)
    out_state, step = driver2.run(state2, 8)
    assert step == 8
    losses = [m["loss"] for m in driver2.metrics_log]
    # replayed rounds produce identical losses as the uninterrupted run
    np.testing.assert_allclose(losses[-1], ref_losses[-1], rtol=1e-6)
    got = jax.device_get(out_state["params"]["head"])
    want = jax.device_get(ref_state["params"]["head"])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_driver_gives_up_after_max_restarts(tmp_path):
    def hook(step):
        raise RuntimeError("always down")

    bundle, driver, state = _driver_setup(tmp_path, failure_hook=hook)
    driver.cfg.max_restarts = 2
    with pytest.raises(RuntimeError):
        driver.run(state, 4)


def test_truncated_manifest_is_skipped(tmp_path):
    """A torn MANIFEST.json (crash mid-write on a pre-atomic layout, or
    a disk fault) must read as 'round incomplete', not crash the restart
    scan with json.JSONDecodeError."""
    spec, plan, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, plan.pp)
    mgr.save(2, state, plan.pp)
    assert mgr.latest_complete_round() == 2
    mf = tmp_path / "round_00000002" / "MANIFEST.json"
    raw = mf.read_text()
    mf.write_text(raw[: len(raw) // 2])          # deliberately truncated
    assert mgr.latest_complete_round() == 1
    # the older round is still restorable
    template = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state)
    restored = mgr.restore(1, template)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["stages"]["layer_0"]["mlp"]["w1"]),
        np.asarray(state["params"]["stages"]["layer_0"]["mlp"]["w1"]))


def test_manifest_write_is_atomic(tmp_path):
    """save() must never leave a MANIFEST.json.tmp behind and the final
    manifest must always parse (written via tmp + os.replace)."""
    import json

    spec, plan, state = _tiny_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, state, plan.pp)
    d = tmp_path / "round_00000000"
    assert not (d / "MANIFEST.json.tmp").exists()
    with open(d / "MANIFEST.json") as f:
        m = json.load(f)
    assert m["done"] and m["stages"] == list(range(plan.pp))


def test_save_restore_preserves_dtypes(tmp_path):
    """bf16 leaves must survive the npz round-trip bit-exactly: np.savez
    silently degrades ml_dtypes bfloat16 to a raw void ``|V2``, so the
    manager dumps the uint16 payload and views it back through the
    template dtype (seed bug: restore died on the void array)."""
    key = jax.random.key(7)
    mk = lambda k, shape, dt: jax.random.normal(
        jax.random.fold_in(key, k), shape, jnp.float32).astype(dt)
    state = {
        "params": {
            "stages": {"layer_0": {"w": mk(0, (2, 4, 8), jnp.bfloat16),
                                   "b": mk(1, (2, 4), jnp.float32)}},
            "embed": mk(2, (16, 8), jnp.bfloat16),
            "layer_windows": jnp.full((2, 1), -1, jnp.int32),
        },
        "step": jnp.zeros((), jnp.int32),
    }
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, state, 2)
    assert mgr.latest_complete_round() == 0
    template = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state)
    restored = mgr.restore(0, template)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(state),
            jax.tree_util.tree_leaves_with_path(restored)):
        assert pa == pb
        assert np.asarray(b).dtype == np.asarray(a).dtype, pa
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), pa


def test_reshard_stages_preserves_global_layers():
    """pp=2 -> pp=4 -> pp=2 roundtrip keeps every global layer's params."""
    spec, plan, state = _tiny_state(pp=2)
    stages = state["params"]["stages"]
    re4 = reshard_stages(stages, 2, 4)
    back = reshard_stages(re4, 4, 2)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(stages),
            jax.tree_util.tree_leaves_with_path(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # spot-check: global layer 3 = (stage 1, pos 1) at pp=2
    #                            = (stage 3, pos 0) at pp=4
    a = np.asarray(stages["layer_1"]["mlp"]["w1"][1])
    b = np.asarray(re4["layer_0"]["mlp"]["w1"][3])
    np.testing.assert_array_equal(a, b)


def test_restart_budget_resets_on_checkpoint(tmp_path):
    """max_restarts bounds CONSECUTIVE failures, not sporadic ones: three
    spread-out faults with successful checkpoints between them must not
    abort a run whose budget is two (the counter resets on each complete
    checkpoint — seed bug: it never reset, so any long run died)."""
    faults = {2, 5, 9}

    def hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError("sporadic failure")

    bundle, driver, state = _driver_setup(tmp_path, failure_hook=hook,
                                          steps_between_ckpt=2)
    driver.cfg.max_restarts = 2
    state, step = driver.run(state, 12)
    assert step == 12
    assert not faults          # every fault actually fired once


def test_reshard_state_interleaved_roundtrip():
    """stash pp=2 -> interleaved pp=2 v=2 -> back: every global layer's
    params/opt survive the storage-order chunk regrouping (the restart
    sync point makes the schedule switch exact)."""
    from repro.core.schedule import ScheduleInterleaved1F1B
    from repro.runtime.driver import reshard_state_for_plan

    spec, plan, state = _tiny_state(pp=2)
    inter = plan.with_(pp=2, tp=1, schedule="interleaved",
                       stash_mode="flush", virtual_stages=2)
    host = jax.device_get(state)
    fwd = reshard_state_for_plan(host, spec, plan, inter)
    # storage row p = s*v + j holds model chunk j*S + s: with 4 chunks of
    # 1 layer each, rows hold global layers [0, 2, 1, 3]
    order = ScheduleInterleaved1F1B(2, 2, virtual_stages=2) \
        .storage_chunk_order()
    assert list(order) == [0, 2, 1, 3]
    src = np.asarray(host["params"]["stages"]["layer_1"]["mlp"]["w1"][0])
    dst = np.asarray(fwd["params"]["stages"]["layer_0"]["mlp"]["w1"][2])
    np.testing.assert_array_equal(src, dst)   # global layer 1 -> row 2
    # interleaved target is flush-family: the stash ring is dropped
    assert "ring" not in fwd["stash"]
    back = reshard_state_for_plan(fwd, spec, inter, plan)
    for key in ("params", "opt_stages"):
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_leaves_with_path(host[key]),
                jax.tree_util.tree_leaves_with_path(back[key])):
            assert pa == pb
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the 1F1B target rebuilds its ring seeded with the live weights
    assert "ring" in back["stash"]
    ring = back["stash"]["ring"]["layer_0"]["mlp"]["w1"]
    assert ring.shape[0] == plan.make_schedule().stash_slots
    np.testing.assert_array_equal(
        np.asarray(ring[0]),
        np.asarray(back["params"]["stages"]["layer_0"]["mlp"]["w1"]))


def test_reshard_to_async_interleaved_builds_chunk_major_ring():
    """1F1B stash -> async interleaved: the chunks regroup into storage
    order exactly as for flush-interleaved, and the target's per-chunk
    ring comes up chunk-major ([stash_slots, S·v, ...]) with every
    version seeded from the regrouped live weights."""
    from repro.runtime.driver import reshard_state_for_plan

    spec, plan, state = _tiny_state(pp=2)          # 1f1b stash, has ring
    host = jax.device_get(state)
    asyn = plan.with_(pp=2, tp=1, schedule="interleaved_async",
                      stash_mode="stash", virtual_stages=2)
    out = reshard_state_for_plan(host, spec, plan, asyn)
    sched = asyn.make_schedule()
    # same storage regrouping as flush-interleaved: global layer 1 -> row 2
    src = np.asarray(host["params"]["stages"]["layer_1"]["mlp"]["w1"][0])
    dst = np.asarray(out["params"]["stages"]["layer_0"]["mlp"]["w1"][2])
    np.testing.assert_array_equal(src, dst)
    ring = out["stash"]["ring"]["layer_0"]["mlp"]["w1"]
    assert ring.shape[0] == sched.stash_slots
    assert ring.shape[1] == 4                      # S·v chunk rows
    for slot in range(sched.stash_slots):
        np.testing.assert_array_equal(
            np.asarray(ring[slot]),
            np.asarray(out["params"]["stages"]["layer_0"]["mlp"]["w1"]))
    # round-trip back to plain 1F1B restores every layer's params/opt
    back = reshard_state_for_plan(out, spec, asyn, plan)
    for key in ("params", "opt_stages"):
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_leaves_with_path(host[key]),
                jax.tree_util.tree_leaves_with_path(back[key])):
            assert pa == pb
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_schedule_only_change_rebuilds_ring():
    """plan_search can flip the schedule at the SAME (pp, v) — e.g.
    stash -> flush to shed the version ring under a tight HBM budget.
    The reshard must drop/rebuild the ring even though no layer moves
    (review catch: the old early-return kept the ring, mismatching the
    new bundle's state template)."""
    from repro.runtime.driver import reshard_state_for_plan

    spec, plan, state = _tiny_state(pp=2)          # stash family: has ring
    host = jax.device_get(state)
    assert "ring" in host["stash"]
    flush = plan.with_(stash_mode="flush")
    out = reshard_state_for_plan(host, spec, plan, flush)
    assert "ring" not in out["stash"]
    np.testing.assert_array_equal(
        np.asarray(out["params"]["stages"]["layer_0"]["mlp"]["w1"]),
        np.asarray(host["params"]["stages"]["layer_0"]["mlp"]["w1"]))
    back = reshard_state_for_plan(out, spec, flush, plan)
    ring = back["stash"]["ring"]["layer_0"]["mlp"]["w1"]
    assert ring.shape[0] == plan.make_schedule().stash_slots
    # identical ring layout (stash <-> vertical share it): true no-op
    vert = plan.with_(stash_mode="vertical")
    assert reshard_state_for_plan(host, spec, plan, vert) is host
