"""Config registry integrity + HLO analyzer correctness + elastic
replanning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import profiler as prof
from repro.launch import hlo_analysis as H
from repro.models.init import attn_static, init_params
from repro.models.spec import validate_stageability
from repro.runtime.driver import elastic_replan, rebalance_from_measurements

PUBLISHED_PARAMS = {           # billions, ±12% tolerance
    "qwen3_14b": 14.8, "gemma3_4b": 3.9, "chatglm3_6b": 6.2,
    "h2o_danube3_4b": 4.0, "llava_next_34b": 34.4, "olmoe_1b_7b": 6.9,
    "deepseek_moe_16b": 16.4, "whisper_medium": 0.77, "rwkv6_1b6": 1.6,
    "jamba_v01_52b": 52.0,
}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_spec_instantiates_and_param_count(arch):
    cfg = configs.get(arch)
    spec, plan = cfg.full_spec(), cfg.PLAN
    assert plan.pp * plan.tp == 16          # 16-way model axis
    validate_stageability(spec, plan.pp)
    if spec.n_heads:
        attn_static(spec, plan.tp)          # head/kv divisibility
    got = spec.param_count() / 1e9
    want = PUBLISHED_PARAMS[arch]
    assert abs(got - want) / want < 0.12, (arch, got, want)
    # init is eval_shape-able (allocation-free dry-run requirement)
    shapes = jax.eval_shape(
        lambda: init_params(spec, plan, jax.random.key(0))[0])
    assert "stages" in shapes


def test_cells_cover_40_with_documented_skips():
    cells = list(configs.cells())
    assert len(cells) == 40
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert skipped == {
        (a, "long_500k")
        for a in ("qwen3_14b", "chatglm3_6b", "llava_next_34b",
                  "olmoe_1b_7b", "deepseek_moe_16b", "whisper_medium")}
    # sub-quadratic archs RUN long_500k
    for a in ("gemma3_4b", "h2o_danube3_4b", "rwkv6_1b6", "jamba_v01_52b"):
        assert (a, "long_500k") not in skipped


def test_hlo_analysis_counts_scan_trip_counts():
    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=7)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    cost = H.analyze(c.as_text())
    assert cost.flops == pytest.approx(7 * 2 * 128 ** 3)
    assert cost.while_trips == [7]
    assert cost.unknown_trip_whiles == 0
    # stock cost_analysis counts the body once — ours must be 7x that
    from repro.parallel.compat import cost_analysis
    stock = cost_analysis(c)["flops"]
    assert cost.flops == pytest.approx(7 * stock)


def test_hlo_analysis_matches_stock_on_whileless_module():
    def f(a, b):
        return (a @ b).sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 96), jnp.float32)).compile()
    cost = H.analyze(c.as_text())
    from repro.parallel.compat import cost_analysis
    assert cost.flops == pytest.approx(cost_analysis(c)["flops"], rel=0.05)


def test_shape_bytes():
    assert H.shape_bytes("bf16[2,4]{1,0}") == 16
    assert H.shape_bytes("f32[]") == 4
    assert H.shape_bytes("(f32[8]{0}, s32[2]{0})") == 40
    assert H.shape_bytes("pred[16]{0}") == 16


def test_model_flops_convention():
    spec = configs.get("qwen3_14b").full_spec()
    t = prof.model_flops_train(spec, tokens=1000)
    assert t == pytest.approx(6 * spec.active_param_count() * 1000)
    moe = configs.get("olmoe_1b_7b").full_spec()
    assert moe.active_param_count() < 0.25 * moe.param_count()


def test_elastic_replan_picks_valid_plan():
    spec = configs.get("qwen3_14b").full_spec()
    old = configs.get("qwen3_14b").PLAN
    new = elastic_replan(spec, old, new_model_axis=8,
                         minibatch_tokens=8192, data_replicas=16)
    assert new.pp * new.tp == 8
    assert spec.n_layers % new.pp == 0
    assert spec.n_heads % new.tp == 0


def test_straggler_rebalance_triggers_on_skew():
    spec = configs.get("qwen3_14b").full_spec()
    plan = configs.get("qwen3_14b").PLAN
    even = [0.1] * plan.pp
    p1, changed = rebalance_from_measurements(
        spec, plan, even, minibatch_tokens=8192, data_replicas=16)
    assert not changed and p1 == plan
    skewed = [0.1] * (plan.pp - 1) + [0.35]
    p2, changed = rebalance_from_measurements(
        spec, plan, skewed, minibatch_tokens=8192, data_replicas=16)
    assert changed
    assert p2.pp * p2.tp == plan.pp * plan.tp
