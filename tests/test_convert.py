"""Checkpoint converter: HF safetensors -> storage-chunk files.

Host-level tests run in-process on one device (pure numpy routing); the
engine round-trip golden — fixture -> convert at (pp, tp, v) -> engine
``load_params`` -> greedy tokens bit-exact vs the direct in-memory load —
runs in subprocesses (tests/convert_check.py) so each case can set its
own host-device count.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint import convert as cv
from repro.models import spec as spec_lib
from repro.parallel.mesh import ParallelismPlan

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

pytestmark = pytest.mark.skipif(
    not cv.HAVE_SAFETENSORS, reason="safetensors not importable")


def _conv_spec(n_layers=8, vocab=200):
    """Dense qwen3-family spec; vocab=200 forces real vocab padding."""
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(
        name="conv-test", d_model=64, n_layers=n_layers, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=vocab, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True)


def _moe_spec(n_layers=4):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="moe")
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(
        name="conv-moe-test", d_model=64, n_layers=n_layers, n_heads=4,
        n_kv=4, d_head=16, d_ff=32, vocab=200, blocks=blocks,
        norm="rmsnorm", act="silu", qk_norm=True,
        moe=spec_lib.MoESpec(n_experts=8, top_k=2, d_expert=32))


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# Storage layout: the converter's arithmetic == the schedule's contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pp,v", [(2, 2), (2, 3), (4, 2), (3, 4)])
def test_storage_order_matches_schedule(pp, v):
    plan = ParallelismPlan(pp=pp, tp=1, microbatches=2 * pp,
                           decode_microbatches=2 * pp,
                           schedule="serve_interleaved", virtual_stages=v)
    assert cv.storage_order(pp, v) == \
        list(plan.make_schedule().storage_chunk_order())


# ---------------------------------------------------------------------------
# Round trips (disk == in-memory; export inverts convert)
# ---------------------------------------------------------------------------

def test_convert_load_matches_direct(tmp_path):
    spec = _conv_spec()
    fix = str(tmp_path / "model.safetensors")
    tensors = cv.make_synthetic_checkpoint(fix, spec, seed=1)
    mf = cv.convert(fix, str(tmp_path / "ck"), spec, pp=2, tp=2,
                    virtual_stages=2)
    assert mf["n_chunks"] == 4
    assert mf["storage_order"] == [0, 2, 1, 3]
    for row in range(4):
        assert (tmp_path / "ck" / f"chunk_{row:04d}.npz").exists()
    assert (tmp_path / "ck" / "shared.npz").exists()
    params, manifest = cv.load_converted(str(tmp_path / "ck"), spec)
    assert manifest["spec"] == spec.name
    direct = cv.hf_to_params(tensors, spec, pp=2, tp=2, virtual_stages=2)
    _assert_trees_equal(params, direct)


def test_sharded_fixture_resolves_and_converts(tmp_path):
    spec = _conv_spec(n_layers=4)
    src = str(tmp_path / "hf")
    tensors = cv.make_synthetic_checkpoint(src, spec, seed=2, shards=3)
    assert len(cv.resolve_shards(src)) == 3
    cv.convert(src, str(tmp_path / "ck"), spec, pp=2, virtual_stages=2)
    params, _ = cv.load_converted(str(tmp_path / "ck"), spec)
    _assert_trees_equal(
        params, cv.hf_to_params(tensors, spec, pp=2, virtual_stages=2))


def test_export_inverts_convert(tmp_path):
    spec = _conv_spec(n_layers=4)
    fix = str(tmp_path / "model.safetensors")
    tensors = cv.make_synthetic_checkpoint(fix, spec, seed=3)
    cv.convert(fix, str(tmp_path / "ck"), spec, pp=2, virtual_stages=2)
    out = cv.export_checkpoint(str(tmp_path / "ck"),
                               str(tmp_path / "back.safetensors"), spec)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])


def test_moe_family_round_trip(tmp_path):
    spec = _moe_spec()
    fix = str(tmp_path / "model.safetensors")
    tensors = cv.make_synthetic_checkpoint(fix, spec, seed=4)
    mf = cv.convert(fix, str(tmp_path / "ck"), spec, pp=2)
    assert mf["family"] == "olmoe"
    params, _ = cv.load_converted(str(tmp_path / "ck"), spec)
    _assert_trees_equal(params, cv.hf_to_params(tensors, spec, pp=2))
    # per-expert accumulation landed each expert slice where it belongs
    w1 = params["stages"]["layer_0"]["moe"]["w1"]
    np.testing.assert_array_equal(
        w1[0, 3], tensors["model.layers.0.mlp.experts.3.gate_proj.weight"].T)
    out = cv.export_checkpoint(str(tmp_path / "ck"),
                               str(tmp_path / "back.safetensors"), spec)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])


def test_two_plan_conversion_matches_reshard(tmp_path):
    """Converting directly at (pp=2, v=2) == converting at (pp=2, v=1)
    and resharding the resulting state with the runtime's
    ``reshard_state_for_plan`` — the two layout paths agree."""
    from repro.runtime.driver import reshard_state_for_plan

    spec = _conv_spec()
    fix = str(tmp_path / "model.safetensors")
    tensors = cv.make_synthetic_checkpoint(fix, spec, seed=5)
    pa = cv.hf_to_params(tensors, spec, pp=2, virtual_stages=1)
    pb = cv.hf_to_params(tensors, spec, pp=2, virtual_stages=2)
    plan_a = ParallelismPlan(pp=2, tp=1, microbatches=4,
                             decode_microbatches=4, schedule="serve_1f")
    plan_b = ParallelismPlan(pp=2, tp=1, microbatches=4,
                             decode_microbatches=4,
                             schedule="serve_interleaved", virtual_stages=2)
    out = reshard_state_for_plan({"params": pa}, spec, plan_a, plan_b)
    _assert_trees_equal(out["params"], pb)


# ---------------------------------------------------------------------------
# Typed error paths (every failure is a ConvertError naming the culprit)
# ---------------------------------------------------------------------------

def test_unknown_key_raises():
    spec = _conv_spec(n_layers=4)
    tensors = {"model.layers.0.self_attn.bogus.weight":
               np.zeros((4, 4), np.float32)}
    with pytest.raises(cv.ConvertError, match="unknown checkpoint key"):
        cv.hf_to_params(tensors, spec, pp=2)


def test_shape_mismatch_names_key_and_shapes():
    spec = _conv_spec(n_layers=4)
    tensors = {"model.layers.0.self_attn.q_proj.weight":
               np.zeros((7, 7), np.float32)}
    with pytest.raises(cv.ConvertError,
                       match=r"does not match expected shape"):
        cv.hf_to_params(tensors, spec, pp=2)


def test_tp_indivisible_names_axis():
    spec = _conv_spec(n_layers=4)
    with pytest.raises(cv.ConvertError, match="does not divide axis"):
        cv.hf_to_params({}, spec, pp=2, tp=3)


def test_layers_indivisible_by_chunks():
    spec = _conv_spec(n_layers=6)
    with pytest.raises(cv.ConvertError, match="not divisible"):
        cv.hf_to_params({}, spec, pp=4)


def test_layer_out_of_range():
    spec = _conv_spec(n_layers=4)
    tensors = {"model.layers.9.input_layernorm.weight":
               np.zeros((64,), np.float32)}
    with pytest.raises(cv.ConvertError, match="out of range"):
        cv.hf_to_params(tensors, spec, pp=2)


def test_incomplete_checkpoint_lists_missing(tmp_path):
    spec = _conv_spec(n_layers=4)
    fix = str(tmp_path / "model.safetensors")
    tensors = cv.make_synthetic_checkpoint(fix, spec, seed=6)
    del tensors["model.layers.3.mlp.down_proj.weight"]
    with pytest.raises(cv.ConvertError, match="incomplete checkpoint"):
        cv.hf_to_params(tensors, spec, pp=2)


def test_missing_shard_paths(tmp_path):
    with pytest.raises(cv.ConvertError, match="missing safetensors shard"):
        cv.resolve_shards(str(tmp_path / "nope"))
    # an index.json referencing an absent shard file names it
    d = tmp_path / "hf"
    d.mkdir()
    with open(d / "model.safetensors.index.json", "w") as f:
        json.dump({"weight_map": {"a": "model-00001-of-00002.safetensors"}},
                  f)
    with pytest.raises(cv.ConvertError, match="missing safetensors shard"):
        cv.resolve_shards(str(d))


def test_load_rejects_wrong_spec_and_missing_files(tmp_path):
    spec = _conv_spec(n_layers=4)
    fix = str(tmp_path / "model.safetensors")
    cv.make_synthetic_checkpoint(fix, spec, seed=7)
    ck = str(tmp_path / "ck")
    cv.convert(fix, ck, spec, pp=2)
    import dataclasses
    other = dataclasses.replace(_conv_spec(n_layers=4), name="other-spec")
    with pytest.raises(cv.ConvertError, match="was converted for spec"):
        cv.load_converted(ck, other)
    os.remove(os.path.join(ck, "chunk_0001.npz"))
    with pytest.raises(cv.ConvertError, match="missing chunk file"):
        cv.load_converted(ck, spec)
    with pytest.raises(cv.ConvertError, match="missing manifest"):
        cv.load_converted(str(tmp_path / "empty"), spec)


# ---------------------------------------------------------------------------
# Engine round-trip golden (subprocess: own device count per case)
# ---------------------------------------------------------------------------

def _run_case(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "convert_check.py"),
         *[str(a) for a in case]],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "MATCH" in out.stdout


# pp, tp, v, steps — (2, 2, 2) is the acceptance-criteria cell
@pytest.mark.parametrize("case", [(2, 1, 2, 3), (2, 2, 2, 3)],
                         ids=lambda c: "-".join(str(x) for x in c))
def test_converted_checkpoint_serves_bit_exact(case):
    _run_case(case)
