"""Data pipeline determinism + optimizer correctness + 1-bit compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, ShardedLoader, SyntheticLM
from repro.optim import SGDM, Adam, RMSProp
from repro.optim.compression import init_errors, onebit_compress_psum


def test_synthetic_lm_deterministic_in_seed_step():
    src = SyntheticLM(vocab=128, seq_len=32, seed=7)
    a = src.round_batch(5, 2, 3)
    b = src.round_batch(5, 2, 3)
    c = src.round_batch(6, 2, 3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    full = SyntheticLM(vocab=128, seq_len=32, seed=7)
    r = full.round_batch(0, 1, 1)
    assert r["tokens"].shape == r["labels"].shape == (1, 1, 32)
    assert (r["tokens"] < 128).all() and (r["tokens"] >= 0).all()


def test_sharded_loader_places_batches():
    src = SyntheticLM(vocab=64, seq_len=16)
    specs = {
        "tokens": jax.ShapeDtypeStruct((2, 2, 16), jnp.int32,
                                       sharding=jax.sharding.SingleDeviceSharding(
                                           jax.devices()[0])),
        "labels": jax.ShapeDtypeStruct((2, 2, 16), jnp.int32,
                                       sharding=jax.sharding.SingleDeviceSharding(
                                           jax.devices()[0])),
    }
    loader = ShardedLoader(src, specs)
    batch = loader.get(0)
    assert batch["tokens"].shape == (2, 2, 16)
    np.testing.assert_array_equal(
        np.asarray(batch["tokens"]), src.round_batch(0, 2, 2)["tokens"])


def test_prefetcher_yields_in_order():
    src = SyntheticLM(vocab=64, seq_len=8)
    specs = {"tokens": jax.ShapeDtypeStruct(
        (1, 1, 8), jnp.int32,
        sharding=jax.sharding.SingleDeviceSharding(jax.devices()[0])),
        "labels": jax.ShapeDtypeStruct(
        (1, 1, 8), jnp.int32,
        sharding=jax.sharding.SingleDeviceSharding(jax.devices()[0]))}
    loader = ShardedLoader(src, specs)
    pf = Prefetcher(loader, start_step=0, prefetch=2)
    try:
        for step in range(3):
            batch = next(pf)
            np.testing.assert_array_equal(
                np.asarray(batch["tokens"]),
                src.round_batch(step, 1, 1)["tokens"])
    finally:
        pf.stop()


def test_sgdm_matches_closed_form():
    opt = SGDM(lr=0.1, momentum=0.9)
    p = {"w": jnp.asarray([1.0, 2.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([0.5, -1.0])}
    p1, st1 = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, 2.1])
    p2, _ = opt.update(g, st1, p1)
    # v2 = 0.9*0.5 + 0.5 = 0.95 ; w = 0.95 - 0.1*0.95
    np.testing.assert_allclose(np.asarray(p2["w"])[0], 0.95 - 0.095,
                               rtol=1e-6)


def test_rmsprop_and_adam_descend_quadratic():
    for opt in (RMSProp(lr=0.05, eps=1e-6), Adam(lr=0.1)):
        w = {"x": jnp.asarray(3.0)}
        st = opt.init(w)
        for step in range(200):
            g = {"x": 2.0 * w["x"]}
            w, st = opt.update(g, st, w, step)
        assert abs(float(w["x"])) < 0.2, (type(opt).__name__, w)


def test_onebit_compression_error_feedback():
    """sign·scale quantization with error feedback: accumulated applied
    updates track the true gradient sum (error stays bounded)."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.normal(size=64).astype(np.float32))
             for _ in range(50)]
    errors = init_errors({"g": g_seq[0]})
    applied = jnp.zeros(64)
    for g in g_seq:
        synced, errors = onebit_compress_psum({"g": g}, errors,
                                              axis=None, n_replicas=1)
        applied = applied + synced["g"]
    true = sum(np.asarray(g) for g in g_seq)
    resid = np.abs(np.asarray(applied) - true)
    # residual equals the final error-feedback buffer -> bounded by the
    # per-step scale, NOT growing with the number of steps
    assert resid.max() < 3.0
    np.testing.assert_allclose(resid, np.abs(np.asarray(errors["g"])),
                               atol=1e-5)


def test_onebit_payload_is_sign_and_scale():
    g = {"g": jnp.asarray([1.0, -2.0, 3.0, -4.0])}
    errors = init_errors(g)
    synced, _ = onebit_compress_psum(g, errors, axis=None, n_replicas=1)
    vals = np.unique(np.abs(np.asarray(synced["g"])))
    assert len(vals) == 1          # one scale for the whole tensor
    np.testing.assert_allclose(vals[0], 2.5)   # mean |g|
