"""Async interleaved (per-chunk weight-version rings) semantics.

Two reference-level equivalence proofs that pin the new schedule to the
two schedules it generalizes (both run the sequential oracle on one
device — the SPMD side is covered by tests/test_pipeline_spmd.py's
``interleaved_async`` matrix rows):

  * versions forced equal (lr = 0 — no update ever lands, so every ring
    slot holds the live weights): the async-interleaved round must match
    the chunked flush reference EXACTLY, microbatch for microbatch.
    This isolates the dataflow (chunk hops, per-chunk ring reads,
    residual routing) from the update semantics.
  * virtual_stages = 1: the interleaved timing degenerates to plain
    1F1B (t_F = s + m, t_B = m + 2(S−1) − s) and the per-chunk ring to
    the classic 2(S−1)+1 stage ring, so async-interleaved must
    reproduce the paper's 1F1B stash semantics bit-for-bit, updates
    included.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reference import reference_init_state, reference_train_step
from repro.models import spec as S
from repro.optim import SGDM
from repro.parallel.mesh import ParallelismPlan


def _tiny_spec(n_layers=4):
    blocks = tuple(S.BlockSpec(window=(-1 if i % 2 else 8))
                   for i in range(n_layers))
    return S.ModelSpec(name="tiny-async", d_model=16, n_layers=n_layers,
                       n_heads=2, n_kv=2, d_head=8, d_ff=32, vocab=32,
                       blocks=blocks)


def _batch(spec, r, bmb=1, seq=8, seed=1):
    ks = jax.random.split(jax.random.key(seed), 2)
    return {
        "tokens": jax.random.randint(ks[0], (r, bmb, seq), 0, spec.vocab,
                                     jnp.int32),
        "labels": jax.random.randint(ks[1], (r, bmb, seq), 0, spec.vocab,
                                     jnp.int32),
    }


def _unpermute_params(state, perm):
    """Storage-order state -> chunk-order state (flush reference view)."""
    inv = np.argsort(np.asarray(perm))
    out = dict(state)
    params = dict(state["params"])
    params["stages"] = jax.tree.map(lambda a: a[inv], params["stages"])
    params["layer_windows"] = params["layer_windows"][inv]
    params["layer_thetas"] = params["layer_thetas"][inv]
    out["params"] = params
    out["opt_stages"] = {k: jax.tree.map(lambda a: a[inv], sub)
                         for k, sub in state["opt_stages"].items()}
    out["stash"] = {"current": params["stages"]}
    return out


def test_async_matches_chunked_flush_when_versions_pinned():
    """lr = 0 pins every weight version to the initial weights: the
    async round's losses must equal the chunk-level flush reference's
    exactly (same chunk program, same exit order, fp32)."""
    spec = _tiny_spec()
    S_, v, R = 2, 2, 4
    asyn = ParallelismPlan(pp=S_, tp=1, microbatches=R, stash_mode="stash",
                           schedule="interleaved_async", virtual_stages=v,
                           zero1=False)
    flush = ParallelismPlan(pp=S_ * v, tp=1, microbatches=R,
                            stash_mode="flush", zero1=False)
    opt = SGDM(lr=0.0, momentum=0.0)
    a_state = reference_init_state(spec, asyn, opt, jax.random.key(0))
    f_state = _unpermute_params(
        a_state, asyn.make_schedule().storage_chunk_order())
    batch = _batch(spec, R)
    a_state, am = reference_train_step(spec, asyn, a_state, batch, opt)
    f_state, fm = reference_train_step(spec, flush, f_state, batch, opt)
    assert float(am["loss"]) == float(fm["loss"])
    assert np.isfinite(float(am["loss"]))


def test_async_v1_is_exactly_1f1b_stash():
    """virtual_stages=1 degenerates to the paper's 1F1B weight stashing:
    identical timing, identical 2(S−1)+1 ring, identical per-microbatch
    update order — the full state must match bitwise after real (lr>0)
    updates."""
    spec = _tiny_spec()
    S_, R = 2, 4
    asyn = ParallelismPlan(pp=S_, tp=1, microbatches=R, stash_mode="stash",
                           schedule="interleaved_async", virtual_stages=1,
                           zero1=False)
    plain = asyn.with_(schedule="auto")           # -> 1f1b stash
    assert asyn.make_schedule().stash_slots == \
        plain.make_schedule().stash_slots == 2 * (S_ - 1) + 1
    opt = SGDM(lr=0.05, momentum=0.9)
    a_state = reference_init_state(spec, asyn, opt, jax.random.key(0))
    p_state = reference_init_state(spec, plain, opt, jax.random.key(0))
    batch = _batch(spec, R)
    for _ in range(2):
        a_state, am = reference_train_step(spec, asyn, a_state, batch, opt)
        p_state, pm = reference_train_step(spec, plain, p_state, batch, opt)
        assert float(am["loss"]) == float(pm["loss"])
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(a_state["params"]),
            jax.tree_util.tree_leaves_with_path(p_state["params"])):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
