"""Pallas kernels vs pure-jnp oracles (interpret mode, CPU).

Sweeps shapes, dtypes, GQA ratios, window sizes, block sizes; plus the
model-level dispatch equivalence (use_pallas on/off must not change the
transformer output).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _qkv(b, sq, sk, h, kv, dh, dt, seed=0):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    return (jax.random.normal(ks[0], (b, sq, h, dh), dt),
            jax.random.normal(ks[1], (b, sk, kv, dh), dt),
            jax.random.normal(ks[2], (b, sk, kv, dh), dt))


FLASH_CASES = [
    # b, sq, sk, h, kv, dh, causal, window, dtype, bq, bk
    (2, 256, 256, 4, 2, 64, True, -1, jnp.float32, 128, 128),
    (1, 128, 128, 4, 4, 64, True, 32, jnp.float32, 64, 64),
    (2, 100, 100, 2, 1, 32, True, -1, jnp.bfloat16, 64, 64),
    (1, 256, 256, 8, 2, 128, False, -1, jnp.float32, 128, 128),
    (1, 64, 192, 2, 2, 16, True, 48, jnp.float32, 32, 64),
    (1, 192, 192, 2, 2, 64, True, 200, jnp.float32, 64, 64),  # w > bk span
    (2, 64, 64, 4, 1, 8, True, 1, jnp.float32, 32, 32),       # self only
]


@pytest.mark.parametrize(
    "b,sq,sk,h,kv,dh,causal,window,dt,bq,bk", FLASH_CASES)
def test_flash_attention_matches_oracle(b, sq, sk, h, kv, dh, causal,
                                        window, dt, bq, bk):
    q, k, v = _qkv(b, sq, sk, h, kv, dh, dt, seed=sq * h + dh)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-2)


def test_flash_traced_window():
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, jnp.float32, seed=7)
    for w in (-1, 16, 64):
        got = ops.flash_attention(q, k, v, window=jnp.int32(w),
                                  block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-3)


def test_flash_matches_blockwise_jnp_twin():
    """The XLA twin used inside training graphs agrees with the kernel."""
    from repro.models.nn import _sdpa_flash_jnp
    q, k, v = _qkv(1, 256, 256, 4, 4, 64, jnp.float32, seed=11)
    got = ops.flash_attention(q, k, v, causal=True, window=-1)
    pos = jnp.arange(256)
    twin = _sdpa_flash_jnp(q, k, v, pos, pos, jnp.int32(-1), True, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(twin),
                               atol=2e-5, rtol=1e-3)


WKV_CASES = [
    # b, s, h, dh, chunk, dtype
    (2, 64, 2, 16, 16, jnp.float32),
    (1, 128, 4, 32, 32, jnp.float32),
    (2, 100, 2, 8, 32, jnp.float32),      # ragged tail padding
    (1, 64, 2, 64, 16, jnp.bfloat16),
    (1, 32, 1, 4, 32, jnp.float32),       # single chunk
]


@pytest.mark.parametrize("b,s,h,dh,chunk,dt", WKV_CASES)
def test_wkv6_matches_stepwise_oracle(b, s, h, dh, chunk, dt):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + dh), 5)
    r = jax.random.normal(ks[0], (b, s, h, dh), dt)
    k = jax.random.normal(ks[1], (b, s, h, dh), dt) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dh), dt)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dh))) * 0.5 + 0.49
    u = jax.random.normal(ks[4], (h, dh)) * 0.1
    y, s_last = ops.wkv6(r, k, v, w.astype(dt), u, chunk=chunk)
    yr, sr = ref.wkv6_ref(r, k, v, w.astype(dt), u)
    atol = 5e-2 if dt == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=atol, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(sr),
                               atol=atol, rtol=1e-2)


def test_wkv6_matches_chunked_jnp_twin():
    from repro.models.nn import wkv6_chunked
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (1, 96, 2, 16))
    k = jax.random.normal(ks[1], (1, 96, 2, 16)) * 0.5
    v = jax.random.normal(ks[2], (1, 96, 2, 16))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 96, 2, 16))) * 0.5 + 0.49
    u = jax.random.normal(ks[4], (2, 16)) * 0.1
    y, s_last = ops.wkv6(r, k, v, w, u, chunk=32)
    yt, st = wkv6_chunked(r, k, v, w, u, chunk=24)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yt),
                               atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(st),
                               atol=1e-3, rtol=1e-2)


MAMBA_CASES = [
    # b, s, ci, n, chunk, ci_block
    (2, 64, 32, 8, 16, 16),
    (1, 128, 64, 16, 32, 32),
    (2, 100, 48, 4, 32, 16),        # ragged tail + ci_block fallback
    (1, 48, 512, 16, 16, 256),
]


@pytest.mark.parametrize("b,s,ci,n,chunk,cib", MAMBA_CASES)
def test_mamba_scan_matches_stepwise_oracle(b, s, ci, n, chunk, cib):
    ks = jax.random.split(jax.random.fold_in(KEY, s * ci + n), 6)
    u = jax.random.normal(ks[0], (b, s, ci))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, ci))) * 0.3
    A = -jnp.exp(jax.random.normal(ks[2], (ci, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (ci,))
    y, h = ops.mamba_scan(u, dt, A, B, C, D, chunk=chunk, ci_block=cib)
    yr, hr = ref.mamba_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=2e-4, rtol=1e-3)


def test_mamba_scan_matches_chunked_jnp_twin():
    from repro.models.nn import selective_scan
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (1, 96, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 96, 64))) * 0.3
    A = -jnp.exp(jax.random.normal(ks[2], (64, 8)) * 0.3)
    B = jax.random.normal(ks[3], (1, 96, 8))
    C = jax.random.normal(ks[4], (1, 96, 8))
    D = jax.random.normal(ks[5], (64,))
    y, h = ops.mamba_scan(u, dt, A, B, C, D, chunk=32, ci_block=64)
    yt, ht = selective_scan(u, dt, A, B, C, D, chunk=24)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yt),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ht),
                               atol=2e-4, rtol=1e-3)


PAGED_CASES = [
    # b, h, kv, dh, page, n_pages, window
    (2, 4, 2, 64, 16, 8, -1),
    (3, 4, 4, 32, 16, 4, -1),        # MHA (group size 1)
    (2, 8, 2, 64, 64, 4, -1),        # big pages, 4:1 GQA
    (2, 4, 1, 32, 16, 8, -1),        # MQA
    (2, 4, 2, 64, 16, 8, 20),        # windowed: dead-page skipping
    (1, 2, 2, 16, 64, 2, 48),        # window inside one page
]


def _paged_case(b, h, kv, dh, page, n_pages, seed):
    """Random pool + permuted tables + ragged per-row lengths."""
    rng = np.random.default_rng(seed)
    n_pool = b * n_pages + 3                     # spare pages stay garbage
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    k_pages = jax.random.normal(ks[1], (n_pool, page, kv, dh), jnp.float32)
    v_pages = jax.random.normal(ks[2], (n_pool, page, kv, dh), jnp.float32)
    lengths = rng.integers(1, n_pages * page + 1, b).astype(np.int32)
    perm = rng.permutation(n_pool)
    tables = np.full((b, n_pages), -1, np.int32)
    used = 0
    for r in range(b):
        need = -(-int(lengths[r]) // page)
        tables[r, :need] = perm[used:used + need]
        used += need
    return q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(lengths)


@pytest.mark.parametrize("b,h,kv,dh,page,n_pages,window", PAGED_CASES)
def test_paged_attention_matches_ref(b, h, kv, dh, page, n_pages, window):
    q, kp, vp, tables, lengths = _paged_case(
        b, h, kv, dh, page, n_pages, seed=b * h + page + n_pages)
    got = ops.paged_attention(q, kp, vp, tables, lengths, window=window)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths,
                                   window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_paged_ref_matches_dense_gather():
    """The paged oracle equals dense attention over the gathered slab."""
    b, h, kv, dh, page, n_pages = 2, 4, 2, 32, 16, 4
    q, kp, vp, tables, lengths = _paged_case(b, h, kv, dh, page, n_pages,
                                             seed=5)
    got = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    outs = []
    for r in range(b):
        ln = int(lengths[r])
        pages = [int(p) for p in tables[r] if p >= 0]
        kd = jnp.concatenate([kp[p] for p in pages], axis=0)[:ln]
        vd = jnp.concatenate([vp[p] for p in pages], axis=0)[:ln]
        # one query at position ln-1 attending over ln dense keys
        o = ref.attention_ref(q[r][None, None], kd[None], vd[None],
                              causal=False)
        outs.append(o[0, 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.stack(outs)),
                               atol=2e-5, rtol=1e-3)


def test_paged_attention_garbage_pages_ignored():
    """NaN in unreferenced / beyond-length pool pages must not leak."""
    b, h, kv, dh, page, n_pages = 2, 4, 2, 32, 16, 4
    q, kp, vp, tables, lengths = _paged_case(b, h, kv, dh, page, n_pages,
                                             seed=9)
    used = set(int(p) for p in np.asarray(tables).ravel() if p >= 0)
    spare = [p for p in range(kp.shape[0]) if p not in used]
    kp = kp.at[jnp.asarray(spare)].set(jnp.nan)
    vp = vp.at[jnp.asarray(spare)].set(jnp.nan)
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


@pytest.mark.parametrize("arch", ["dense", "rwkv", "hybrid"])
def test_model_dispatch_equivalence(arch):
    """use_pallas() on/off must not change transformer outputs."""
    import sys as _sys
    import os as _os
    _sys.path.insert(0, _os.path.dirname(__file__))
    from spmd_pipeline_check import build_tiny_spec
    from repro.models.init import init_params
    from repro.models.stage import full_transformer, make_statics
    from repro.parallel.mesh import ParallelismPlan

    spec = build_tiny_spec(arch)
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1, remat=False)
    params, _ = init_params(spec, plan, jax.random.key(3), jnp.float32)
    st = make_statics(spec, plan, tokens_per_mb=64)
    x = jax.random.normal(jax.random.key(4), (2, 16, spec.d_model))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    try:
        ops.enable(False)
        y0, _ = full_transformer(params, x, st, positions=pos)
        ops.enable(True)
        y1, _ = full_transformer(params, x, st, positions=pos)
    finally:
        ops.enable(False)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-4, rtol=1e-3)
