"""Observability (ISSUE 10): registry/trace/reconcile units, the
batcher's no-completions None-not-NaN regression, bucketed-trace
agreement with the real engine's ``pick_bucket`` choices, and the
registry-driven replan flip — telemetry collected through
``Registry.timer``, never hand-injected.

The analytic exactness of the trace synthesis (span counts == table
non-bubble cells, reconcile ratio == 1.0 on a modeled clock) is the CI
gate's job (scripts/obs_smoke.py); this file covers the units and the
real-engine / real-driver integration on the single CPU device."""
import collections
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import profiler as prof
from repro.core.schedule import (F_MB, SCHEDULES, pick_bucket,
                                 weighted_round_time)
from repro.launch.mesh import make_host_mesh
from repro.models import spec as spec_lib
from repro.obs import (Observability, Registry, reconcile, stage_seconds)
from repro.parallel.mesh import ParallelismPlan, split_model_axis
from repro.runtime.driver import (DriverConfig, TrainDriver,
                                  replan_from_registry)
from repro.serving.batcher import (BatchingReport, ContinuousBatchingSession,
                                   Request)
from repro.serving.engine import build_serving
from scripts.bench_check import _bad_numbers, check_metrics_snapshot

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_gauge_series_and_kind_collision():
    reg = Registry()
    c = reg.counter("rounds_total")
    c.inc(kind="decode")
    c.inc(2, kind="decode")
    c.inc(kind="verify")
    assert c.value(kind="decode") == 3
    assert c.value(kind="verify") == 1
    assert c.value(kind="nope") == 0            # untouched series read as 0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1, kind="decode")
    reg.gauge("pages_free").set(5)
    assert reg.gauge("pages_free").value() == 5
    with pytest.raises(TypeError, match="gauge"):
        reg.counter("pages_free")               # same name, different kind


def test_histogram_empty_stats_are_none_never_nan():
    h = Registry().histogram("round_seconds")
    st = h.stats(kind="decode")
    assert st["count"] == 0 and st["sum"] == 0.0
    assert st["mean"] is None and st["min"] is None
    assert st["p50"] is None and st["p99"] is None
    assert _bad_numbers(st) == []
    h.observe(1.0, kind="decode")
    h.observe(3.0, kind="decode")
    st = h.stats(kind="decode")
    assert st["count"] == 2 and st["mean"] == 2.0
    assert st["min"] == 1.0 and st["max"] == 3.0


def test_timer_observes_elapsed_on_pluggable_clock():
    reg = Registry()
    clock = FakeClock()
    with reg.timer("launch_phase_seconds", clock=clock,
                   phase="compile") as t:
        clock.advance(1.5)
    assert t.elapsed == 1.5
    st = reg.histogram("launch_phase_seconds").stats(phase="compile")
    assert st["count"] == 1 and st["sum"] == 1.5


def test_snapshot_passes_bench_check_schema(tmp_path):
    reg = Registry()
    reg.counter("rounds_total").inc(4, kind="decode")
    reg.gauge("pages_free").set(7)
    reg.histogram("round_seconds").observe(0.25, kind="decode")
    snap = reg.snapshot()
    assert check_metrics_snapshot(snap) == []
    assert json.loads(json.dumps(snap)) == snap     # JSON-safe
    path = tmp_path / "metrics.json"
    reg.save(str(path))
    with open(path) as f:
        assert check_metrics_snapshot(json.load(f)) == []


# ---------------------------------------------------------------------------
# trace + reconcile
# ---------------------------------------------------------------------------

def test_trace_span_counts_and_reconcile_fixed_point():
    """Rounds timed on a modeled clock charging exactly the
    weighted_round_time prediction reconcile at ratio 1.0 and carry the
    table's non-bubble cell count per stage (obs_smoke asserts the same
    invariants harder; this keeps them in the pytest net)."""
    S, R = 2, 4
    sched = SCHEDULES["serve_1f"](S, R)
    tf = np.array([1.0e-3, 2.0e-3])
    cost, wbubble = weighted_round_time(sched, tf, 0.0)
    clock = FakeClock()
    obs = Observability(trace=True, clock=clock)
    for _ in range(3):
        t0 = clock()
        clock.advance(cost)
        obs.on_round("decode", sched, t0, clock(), t_fwd=tf, t_bwd=0.0)
    cells = (np.asarray(sched.tables().fwd)[:, :, F_MB] >= 0).sum(axis=0)
    counts = obs.trace.span_counts("decode")
    assert [counts[s] for s in range(S)] == (cells * 3).tolist()
    rep = reconcile(sched, trace=obs.trace, registry=obs.registry,
                    kind="decode", t_fwd=tf)
    assert rep.rounds == 3
    assert rep.round_ratio == pytest.approx(1.0, abs=1e-9)
    assert rep.measured_bubble == pytest.approx(float(wbubble), abs=1e-9)


def test_reconcile_falls_back_to_registry_without_trace():
    sched = SCHEDULES["serve_1f"](2, 4)
    reg = Registry()
    reg.histogram("round_seconds").observe(0.5, kind="decode")
    rep = reconcile(sched, registry=reg, kind="decode")
    assert rep.rounds == 1 and rep.measured_round_s == 0.5
    # no absolute costs: unit-free comparison only
    assert rep.predicted_round_s is None and rep.round_ratio is None
    assert rep.predicted_bubble > 0
    assert "n/a" in str(rep)


def test_stage_seconds_refuses_partial_telemetry():
    reg = Registry()
    h = reg.histogram("stage_round_seconds")
    h.observe(1.0, stage=0)
    with pytest.raises(ValueError, match="stage=1"):
        stage_seconds(reg, 2)
    h.observe(2.0, stage=1)
    assert stage_seconds(reg, 2) == [1.0, 2.0]


# ---------------------------------------------------------------------------
# batcher regression: zero completions must summarize to None, not NaN
# ---------------------------------------------------------------------------

def test_empty_report_summary_has_none_latencies_not_nan():
    r = Request(rid=0, prompt=np.array([1, 2], np.int32),
                max_new_tokens=4, arrival=0)
    rep = BatchingReport(requests=[r], policy="continuous", steps=3,
                         decode_rounds=3, admit_rounds=1,
                         wall_seconds=0.25)
    s = rep.summary()
    assert s["completed"] == 0
    assert s["p50_per_token_latency_s"] is None
    assert s["p99_per_token_latency_s"] is None
    assert s["mean_ttft_s"] is None
    assert _bad_numbers(s) == []                # the bench_check gate
    assert json.loads(json.dumps(s)) == s       # survives a round-trip


# ---------------------------------------------------------------------------
# real engine: bucketed rounds traced with the pick_bucket choices
# ---------------------------------------------------------------------------

def _attn_spec(n_layers=2):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(
        name="obs-test", d_model=64, n_layers=n_layers, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu")


def _bucketed_session(n_slots=4, prefill=8, cache=64):
    """pp=1 on the single CPU device — full engine code path."""
    spec = _attn_spec()
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    plan = ParallelismPlan(pp=1, tp=1, microbatches=n_slots,
                           decode_microbatches=n_slots,
                           schedule="serve_1f")
    obs = Observability(trace=True)
    sess = build_serving(spec, plan, dmesh, cache_len=cache,
                         global_batch=n_slots, prefill_len=prefill,
                         compute_dtype=jnp.float32, buckets=True, obs=obs)
    sess.start(jax.random.key(0))
    return sess, obs


def test_bucketed_trace_agrees_with_engine_bucket_log():
    """ISSUE-10 acceptance: the staggered bucket-switching trace
    (batch_smoke's shape: two early finishers shrink the bucket, a late
    arrival grows it back) must leave registry counters, trace round
    records, and span tags all agreeing with the engine's own
    ``_bucket_log`` — and the per-stage span counts must equal the
    non-bubble cells of the tables actually walked."""
    sess, obs = _bucketed_session()
    R = sess.sched.n_microbatches
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 256, 8).astype(np.int32) for _ in range(5)]
    trace = [Request(rid=i, prompt=p, max_new_tokens=m, arrival=a)
             for i, (p, m, a) in enumerate(zip(
                 prompts, [3, 3, 12, 12, 4], [0, 0, 0, 0, 5]))]

    lives = []                    # live-slot count the bucket picker saw
    orig_decode = sess.decode

    def spy_decode(tokens, bucket=None):
        lives.append(int(sess._live.sum()))
        return orig_decode(tokens, bucket)

    sess.decode = spy_decode
    report = ContinuousBatchingSession(sess).run(trace)
    assert len(report.completed) == 5

    log = list(sess._bucket_log)
    assert any(b < R for b in log), "trace never shrank the bucket"
    assert all(b in sess.buckets for b in log)

    # registry counters == the engine's own bucket log, per bucket
    ctr = obs.registry.counter("bucket_rounds_total")
    counted = collections.Counter()
    for ls in ctr.labelsets():
        counted[int(ls["bucket"])] += int(ctr.value(**ls))
    assert counted == collections.Counter(log)

    # trace rounds carry the same bucket sequence, in order
    traced = [r.bucket for r in obs.trace.rounds
              if r.kind in ("decode", "verify", "admit")]
    assert traced == log

    # decode-round tags == pick_bucket of the live count decode() saw
    decode_buckets = [r.bucket for r in obs.trace.rounds
                      if r.kind == "decode"]
    assert len(decode_buckets) == len(lives)
    assert decode_buckets == [pick_bucket(n, sess.buckets) for n in lives]

    # per-stage span counts == non-bubble cells of the walked tables
    S = sess.sched.n_stages
    expected = np.zeros(S, int)
    for rec in obs.trace.rounds:
        sched = (sess.sched if rec.bucket in (None, R)
                 else sess._bucket_scheds[rec.bucket])
        expected += (np.asarray(sched.tables().fwd)[:, :, F_MB]
                     >= 0).sum(axis=0)
    counts = obs.trace.span_counts()
    assert [counts.get(s, 0) for s in range(S)] == expected.tolist()

    # trace JSON + metrics snapshot are artifact-clean, and the
    # batcher's scheduler-level series rode the same registry
    doc = json.loads(json.dumps(obs.trace.to_json()))
    assert all(e["ph"] in ("M", "X") for e in doc["traceEvents"])
    assert check_metrics_snapshot(obs.registry.snapshot()) == []
    reg = obs.registry
    assert reg.counter("requests_completed_total").value(
        policy="continuous") == 5
    assert reg.histogram("ttft_seconds").stats(
        policy="continuous")["count"] == 5


# ---------------------------------------------------------------------------
# driver: rounds into the registry; replanning off collected telemetry
# ---------------------------------------------------------------------------

def mk_spec(n_layers=8, heads=4, d_model=256, d_ff=1024, vocab=1024):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(name="t", d_model=d_model,
                              n_layers=n_layers, n_heads=heads,
                              n_kv=heads, d_head=max(d_model // heads, 8),
                              d_ff=d_ff, vocab=vocab, blocks=blocks,
                              norm="rmsnorm", act="silu")


def _time_stages(reg, stage_s, rounds=3):
    """Collect per-stage wall times through the registry's own timer —
    the measured path, not hand-injected numbers."""
    clock = FakeClock()
    for _ in range(rounds):
        for s, sec in enumerate(stage_s):
            with reg.timer("stage_round_seconds", clock=clock, stage=s):
                clock.advance(sec)


def test_replan_from_registry_flips_on_measured_straggler():
    """ISSUE-10 acceptance: elastic_replan flips the plan from
    telemetry collected through the registry.  Same config as
    tests/test_plan_search.py::test_rebalance_responds_to_measurements,
    but the measurements arrive via Registry.timer → stage_seconds."""
    spec = mk_spec()
    hw = dataclasses.replace(prof.TPU_V5E, link_bw=1e11, hbm_bytes=1e18)
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    kw = dict(minibatch_tokens=4096, data_replicas=1)

    reg = Registry()
    _time_stages(reg, [0.1, 0.1, 0.1, 0.2])     # 2x straggler on stage 3
    p, changed = replan_from_registry(spec, plan, reg, hw, **kw)
    assert changed
    assert (p.pp, p.tp) == (2, 2)

    even = Registry()
    _time_stages(even, [0.1, 0.1, 0.1, 0.1])    # balanced: no-op
    p, changed = replan_from_registry(spec, plan, even, hw, **kw)
    assert not changed and p == plan


def test_train_driver_reports_rounds_and_stage_seconds(tmp_path):
    from repro.core.pipeline import build_pipeline
    from repro.data.pipeline import ShardedLoader, SyntheticLM
    from repro.optim import SGDM

    spec = _attn_spec(n_layers=2)
    plan = ParallelismPlan(pp=1, tp=1, microbatches=2, stash_mode="stash")
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    obs = Observability(trace=True)
    bundle = build_pipeline(spec, plan, dmesh, seq_len=16, global_batch=4,
                            optimizer=SGDM(lr=0.01),
                            compute_dtype=jnp.float32, obs=obs)
    loader = ShardedLoader(SyntheticLM(spec.vocab, 16),
                           bundle.batch_specs())
    # the driver inherits obs from the bundle; stage_seconds_fn feeds
    # the histograms replan_from_registry reads (the SPMD step is one
    # fused program — the host cannot time stages individually)
    driver = TrainDriver(bundle, loader, str(tmp_path), DriverConfig(),
                         stage_seconds_fn=lambda step: [0.01])
    state = jax.jit(bundle.init_state,
                    out_shardings=bundle.state_shardings())(
        jax.random.key(0))
    driver.run(state, 3)

    reg = obs.registry
    assert reg.counter("rounds_total").value(kind="train") == 3
    assert reg.histogram("round_seconds").stats(kind="train")["count"] == 3
    assert reg.histogram("stage_round_seconds").stats(stage=0)["count"] == 3
    assert stage_seconds(reg, 1) == [pytest.approx(0.01)]
    recs = [r for r in obs.trace.rounds if r.kind == "train"]
    assert len(recs) == 3 and all(r.n_spans > 0 for r in recs)


# ---------------------------------------------------------------------------
# launcher flags: --trace-out / --metrics-out produce valid artifacts
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_trace_and_metrics_flags(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    tr, mt = tmp_path / "trace.json", tmp_path / "metrics.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-14b",
         "--smoke", "--tokens", "4", "--host-devices", "2", "--batch", "2",
         "--trace-out", str(tr), "--metrics-out", str(mt)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "reconcile[" in out.stdout

    doc = json.loads(tr.read_text())
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert spans and all(e["args"]["phase"] in ("F", "B", "bubble")
                         for e in spans)
    snap = json.loads(mt.read_text())
    assert check_metrics_snapshot(snap, "metrics.json") == []
    hist_names = {r["name"] for r in snap["histograms"]}
    assert {"round_seconds", "launch_phase_seconds"} <= hist_names
