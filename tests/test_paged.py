"""Paged KV cache: allocator invariants, planner pricing, error paths.

The bit-exactness of the paged data path itself is proven end-to-end by
scripts/batch_smoke.py (ragged trace, dense vs paged vs solo) and the
kernel parity matrix in tests/test_kernels.py; this file covers the
host-side allocator contract, the pages-in-use memory pricing the
planner uses (including the plan_search golden: a decode plan that is
HBM-infeasible dense fits paged), and the loud-failure paths.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitioner import plan_search
from repro.core.profiler import TPU_V5E
from repro.core.schedule import serving_cache_bytes
from repro.models import spec as spec_lib
from repro.parallel.mesh import ParallelismPlan
from repro.serving.batcher import PageAllocator


def _attn_spec(n_layers=8, window=0):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense",
                                      window=window)
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(
        name="paged-test", d_model=64, n_layers=n_layers, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu")


def _serve_plan(pp=2, r=8, schedule="serve_1f"):
    return ParallelismPlan(pp=pp, tp=1, microbatches=r,
                           decode_microbatches=r, schedule=schedule)


# ---------------------------------------------------------------------------
# PageAllocator: the host-side free-list contract
# ---------------------------------------------------------------------------

def test_allocator_alloc_extend_release_roundtrip():
    a = PageAllocator(pool_pages=16, n_slots=4, max_pages=4, page_size=16)
    assert a.free_pages == 16 and a.live_pages == 0
    a.alloc_slot(0, 17)                       # 2 pages (17 tokens)
    assert a.counts[0] == 2 and a.free_pages == 14
    a.extend_slot(0, 33)                      # crosses into page 3
    assert a.counts[0] == 3 and a.free_pages == 13
    a.extend_slot(0, 34)                      # same page: no growth
    assert a.counts[0] == 3
    a.check()
    a.release_slot(0)
    assert a.free_pages == 16 and a.counts[0] == 0
    assert (a.tables[0] == -1).all()
    a.release_slot(0)                         # idempotent
    a.check()


def test_allocator_reuses_freed_pages():
    a = PageAllocator(pool_pages=4, n_slots=2, max_pages=2, page_size=8)
    a.alloc_slot(0, 16)
    first = set(a.tables[0][a.tables[0] >= 0])
    a.alloc_slot(1, 16)
    assert a.free_pages == 0
    a.release_slot(0)
    a.alloc_slot(0, 9)                        # must reuse slot 0's pages
    reused = set(a.tables[0][a.tables[0] >= 0])
    assert reused <= first
    a.check()


def test_allocator_capacity_and_exhaustion_errors():
    a = PageAllocator(pool_pages=3, n_slots=2, max_pages=2, page_size=8)
    with pytest.raises(ValueError, match="16"):
        a.alloc_slot(0, 17)                   # over per-slot capacity
    a.alloc_slot(0, 16)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc_slot(1, 16)                   # pool has 1 page left
    a.alloc_slot(1, 8)                        # 1 page fits
    with pytest.raises(RuntimeError, match="exhausted"):
        a.extend_slot(1, 9)
    a.check()
    # failed alloc/extend must not leak pages
    assert a.free_pages == 0 and a.live_pages == 3


def test_allocator_check_catches_double_booking():
    a = PageAllocator(pool_pages=4, n_slots=2, max_pages=2, page_size=8)
    a.alloc_slot(0, 8)
    a.alloc_slot(1, 8)
    a.tables[1, 0] = a.tables[0, 0]           # corrupt: shared page
    with pytest.raises(AssertionError):
        a.check()


# ---------------------------------------------------------------------------
# serving_cache_bytes: pages-in-use pricing
# ---------------------------------------------------------------------------

def test_cache_bytes_paged_occupancy_one_matches_dense():
    spec, plan = _attn_spec(), _serve_plan()
    sched = plan.make_schedule()
    kw = dict(cache_len=1024, global_batch=8)
    dense = serving_cache_bytes(spec, plan, sched, **kw)
    paged = serving_cache_bytes(spec, plan, sched, page_size=64,
                                kv_occupancy=1.0, n_slots=8, **kw)
    table = 8 * (1024 // 64) * 4.0            # per-slot int32 tables
    assert paged == dense + table


def test_cache_bytes_paged_scales_with_occupancy_slot_granular():
    spec, plan = _attn_spec(), _serve_plan()
    sched = plan.make_schedule()
    kw = dict(cache_len=1024, global_batch=8, page_size=64, n_slots=8)
    dense = serving_cache_bytes(spec, plan, sched, cache_len=1024,
                                global_batch=8)
    table = 8 * (1024 // 64) * 4.0
    half = serving_cache_bytes(spec, plan, sched, kv_occupancy=0.5, **kw)
    assert abs(half - (dense / 2 + table)) < 1e-6
    # 0.3 of 8 slots rounds UP to 3 whole slots' worth of pages
    frac = serving_cache_bytes(spec, plan, sched, kv_occupancy=0.3, **kw)
    assert abs(frac - (dense * 3 / 8 + table)) < 1e-6


def test_cache_bytes_recurrent_state_stays_dense():
    """Paging thins attention KV only: mamba/windowed stay full price."""
    blocks = tuple(spec_lib.BlockSpec(mixer=("attn" if i % 2 else "mamba"),
                                      ffn="dense") for i in range(8))
    spec = spec_lib.ModelSpec(
        name="hybrid-test", d_model=64, n_layers=8, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, blocks=blocks, norm="rmsnorm",
        act="silu", mamba=spec_lib.MambaSpec())
    plan = _serve_plan()
    sched = plan.make_schedule()
    kw = dict(cache_len=1024, global_batch=8)
    dense = serving_cache_bytes(spec, plan, sched, **kw)
    floor = serving_cache_bytes(spec, plan, sched, page_size=64,
                                kv_occupancy=0.0, n_slots=8, **kw)
    table = 8 * (1024 // 64) * 4.0
    # at zero occupancy only the recurrent state + tables remain, and
    # that floor is strictly positive (mamba conv + ssm state is dense)
    assert table < floor < dense

    # windowed attention (ring buffer < cache_len) is never paged
    wspec = _attn_spec(window=128)
    wdense = serving_cache_bytes(wspec, plan, sched, **kw)
    wpaged = serving_cache_bytes(wspec, plan, sched, page_size=64,
                                 kv_occupancy=0.0, n_slots=8, **kw)
    assert wpaged == wdense                   # no paged layer, no tables


def test_cache_bytes_paged_rejects_sp_and_bad_page_size():
    spec, plan = _attn_spec(), _serve_plan()
    sched = plan.make_schedule()
    with pytest.raises(AssertionError):
        serving_cache_bytes(spec, plan, sched, cache_len=1024,
                            global_batch=8, sp=True, page_size=64)
    with pytest.raises(AssertionError):
        serving_cache_bytes(spec, plan, sched, cache_len=1000,
                            global_batch=8, page_size=64)


# ---------------------------------------------------------------------------
# plan_search golden: dense-infeasible decode plan fits paged
# ---------------------------------------------------------------------------

def test_plan_search_paged_unlocks_infeasible_decode_plan():
    import dataclasses

    spec = _attn_spec(n_layers=8)
    plan = _serve_plan(pp=2, r=32)
    sched = plan.make_schedule()
    dense_cache = serving_cache_bytes(spec, plan, sched, cache_len=4096,
                                      global_batch=32)
    # budget: generous for weights/workspace, too tight for the dense
    # cache, roomy for the paged cache at 25% occupancy
    budget = 0.5 * dense_cache
    hw = dataclasses.replace(TPU_V5E, hbm_bytes=budget)
    kw = dict(minibatch_tokens=32, workload="decode", cache_len=4096,
              global_batch=32, occupancy=0.25, return_all=True)
    dense = plan_search(spec, plan, 2, hw, **kw)
    paged = plan_search(spec, plan, 2, hw, page_size=64, **kw)

    def feas(cands, pp):
        return [c.feasible for c in cands if c.plan.pp == pp
                and c.plan.schedule == "serve_1f"]
    assert not any(feas(dense, 2)), "dense pp=2 should blow the budget"
    assert all(feas(paged, 2)), "paged pp=2 should fit at 25% occupancy"
    # the paged feasible set is a superset of the dense one
    dense_ok = {(c.plan.pp, c.plan.schedule, c.plan.virtual_stages)
                for c in dense if c.feasible}
    paged_ok = {(c.plan.pp, c.plan.schedule, c.plan.virtual_stages)
                for c in paged if c.feasible}
    assert dense_ok <= paged_ok


def test_plan_search_rejects_paged_train_and_sp():
    spec = _attn_spec()
    plan = _serve_plan()
    with pytest.raises(AssertionError, match="training"):
        plan_search(spec, plan, 2, TPU_V5E, minibatch_tokens=32,
                    workload="train", page_size=64)
    with pytest.raises(AssertionError, match="exclusive"):
        plan_search(spec, plan, 2, TPU_V5E, minibatch_tokens=32,
                    workload="decode", cache_len=4096, global_batch=32,
                    sp=True, page_size=64)


# ---------------------------------------------------------------------------
# build_serving error paths (validation precedes any device work)
# ---------------------------------------------------------------------------

def test_build_serving_rejects_bad_paged_configs():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.mesh import split_model_axis
    from repro.serving.engine import build_serving

    spec = _attn_spec()
    plan = _serve_plan(pp=1, r=2)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    with pytest.raises(ValueError, match="multiple"):
        build_serving(spec, plan, dmesh, cache_len=100, global_batch=2,
                      page_size=16)
    with pytest.raises(ValueError, match="exclusive"):
        build_serving(spec, plan, dmesh, cache_len=128, global_batch=2,
                      sp=True, page_size=16)


# ---------------------------------------------------------------------------
# host mirrors vs device state: randomized op-sequence fuzz (real engine)
# ---------------------------------------------------------------------------

def _tiny_session(page_size, n_slots=4, prefill=8, cache=64,
                  buckets=True, pool_pages=None, spec_k=None,
                  start=True):
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.mesh import split_model_axis
    from repro.serving.engine import build_serving

    spec = _attn_spec(n_layers=2)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    plan = _serve_plan(pp=1, r=n_slots,
                       schedule="serve_spec_1f" if spec_k else "serve_1f")
    sess = build_serving(spec, plan, dmesh, cache_len=cache,
                         global_batch=n_slots, prefill_len=prefill,
                         compute_dtype=jnp.float32, page_size=page_size,
                         buckets=buckets, pool_pages=pool_pages,
                         spec_k=spec_k)
    if start:
        sess.start(jax.random.key(0))
    return sess


@pytest.mark.parametrize("page_size", [0, 16])
@pytest.mark.parametrize("spec_k", [None, 2])
def test_host_mirrors_track_device_state_under_random_ops(page_size,
                                                          spec_k):
    """ISSUE-7/8: the engine's host ``_pos``/``_live`` mirrors (which
    the bucket picker and paged allocator trust) must equal the device
    ``state["pos"]``/``state["live"]`` after EVERY admit / decode /
    reset / compact — and, on a speculative session, after every
    verify (variable per-slot advance + rejected-suffix page release)
    and rollback_slots (pos rewind + page truncation) — under a
    randomized legal op sequence, with the page allocator invariants
    holding throughout."""
    R, PREFILL = 4, 8
    sess = _tiny_session(page_size, n_slots=R, prefill=PREFILL,
                         spec_k=spec_k)
    rng = np.random.default_rng(42)

    def check(op):
        np.testing.assert_array_equal(
            sess._pos, np.asarray(sess.state["pos"]),
            err_msg=f"pos mirror diverged after {op}")
        np.testing.assert_array_equal(
            sess._live, np.asarray(sess.state["live"]),
            err_msg=f"live mirror diverged after {op}")
        if sess._alloc is not None:
            sess._alloc.check()

    prefix = True          # live slots known to form a bucket prefix?
    ops = ["admit", "decode", "reset", "compact"]
    if spec_k:
        ops += ["verify", "rollback"]
    for step in range(40):
        op = rng.choice(ops)
        if op == "admit":
            free = [i for i in range(R) if not sess._live[i]]
            if not free:
                continue
            picks = rng.choice(free, size=rng.integers(1, len(free) + 1),
                               replace=False)
            mask = np.zeros(R, np.int32)
            mask[picks] = 1
            toks = rng.integers(1, 256, (R, 1, PREFILL)).astype(np.int32)
            sess.write_prefill_into_slots({"tokens": toks}, mask)
            prefix = False
        elif op == "decode":
            # an arbitrary live layout only runs the full-R program;
            # after a compaction to a prefix the auto bucket pick is
            # legal too — exercise both
            bucket = None if prefix else R
            sess.decode(rng.integers(1, 256, R).astype(np.int32),
                        bucket=bucket)
        elif op == "verify":
            # variable per-slot advance (accepted + 1) + rejected-
            # suffix rollback; random drafts exercise the whole 0..k
            # acceptance range.  Skip when a live slot lacks headroom —
            # the typed CacheExhausted path has its own test below.
            if any(sess._pos[i] + spec_k + 1 > sess.cache_len
                   for i in range(R) if sess._live[i]):
                continue
            toks = rng.integers(1, 256, (R, spec_k + 1)).astype(np.int32)
            sess.verify(toks, bucket=None if prefix else R)
        elif op == "rollback":
            live = [i for i in range(R) if sess._live[i]]
            if not live:
                continue
            mask = np.zeros(R, np.int32)
            new_pos = sess._pos.copy()
            for i in live:
                if rng.random() < 0.5:
                    mask[i] = 1
                    new_pos[i] = rng.integers(sess._prompt_len[i],
                                              sess._pos[i] + 1)
            if not mask.any():
                continue
            sess.rollback_slots(mask, new_pos)
        elif op == "reset":
            mask = (rng.random(R) < 0.5).astype(np.int32)
            sess.reset_slots(mask)
            prefix = False
        else:
            if rng.random() < 0.5:
                # batcher-style: occupied slots first, stable
                occ = [i for i in range(R) if sess._live[i]]
                perm = occ + [i for i in range(R) if not sess._live[i]]
                prefix = True
            else:
                perm = rng.permutation(R).tolist()
                prefix = False
            sess.compact_slots(perm)
        check(f"{op} (step {step})")
    # the fuzz must have executed every op kind at least once
    assert sess.state is not None


# ---------------------------------------------------------------------------
# CacheExhausted backpressure: truncate-and-continue, never a crash
# ---------------------------------------------------------------------------

def test_cache_exhausted_truncates_request_instead_of_crashing():
    """ISSUE-7: a decode that would overflow a slot's paged KV capacity
    raises the typed :class:`CacheExhausted` BEFORE any allocator
    mutation; the batcher catches it, finishes the blocked request as
    ``truncated`` (keeping its tokens), frees the slot's pages and
    retries the round — the serve loop never crashes and the other
    requests are unaffected."""
    from repro.serving.batcher import ContinuousBatchingSession, Request
    from repro.serving.engine import CacheExhausted

    PREFILL, CACHE, PAGE = 8, 16, 4      # capacity: 16 tokens per slot
    sess = _tiny_session(PAGE, n_slots=2, prefill=PREFILL, cache=CACHE,
                         buckets=False)
    rng = np.random.default_rng(5)
    trace = [
        # 8 prompt + 20 new > 16-token capacity: must truncate mid-decode
        Request(rid=0, prompt=rng.integers(1, 256, PREFILL)
                .astype(np.int32), max_new_tokens=20, arrival=0),
        # fits comfortably: must finish untruncated, unaffected
        Request(rid=1, prompt=rng.integers(1, 256, PREFILL)
                .astype(np.int32), max_new_tokens=4, arrival=0),
    ]
    server = ContinuousBatchingSession(sess)
    report = server.run(trace)
    assert len(report.completed) == 2, report.summary()
    long_r, short_r = trace
    assert long_r.truncated and long_r.finished
    # prefill token + decodes up to the 16-token capacity, never more
    assert 0 < len(long_r.tokens) <= CACHE - PREFILL + 1
    assert short_r.finished and not short_r.truncated
    assert len(short_r.tokens) == 4
    # eviction returned every page: pool fully free, invariants hold
    sess._alloc.check()
    assert sess._alloc.live_pages == 0

    # engine-level contract: the raise is typed, names the blocked
    # slots, and leaves the allocator untouched (the op is retryable)
    sess2 = _tiny_session(PAGE, n_slots=2, prefill=PREFILL, cache=CACHE,
                          buckets=False)
    toks = rng.integers(1, 256, (2, 1, PREFILL)).astype(np.int32)
    sess2.write_prefill_into_slots({"tokens": toks},
                                   np.array([1, 1], np.int32))
    for _ in range(CACHE - PREFILL):
        sess2.decode(np.zeros(2, np.int32))
    before = (sess2._alloc.tables.copy(), sess2._alloc.counts.copy())
    with pytest.raises(CacheExhausted) as ei:
        sess2.decode(np.zeros(2, np.int32))
    assert isinstance(ei.value, RuntimeError)        # old handlers survive
    assert set(ei.value.slots) == {0, 1}
    np.testing.assert_array_equal(sess2._alloc.tables, before[0])
    np.testing.assert_array_equal(sess2._alloc.counts, before[1])
    sess2._alloc.check()
    # evicting the blocked slots makes the next decode legal again
    sess2.reset_slots(np.array([1, 1], np.int32))
    for i in (0, 1):
        sess2._alloc.release_slot(i)
    sess2.decode(np.zeros(2, np.int32))


# ---------------------------------------------------------------------------
# Negative paths: typed errors that name the offending argument (ISSUE-8)
# ---------------------------------------------------------------------------

def test_ops_before_start_raise_typed_errors():
    """Session ops before start() fail with a ValueError naming the op
    — never an opaque AttributeError on the missing device state."""
    R, K = 4, 2
    sess = _tiny_session(0, n_slots=R, spec_k=K, start=False)
    tok = np.zeros(R, np.int32)
    with pytest.raises(ValueError, match=r"decode\(\) before start"):
        sess.decode(tok)
    with pytest.raises(ValueError, match=r"draft\(\) before start"):
        sess.draft(tok)
    with pytest.raises(ValueError, match=r"verify\(\) before start"):
        sess.verify(np.zeros((R, K + 1), np.int32))
    with pytest.raises(ValueError,
                       match=r"rollback_slots\(\) before start"):
        sess.rollback_slots(np.ones(R, np.int32), np.zeros(R, np.int64))


def test_spec_ops_on_plain_session_raise_typed_errors():
    sess = _tiny_session(0)          # serve_1f, no spec_k
    with pytest.raises(ValueError, match="non-speculative session"):
        sess.draft(np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="non-speculative session"):
        sess.verify(np.zeros((4, 3), np.int32))


def test_spec_k_exceeding_cache_headroom_rejected_at_build():
    """A spec_k whose verify round could never fit (spec_k+1 >
    cache_len) is rejected by build_serving, naming both numbers."""
    with pytest.raises(ValueError,
                       match=r"spec_k=4 exceeds the cache_len headroom"):
        _tiny_session(0, prefill=2, cache=4, spec_k=4)


def test_verify_without_headroom_raises_evictable_cache_exhausted():
    """verify() on slots within spec_k+1 of capacity raises the typed
    CacheExhausted (listing the blocked slots) before touching state —
    the batcher's evict-and-retry path, same as decode()."""
    from repro.serving.engine import CacheExhausted

    R, K, PREFILL, CACHE = 4, 2, 8, 16
    sess = _tiny_session(0, n_slots=R, prefill=PREFILL, cache=CACHE,
                         spec_k=K)
    rng = np.random.default_rng(3)
    toks = rng.integers(1, 256, (R, 1, PREFILL)).astype(np.int32)
    sess.write_prefill_into_slots({"tokens": toks},
                                  np.ones(R, np.int32))
    while sess._pos[0] + K + 1 <= CACHE:
        sess.decode(rng.integers(1, 256, R).astype(np.int32))
    pos_before = sess._pos.copy()
    with pytest.raises(CacheExhausted, match="lack verify headroom") as ei:
        sess.verify(rng.integers(1, 256, (R, K + 1)).astype(np.int32))
    assert set(ei.value.slots) == set(range(R))
    np.testing.assert_array_equal(sess._pos, pos_before)


def test_verify_rejects_wrong_token_shape():
    sess = _tiny_session(0, spec_k=2)
    with pytest.raises(ValueError,
                       match=r"tokens must be \(global_batch, spec_k\+1\)"):
        sess.verify(np.zeros((4, 2), np.int32))     # spec_k, not spec_k+1
    with pytest.raises(ValueError,
                       match=r"tokens must be \(global_batch, spec_k\+1\)"):
        sess.verify(np.zeros(4, np.int32))          # missing draft axis


def test_admit_rejects_mismatched_and_out_of_range_lens():
    R, PREFILL = 4, 8
    sess = _tiny_session(0, n_slots=R, prefill=PREFILL)
    toks = np.ones((R, 1, PREFILL), np.int32)
    mask = np.ones(R, np.int32)
    with pytest.raises(ValueError,
                       match=rf"lens has {R - 1} entries for R={R} slots"):
        sess.write_prefill_into_slots(
            {"tokens": toks, "lens": np.full(R - 1, PREFILL)}, mask)
    with pytest.raises(ValueError,
                       match=rf"lens entries must lie in \[1, {PREFILL}\]"):
        sess.write_prefill_into_slots(
            {"tokens": toks, "lens": np.full(R, PREFILL + 1)}, mask)
    with pytest.raises(ValueError,
                       match=rf"lens entries must lie in \[1, {PREFILL}\]"):
        sess.write_prefill_into_slots(
            {"tokens": toks, "lens": np.zeros(R, np.int64)}, mask)


def test_rollback_slots_validates_mask_bounds_and_direction():
    """rollback_slots: wrong-length arguments, positions below the
    prompt, and forward 'rollbacks' each get a typed ValueError naming
    the argument; state is untouched on every rejection."""
    R, K, PREFILL = 4, 2, 8
    sess = _tiny_session(0, n_slots=R, prefill=PREFILL, spec_k=K)
    rng = np.random.default_rng(7)
    toks = rng.integers(1, 256, (R, 1, PREFILL)).astype(np.int32)
    sess.write_prefill_into_slots({"tokens": toks},
                                  np.ones(R, np.int32))
    for _ in range(4):
        sess.decode(rng.integers(1, 256, R).astype(np.int32))
    pos_before = sess._pos.copy()            # PREFILL + 4 everywhere
    ones = np.ones(R, np.int32)

    with pytest.raises(ValueError,
                       match=rf"slot_mask has {R + 1} entries for R={R}"):
        sess.rollback_slots(np.ones(R + 1, np.int32), pos_before)
    with pytest.raises(ValueError,
                       match=rf"new_pos has {R - 1} entries for R={R}"):
        sess.rollback_slots(ones, pos_before[:-1])
    below = pos_before.copy()
    below[1] = PREFILL - 1                   # would orphan prompt KV
    with pytest.raises(ValueError, match="below their prompt length"):
        sess.rollback_slots(ones, below)
    fwd = pos_before.copy()
    fwd[2] += 1                              # rollback can't advance
    with pytest.raises(ValueError, match=r"new_pos advances slots \[2\]"):
        sess.rollback_slots(ones, fwd)

    np.testing.assert_array_equal(sess._pos, pos_before)
    np.testing.assert_array_equal(sess._pos,
                                  np.asarray(sess.state["pos"]))
    # and the legal rollback still works after the rejections
    legal = pos_before - 2
    sess.rollback_slots(ones, legal)
    np.testing.assert_array_equal(sess._pos, legal)
