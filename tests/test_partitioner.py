"""Partitioning DP (paper §3.2): optimality vs brute force + paper-style
configs from realistic profiles + numpy-vectorized DP == scalar oracle.

Hypothesis-based property tests run when the package is installed (see
requirements-dev.txt); fixed-seed random sweeps cover the same ground
otherwise so the module never fails collection."""
import numpy as np
import pytest

from repro.core import profiler as prof
from repro.core.partitioner import (Partition, partition,
                                    partition_brute_force,
                                    partition_rectangular, partition_scalar)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _mk_profiles(ts, acts, ws):
    return [prof.LayerProfile(f"l{i}", t / 3, 2 * t / 3, a, w)
            for i, (t, a, w) in enumerate(zip(ts, acts, ws))]


def _check_dp_against_brute_force(layers, machines, bw):
    hw = prof.Hardware("t", flops_peak=1e12, hbm_bw=1e11, link_bw=bw)
    ts, acts, ws = zip(*layers)
    profiles = _mk_profiles(ts, acts, ws)
    got = partition(profiles, machines, hw)
    want = partition_brute_force(profiles, machines, hw)
    assert got.bottleneck_time == pytest.approx(want, rel=1e-9)
    # reconstruction covers all layers with all machines
    assert got.stages[0].start == 0
    assert got.stages[-1].end == len(profiles) - 1
    assert sum(s.replicas for s in got.stages) == machines
    for a, b in zip(got.stages, got.stages[1:]):
        assert b.start == a.end + 1


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.floats(0.01, 10), st.floats(1, 1e6),
                              st.floats(1, 1e7)),
                    min_size=2, max_size=6),
           st.integers(2, 4), st.floats(1e4, 1e8))
    @settings(max_examples=30)
    def test_dp_matches_brute_force(layers, machines, bw):
        _check_dp_against_brute_force(layers, machines, bw)


def test_dp_matches_brute_force_seeded():
    rng = np.random.default_rng(7)
    for _ in range(30):
        n = int(rng.integers(2, 7))
        layers = [(float(rng.uniform(0.01, 10)), float(rng.uniform(1, 1e6)),
                   float(rng.uniform(1, 1e7))) for _ in range(n)]
        _check_dp_against_brute_force(layers, int(rng.integers(2, 5)),
                                      float(rng.uniform(1e4, 1e8)))


def test_vectorized_dp_identical_to_scalar():
    """The numpy-vectorized DP must reproduce the original pure-Python
    recurrence EXACTLY — same bottleneck float, same stage boundaries,
    same replica counts, same tie-breaking."""
    rng = np.random.default_rng(0)
    for hw in (prof.CLUSTER_A, prof.CLUSTER_B, prof.TPU_V5E):
        for _ in range(12):
            n = int(rng.integers(2, 15))
            machines = int(rng.integers(2, 9))
            profiles = [prof.LayerProfile(
                f"l{i}", float(rng.uniform(1e-3, 1e-2)),
                float(rng.uniform(2e-3, 2e-2)),
                float(rng.uniform(1e4, 1e7)), float(rng.uniform(1e4, 1e7)))
                for i in range(n)]
            fast = partition(profiles, machines, hw)
            slow = partition_scalar(profiles, machines, hw)
            assert fast.stages == slow.stages, (fast, slow)
            assert fast.bottleneck_time == slow.bottleneck_time
            assert fast.noam == slow.noam


def _vgg16_like(minibatch=32):
    """Heavy-conv front (high activations, few params) + fat FC tail
    (little compute, huge params) — the Figure-5 shape that makes
    PipeDream split VGG16 as 7-1 on 8 V100s with 10 Gbps (paper: 32
    img/minibatch ≈ 0.14 s compute vs ≈ 0.39 s parameter sync)."""
    profiles = []
    for i in range(13):  # conv layers: ~all the compute, ~5% of params
        t = 0.003
        act = minibatch * (224 * 224 * 64 / (2 ** min(i // 2, 4))) * 4
        profiles.append(prof.LayerProfile(f"conv{i}", t, 2 * t, act, 2e6))
    for i, w in enumerate([102_760_448, 16_777_216, 4_096_000]):
        profiles.append(prof.LayerProfile(f"fc{i}", 0.002, 0.004,
                                          minibatch * 4096 * 4, w))
    return profiles


def test_vgg16_like_splits_off_fc_tail():
    """On a slow network the optimizer must NOT choose pure data
    parallelism for a VGG16-like profile; the param-heavy FC tail gets
    its own (small) stage — the paper's 7-1 / 2-1-1 family."""
    from repro.core.partitioner import stage_time

    hw = prof.CLUSTER_B
    part = partition(_vgg16_like(), 8, hw)
    # the paper's Table-1 config for VGG16 on 8 machines of Cluster-B
    assert part.config_string == "7-1"
    assert part.noam == 2
    # and it beats pure data parallelism
    dp = stage_time(_vgg16_like(), 0, 15, 8, hw)
    assert part.bottleneck_time < dp


def test_compute_bound_model_prefers_data_parallel():
    """Inception-v3-on-Cluster-A regime: communication is cheap relative
    to compute ⇒ the optimizer picks a single replicated stage (paper
    Table 1 row 'Inception-v3 8(A) config=8')."""
    hw = prof.Hardware("fat-net", flops_peak=11e12, hbm_bw=480e9,
                       link_bw=3.2e9, mfu=0.35)
    # uniform compute-heavy layers with small activations and params
    profiles = _mk_profiles([0.02] * 10, [1e5] * 10, [1e6] * 10)
    part = partition(profiles, 8, hw)
    assert part.config_string == "8"
    assert part.noam == 1


def test_rectangular_balances_stages():
    hw = prof.TPU_V5E
    ts = [1.0, 1.0, 1.0, 1.0, 4.0, 4.0]   # skewed work
    profiles = _mk_profiles(ts, [1e4] * 6, [1e6] * 6)
    part = partition_rectangular(profiles, 2, 1, hw)
    # balanced split puts the two heavy layers alone: [0..3] | [4..5]
    assert part.stages[0].end == 3 and part.stages[1].start == 4
    assert part.bottleneck_time == pytest.approx(8.0)


def test_noam_from_partition():
    hw = prof.CLUSTER_B
    part = partition(_vgg16_like(), 8, hw)
    assert part.noam == int(np.ceil(8 / part.stages[0].replicas))
