"""SPMD pipeline == sequential reference, bit-level (fp32).

Each case runs in a subprocess so it can set
--xla_force_host_platform_device_count before jax initializes (the main
pytest process keeps 1 device per the task spec).
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

MATRIX = [
    # data, pp, tp, mode,     arch,    zero1
    (1, 2, 1, "stash", "dense", 0),
    (2, 2, 2, "stash", "dense", 1),     # replication + TP + ZeRO-1
    (1, 4, 1, "stash", "dense", 0),     # deeper pipe, V=7 ring
    (2, 2, 1, "flush", "dense", 0),     # PipeDream-flush (no ring)
    (1, 2, 1, "vertical", "dense", 0),  # vertical sync
    (1, 2, 1, "2bw", "dense", 0),       # 2-version accumulate
    (2, 2, 2, "stash", "moe", 1),       # expert-parallel stage
    (1, 2, 1, "stash", "rwkv", 0),      # attention-free stage
    (1, 2, 2, "stash", "hybrid", 0),    # mamba+moe+attn mixed stage
]


@pytest.mark.parametrize("data,pp,tp,mode,arch,zero1", MATRIX)
def test_pipeline_matches_reference(data, pp, tp, mode, arch, zero1):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "spmd_pipeline_check.py"),
         str(data), str(pp), str(tp), mode, arch, str(zero1)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "MATCH" in out.stdout
