"""SPMD pipeline == sequential reference, bit-level (fp32).

Each case runs in a subprocess so it can set
--xla_force_host_platform_device_count before jax initializes (the main
pytest process keeps 1 device per the task spec).

A small fast subset runs by default; the full matrix (every stash-mode /
schedule / arch combination) carries the ``slow`` marker — run it with
``pytest -m slow`` (or ``-m ''``).
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

# data, pp, tp, mode, arch, zero1, schedule, virtual_stages, steps
FAST_MATRIX = [
    (1, 2, 1, "stash", "dense", 0, "auto", 1, 1),
    (2, 2, 1, "flush", "dense", 0, "auto", 1, 1),      # PipeDream-flush
    (1, 2, 1, "flush", "dense", 0, "interleaved", 2, 2),  # virtual stages
    # per-chunk version rings, per-microbatch updates (vs the native
    # async sequential oracle, storage order)
    (1, 2, 1, "stash", "dense", 0, "interleaved_async", 2, 1),
]

SLOW_MATRIX = [
    (2, 2, 2, "stash", "dense", 1, "auto", 1, 1),   # replication + TP + ZeRO-1
    (1, 4, 1, "stash", "dense", 0, "auto", 1, 1),   # deeper pipe, V=7 ring
    (1, 2, 1, "vertical", "dense", 0, "auto", 1, 1),  # vertical sync
    (1, 2, 1, "2bw", "dense", 0, "auto", 1, 1),     # 2-version accumulate
    (2, 2, 2, "stash", "moe", 1, "auto", 1, 1),     # expert-parallel stage
    (1, 2, 1, "stash", "rwkv", 0, "auto", 1, 1),    # attention-free stage
    (1, 2, 2, "stash", "hybrid", 0, "auto", 1, 1),  # mamba+moe+attn mixed
    (1, 2, 2, "flush", "dense", 0, "interleaved", 2, 1),   # interleave + TP
    (1, 2, 1, "flush", "dense8", 0, "interleaved", 4, 1),  # v=4, 8 chunks
    (1, 4, 1, "flush", "dense8", 0, "interleaved", 2, 1),  # S=4, v=2
    # async interleaved: ring rotation across rounds, v=4, TP, ZeRO-1
    (1, 4, 1, "stash", "dense8", 0, "interleaved_async", 2, 2),
    (1, 2, 1, "stash", "dense8", 0, "interleaved_async", 4, 1),
    (1, 2, 2, "stash", "dense", 0, "interleaved_async", 2, 1),
    (2, 2, 1, "stash", "dense", 1, "interleaved_async", 2, 1),
]


def _run_case(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "spmd_pipeline_check.py"),
         *[str(a) for a in case]],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "MATCH" in out.stdout


@pytest.mark.parametrize("case", FAST_MATRIX, ids=lambda c: "-".join(
    str(x) for x in c))
def test_pipeline_matches_reference(case):
    _run_case(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", SLOW_MATRIX, ids=lambda c: "-".join(
    str(x) for x in c))
def test_pipeline_matches_reference_full(case):
    _run_case(case)
