"""Schedule-aware, memory-aware planner (PipeDream-2BW/BaPipe-style).

Covers the ISSUE-2 acceptance criteria:
  * memory_model golden values for all three schedules vs hand-computed
    ring sizes;
  * the time-weighted simulator round_time (ramp ticks charged only for
    the direction that runs; per-stage heterogeneous costs);
  * plan_search rejects over-HBM-budget candidates and prefers
    interleaved at S >= 3 / v >= 2 on the same (S, R);
  * rebalance_from_measurements provably responds to
    measured_stage_seconds — the plan flips only when measurements are
    injected (the replanner used to ignore them entirely).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import profiler as prof
from repro.core.partitioner import plan_search
from repro.core.schedule import (Schedule1F1B, ScheduleGPipe,
                                 ScheduleInterleaved1F1B,
                                 ScheduleInterleavedAsync1F1B,
                                 weighted_round_time)
from repro.models import spec as S
from repro.models.spec import _block_params
from repro.parallel.mesh import ParallelismPlan
from repro.runtime.driver import (elastic_replan,
                                  rebalance_from_measurements)


def mk_spec(n_layers=8, heads=4, d_model=256, d_ff=1024, vocab=1024):
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(n_layers))
    return S.ModelSpec(name="t", d_model=d_model, n_layers=n_layers,
                       n_heads=heads, n_kv=heads,
                       d_head=max(d_model // heads, 8), d_ff=d_ff,
                       vocab=vocab, blocks=blocks, norm="rmsnorm",
                       act="silu")


HW = dataclasses.replace(prof.TPU_V5E, hbm_bytes=1e18)
MB_TOKENS = 512


def _hand_terms(spec, plan):
    """The hand-computed building blocks the goldens are stated in."""
    n_chunks = plan.pp * plan.virtual_stages
    lps = spec.n_layers // n_chunks
    p_blk = _block_params(spec, spec.blocks[0])
    blocks = plan.virtual_stages * lps * p_blk / plan.tp   # per stage
    shared = (2 * spec.vocab * spec.d_model + spec.d_model) \
        / (plan.pp * plan.tp)
    act = MB_TOKENS * spec.d_model * prof.ACT_BYTES
    return blocks, shared, act


# ---------------------------------------------------------------------------
# memory_model goldens
# ---------------------------------------------------------------------------

def test_memory_model_1f1b_golden():
    """Stash family: V = 2(S-1)+1 weight versions + same-depth residual
    ring; no round-long grad accumulator."""
    spec = mk_spec()
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    sched = plan.make_schedule()
    assert isinstance(sched, Schedule1F1B)
    mm = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS)
    blocks, shared, act = _hand_terms(spec, plan)
    pb = HW.param_bytes
    assert mm.weight_bytes == pytest.approx((blocks + shared) * pb)
    assert mm.stash_bytes == pytest.approx(7 * blocks * pb)       # 2(S-1)+1
    assert mm.resid_bytes == pytest.approx(7 * act)
    assert mm.grad_bytes == 0.0
    # vertical sync shares the exact same ring
    vplan = plan.with_(stash_mode="vertical")
    vm = vplan.make_schedule().memory_model(spec, vplan, HW,
                                            microbatch_tokens=MB_TOKENS)
    assert vm.stash_bytes == mm.stash_bytes
    assert vm.total_bytes == mm.total_bytes


def test_memory_model_gpipe_golden():
    """Flush: no ring at weight_versions=1 but a round-long grad
    accumulator; 2BW keeps exactly the double buffer.  In-flight
    residuals are 1F1B-timing-bounded (2(S-1)+1), not the naive R."""
    spec = mk_spec()
    plan = ParallelismPlan(pp=4, tp=1, microbatches=32, stash_mode="flush")
    sched = plan.make_schedule()
    assert isinstance(sched, ScheduleGPipe)
    mm = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS)
    blocks, shared, act = _hand_terms(spec, plan)
    pb = HW.param_bytes
    assert mm.stash_bytes == 0.0
    assert mm.grad_bytes == pytest.approx(blocks * pb)
    assert mm.resid_bytes == pytest.approx(7 * act)   # NOT 32 × act
    plan2 = plan.with_(stash_mode="2bw")
    m2 = plan2.make_schedule().memory_model(spec, plan2, HW,
                                            microbatch_tokens=MB_TOKENS)
    assert m2.stash_bytes == pytest.approx(2 * blocks * pb)


def test_memory_model_interleaved_golden():
    """Interleaved: same per-stage weight total as the plain S-way split
    (chunks are extra *cuts*, not extra copies), flush-family grad
    accumulator, and a strictly deeper residual ring."""
    spec = mk_spec(n_layers=12)
    plan = ParallelismPlan(pp=3, tp=1, microbatches=6, stash_mode="flush",
                           schedule="interleaved", virtual_stages=2)
    sched = plan.make_schedule()
    assert isinstance(sched, ScheduleInterleaved1F1B)
    mm = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS)
    blocks, shared, act = _hand_terms(spec, plan)
    pb = HW.param_bytes
    assert mm.weight_bytes == pytest.approx((blocks + shared) * pb)
    plain = ParallelismPlan(pp=3, tp=1, microbatches=6, stash_mode="flush")
    pm = plain.make_schedule().memory_model(spec, plain, HW,
                                            microbatch_tokens=MB_TOKENS)
    assert mm.weight_bytes == pytest.approx(pm.weight_bytes)
    assert mm.stash_bytes == 0.0
    assert mm.grad_bytes == pytest.approx(blocks * pb)
    # the interval-coloured ring is deeper than the plain 2(S-1)+1
    assert sched.resid_slots > 2 * (plan.pp - 1) + 1
    assert mm.resid_bytes == pytest.approx(sched.resid_slots * act)
    assert mm.resid_bytes > pm.resid_bytes


def test_memory_model_interleaved_async_golden():
    """Async interleaved: the per-chunk version ring costs
    min(2S, R) × stage weights (each of the v chunks keeps its own
    versions of its 1/v share), there is no round-long grad
    accumulator, and everything timing-derived (weights, residual ring)
    is shared bit-for-bit with flush-interleaved."""
    spec = mk_spec(n_layers=12)
    plan = ParallelismPlan(pp=3, tp=1, microbatches=6, stash_mode="stash",
                           schedule="interleaved_async", virtual_stages=2)
    sched = plan.make_schedule()
    assert isinstance(sched, ScheduleInterleavedAsync1F1B)
    mm = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS)
    blocks, shared, act = _hand_terms(spec, plan)
    pb = HW.param_bytes
    assert sched.stash_slots == 6                  # min(2·3, 6)
    assert mm.stash_bytes == pytest.approx(6 * blocks * pb)
    assert mm.grad_bytes == 0.0
    flush = ParallelismPlan(pp=3, tp=1, microbatches=6, stash_mode="flush",
                            schedule="interleaved", virtual_stages=2)
    fm = flush.make_schedule().memory_model(spec, flush, HW,
                                            microbatch_tokens=MB_TOKENS)
    assert mm.weight_bytes == pytest.approx(fm.weight_bytes)
    assert mm.resid_bytes == pytest.approx(fm.resid_bytes)
    # per-microbatch updates at virtual stages are paid for in HBM: the
    # ring strictly outweighs the accumulator it replaces
    assert mm.total_bytes > fm.total_bytes


def test_memory_model_zero1_and_tp_sharding():
    spec = mk_spec()
    plan = ParallelismPlan(pp=2, tp=2, microbatches=4, stash_mode="flush",
                           zero1=True)
    sched = plan.make_schedule()
    m1 = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS,
                            data_replicas=1)
    m8 = sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS,
                            data_replicas=8)
    assert m8.optimizer_bytes == pytest.approx(m1.optimizer_bytes / 8)
    noz = plan.with_(zero1=False)
    mn = noz.make_schedule().memory_model(spec, noz, HW,
                                          microbatch_tokens=MB_TOKENS,
                                          data_replicas=8)
    assert mn.optimizer_bytes == pytest.approx(m1.optimizer_bytes)
    # doubling tp halves the per-device block weights
    wide = ParallelismPlan(pp=2, tp=4, microbatches=4, stash_mode="flush")
    mw = wide.make_schedule().memory_model(spec, wide, HW,
                                           microbatch_tokens=MB_TOKENS)
    b2, _, _ = _hand_terms(spec, plan)
    b4, _, _ = _hand_terms(spec, wide)
    assert b4 == pytest.approx(b2 / 2)
    assert mw.grad_bytes == pytest.approx(m1.grad_bytes / 2)


def test_memory_model_rejects_mismatched_plan():
    spec = mk_spec()
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8)
    sched = Schedule1F1B(2, 8)    # S=2 schedule, pp=4 plan
    with pytest.raises(AssertionError):
        sched.memory_model(spec, plan, HW, microbatch_tokens=MB_TOKENS)


# ---------------------------------------------------------------------------
# time-weighted round_time
# ---------------------------------------------------------------------------

def test_weighted_round_time_1f1b_closed_form():
    """Ramp/drain ticks run only one direction: F is busy somewhere for
    R+S-1 ticks and likewise B, so the round costs (R+S-1)(t_f+t_b) —
    not n_ticks(t_f+t_b) = (R+2S-2)(t_f+t_b)."""
    for s, r in [(1, 4), (2, 4), (4, 8), (5, 13)]:
        sched = Schedule1F1B(s, r)
        rt, bub = weighted_round_time(sched, 1.0, 2.0)
        assert rt == pytest.approx((r + s - 1) * 3.0)
        assert bub == pytest.approx(1.0 - r / (r + s - 1))
        # the slot-count bubble over-charges relative to the weighted one
        assert bub <= sched.bubble_fraction + 1e-12


def test_weighted_round_time_per_stage_straggler():
    sched = Schedule1F1B(4, 8)
    base, _ = weighted_round_time(sched, [1.0] * 4, [2.0] * 4)
    slow, _ = weighted_round_time(sched, [1.0, 1.0, 2.0, 1.0],
                                  [2.0, 2.0, 4.0, 1.0])
    assert base == pytest.approx((8 + 3) * 3.0)
    assert slow > base
    # a stage that is busy every steady tick bounds the round from below
    assert slow >= 8 * 6.0    # R × straggler (F+B) work


def test_simulator_reports_both_bubbles():
    from benchmarks.simulator import simulate_schedule
    sched = ScheduleInterleaved1F1B(4, 8, virtual_stages=2)
    sim = simulate_schedule(sched)
    assert sim.bubble_fraction == pytest.approx(sched.bubble_fraction)
    assert sim.weighted_bubble_fraction < sim.bubble_fraction
    rt, bub = weighted_round_time(sched)
    assert sim.round_time == pytest.approx(rt)
    assert sim.weighted_bubble_fraction == pytest.approx(bub)


# ---------------------------------------------------------------------------
# plan_search
# ---------------------------------------------------------------------------

def test_plan_search_prefers_interleaved_at_depth():
    """Acceptance: S >= 3, v >= 2 -> interleaved beats plain 1F1B on the
    same (S, R).  heads=3 pins tp=1, so pp=4 is the only split."""
    spec = mk_spec(n_layers=8, heads=3, d_model=192)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    cands = plan_search(spec, base, 4, HW, minibatch_tokens=MB_TOKENS,
                        data_replicas=1, return_all=True)
    assert all(c.plan.pp == 4 for c in cands)
    best = cands[0]
    assert best.plan.schedule == "interleaved"
    assert best.plan.virtual_stages >= 2
    plain = [c for c in cands if c.plan.schedule == "1f1b"]
    assert plain and best.round_time < min(c.round_time for c in plain)
    # chosen plan is actually constructible
    best.plan.make_schedule().validate()


def test_plan_search_enforces_hbm_budget():
    """The fastest candidate must lose to a feasible one when it does
    not fit; an impossible budget raises instead of returning garbage."""
    spec = mk_spec(n_layers=8, heads=16, d_model=2048, d_ff=8192,
                   vocab=32000)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    roomy = plan_search(spec, base, 4, HW, minibatch_tokens=4096,
                        data_replicas=1, schedules=("1f1b",))
    assert roomy.plan.pp == 4          # fastest round wins unconstrained
    assert roomy.feasible
    # 1f1b@pp4 needs ~3.7 GB (7-slot stash ring); 2.3 GB only fits pp=1
    tight = plan_search(spec, base, 4, HW, minibatch_tokens=4096,
                        data_replicas=1, schedules=("1f1b",),
                        hbm_bytes=2.3e9)
    assert tight.plan.pp == 1
    assert tight.memory.total_bytes <= 2.3e9
    assert tight.round_time > roomy.round_time   # paid time for memory
    with pytest.raises(AssertionError):
        plan_search(spec, base, 4, HW, minibatch_tokens=4096,
                    data_replicas=1, schedules=("1f1b",), hbm_bytes=1e8)


def test_plan_search_prices_async_interleaved_golden():
    """plan_search prices the per-chunk version ring and accepts
    async-interleaved under the HBM budget: with an async base plan the
    (equal-round_time) tie-break keeps it over flush-interleaved, and a
    budget that admits the flush accumulator but not the async ring
    rejects the async candidate and falls back to flush-interleaved."""
    spec = mk_spec(n_layers=8, heads=3, d_model=192)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash",
                           schedule="interleaved_async", virtual_stages=2)
    cands = plan_search(spec, base, 4, HW, minibatch_tokens=MB_TOKENS,
                        data_replicas=1, return_all=True)
    best = cands[0]
    assert best.plan.schedule == "interleaved_async"
    assert best.plan.virtual_stages == 2 and best.feasible
    best.plan.make_schedule().validate()
    flush = [c for c in cands if c.plan.schedule == "interleaved"
             and c.plan.pp == best.plan.pp
             and c.plan.virtual_stages == best.plan.virtual_stages]
    assert len(flush) == 1
    # identical timing tables -> identical simulated round; the async
    # pick is the keep-the-base-schedule tie-break, and it pays for the
    # per-microbatch semantics in HBM
    assert flush[0].round_time == pytest.approx(best.round_time)
    assert best.memory.total_bytes > flush[0].memory.total_bytes
    # budget between the two: the ring no longer fits, the accumulator
    # does -> plan_search must reject async and pick flush-interleaved
    budget = (best.memory.total_bytes + flush[0].memory.total_bytes) / 2
    tight = plan_search(spec, base, 4, HW, minibatch_tokens=MB_TOKENS,
                        data_replicas=1, hbm_bytes=budget)
    assert tight.plan.schedule == "interleaved"
    assert tight.feasible and tight.memory.total_bytes <= budget


def test_plan_search_candidates_respect_structure():
    spec = mk_spec(n_layers=8, heads=4)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    cands = plan_search(spec, base, 4, HW, minibatch_tokens=MB_TOKENS,
                        data_replicas=1, return_all=True)
    for c in cands:
        plan = c.plan
        assert plan.pp * plan.tp == 4
        assert spec.n_layers % (plan.pp * plan.virtual_stages) == 0
        assert spec.n_heads % plan.tp == 0
        if plan.schedule == "interleaved":
            assert plan.microbatches % plan.pp == 0
            assert plan.stash_mode == "flush"
        if plan.schedule == "interleaved_async":
            assert plan.microbatches % plan.pp == 0
            assert plan.stash_mode == "stash"
        plan.make_schedule().validate()
    # ranked by round_time (ties broken deterministically)
    rts = [c.round_time for c in cands]
    assert rts == sorted(rts)


# ---------------------------------------------------------------------------
# measured-profile rebalance (the replanner bugfix)
# ---------------------------------------------------------------------------

def test_scale_profiles_to_measurements():
    spec = mk_spec()
    profiles = prof.profile_analytic(spec, HW, minibatch_tokens=MB_TOKENS)
    spans = prof.profile_stage_spans(len(profiles), 4)
    predicted = [sum(profiles[i].t_total for i in span) for span in spans]
    # measurements proportional to the prediction carry no information:
    # the scaled profile is the original (median-normalized ratios)
    even = prof.scale_profiles_to_measurements(
        profiles, [3.0 * p for p in predicted], n_stages=4)
    for a, b in zip(profiles, even):
        assert b.t_total == pytest.approx(a.t_total)
    # a 2× straggler on stage 3 scales exactly its layers (incl. head)
    meas = list(predicted)
    meas[3] *= 2.0
    skew = prof.scale_profiles_to_measurements(profiles, meas, n_stages=4)
    assert skew[1].t_total == pytest.approx(profiles[1].t_total)
    assert skew[-1].t_total == pytest.approx(2 * profiles[-1].t_total)
    assert skew[-2].t_total == pytest.approx(2 * profiles[-2].t_total)


def test_rebalance_responds_to_measurements():
    """Acceptance: the plan flips ONLY when measurements are injected.

    On a fat-link cluster the analytic profile keeps the deep pure
    pipeline; a 2× straggler makes its layers genuinely slower, and the
    search flips to pp=2 × tp=2 — deeper tensor parallelism shrinks the
    straggling stage's work, which is exactly what the docstring always
    promised and the old code never did (it ignored
    measured_stage_seconds and re-ran the same analytic search)."""
    spec = mk_spec()
    hw = dataclasses.replace(prof.TPU_V5E, link_bw=1e11, hbm_bytes=1e18)
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    kw = dict(minibatch_tokens=4096, data_replicas=1)
    analytic = elastic_replan(spec, plan, 4, hw, **kw)
    assert (analytic.pp, analytic.tp) == (4, 1)
    measured = elastic_replan(spec, plan, 4, hw,
                              measured_stage_seconds=[1.0, 1.0, 1.0, 2.0],
                              **kw)
    assert (measured.pp, measured.tp) == (2, 2)
    # the full rebalance entry point: no-op on even times, flips on skew
    p, changed = rebalance_from_measurements(spec, plan,
                                             [1.0, 1.0, 1.0, 1.0], hw, **kw)
    assert not changed and p == plan
    p, changed = rebalance_from_measurements(spec, plan,
                                             [1.0, 1.0, 1.0, 2.0], hw, **kw)
    assert changed
    assert (p.pp, p.tp) == (2, 2)


def test_rebalance_can_switch_schedule():
    """On a thin link the straggler does not justify more tp (all-reduce
    too expensive) — the search instead re-picks the schedule at the
    same (pp, tp), trading the stash ring for interleaved bubble; the
    legacy halve-pp fallback must NOT clobber a schedule-only change."""
    spec = mk_spec()
    hw = dataclasses.replace(prof.TPU_V5E, link_bw=2e9, hbm_bytes=1e18)
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="stash")
    kw = dict(minibatch_tokens=4096, data_replicas=1)
    analytic = elastic_replan(spec, plan, 4, hw, **kw)
    assert analytic.schedule == "1f1b"
    p, changed = rebalance_from_measurements(spec, plan,
                                             [1.0, 1.0, 1.0, 2.0], hw, **kw)
    assert changed
    assert (p.pp, p.tp) == (4, 1)
    assert p.schedule == "interleaved" and p.virtual_stages >= 2
