"""Quantized weight / KV storage: leaf codecs, tree transform, kernel
parity, engine greedy-match, and the planner's pricing of it all."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import quant
from repro.core.partitioner import plan_search
from repro.core.profiler import TPU_V5E
from repro.kernels import ops, ref
from repro.models import spec as spec_lib
from repro.parallel.mesh import ParallelismPlan

KEY = jax.random.key(0)


def _attn_spec(n_layers=8, window=0):
    blocks = tuple(spec_lib.BlockSpec(mixer="attn", ffn="dense",
                                      window=window)
                   for _ in range(n_layers))
    return spec_lib.ModelSpec(
        name="quant-test", d_model=64, n_layers=n_layers, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, blocks=blocks,
        norm="rmsnorm", act="silu")


def _serve_plan(pp=2, r=8, schedule="serve_1f"):
    return ParallelismPlan(pp=pp, tp=1, microbatches=r,
                           decode_microbatches=r, schedule=schedule)


# ---------------------------------------------------------------------------
# Leaf codecs
# ---------------------------------------------------------------------------

def test_int8_quantize_shape_and_error_bound():
    w = jax.random.normal(KEY, (32, 48), jnp.float32)
    q = quant.quantize(w, "int8", axis=0)
    assert q["q"].dtype == jnp.int8 and q["q"].shape == w.shape
    assert q["scale"].shape == (1, 48)        # keepdims on the reduced axis
    deq = np.asarray(quant.dequantize(q))
    # round-to-nearest: per-element error <= scale/2 of its channel
    bound = 0.5 * np.asarray(q["scale"]) + 1e-6
    assert (np.abs(np.asarray(w) - deq) <= bound).all()


def test_int8_zero_channel_dequantizes_to_exact_zero():
    w = jnp.zeros((8, 4), jnp.float32)
    q = quant.quantize(w, "int8", axis=0)
    np.testing.assert_array_equal(np.asarray(quant.dequantize(q)), 0.0)


def test_fp8_quantize_roundtrip_tolerance():
    w = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32)
    q = quant.quantize(w, "fp8", axis=0)
    assert q["q"].dtype == jnp.float8_e4m3fn
    deq = np.asarray(quant.dequantize(q))
    assert np.isfinite(deq).all()
    # e4m3: 3 mantissa bits -> <= 2^-4 relative error on normal values
    np.testing.assert_allclose(deq, np.asarray(w), rtol=0.08, atol=1e-3)


def test_maybe_dequant_passthrough_and_dtype():
    w = jax.random.normal(KEY, (4, 4), jnp.float32)
    assert quant.maybe_dequant(w) is w
    assert quant.maybe_dequant(w, jnp.bfloat16).dtype == jnp.bfloat16
    q = quant.quantize(w, "int8", axis=1)
    assert quant.maybe_dequant(q, jnp.bfloat16).dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Whole-tree transform (params + pspec twin in lockstep)
# ---------------------------------------------------------------------------

def test_quantize_params_structure_and_pspecs():
    stages = {
        "layer_0": {
            "norm1": {"scale": jnp.ones((2, 64))},
            "attn": {"wq": jax.random.normal(KEY, (2, 64, 4, 16))},
            "moe": {"router": jax.random.normal(KEY, (2, 64, 8)),
                    "w1": jax.random.normal(KEY, (2, 8, 64, 32))},
        }}
    pspecs = {
        "layer_0": {
            "norm1": {"scale": P("stage", None)},
            "attn": {"wq": P("stage", None, "model", None)},
            "moe": {"router": P("stage", None, None),
                    "w1": P("stage", "model", None, None)},
        }}
    params = {"stages": stages,
              "embed": jax.random.normal(KEY, (256, 64)),
              "head": jax.random.normal(KEY, (64, 256))}
    full = {"stages": pspecs, "embed": P(None, None),
            "head": P(None, "model")}
    qp, qs = quant.quantize_params(params, full, "int8")
    l0, s0 = qp["stages"]["layer_0"], qs["stages"]["layer_0"]
    # norms and routers pass through untouched
    assert not quant.is_quantized(l0["norm1"]["scale"])
    assert not quant.is_quantized(l0["moe"]["router"])
    assert s0["norm1"]["scale"] == P("stage", None)
    # matmuls quantize along their contraction axis (stage-stacked)
    assert quant.is_quantized(l0["attn"]["wq"])
    assert l0["attn"]["wq"]["scale"].shape == (2, 1, 4, 16)
    assert quant.is_quantized(l0["moe"]["w1"])
    assert l0["moe"]["w1"]["scale"].shape == (2, 8, 1, 32)
    # the scale pspec zeroes the reduced axis, keeps the rest
    assert s0["attn"]["wq"]["scale"] == P("stage", None, "model", None)
    assert s0["moe"]["w1"]["scale"] == P("stage", "model", None, None)
    # shared leaves: embed per vocab row, head per vocab column
    assert qp["embed"]["scale"].shape == (256, 1)
    assert qp["head"]["scale"].shape == (1, 256)
    assert qs["head"]["scale"] == P(None, "model")
    # fp32/bf16/None are identity
    same, _ = quant.quantize_params(params, full, "bf16")
    assert same["stages"] is not None
    assert not quant.is_quantized(same["stages"]["layer_0"]["attn"]["wq"])


def test_quantize_params_rejects_unknown_dtype():
    with pytest.raises(ValueError, match="unknown weight dtype"):
        quant.quantize_params({"stages": {}}, None, "int4")


def test_quantize_params_works_under_eval_shape():
    params = {"stages": {"layer_0": {"attn": {
        "wq": jnp.zeros((2, 64, 4, 16))}}},
        "embed": jnp.zeros((256, 64)), "head": jnp.zeros((64, 256))}
    shapes = jax.eval_shape(
        lambda p: quant.quantize_params(p, None, "int8")[0], params)
    wq = shapes["stages"]["layer_0"]["attn"]["wq"]
    assert wq["q"].dtype == jnp.int8
    assert wq["scale"].shape == (2, 1, 4, 16)


# ---------------------------------------------------------------------------
# int8 KV pages: write-side helpers + kernel/oracle parity
# ---------------------------------------------------------------------------

def test_kv_page_batched_roundtrip_and_zero_pages():
    pages = jax.random.normal(jax.random.key(2), (3, 16, 2, 8), jnp.float32)
    q, s = quant.quantize_kv_page_batched(pages)
    assert q.dtype == jnp.int8 and q.shape == pages.shape
    assert s.shape == (3, 2)
    deq = np.asarray(quant.dequantize_kv_pages(q, s))
    bound = 0.5 * np.asarray(s)[:, None, :, None] + 1e-6
    assert (np.abs(np.asarray(pages) - deq) <= bound).all()
    # all-zero pages survive exactly (scale falls back to 1/127)
    qz, sz = quant.quantize_kv_page_batched(jnp.zeros((2, 4, 2, 8)))
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize_kv_pages(qz, sz)), 0.0)


def _paged_case(b, h, kv, dh, page, n_pages, seed):
    rng = np.random.default_rng(seed)
    n_pool = b * n_pages + 3
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    k_pages = jax.random.normal(ks[1], (n_pool, page, kv, dh), jnp.float32)
    v_pages = jax.random.normal(ks[2], (n_pool, page, kv, dh), jnp.float32)
    lengths = rng.integers(1, n_pages * page + 1, b).astype(np.int32)
    perm = rng.permutation(n_pool)
    tables = np.full((b, n_pages), -1, np.int32)
    used = 0
    for r in range(b):
        need = -(-int(lengths[r]) // page)
        tables[r, :need] = perm[used:used + need]
        used += need
    return q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(lengths)


@pytest.mark.parametrize("b,h,kv,dh,page,n_pages,window", [
    (2, 4, 2, 64, 16, 8, -1),
    (2, 8, 2, 64, 64, 4, -1),        # big pages, 4:1 GQA
    (2, 4, 2, 64, 16, 8, 20),        # windowed: dead-page skipping
])
def test_paged_attention_int8_kernel_matches_ref(b, h, kv, dh, page,
                                                 n_pages, window):
    q, kp, vp, tables, lengths = _paged_case(
        b, h, kv, dh, page, n_pages, seed=b + h + page)
    kq, ks = quant.quantize_kv_page_batched(kp)
    vq, vs = quant.quantize_kv_page_batched(vp)
    got = ops.paged_attention(q, kq, vq, tables, lengths, window=window,
                              k_scale=ks, v_scale=vs)
    want = ref.paged_attention_ref(q, kq, vq, tables, lengths,
                                   window=window, k_scale=ks, v_scale=vs)
    # kernel vs oracle on the SAME int8 pools: f32 noise only
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-3)
    # and both track the unquantized attention within int8 rounding
    full = ref.paged_attention_ref(q, kp, vp, tables, lengths,
                                   window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=0.05, rtol=0.05)


# ---------------------------------------------------------------------------
# Engine: quantized decode tracks the fp32 greedy continuation
# ---------------------------------------------------------------------------

def _session(weight_dtype=None, kv_dtype=None, page_size=0, n_slots=4,
             prefill=8, cache=64):
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.mesh import split_model_axis
    from repro.serving.engine import build_serving

    spec = _attn_spec(n_layers=2)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    sess = build_serving(spec, _serve_plan(pp=1, r=n_slots), dmesh,
                         cache_len=cache, global_batch=n_slots,
                         prefill_len=prefill, compute_dtype=jnp.float32,
                         page_size=page_size, weight_dtype=weight_dtype,
                         kv_dtype=kv_dtype)
    sess.start(jax.random.key(0))
    return sess


def _greedy_run(sess, steps=8):
    tokens = jax.random.randint(jax.random.key(3), (4, 8), 1, 256,
                                jnp.int32)
    tk = jnp.asarray(np.asarray(tokens).reshape(
        sess.prefill_specs["tokens"].shape))
    toks = [np.asarray(sess.prefill({"tokens": tk}))]
    for _ in range(steps):
        toks.append(np.asarray(sess.decode(jnp.asarray(toks[-1]))))
    return np.stack(toks)


@pytest.mark.parametrize("weight_dtype,kv_dtype,page_size", [
    ("int8", None, 0),               # int8 weights, dense fp32 cache
    (None, "int8", 16),              # fp32 weights, paged int8 KV
    ("int8", "int8", 16),            # both
])
def test_quantized_engine_tracks_fp32_greedy(weight_dtype, kv_dtype,
                                             page_size):
    """Same init key -> same underlying weights; the quantized session
    must emit (mostly) the same greedy continuation as the fp32 one."""
    want = _greedy_run(_session())
    got = _greedy_run(_session(weight_dtype=weight_dtype,
                               kv_dtype=kv_dtype, page_size=page_size))
    match = float(np.mean(got == want))
    assert match >= 0.75, f"greedy match rate {match} < 0.75 for " \
        f"w={weight_dtype} kv={kv_dtype}"


def test_build_serving_int8_kv_requires_paged_cache():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.mesh import split_model_axis
    from repro.serving.engine import build_serving

    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    with pytest.raises(ValueError, match="paged"):
        build_serving(_attn_spec(n_layers=2), _serve_plan(pp=1, r=2),
                      dmesh, cache_len=64, global_batch=2,
                      kv_dtype="int8")
    with pytest.raises(ValueError, match="weight_dtype"):
        build_serving(_attn_spec(n_layers=2), _serve_plan(pp=1, r=2),
                      dmesh, cache_len=64, global_batch=2,
                      weight_dtype="int4")
    with pytest.raises(ValueError, match="kv_dtype"):
        build_serving(_attn_spec(n_layers=2), _serve_plan(pp=1, r=2),
                      dmesh, cache_len=64, global_batch=2,
                      kv_dtype="fp8")


# ---------------------------------------------------------------------------
# Planner pricing
# ---------------------------------------------------------------------------

def test_weight_byte_cost_ratios():
    spec = _attn_spec()
    assert quant.weight_byte_cost(None, spec, TPU_V5E) == \
        TPU_V5E.param_bytes
    fp32 = quant.weight_byte_cost("fp32", spec, TPU_V5E)
    int8 = quant.weight_byte_cost("int8", spec, TPU_V5E)
    assert fp32 / int8 >= 1.9          # the BENCH_quant gate's floor
    # scale overhead is priced: strictly more than the raw payload byte
    assert 1.0 < int8 < 1.5
    assert quant.kv_byte_cost("int8", spec, page_size=64) < \
        quant.kv_byte_cost("fp32", spec) / 3


def test_memory_model_prices_quantized_serving():
    spec, plan = _attn_spec(), _serve_plan()
    sched = plan.make_schedule()
    kw = dict(microbatch_tokens=32, data_replicas=1, cache_len=4096,
              global_batch=32)
    mm32 = sched.memory_model(spec, plan, TPU_V5E, weight_dtype="fp32",
                              kv_dtype="fp32", **kw)
    mm8 = sched.memory_model(spec, plan, TPU_V5E, weight_dtype="int8",
                             kv_dtype="int8", page_size=64, **kw)
    assert mm32.weight_bytes / mm8.weight_bytes >= 1.9
    assert mm8.cache_bytes < mm32.cache_bytes
    # default (None) keeps the pre-quantization pricing exactly
    mm_def = sched.memory_model(spec, plan, TPU_V5E, **kw)
    mm_none = sched.memory_model(spec, plan, TPU_V5E, weight_dtype=None,
                                 kv_dtype=None, **kw)
    assert mm_def.weight_bytes == mm_none.weight_bytes
    assert mm_def.cache_bytes == mm_none.cache_bytes


def test_plan_search_rejects_quantized_training():
    with pytest.raises(AssertionError, match="full-precision"):
        plan_search(_attn_spec(), _serve_plan(), 2, TPU_V5E,
                    minibatch_tokens=32, workload="train",
                    weight_dtype="int8")


def test_plan_search_int8_unlocks_infeasible_decode_plan():
    """The acceptance golden: a budget the fp32 weights+cache blow but
    int8 weights + paged int8 KV fit — quantization changes the
    feasible set, and the choice records what unlocked it."""
    spec = _attn_spec(n_layers=8)
    plan = _serve_plan(pp=2, r=32)
    sched = plan.make_schedule()
    kw = dict(microbatch_tokens=32, data_replicas=1, cache_len=4096,
              global_batch=32)
    mm32p = sched.memory_model(spec, plan, TPU_V5E, weight_dtype="fp32",
                               kv_dtype="fp32", page_size=64,
                               kv_occupancy=0.25, **kw)
    mm8 = sched.memory_model(spec, plan, TPU_V5E, weight_dtype="int8",
                             kv_dtype="int8", page_size=64,
                             kv_occupancy=0.25, **kw)
    budget = 0.5 * (mm32p.total_bytes + mm8.total_bytes)
    assert mm8.fits(budget) and not mm32p.fits(budget)
    hw = dataclasses.replace(TPU_V5E, hbm_bytes=budget)
    skw = dict(minibatch_tokens=32, workload="decode", cache_len=4096,
               global_batch=32, return_all=True)
    fp32_dense = plan_search(spec, plan, 2, hw, weight_dtype="fp32",
                             kv_dtype="fp32", **skw)
    fp32_paged = plan_search(spec, plan, 2, hw, weight_dtype="fp32",
                             kv_dtype="fp32", page_size=64,
                             occupancy=0.25, **skw)
    int8 = plan_search(spec, plan, 2, hw, weight_dtype="int8",
                       kv_dtype="int8", page_size=64, occupancy=0.25,
                       **skw)

    def feas(cands):
        return [c.feasible for c in cands if c.plan.pp == 2
                and c.plan.schedule == "serve_1f"]
    assert not any(feas(fp32_dense)), "fp32 dense pp=2 should blow it"
    assert not any(feas(fp32_paged)), "fp32 paged pp=2 should blow it"
    assert all(feas(int8)), "int8 pp=2 should fit"
    best = [c for c in int8 if c.feasible][0]
    assert best.weight_dtype == "int8" and best.kv_dtype == "int8"
    assert " w=int8" in best.describe() and " kv=int8" in best.describe()
